//! **Ablation abl2** — the paper's §6 future-work idea, measured: once
//! BEDPP goes dead (≈0.45·λmax), re-hybridize SSR with a *frozen* SEDPP
//! rule (O(np) once, O(p) per λ afterwards). Does SSR-BEDPP-SEDPP beat
//! SSR-BEDPP on the lower half of the path?

use hssr::bench_harness::{default_reps, measure};
use hssr::coordinator::report::Table;
use hssr::data::DataSpec;
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path, PathConfig};

fn main() {
    let reps = default_reps();
    let specs = [
        DataSpec::gene_like(536, 6_000),
        DataSpec::nyt_like(800, 6_000),
        DataSpec::synthetic(1000, 6_000, 20),
    ];
    let mut table = Table::new(
        "§6 re-hybridization — SSR-BEDPP vs SSR-BEDPP-SEDPP",
        &["dataset", "method", "time (s)", "cols scanned", "KKT checks", "safe@λmin"],
    );
    for spec in &specs {
        let datasets: Vec<_> = (0..reps).map(|r| spec.generate(50 + r as u64)).collect();
        for rule in [RuleKind::SsrBedpp, RuleKind::SsrBedppSedpp] {
            let cfg = PathConfig { rule, ..PathConfig::default() };
            let t = measure(
                reps,
                |rep| &datasets[rep],
                |ds| fit_lasso_path(ds, &cfg).expect("fit"),
            );
            // instrumentation from one representative fit
            let fit = fit_lasso_path(&datasets[0], &cfg).expect("fit");
            table.push_row(vec![
                spec.name(),
                rule.label().to_string(),
                format!("{:.3} ({:.3})", t.mean, t.se),
                fit.total_cols_scanned().to_string(),
                fit.total_kkt_checks().to_string(),
                fit.metrics.last().unwrap().safe_size.to_string(),
            ]);
        }
    }
    table.emit("ablation_rehybrid").expect("emit");
    println!(
        "paper §6 prediction: the frozen-SEDPP phase keeps the safe set < p \
         after BEDPP dies, trimming KKT checks on the lower half of the path."
    );
}
