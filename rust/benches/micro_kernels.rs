//! Micro-benchmarks of the L3 hot-path kernels (dot, axpy, blocked scan,
//! CD cycle) — the profiling substrate for the §Perf optimization pass.
//! Includes the pooled-vs-scoped scan comparison (persistent worker pool
//! against the old spawn-per-scan `thread::scope` kernels) and the fused
//! single-pass KKT kernel against its three-pass baseline.

use std::time::Instant;

use hssr::coordinator::report::Table;
use hssr::data::DataSpec;
use hssr::linalg::{blocked, ops, pool, simd};
use hssr::solver::{cd, Penalty};

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let ds = DataSpec::synthetic(1024, 4096, 20).generate(5);
    let n = ds.n();
    let p = ds.p();
    let v = ds.y.clone();
    let mut out = vec![0.0; p];
    let mut table = Table::new("micro kernels", &["kernel", "time", "throughput"]);
    println!("pool: {} threads", pool::global().threads());

    // dot
    let a = ds.x.col(0);
    let b = ds.x.col(1);
    let t = time_it(200_000, || {
        std::hint::black_box(ops::dot(std::hint::black_box(a), std::hint::black_box(b)));
    });
    table.push_row(vec![
        format!("dot n={n}"),
        format!("{:.1} ns", t * 1e9),
        format!("{:.2} GF/s", 2.0 * n as f64 / t / 1e9),
    ]);

    // axpy
    let mut y = vec![0.0; n];
    let t = time_it(200_000, || {
        ops::axpy(std::hint::black_box(0.5), std::hint::black_box(a), &mut y);
    });
    table.push_row(vec![
        format!("axpy n={n}"),
        format!("{:.1} ns", t * 1e9),
        format!("{:.2} GF/s", 2.0 * n as f64 / t / 1e9),
    ]);

    // full scan — persistent pool vs spawn-per-scan baseline
    let t_pool = time_it(30, || {
        blocked::scan_all(&ds.x, std::hint::black_box(&v), &mut out);
    });
    table.push_row(vec![
        format!("scan_all pooled {n}×{p}"),
        format!("{:.2} ms", t_pool * 1e3),
        format!("{:.2} GB/s", (n * p * 8) as f64 / t_pool / 1e9),
    ]);
    let t_scoped = time_it(30, || {
        blocked::scan_all_scoped(&ds.x, std::hint::black_box(&v), &mut out);
    });
    table.push_row(vec![
        format!("scan_all scoped {n}×{p}"),
        format!("{:.2} ms", t_scoped * 1e3),
        format!("{:.2} GB/s", (n * p * 8) as f64 / t_scoped / 1e9),
    ]);
    println!(
        "pooled scan is {:.2}× the scoped (spawn-per-scan) baseline",
        t_scoped / t_pool
    );

    // subset scan (10% of columns), pooled vs scoped
    let idx: Vec<usize> = (0..p).step_by(10).collect();
    let mut sub = vec![0.0; idx.len()];
    let t = time_it(200, || {
        blocked::scan_subset(&ds.x, std::hint::black_box(&v), &idx, &mut sub);
    });
    table.push_row(vec![
        format!("scan_subset pooled 10% of {p}"),
        format!("{:.2} ms", t * 1e3),
        format!("{:.2} GB/s", (n * idx.len() * 8) as f64 / t / 1e9),
    ]);
    let t = time_it(200, || {
        blocked::scan_subset_scoped(&ds.x, std::hint::black_box(&v), &idx, &mut sub);
    });
    table.push_row(vec![
        format!("scan_subset scoped 10% of {p}"),
        format!("{:.2} ms", t * 1e3),
        format!("{:.2} GB/s", (n * idx.len() * 8) as f64 / t / 1e9),
    ]);

    // fused KKT pass vs its three-pass baseline (candidate scan + filter +
    // strong refresh), at a representative mid-path state.
    let survive: Vec<bool> = (0..p).map(|j| j % 3 != 1).collect();
    let in_strong: Vec<bool> = (0..p).map(|j| j % 20 == 0).collect();
    let viol = |zj: f64| zj.abs() > 0.02;
    let mut z = vec![0.0; p];
    let mut z_valid = vec![false; p];
    let t_fused = time_it(30, || {
        z_valid.iter_mut().for_each(|b| *b = false);
        std::hint::black_box(blocked::fused_kkt(
            &ds.x, &v, &survive, &in_strong, &viol, true, &mut z, &mut z_valid,
        ));
    });
    let check: Vec<usize> = (0..p).filter(|&j| survive[j] && !in_strong[j]).collect();
    let strong: Vec<usize> = (0..p).filter(|&j| survive[j] && in_strong[j]).collect();
    let mut cbuf = vec![0.0; check.len()];
    let mut sbuf = vec![0.0; strong.len()];
    let t_3pass = time_it(30, || {
        blocked::scan_subset(&ds.x, &v, &check, &mut cbuf);
        let viols: Vec<usize> = check
            .iter()
            .zip(&cbuf)
            .filter(|&(_, &zj)| viol(zj))
            .map(|(&j, _)| j)
            .collect();
        std::hint::black_box(viols);
        blocked::scan_subset(&ds.x, &v, &strong, &mut sbuf);
    });
    table.push_row(vec![
        format!("fused_kkt {n}×{p}"),
        format!("{:.2} ms", t_fused * 1e3),
        format!("{:.2} GB/s", (n * (check.len() + strong.len()) * 8) as f64 / t_fused / 1e9),
    ]);
    table.push_row(vec![
        format!("3-pass kkt {n}×{p}"),
        format!("{:.2} ms", t_3pass * 1e3),
        format!("{:.2} GB/s", (n * (check.len() + strong.len()) * 8) as f64 / t_3pass / 1e9),
    ]);

    // one CD cycle over 200 active features
    let active: Vec<usize> = (0..200).collect();
    let mut beta = vec![0.0; p];
    let mut r = ds.y.clone();
    let t = time_it(500, || {
        std::hint::black_box(cd::cd_cycle(&ds.x, Penalty::Lasso, 0.05, &active, &mut beta, &mut r));
    });
    table.push_row(vec![
        "cd_cycle |H|=200".into(),
        format!("{:.2} µs", t * 1e6),
        format!("{:.2} GB/s", (n * active.len() * 8 * 2) as f64 / t / 1e9),
    ]);

    // ---- SIMD A/B: scalar vs dispatched kernels on L2-resident data ----
    // The big matrix above is DRAM-bound, which hides ALU gains; the SIMD
    // rows use an L2-resident design (512×200 ≈ 0.8 MB) so the kernels are
    // compute-bound and the lane speedup is visible.
    let l2 = DataSpec::synthetic(512, 200, 10).generate(6);
    let (ln, lp) = (l2.n(), l2.p());
    let lr = l2.y.clone();
    let mut lsurvive = vec![true; lp];
    let mut lz = vec![0.0; lp];
    let mut lz_valid = vec![false; lp];
    let mut simd_rows: Vec<(String, f64)> = Vec::new();
    for on in [false, true] {
        simd::force(on);
        let label = if on { simd::level().label() } else { "scalar" };
        let a = l2.x.col(0);
        let b = l2.x.col(1);
        let t = time_it(500_000, || {
            std::hint::black_box(ops::dot(std::hint::black_box(a), std::hint::black_box(b)));
        });
        table.push_row(vec![
            format!("dot n={ln} [{label}]"),
            format!("{:.1} ns", t * 1e9),
            format!("{:.2} GF/s", 2.0 * ln as f64 / t / 1e9),
        ]);
        let t = time_it(2_000, || {
            lsurvive.iter_mut().for_each(|s| *s = true);
            lz_valid.iter_mut().for_each(|v| *v = false);
            std::hint::black_box(blocked::fused_screen(
                &l2.x,
                std::hint::black_box(&lr),
                None,
                0.02,
                &mut lsurvive,
                &mut lz,
                &mut lz_valid,
            ));
        });
        simd_rows.push((label.to_string(), t));
        table.push_row(vec![
            format!("fused_screen {ln}×{lp} [{label}]"),
            format!("{:.2} µs", t * 1e6),
            format!("{:.2} GB/s", (ln * lp * 8) as f64 / t / 1e9),
        ]);
    }
    if let [(_, t_scalar), (lvl, t_simd)] = simd_rows.as_slice() {
        println!(
            "fused_screen SIMD ({lvl}) is {:.2}× the scalar kernel",
            t_scalar / t_simd
        );
    }

    // f32 shadow scan vs the f64 scan at the same L2-resident size: the
    // mixed-precision screening path's raw kernel advantage (half the
    // bytes, twice the lanes).
    let mirror: Vec<f32> = (0..lp)
        .flat_map(|j| l2.x.col(j).iter().map(|&v| v as f32).collect::<Vec<f32>>())
        .collect();
    let v32: Vec<f32> = lr.iter().map(|&v| v as f32).collect();
    let mut lout = vec![0.0; lp];
    let t64 = time_it(2_000, || {
        blocked::scan_all(&l2.x, std::hint::black_box(&lr), &mut lout);
    });
    table.push_row(vec![
        format!("scan_all f64 {ln}×{lp}"),
        format!("{:.2} µs", t64 * 1e6),
        format!("{:.2} GB/s", (ln * lp * 8) as f64 / t64 / 1e9),
    ]);
    let t32 = time_it(2_000, || {
        blocked::scan_all_f32_mirror(
            std::hint::black_box(&mirror),
            ln,
            lp,
            std::hint::black_box(&v32),
            &mut lout,
        );
    });
    table.push_row(vec![
        format!("scan_all f32 {ln}×{lp}"),
        format!("{:.2} µs", t32 * 1e6),
        format!("{:.2} GB/s", (ln * lp * 4) as f64 / t32 / 1e9),
    ]);
    println!("f32 scan is {:.2}× the f64 scan (SIMD {})", t64 / t32, simd::level().label());
    simd::reset();

    table.emit("micro_kernels").expect("emit");
}
