//! Micro-benchmarks of the L3 hot-path kernels (dot, axpy, blocked scan,
//! CD cycle) — the profiling substrate for the §Perf optimization pass.

use std::time::Instant;

use hssr::coordinator::report::Table;
use hssr::data::DataSpec;
use hssr::linalg::{blocked, ops};
use hssr::solver::{cd, Penalty};

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let ds = DataSpec::synthetic(1024, 4096, 20).generate(5);
    let n = ds.n();
    let p = ds.p();
    let v = ds.y.clone();
    let mut out = vec![0.0; p];
    let mut table = Table::new("micro kernels", &["kernel", "time", "throughput"]);

    // dot
    let a = ds.x.col(0);
    let b = ds.x.col(1);
    let t = time_it(200_000, || {
        std::hint::black_box(ops::dot(std::hint::black_box(a), std::hint::black_box(b)));
    });
    table.push_row(vec![
        format!("dot n={n}"),
        format!("{:.1} ns", t * 1e9),
        format!("{:.2} GF/s", 2.0 * n as f64 / t / 1e9),
    ]);

    // axpy
    let mut y = vec![0.0; n];
    let t = time_it(200_000, || {
        ops::axpy(std::hint::black_box(0.5), std::hint::black_box(a), &mut y);
    });
    table.push_row(vec![
        format!("axpy n={n}"),
        format!("{:.1} ns", t * 1e9),
        format!("{:.2} GF/s", 2.0 * n as f64 / t / 1e9),
    ]);

    // full scan
    let t = time_it(30, || {
        blocked::scan_all(&ds.x, std::hint::black_box(&v), &mut out);
    });
    table.push_row(vec![
        format!("scan_all {n}×{p}"),
        format!("{:.2} ms", t * 1e3),
        format!("{:.2} GB/s", (n * p * 8) as f64 / t / 1e9),
    ]);

    // subset scan (10% of columns)
    let idx: Vec<usize> = (0..p).step_by(10).collect();
    let mut sub = vec![0.0; idx.len()];
    let t = time_it(200, || {
        blocked::scan_subset(&ds.x, std::hint::black_box(&v), &idx, &mut sub);
    });
    table.push_row(vec![
        format!("scan_subset 10% of {p}"),
        format!("{:.2} ms", t * 1e3),
        format!("{:.2} GB/s", (n * idx.len() * 8) as f64 / t / 1e9),
    ]);

    // one CD cycle over 200 active features
    let active: Vec<usize> = (0..200).collect();
    let mut beta = vec![0.0; p];
    let mut r = ds.y.clone();
    let t = time_it(500, || {
        std::hint::black_box(cd::cd_cycle(&ds.x, Penalty::Lasso, 0.05, &active, &mut beta, &mut r));
    });
    table.push_row(vec![
        "cd_cycle |H|=200".into(),
        format!("{:.2} µs", t * 1e6),
        format!("{:.2} GB/s", (n * active.len() * 8 * 2) as f64 / t / 1e9),
    ]);

    table.emit("micro_kernels").expect("emit");
}
