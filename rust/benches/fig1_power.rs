//! **Figure 1** — percent of features discarded by each rule along the
//! λ path, on the GENE-like workload.
//!
//! Paper shape to reproduce: HSSR ≥ SSR ≈ SEDPP ≫ BEDPP > Dome; BEDPP dies
//! near λ/λmax ≈ 0.45, Dome near 0.6, and the sequential rules keep
//! discarding ≈ all features to the end of the path.
//!
//! Default dims are scaled (536×4,000); `HSSR_BENCH_FULL=1` restores the
//! paper's 536×17,322.

use hssr::bench_harness::full_scale;
use hssr::coordinator::metrics::screening_power;
use hssr::coordinator::report::Table;
use hssr::data::DataSpec;
use hssr::solver::path::PathConfig;

fn main() {
    let p = if full_scale() { 17_322 } else { 4_000 };
    let ds = DataSpec::gene_like(536, p).generate(1);
    println!("fig1: screening power on {}", ds.name);
    let cfg = PathConfig { n_lambda: 100, ..PathConfig::default() };
    let curves = screening_power(&ds, &cfg).expect("power analysis");

    let mut table = Table::new(
        "Figure 1 — % of features discarded",
        &["λ/λmax", "Dome", "BEDPP", "SEDPP", "SSR", "SSR-BEDPP", "SSR-GapSafe"],
    );
    let k = curves[0].lambda_frac.len();
    for i in (0..k).step_by(5) {
        let mut row = vec![format!("{:.3}", curves[0].lambda_frac[i])];
        for c in &curves {
            row.push(format!("{:.1}", 100.0 * c.discarded_frac[i]));
        }
        table.push_row(row);
    }
    table.emit("fig1_power").expect("emit");

    // Shutoff points (paper: Dome ≈ 0.6·λmax, BEDPP ≈ 0.45·λmax on GENE).
    for c in &curves {
        if let Some(i) = c.discarded_frac.iter().position(|&d| d == 0.0) {
            if i > 0 && (c.rule == "Dome" || c.rule == "BEDPP") {
                println!("{}: shuts off at λ/λmax ≈ {:.2}", c.rule, c.lambda_frac[i]);
            }
        }
    }
}
