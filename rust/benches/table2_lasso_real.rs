//! **Table 2 + Figure 3** — lasso path timings on the four real-data-like
//! workloads (GENE, MNIST, GWAS, NYT regimes; see DESIGN.md §2 for the
//! substitutions), all six methods, mean (SE) over replications, plus the
//! speedup-vs-Basic-PCD panel of Figure 3.
//!
//! Paper shape to reproduce: SSR-BEDPP fastest everywhere (13.8×–52.7× vs
//! Basic PCD), SSR-Dome second, SSR ≈ SEDPP, AC behind both, and the
//! MNIST-like regime showing the largest hybrid gains.
//!
//! Defaults are scaled ×3–10 down; `HSSR_BENCH_FULL=1` restores paper dims
//! (GWAS stays ×1 in n but scaled ×10 in p even in full mode — 660k × 313
//! f64 is 1.6 GB; set HSSR_GWAS_P to override).

use hssr::bench_harness::{default_reps, full_scale};
use hssr::coordinator::{run_method_sweep, speedup_table, timing_table};
use hssr::data::DataSpec;
use hssr::screening::RuleKind;
use hssr::solver::path::PathConfig;

fn main() {
    let full = full_scale();
    let gwas_p: usize = std::env::var("HSSR_GWAS_P")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(66_050);
    let specs = if full {
        vec![
            DataSpec::gene_like(536, 17_322),
            DataSpec::mnist_like(784, 60_000),
            DataSpec::gwas_like(313, gwas_p),
            DataSpec::nyt_like(5_000, 55_000),
        ]
    } else {
        vec![
            DataSpec::gene_like(536, 4_000),
            DataSpec::mnist_like(400, 3_000),
            DataSpec::gwas_like(313, 16_000),
            DataSpec::nyt_like(800, 5_000),
        ]
    };
    let reps = default_reps();
    println!(
        "table2: real-data-like lasso ({} mode, {reps} reps)",
        if full { "paper-scale" } else { "scaled" }
    );
    let methods = RuleKind::paper_lasso_methods();
    let cells =
        run_method_sweep(&specs, &methods, reps, &PathConfig::default(), 31).expect("sweep");
    timing_table("Table 2 — average seconds (SE) for the lasso path", &cells)
        .emit("table2_lasso_real")
        .expect("emit");
    speedup_table("Figure 3 — speedup relative to Basic PCD", &cells, RuleKind::BasicPcd)
        .emit("fig3_speedup")
        .expect("emit");
}
