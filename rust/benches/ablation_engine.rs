//! **Ablation abl3** — scan-engine comparison: the native blocked Rust
//! kernels vs the AOT JAX/Pallas artifact through PJRT, on (a) raw scan
//! throughput and (b) an end-to-end path fit.
//!
//! The PJRT path exists to prove the three-layer composition; on CPU the
//! per-call overhead (tile fill + literal creation + dispatch) dominates,
//! which this bench quantifies. Requires `make artifacts` for the PJRT
//! rows; prints native-only otherwise.

use std::time::Instant;

use hssr::coordinator::report::Table;
use hssr::data::DataSpec;
use hssr::runtime::{make_engine, native::NativeEngine, EngineKind, ScanEngine};
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path_with_engine, PathConfig};

fn scan_throughput(engine: &dyn ScanEngine, ds: &hssr::data::Dataset, iters: usize) -> f64 {
    let mut out = vec![0.0; ds.p()];
    let t = Instant::now();
    for _ in 0..iters {
        engine.scan_all(&ds.x, &ds.y, &mut out).expect("scan");
    }
    let secs = t.elapsed().as_secs_f64();
    // effective GB/s of matrix traffic
    (iters * ds.n() * ds.p() * 8) as f64 / secs / 1e9
}

fn main() {
    let ds = DataSpec::synthetic(1024, 4096, 20).generate(4);
    println!("ablation_engine: scans on {}", ds.name);
    let native = NativeEngine::new();
    let mut table = Table::new(
        "engine ablation — native vs PJRT (AOT Pallas)",
        &["engine", "scan GB/s", "path fit (s, SSR-BEDPP, 30λ)"],
    );

    let cfg = PathConfig { rule: RuleKind::SsrBedpp, n_lambda: 30, ..PathConfig::default() };
    let gbps = scan_throughput(&native, &ds, 20);
    let fit = fit_lasso_path_with_engine(&ds, &cfg, &native).expect("fit");
    table.push_row(vec![
        "native".into(),
        format!("{gbps:.2}"),
        format!("{:.3}", fit.seconds),
    ]);

    match make_engine(EngineKind::Pjrt, "artifacts") {
        Ok(engine) => {
            let gbps = scan_throughput(engine.as_ref(), &ds, 2);
            let fit = fit_lasso_path_with_engine(&ds, &cfg, engine.as_ref()).expect("fit");
            table.push_row(vec![
                engine.name().into(),
                format!("{gbps:.2}"),
                format!("{:.3}", fit.seconds),
            ]);
        }
        Err(e) => println!("pjrt unavailable: {e}"),
    }
    table.emit("ablation_engine").expect("emit");
}
