//! **Ablation abl4** — λ-grid density: the paper (and Tibshirani et al.
//! 2012) note that SSR violations "are quite rare" on a standard 100-point
//! grid. This ablation measures how violation counts, re-solve rounds, and
//! total time react as the grid coarsens — the regime where the strong-rule
//! bound `|z| < 2λ_{k+1} − λ_k` becomes aggressive — and how the safe half
//! of SSR-BEDPP shields against it.

use hssr::coordinator::report::Table;
use hssr::data::DataSpec;
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path, PathConfig};

fn main() {
    let ds = DataSpec::mnist_like(400, 3_000).generate(13);
    println!("ablation_grid: violations vs grid density on {}", ds.name);
    let mut table = Table::new(
        "λ-grid density ablation",
        &["K", "method", "time (s)", "violations", "KKT checks", "max |H| growth"],
    );
    for k in [100usize, 50, 25, 10, 5] {
        for rule in [RuleKind::Ssr, RuleKind::SsrBedpp] {
            let cfg = PathConfig { rule, n_lambda: k, ..PathConfig::default() };
            let fit = fit_lasso_path(&ds, &cfg).expect("fit");
            let max_growth = fit
                .metrics
                .iter()
                .map(|m| m.violations)
                .max()
                .unwrap_or(0);
            table.push_row(vec![
                k.to_string(),
                rule.label().to_string(),
                format!("{:.3}", fit.seconds),
                fit.total_violations().to_string(),
                fit.total_kkt_checks().to_string(),
                max_growth.to_string(),
            ]);
        }
    }
    table.emit("ablation_grid").expect("emit");
    println!(
        "paper context (§2.1): violations are rare on the standard K=100 grid;\n\
         coarse grids stress the unit-slope assumption (5)."
    );
}
