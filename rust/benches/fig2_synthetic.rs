//! **Figure 2** — average computing time for the lasso path on synthetic
//! data, (left) as a function of p with n = 1,000 and (right) as a function
//! of n with p fixed.
//!
//! Paper shape to reproduce: SSR-BEDPP uniformly fastest (≈5× over Basic
//! PCD, ≈2× over SSR/SEDPP); SSR and SEDPP indistinguishable; SSR-Dome
//! between; AC slightly behind SSR.
//!
//! Defaults are scaled for wall-clock sanity; `HSSR_BENCH_FULL=1` restores
//! the paper's sweep (p → 10,000, n → 10,000, 20 replications).

use hssr::bench_harness::{default_reps, full_scale};
use hssr::coordinator::{report::Table, run_method_sweep};
use hssr::data::DataSpec;
use hssr::screening::RuleKind;
use hssr::solver::path::PathConfig;

fn sweep(title: &str, stem: &str, specs: &[DataSpec], size_label: fn(&DataSpec) -> String) {
    let methods = RuleKind::paper_lasso_methods();
    let reps = default_reps();
    let cfg = PathConfig::default();
    let cells = run_method_sweep(specs, &methods, reps, &cfg, 11).expect("sweep");
    let mut headers = vec!["size".to_string()];
    headers.extend(methods.iter().map(|m| m.label().to_string()));
    let mut table = Table { title: title.to_string(), headers, rows: Vec::new() };
    for spec in specs {
        let name = spec.name();
        let mut row = vec![size_label(spec)];
        for m in methods {
            let cell = cells
                .iter()
                .find(|c| c.rule == m && c.dataset == name)
                .map(|c| format!("{:.3}", c.timing.mean))
                .unwrap_or_default();
            row.push(cell);
        }
        table.rows.push(row);
    }
    table.emit(stem).expect("emit");
}

fn main() {
    let full = full_scale();
    println!(
        "fig2: synthetic sweeps ({} mode, {} reps)",
        if full { "paper-scale" } else { "scaled" },
        default_reps()
    );

    // Case 1: varying p, n = 1,000 (paper: p ∈ 1,000…10,000).
    let ps: &[usize] = if full { &[1000, 2500, 5000, 7500, 10_000] } else { &[1000, 2500, 5000] };
    let specs_p: Vec<DataSpec> =
        ps.iter().map(|&p| DataSpec::synthetic(1000, p, 20)).collect();
    sweep(
        "Figure 2 (left) — time vs p (n = 1000), seconds",
        "fig2_vs_p",
        &specs_p,
        |s| match s {
            DataSpec::Synthetic { p, .. } => format!("p={p}"),
            _ => unreachable!(),
        },
    );

    // Case 2: varying n, p fixed (paper: p = 10,000, n ∈ 200…10,000).
    let p_fixed = if full { 10_000 } else { 5_000 };
    let ns: &[usize] = if full { &[200, 1000, 2500, 5000, 10_000] } else { &[200, 500, 1000] };
    let specs_n: Vec<DataSpec> =
        ns.iter().map(|&n| DataSpec::synthetic(n, p_fixed, 20)).collect();
    sweep(
        "Figure 2 (right) — time vs n, seconds",
        "fig2_vs_n",
        &specs_n,
        |s| match s {
            DataSpec::Synthetic { n, .. } => format!("n={n}"),
            _ => unreachable!(),
        },
    );
}
