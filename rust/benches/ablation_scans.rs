//! **Ablation abl1** — measured validation of Table 1's complexity column
//! and the §3.2.3 memory-efficiency claim: count the columns each method
//! actually reads over the path (screening + KKT traffic; CD coordinate
//! updates reported separately).
//!
//! Expected: SSR and AC scan Θ(pK) columns; HSSR scans `Σ_k |S_k|` ≪ pK;
//! SEDPP's scans happen inside the rule (full pK — reported via its
//! analytic count); Basic PCD scans nothing but pays Θ(pK) CD updates.

use hssr::coordinator::metrics::{scan_traffic, scan_traffic_table};
use hssr::coordinator::report::Table;
use hssr::data::DataSpec;
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path, PathConfig};

fn main() {
    let ds = DataSpec::gene_like(536, 6_000).generate(3);
    let k = 100usize;
    println!("ablation_scans: {} over {k} λ values", ds.name);
    let pk = (ds.p() * k) as u64;

    let mut table = Table::new(
        "Table 1 (measured) — column-scan and update counts over the path",
        &["Method", "screen+KKT cols", "analytic", "CD coord updates", "cols / pK"],
    );
    for rule in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::Sedpp,
        RuleKind::SsrDome,
        RuleKind::SsrBedpp,
        RuleKind::SsrBedppSedpp,
    ] {
        let cfg = PathConfig { rule, n_lambda: k, ..PathConfig::default() };
        let fit = fit_lasso_path(&ds, &cfg).expect("fit");
        // SEDPP hides its full scan inside the rule: account analytically.
        let analytic = match rule {
            RuleKind::Sedpp => pk,
            RuleKind::SsrBedppSedpp => {
                // one full scan at freeze time + per-λ safe-set scans
                fit.total_cols_scanned() + ds.p() as u64
            }
            _ => fit.total_cols_scanned(),
        };
        let updates: u64 = fit.metrics.iter().map(|m| m.coord_updates).sum();
        table.push_row(vec![
            rule.label().to_string(),
            fit.total_cols_scanned().to_string(),
            analytic.to_string(),
            updates.to_string(),
            format!("{:.2}", analytic as f64 / pk as f64),
        ]);
    }
    table.emit("ablation_scans").expect("emit");
    println!(
        "paper claim §3.2.3: HSSR column traffic = Σ|S_k| ≪ pK; \
         SSR/SEDPP = pK (the 1.00 rows above)."
    );

    // Out-of-core cross-check: the same paths driven through the counting
    // chunked-store engine, so the fetch counters (and chunk faults) are
    // *measured* rather than derived from path metrics.
    let cfg = PathConfig { n_lambda: k, ..PathConfig::default() };
    let rows = scan_traffic(
        &ds,
        &cfg,
        256,
        &[RuleKind::Ssr, RuleKind::SsrDome, RuleKind::SsrBedpp],
    )
    .expect("traffic");
    scan_traffic_table("measured chunked-store traffic (256-col chunks)", &rows)
        .emit("ablation_scans_traffic")
        .expect("emit traffic");
}
