//! **Ablation abl1** — measured validation of Table 1's complexity column
//! and the §3.2.3 memory-efficiency claim: count the columns each method
//! actually reads over the path (screening + KKT traffic; CD coordinate
//! updates reported separately), then replay the headline rules against
//! the **real disk-backed store** under cache pressure so the byte gap is
//! actual read traffic.
//!
//! Expected: SSR and AC scan Θ(pK) columns; HSSR scans `Σ_k |S_k|` ≪ pK;
//! SEDPP's in-rule scans — the last analytic holdout — are engine-routed
//! now, like gap-safe's, so every column in the table is *measured*;
//! Basic PCD scans nothing but pays Θ(pK) CD updates.

use hssr::coordinator::metrics::{
    group_scan_traffic, ooc_fit_traffic, ooc_scan_traffic, ooc_traffic_table,
    scan_traffic, scan_traffic_table,
};
use hssr::coordinator::report::Table;
use hssr::data::synth::generate_grouped;
use hssr::data::DataSpec;
use hssr::linalg::simd;
use hssr::runtime::Precision;
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path, GroupPathConfig};
use hssr::solver::path::{fit_lasso_path, PathConfig};

fn main() {
    let ds = DataSpec::gene_like(536, 6_000).generate(3);
    let k = 100usize;
    println!("ablation_scans: {} over {k} λ values", ds.name);
    let pk = (ds.p() * k) as u64;

    let mut table = Table::new(
        "Table 1 (measured) — column-scan and update counts over the path",
        &["Method", "screen+KKT cols", "CD coord updates", "cols / pK"],
    );
    for rule in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::Sedpp,
        RuleKind::SsrDome,
        RuleKind::SsrBedpp,
        RuleKind::SsrBedppSedpp,
        RuleKind::SsrGapSafe,
    ] {
        let cfg = PathConfig { rule, n_lambda: k, ..PathConfig::default() };
        let fit = fit_lasso_path(&ds, &cfg).expect("fit");
        // Every rule's in-rule scans — SEDPP's per-λ dual scans, the
        // re-hybridized rule's freeze-time scan, gap-safe's dual
        // refreshes — are engine-routed, so the measured column *is* the
        // analytic count (no derived entries remain).
        let updates: u64 = fit.metrics.iter().map(|m| m.coord_updates).sum();
        table.push_row(vec![
            rule.label().to_string(),
            fit.total_cols_scanned().to_string(),
            updates.to_string(),
            format!("{:.2}", fit.total_cols_scanned() as f64 / pk as f64),
        ]);
    }
    table.emit("ablation_scans").expect("emit");
    println!(
        "paper claim §3.2.3: HSSR column traffic = Σ|S_k| ≪ pK; \
         SSR/SEDPP = pK (the 1.00 rows above)."
    );

    // ---- per-λ safe-set rejections: static BEDPP/SEDPP vs dynamic
    // gap-safe (screen-time |S| plus its mid-λ re-fires) ----
    let rej_rules = [RuleKind::SsrBedpp, RuleKind::Sedpp, RuleKind::SsrGapSafe];
    let rej_fits: Vec<_> = rej_rules
        .iter()
        .map(|&rule| {
            let cfg = PathConfig { rule, n_lambda: k, ..PathConfig::default() };
            fit_lasso_path(&ds, &cfg).expect("rejection fit")
        })
        .collect();
    let mut rtable = Table::new(
        "per-λ safe-set rejections (p − |S|; gap-safe adds dynamic re-fires)",
        &[
            "λ/λmax",
            "BEDPP rejected",
            "SEDPP rejected",
            "GapSafe rejected",
            "GapSafe re-fired",
        ],
    );
    let lmax = rej_fits[0].lambda_max;
    for i in (0..k).step_by((k / 20).max(1)) {
        rtable.push_row(vec![
            format!("{:.2}", rej_fits[0].metrics[i].lambda / lmax),
            (ds.p() - rej_fits[0].metrics[i].safe_size).to_string(),
            (ds.p() - rej_fits[1].metrics[i].safe_size).to_string(),
            (ds.p() - rej_fits[2].metrics[i].safe_size).to_string(),
            rej_fits[2].metrics[i].rescreen_discards.to_string(),
        ]);
    }
    rtable.emit("ablation_scans_rejections").expect("emit rejections");

    // Out-of-core cross-check: the same paths driven through the counting
    // chunked-store engine, so the fetch counters (and chunk faults) are
    // *measured* rather than derived from path metrics.
    let cfg = PathConfig { n_lambda: k, ..PathConfig::default() };
    let rows = scan_traffic(
        &ds,
        &cfg,
        256,
        &[RuleKind::Ssr, RuleKind::SsrDome, RuleKind::SsrBedpp],
    )
    .expect("traffic");
    scan_traffic_table("measured chunked-store traffic (256-col chunks)", &rows)
        .emit("ablation_scans_traffic")
        .expect("emit traffic");

    // ---- the real thing: disk-backed store under cache pressure ----
    // The matrix is spilled to an HSSRSTOR1 store and every scan is served
    // through the OocEngine's LRU chunk cache with a budget ≪ the matrix
    // footprint, so the §3.2.3 bytes-scanned gap shows up as *actual* disk
    // reads. SSR-GapSafe rides along: its in-rule scans are engine-routed,
    // so its traffic is fully measured too.
    let chunk_cols = 256usize;
    let matrix_bytes = ds.n() * ds.p() * 8;
    let budget = (matrix_bytes / 8).max(chunk_cols * ds.n() * 8); // 1/8 of the matrix
    let ooc_rows = ooc_scan_traffic(
        &ds,
        &cfg,
        chunk_cols,
        budget,
        &[RuleKind::Ssr, RuleKind::SsrDome, RuleKind::SsrBedpp, RuleKind::SsrGapSafe],
    )
    .expect("ooc traffic");
    ooc_traffic_table(
        &format!(
            "measured DISK traffic, cache budget {:.0} MB vs {:.0} MB matrix \
             (256-col chunks)",
            budget as f64 / 1e6,
            matrix_bytes as f64 / 1e6
        ),
        &ooc_rows,
    )
    .emit("ablation_scans_ooc")
    .expect("emit ooc traffic");

    // Cache-pressure rows: the same paths under a budget of ~2 chunks —
    // every non-resident touch is a real read; HSSR's shrinking safe set
    // is the only thing that keeps traffic sublinear. Run prefetch-off
    // then prefetch-on so the λ-ahead prefetcher's hit rate, waste, and
    // demand-stall savings are measured head-to-head on one store.
    let harsh = 2 * chunk_cols * ds.n() * 8;
    let harsh_rules = [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe];
    let harsh_rows = ooc_fit_traffic(&ds, &cfg, chunk_cols, harsh, &harsh_rules, false)
        .expect("harsh ooc traffic");
    ooc_traffic_table(
        &format!(
            "cache-pressure: budget {:.1} MB (2 chunks) vs {:.0} MB matrix, \
             prefetch OFF",
            harsh as f64 / 1e6,
            matrix_bytes as f64 / 1e6
        ),
        &harsh_rows,
    )
    .emit("ablation_scans_ooc_pressure")
    .expect("emit ooc pressure");
    let pf_rows = ooc_fit_traffic(&ds, &cfg, chunk_cols, harsh, &harsh_rules, true)
        .expect("harsh ooc traffic, prefetch");
    ooc_traffic_table(
        &format!(
            "cache-pressure: budget {:.1} MB (2 chunks) vs {:.0} MB matrix, \
             prefetch ON (λ-ahead)",
            harsh as f64 / 1e6,
            matrix_bytes as f64 / 1e6
        ),
        &pf_rows,
    )
    .emit("ablation_scans_ooc_pressure_prefetch")
    .expect("emit ooc pressure prefetch");
    for (off, on) in harsh_rows.iter().zip(&pf_rows) {
        let issued = on.prefetch_issued.max(1);
        println!(
            "prefetch ablation [{}]: stalls {} → {}, hit rate {:.0}% \
             ({} hits / {} issued, {} wasted)",
            off.rule.label(),
            off.stalls,
            on.stalls,
            100.0 * on.prefetch_hits as f64 / issued as f64,
            on.prefetch_hits,
            on.prefetch_issued,
            on.prefetch_wasted,
        );
    }

    // mmap vs seek/read chunk service, same budget and rules. The gate is
    // compile-time (feature `mmap`) *and* runtime (HSSR_MMAP), so one
    // binary benches both services back to back.
    #[cfg(feature = "mmap")]
    {
        std::env::set_var("HSSR_MMAP", "1");
        let mmap_rows =
            ooc_fit_traffic(&ds, &cfg, chunk_cols, harsh, &harsh_rules, false)
                .expect("harsh ooc traffic, mmap");
        std::env::remove_var("HSSR_MMAP");
        ooc_traffic_table(
            &format!(
                "cache-pressure: budget {:.1} MB (2 chunks), mmap chunk service",
                harsh as f64 / 1e6
            ),
            &mmap_rows,
        )
        .emit("ablation_scans_ooc_pressure_mmap")
        .expect("emit ooc pressure mmap");
    }
    #[cfg(not(feature = "mmap"))]
    println!("mmap chunk service not compiled in (enable with --features mmap)");

    // ---- group screen: single-traversal bytes per rule ----
    // The fused pipeline's `fused_group_screen` + `fused_group_kkt` read
    // each needed column exactly once per λ; the unfused driver's separate
    // screen / refresh / KKT / end-of-step passes read strictly more. The
    // table reports both (native engine metrics), and the chunked-store
    // columns cross-check that the fused counts equal measured fetches.
    let gds = generate_grouped(400, 800, 5, 10, 9);
    let gk = 100usize;
    let gpk = (gds.p() * gk) as u64;
    let mut gtable = Table::new(
        "group screen traffic — fused single traversal vs unfused (bytes per rule)",
        &["Method", "fused cols", "fused MB", "unfused cols", "unfused MB", "fused cols / pK"],
    );
    let rules = [RuleKind::Ssr, RuleKind::Sedpp, RuleKind::SsrBedpp, RuleKind::SsrGapSafe];
    for rule in rules {
        let fused_cfg =
            GroupPathConfig { rule, n_lambda: gk, fused: true, ..GroupPathConfig::default() };
        let unfused_cfg = GroupPathConfig { fused: false, ..fused_cfg.clone() };
        let f = fit_group_path(&gds, &fused_cfg).expect("fused group fit");
        let u = fit_group_path(&gds, &unfused_cfg).expect("unfused group fit");
        let mb = |cols: u64| cols as f64 * gds.n() as f64 * 8.0 / 1e6;
        gtable.push_row(vec![
            rule.label().to_string(),
            f.total_cols_scanned().to_string(),
            format!("{:.1}", mb(f.total_cols_scanned())),
            u.total_cols_scanned().to_string(),
            format!("{:.1}", mb(u.total_cols_scanned())),
            format!("{:.2}", f.total_cols_scanned() as f64 / gpk as f64),
        ]);
    }
    gtable.emit("ablation_scans_group").expect("emit group");

    // Measured out-of-core cross-check for the group path (scan-then-filter
    // engine → every read is a counted fetch; selections identical).
    let gcfg = GroupPathConfig { n_lambda: gk, ..GroupPathConfig::default() };
    let grows = group_scan_traffic(&gds, &gcfg, 64, &rules).expect("group traffic");
    scan_traffic_table("measured chunked-store group traffic (64-col chunks)", &grows)
        .emit("ablation_scans_group_traffic")
        .expect("emit group traffic");

    // ---- kernel-shape ablation: SIMD × precision × fused epoch ----
    // Same SSR-GapSafe path under the four SIMD/precision combinations
    // (f32 only reshapes the screening scans — the coefficient paths must
    // not move a bit) plus the fused-epoch two-pass baseline, so the
    // hardware knobs' wall-clock and traffic effects are on the record.
    let mut ktable = Table::new(
        "kernel ablation — SSR-GapSafe path under SIMD / precision / fused-epoch knobs",
        &["config", "seconds", "screen+KKT cols", "betas vs baseline"],
    );
    let kcfg = PathConfig {
        rule: RuleKind::SsrGapSafe,
        n_lambda: k,
        precision: Precision::F64,
        fused_epoch: true,
        ..PathConfig::default()
    };
    simd::force(false);
    let baseline = fit_lasso_path(&ds, &kcfg).expect("kernel-ablation baseline");
    let variants: [(&str, bool, Precision, bool); 5] = [
        ("simd=0 f64", false, Precision::F64, true),
        ("simd=1 f64", true, Precision::F64, true),
        ("simd=0 f32", false, Precision::F32, true),
        ("simd=1 f32", true, Precision::F32, true),
        ("simd=1 f64 two-pass", true, Precision::F64, false),
    ];
    for (label, simd_on, precision, fused_epoch) in variants {
        simd::force(simd_on);
        let fit = fit_lasso_path(&ds, &PathConfig { precision, fused_epoch, ..kcfg.clone() })
            .expect("kernel-ablation fit");
        ktable.push_row(vec![
            label.to_string(),
            format!("{:.3}", fit.seconds),
            fit.total_cols_scanned().to_string(),
            if fit.betas == baseline.betas { "identical".into() } else { "DIFFER".into() },
        ]);
        assert_eq!(
            fit.betas, baseline.betas,
            "{label}: kernel knobs changed the solution"
        );
    }
    simd::reset();
    ktable.emit("ablation_scans_kernels").expect("emit kernel ablation");
}
