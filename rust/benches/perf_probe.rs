//! §Perf probe (not a paper artifact): decompose PJRT scan cost by layer.
use std::time::Instant;
use hssr::data::DataSpec;
use hssr::runtime::{pjrt::PjrtEngine, ScanEngine};

fn main() {
    let ds = DataSpec::synthetic(1024, 4096, 20).generate(4);
    let mut out = vec![0.0; ds.p()];
    let mut dirs: Vec<String> =
        std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    if dirs.is_empty() {
        dirs.push("artifacts".to_string());
    }
    for dir in dirs {
        match PjrtEngine::load(&dir) {
            Ok(e) => {
                // warmup
                e.scan_all(&ds.x, &ds.y, &mut out).unwrap();
                let t = Instant::now();
                let iters = 5;
                for _ in 0..iters {
                    e.scan_all(&ds.x, &ds.y, &mut out).unwrap();
                }
                let s = t.elapsed().as_secs_f64() / iters as f64;
                println!(
                    "{dir}: engine {} tile {:?} — {:.1} ms/scan, {:.2} GB/s",
                    e.name(),
                    e.tile_shape(),
                    s * 1e3,
                    (ds.n() * ds.p() * 8) as f64 / s / 1e9
                );
            }
            Err(e) => println!("{dir}: {e}"),
        }
    }
}
