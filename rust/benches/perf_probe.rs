//! §Perf probe (not a paper artifact): quantify the persistent-pool and
//! fused-pass wins on a p ≫ n synthetic problem, and emit the results as
//! machine-readable `BENCH_perf.json` at the repository root so the perf
//! trajectory is tracked across PRs.
//!
//! Measured ops:
//!
//! * `scan_all_pooled` / `scan_all_scoped` — the persistent worker pool
//!   against the old spawn-per-scan `thread::scope` kernel;
//! * `fused_kkt` / `kkt_three_pass` — the single-traversal KKT kernel
//!   against its scan → filter → strong-refresh baseline;
//! * `path_fused` / `path_three_pass` — the whole SSR-BEDPP path with the
//!   fused driver vs the unfused scan-then-filter driver (ns per λ step).

use std::time::Instant;

use hssr::data::DataSpec;
use hssr::linalg::{blocked, pool};
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path, PathConfig};

struct Entry {
    op: &'static str,
    n: usize,
    p: usize,
    ns_iter: f64,
}

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let threads = pool::global().threads();
    // p ≫ n: the regime the paper (and the screening scans) target.
    let n = 256;
    let p = 24_000;
    let ds = DataSpec::synthetic(n, p, 20).generate(4);
    let v = ds.y.clone();
    let mut entries: Vec<Entry> = Vec::new();
    println!("perf_probe: n={n}, p={p}, pool threads={threads}");

    // -- pooled vs scoped full scan --
    let mut out = vec![0.0; p];
    blocked::scan_all(&ds.x, &v, &mut out); // warm the pool
    let t_pool = time_it(20, || blocked::scan_all(&ds.x, &v, &mut out));
    let t_scoped = time_it(20, || blocked::scan_all_scoped(&ds.x, &v, &mut out));
    println!(
        "scan_all: pooled {:.3} ms vs scoped {:.3} ms ({:.2}×)",
        t_pool * 1e3,
        t_scoped * 1e3,
        t_scoped / t_pool
    );
    entries.push(Entry { op: "scan_all_pooled", n, p, ns_iter: t_pool * 1e9 });
    entries.push(Entry { op: "scan_all_scoped", n, p, ns_iter: t_scoped * 1e9 });

    // -- fused KKT kernel vs three-pass baseline --
    let survive: Vec<bool> = (0..p).map(|j| j % 3 != 1).collect();
    let in_strong: Vec<bool> = (0..p).map(|j| j % 25 == 0).collect();
    let viol = |zj: f64| zj.abs() > 0.02;
    let mut z = vec![0.0; p];
    let mut z_valid = vec![false; p];
    let t_fused = time_it(20, || {
        z_valid.iter_mut().for_each(|b| *b = false);
        std::hint::black_box(blocked::fused_kkt(
            &ds.x, &v, &survive, &in_strong, &viol, true, &mut z, &mut z_valid,
        ));
    });
    let check: Vec<usize> = (0..p).filter(|&j| survive[j] && !in_strong[j]).collect();
    let strong: Vec<usize> = (0..p).filter(|&j| survive[j] && in_strong[j]).collect();
    let mut cbuf = vec![0.0; check.len()];
    let mut sbuf = vec![0.0; strong.len()];
    let t_3pass = time_it(20, || {
        blocked::scan_subset(&ds.x, &v, &check, &mut cbuf);
        let viols: Vec<usize> = check
            .iter()
            .zip(&cbuf)
            .filter(|&(_, &zj)| viol(zj))
            .map(|(&j, _)| j)
            .collect();
        std::hint::black_box(viols);
        blocked::scan_subset(&ds.x, &v, &strong, &mut sbuf);
    });
    println!(
        "kkt pass: fused {:.3} ms vs three-pass {:.3} ms ({:.2}×)",
        t_fused * 1e3,
        t_3pass * 1e3,
        t_3pass / t_fused
    );
    entries.push(Entry { op: "fused_kkt", n, p, ns_iter: t_fused * 1e9 });
    entries.push(Entry { op: "kkt_three_pass", n, p, ns_iter: t_3pass * 1e9 });

    // -- whole path: fused driver vs unfused scan-then-filter driver --
    let n_lambda = 50;
    let mk = |fused: bool| PathConfig {
        rule: RuleKind::SsrBedpp,
        n_lambda,
        fused,
        ..PathConfig::default()
    };
    let fit = fit_lasso_path(&ds, &mk(true)).expect("warmup fit");
    std::hint::black_box(fit.total_cols_scanned());
    let t_path_fused = time_it(3, || {
        std::hint::black_box(fit_lasso_path(&ds, &mk(true)).unwrap().seconds);
    });
    let t_path_3pass = time_it(3, || {
        std::hint::black_box(fit_lasso_path(&ds, &mk(false)).unwrap().seconds);
    });
    println!(
        "SSR-BEDPP path ({n_lambda} λ): fused {:.3} s vs three-pass {:.3} s ({:.2}×)",
        t_path_fused,
        t_path_3pass,
        t_path_3pass / t_path_fused
    );
    entries.push(Entry {
        op: "path_fused",
        n,
        p,
        ns_iter: t_path_fused * 1e9 / n_lambda as f64,
    });
    entries.push(Entry {
        op: "path_three_pass",
        n,
        p,
        ns_iter: t_path_3pass * 1e9 / n_lambda as f64,
    });

    // -- emit BENCH_perf.json at the repo root --
    let mut json = String::from("[\n");
    for (i, e) in entries.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"op\": \"{}\", \"n\": {}, \"p\": {}, \"ns_iter\": {:.1}, \"threads\": {}}}{}\n",
            e.op,
            e.n,
            e.p,
            e.ns_iter,
            threads,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("]\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|root| root.join("BENCH_perf.json"))
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_perf.json"));
    std::fs::write(&path, json).expect("write BENCH_perf.json");
    println!("wrote {}", path.display());
}
