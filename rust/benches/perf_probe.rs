//! §Perf probe (not a paper artifact): quantify the persistent-pool and
//! fused-pass wins on a p ≫ n synthetic problem, and emit the results as
//! machine-readable `BENCH_perf.json` at the repository root so the perf
//! trajectory is tracked across PRs.
//!
//! Measured ops:
//!
//! * `scan_all_pooled` / `scan_all_scoped` — the persistent worker pool
//!   against the old spawn-per-scan `thread::scope` kernel;
//! * `fused_kkt` / `kkt_three_pass` — the single-traversal KKT kernel
//!   against its scan → filter → strong-refresh baseline;
//! * `path_fused` / `path_three_pass` — the whole SSR-BEDPP path with the
//!   fused driver vs the unfused scan-then-filter driver (ns per λ step).

use std::time::Instant;

use hssr::data::DataSpec;
use hssr::linalg::{blocked, pool, simd};
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path, PathConfig};

struct Entry {
    op: &'static str,
    n: usize,
    p: usize,
    ns_iter: f64,
}

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    t.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let threads = pool::global().threads();
    // p ≫ n: the regime the paper (and the screening scans) target.
    let n = 256;
    let p = 24_000;
    let ds = DataSpec::synthetic(n, p, 20).generate(4);
    let v = ds.y.clone();
    let mut entries: Vec<Entry> = Vec::new();
    println!("perf_probe: n={n}, p={p}, pool threads={threads}");

    // -- pooled vs scoped full scan --
    let mut out = vec![0.0; p];
    blocked::scan_all(&ds.x, &v, &mut out); // warm the pool
    let t_pool = time_it(20, || blocked::scan_all(&ds.x, &v, &mut out));
    let t_scoped = time_it(20, || blocked::scan_all_scoped(&ds.x, &v, &mut out));
    println!(
        "scan_all: pooled {:.3} ms vs scoped {:.3} ms ({:.2}×)",
        t_pool * 1e3,
        t_scoped * 1e3,
        t_scoped / t_pool
    );
    entries.push(Entry { op: "scan_all_pooled", n, p, ns_iter: t_pool * 1e9 });
    entries.push(Entry { op: "scan_all_scoped", n, p, ns_iter: t_scoped * 1e9 });

    // -- fused KKT kernel vs three-pass baseline --
    let survive: Vec<bool> = (0..p).map(|j| j % 3 != 1).collect();
    let in_strong: Vec<bool> = (0..p).map(|j| j % 25 == 0).collect();
    let viol = |zj: f64| zj.abs() > 0.02;
    let mut z = vec![0.0; p];
    let mut z_valid = vec![false; p];
    let t_fused = time_it(20, || {
        z_valid.iter_mut().for_each(|b| *b = false);
        std::hint::black_box(blocked::fused_kkt(
            &ds.x, &v, &survive, &in_strong, &viol, true, &mut z, &mut z_valid,
        ));
    });
    let check: Vec<usize> = (0..p).filter(|&j| survive[j] && !in_strong[j]).collect();
    let strong: Vec<usize> = (0..p).filter(|&j| survive[j] && in_strong[j]).collect();
    let mut cbuf = vec![0.0; check.len()];
    let mut sbuf = vec![0.0; strong.len()];
    let t_3pass = time_it(20, || {
        blocked::scan_subset(&ds.x, &v, &check, &mut cbuf);
        let viols: Vec<usize> = check
            .iter()
            .zip(&cbuf)
            .filter(|&(_, &zj)| viol(zj))
            .map(|(&j, _)| j)
            .collect();
        std::hint::black_box(viols);
        blocked::scan_subset(&ds.x, &v, &strong, &mut sbuf);
    });
    println!(
        "kkt pass: fused {:.3} ms vs three-pass {:.3} ms ({:.2}×)",
        t_fused * 1e3,
        t_3pass * 1e3,
        t_3pass / t_fused
    );
    entries.push(Entry { op: "fused_kkt", n, p, ns_iter: t_fused * 1e9 });
    entries.push(Entry { op: "kkt_three_pass", n, p, ns_iter: t_3pass * 1e9 });

    // -- whole path: fused driver vs unfused scan-then-filter driver --
    let n_lambda = 50;
    let mk = |fused: bool| PathConfig {
        rule: RuleKind::SsrBedpp,
        n_lambda,
        fused,
        ..PathConfig::default()
    };
    let fit = fit_lasso_path(&ds, &mk(true)).expect("warmup fit");
    std::hint::black_box(fit.total_cols_scanned());
    let t_path_fused = time_it(3, || {
        std::hint::black_box(fit_lasso_path(&ds, &mk(true)).unwrap().seconds);
    });
    let t_path_3pass = time_it(3, || {
        std::hint::black_box(fit_lasso_path(&ds, &mk(false)).unwrap().seconds);
    });
    println!(
        "SSR-BEDPP path ({n_lambda} λ): fused {:.3} s vs three-pass {:.3} s ({:.2}×)",
        t_path_fused,
        t_path_3pass,
        t_path_3pass / t_path_fused
    );
    entries.push(Entry {
        op: "path_fused",
        n,
        p,
        ns_iter: t_path_fused * 1e9 / n_lambda as f64,
    });
    entries.push(Entry {
        op: "path_three_pass",
        n,
        p,
        ns_iter: t_path_3pass * 1e9 / n_lambda as f64,
    });

    // -- SIMD A/B on the fused screen kernel, L2-resident sizing --
    // The p ≫ n matrix above is DRAM-bound; the SIMD rows use a 512×200
    // design (≈0.8 MB, L2-resident) so the kernels are compute-bound and
    // the lane win is what's measured.
    let l2 = DataSpec::synthetic(512, 200, 10).generate(6);
    let (ln, lp) = (l2.n(), l2.p());
    let lr = l2.y.clone();
    let mut lsurvive = vec![true; lp];
    let mut lz = vec![0.0; lp];
    let mut lz_valid = vec![false; lp];
    let mut screen_times = [0.0f64; 2];
    for (slot, on) in [false, true].into_iter().enumerate() {
        simd::force(on);
        let t = time_it(2_000, || {
            lsurvive.iter_mut().for_each(|s| *s = true);
            lz_valid.iter_mut().for_each(|v| *v = false);
            std::hint::black_box(blocked::fused_screen(
                &l2.x,
                &lr,
                None,
                0.02,
                &mut lsurvive,
                &mut lz,
                &mut lz_valid,
            ));
        });
        screen_times[slot] = t;
        entries.push(Entry {
            op: if on { "fused_screen_simd" } else { "fused_screen_scalar" },
            n: ln,
            p: lp,
            ns_iter: t * 1e9,
        });
    }
    println!(
        "fused_screen {ln}×{lp}: scalar {:.2} µs vs SIMD ({}) {:.2} µs ({:.2}×)",
        screen_times[0] * 1e6,
        simd::level().label(),
        screen_times[1] * 1e6,
        screen_times[0] / screen_times[1]
    );

    // -- f32 shadow scan vs f64 scan, same L2-resident size --
    let mirror: Vec<f32> = (0..lp)
        .flat_map(|j| l2.x.col(j).iter().map(|&v| v as f32).collect::<Vec<f32>>())
        .collect();
    let v32: Vec<f32> = lr.iter().map(|&v| v as f32).collect();
    let mut lout = vec![0.0; lp];
    let t64 = time_it(2_000, || {
        blocked::scan_all(&l2.x, std::hint::black_box(&lr), &mut lout);
    });
    let t32 = time_it(2_000, || {
        blocked::scan_all_f32_mirror(&mirror, ln, lp, std::hint::black_box(&v32), &mut lout);
    });
    println!(
        "scan {ln}×{lp}: f64 {:.2} µs vs f32 {:.2} µs ({:.2}×)",
        t64 * 1e6,
        t32 * 1e6,
        t64 / t32
    );
    entries.push(Entry { op: "scan_f64", n: ln, p: lp, ns_iter: t64 * 1e9 });
    entries.push(Entry { op: "scan_f32", n: ln, p: lp, ns_iter: t32 * 1e9 });
    simd::reset();

    // -- disabled-tracing overhead guard --
    // Every driver phase boundary, pool dispatch, and store chunk miss
    // begins a `Span`; with tracing off that must stay one relaxed atomic
    // load. Assert a generous absolute per-call bound so a regression
    // (e.g. an accidental allocation or env read on the disabled path)
    // fails the bench leg rather than silently taxing every fit.
    hssr::obs::trace::set_enabled(false);
    let t_span_off = time_it(4, || {
        for _ in 0..1_000_000 {
            let mut sp =
                std::hint::black_box(hssr::obs::trace::Span::begin("probe", "bench"));
            sp.arg_u64("k", 1);
            std::hint::black_box(&sp);
        }
    }) / 1e6;
    println!("trace disabled span: {:.1} ns/call", t_span_off * 1e9);
    assert!(
        t_span_off * 1e9 < 150.0,
        "disabled-tracing Span::begin costs {:.1} ns/call (budget 150 ns)",
        t_span_off * 1e9
    );
    entries.push(Entry { op: "trace_disabled_span", n: 0, p: 0, ns_iter: t_span_off * 1e9 });

    // -- emit BENCH_perf.json at the repo root --
    let mut json = String::from("[\n");
    for (i, e) in entries.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"op\": \"{}\", \"n\": {}, \"p\": {}, \"ns_iter\": {:.1}, \"threads\": {}}}{}\n",
            e.op,
            e.n,
            e.p,
            e.ns_iter,
            threads,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    json.push_str("]\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|root| root.join("BENCH_perf.json"))
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_perf.json"));
    std::fs::write(&path, json).expect("write BENCH_perf.json");
    println!("wrote {}", path.display());
}
