//! **Figure 4** — group lasso path time as a function of the number of
//! groups (n = 1,000, W_g = 10, 10 true groups), plus the same sweep for
//! the group elastic net (α = 0.8) now that the unified driver supports it.
//!
//! Paper shape to reproduce: SSR-BEDPP > 7× over Basic GD and ≈ 2× over
//! SSR/SEDPP; SSR ≈ SEDPP; AC slightly behind. The enet rows should track
//! the lasso rows closely (the α scaling changes bounds, not complexity).
//!
//! Defaults scaled; `HSSR_BENCH_FULL=1` → G up to 10,000.

use hssr::bench_harness::{default_reps, full_scale, measure, Timing};
use hssr::coordinator::report::Table;
use hssr::data::synth::generate_grouped;
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path, GroupPathConfig};
use hssr::solver::Penalty;

const METHODS: [RuleKind; 6] = [
    RuleKind::BasicPcd,
    RuleKind::ActiveCycling,
    RuleKind::Ssr,
    RuleKind::Sedpp,
    RuleKind::SsrBedpp,
    RuleKind::SsrGapSafe,
];

fn label(rule: RuleKind) -> &'static str {
    if rule == RuleKind::BasicPcd {
        "Basic GD"
    } else {
        rule.label()
    }
}

fn main() {
    let full = full_scale();
    let n = if full { 1000 } else { 500 };
    let gs: &[usize] = if full { &[100, 500, 1000, 5000, 10_000] } else { &[100, 250, 500] };
    let w = 10;
    let reps = default_reps();
    println!(
        "fig4: group lasso vs G ({} mode, {reps} reps, n={n}, W={w})",
        if full { "paper-scale" } else { "scaled" }
    );

    let mut headers = vec!["G".to_string(), "α".to_string()];
    headers.extend(METHODS.iter().map(|&m| label(m).to_string()));
    let mut table = Table {
        title: "Figure 4 — group lasso / elastic-net seconds vs number of groups".into(),
        headers,
        rows: Vec::new(),
    };
    for &g in gs {
        // Pre-generate replication datasets (untimed).
        let datasets: Vec<_> = (0..reps)
            .map(|rep| generate_grouped(n, g, w, 10, 100 + rep as u64))
            .collect();
        for (alpha_label, penalty) in
            [("1.0", Penalty::Lasso), ("0.8", Penalty::ElasticNet { alpha: 0.8 })]
        {
            let mut row = vec![g.to_string(), alpha_label.to_string()];
            for &rule in &METHODS {
                let cfg =
                    GroupPathConfig { rule, penalty, ..GroupPathConfig::default() };
                let t: Timing = measure(
                    reps,
                    |rep| &datasets[rep],
                    |ds| fit_group_path(ds, &cfg).expect("fit"),
                );
                row.push(format!("{:.3}", t.mean));
            }
            println!("G={g} α={alpha_label}: {row:?}");
            table.rows.push(row);
        }
    }
    table.emit("fig4_group_synth").expect("emit");
}
