//! Serve-mode throughput probe (not a paper artifact): fits/sec when many
//! concurrent clients share one [`FitService`] — one worker pool, one
//! store, one chunk cache — as the admission bound doubles from 1 to
//! `HSSR_BENCH_CLIENTS`. Emits machine-readable `BENCH_serve.json` at the
//! repository root (same row shape as `BENCH_perf.json`: `ns_iter` is
//! nanoseconds per *fit*), so the serving-throughput trajectory is
//! tracked across PRs alongside the kernel probe.
//!
//! Scale knobs (CI keeps the defaults small; the paper regime is
//! p = 10⁴–10⁵ with up to 64 clients):
//!
//! * `HSSR_BENCH_N` / `HSSR_BENCH_P` — problem shape (default 200×10000);
//! * `HSSR_BENCH_CLIENTS` — top of the 1,2,4,… concurrency sweep (8);
//! * `HSSR_BENCH_FITS` — requests per sweep point (2× top concurrency).

use std::time::Instant;

use hssr::coordinator::serve::FitService;
use hssr::data::DataSpec;
use hssr::linalg::pool;
use hssr::runtime::ooc::OocEngine;
use hssr::screening::RuleKind;
use hssr::solver::path::PathConfig;

fn env_or(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let threads = pool::global().threads();
    let n = env_or("HSSR_BENCH_N", 200);
    let p = env_or("HSSR_BENCH_P", 10_000);
    let max_clients = env_or("HSSR_BENCH_CLIENTS", 8).max(1);
    let fits = env_or("HSSR_BENCH_FITS", 2 * max_clients).max(1);
    let ds = DataSpec::synthetic(n, p, 20).generate(4);
    let budget = hssr::data::store::cache_budget_bytes();
    let engine = OocEngine::spill(&ds.x, &ds.y, budget).expect("spill design");
    println!(
        "serve_throughput: n={n}, p={p}, {fits} fits per point, pool threads={threads}, \
         cache budget {} MB",
        budget >> 20
    );

    let rules = [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe];
    let cfgs: Vec<PathConfig> = (0..fits)
        .map(|i| PathConfig {
            rule: rules[i % rules.len()],
            n_lambda: 30,
            tol: 1e-6,
            ..PathConfig::default()
        })
        .collect();

    // Warm the pool and the page cache once, untimed.
    let warm = FitService::new(engine.shared_store(), 1);
    warm.run_one(&cfgs[0]).expect("warmup fit");

    let mut rows: Vec<(String, f64)> = Vec::new();
    let mut clients = 1usize;
    while clients <= max_clients {
        engine.store().reset();
        let svc = FitService::new(engine.shared_store(), clients);
        let t0 = Instant::now();
        let out = svc.run_batch(&cfgs).expect("serve batch");
        let secs = t0.elapsed().as_secs_f64();
        let c = svc.store().counters();
        println!(
            "concurrency {clients:>3}: {:.3}s for {} fits ({:.2} fits/s), \
             {} cache hits ({} cross-fit), peak resident {:.2} MB",
            secs,
            out.len(),
            out.len() as f64 / secs.max(1e-9),
            c.cache_hits(),
            c.cross_fit_hits(),
            c.peak_resident() as f64 / 1e6,
        );
        rows.push((format!("serve_fit_c{clients}"), secs * 1e9 / out.len() as f64));
        clients *= 2;
    }

    let mut json = String::from("[\n");
    for (i, (op, ns)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"op\": \"{op}\", \"n\": {n}, \"p\": {p}, \"ns_iter\": {ns:.1}, \
             \"threads\": {threads}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("]\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|root| root.join("BENCH_serve.json"))
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_serve.json"));
    std::fs::write(&path, json).expect("write BENCH_serve.json");
    println!("wrote {}", path.display());
}
