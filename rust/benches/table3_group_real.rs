//! **Table 3** — group lasso timings on the two real-data-like workloads:
//! GRVS (rare-variant genes) and GENE-SPLINE (B-spline expansion of the
//! expression panel). Five methods; time + speedup vs Basic GD.
//!
//! Paper shape to reproduce: SSR-BEDPP fastest (6.3× / 33.4× vs Basic GD,
//! ≈1.4× vs SSR/SEDPP); SSR ≈ SEDPP; AC behind.
//!
//! Defaults scaled; `HSSR_BENCH_FULL=1` → GRVS 697×(G=3,205), GENE-SPLINE
//! 536×86,610 (G=17,322).

use hssr::bench_harness::{default_reps, full_scale, measure, Timing};
use hssr::coordinator::report::Table;
use hssr::data::{bspline, realistic, DataSpec, GroupedDataset};
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path, GroupPathConfig};

const METHODS: [RuleKind; 5] = [
    RuleKind::BasicPcd,
    RuleKind::ActiveCycling,
    RuleKind::Ssr,
    RuleKind::Sedpp,
    RuleKind::SsrBedpp,
];

fn label(rule: RuleKind) -> &'static str {
    if rule == RuleKind::BasicPcd {
        "Basic GD"
    } else {
        rule.label()
    }
}

fn bench_dataset(name: &str, datasets: &[GroupedDataset], reps: usize) -> Vec<(String, Timing)> {
    let mut out = Vec::new();
    for &rule in &METHODS {
        let cfg = GroupPathConfig { rule, ..GroupPathConfig::default() };
        let t = measure(
            reps,
            |rep| &datasets[rep],
            |ds| fit_group_path(ds, &cfg).expect("fit"),
        );
        println!("{name} / {}: {}", label(rule), t.paper_format());
        out.push((label(rule).to_string(), t));
    }
    out
}

fn main() {
    let full = full_scale();
    let reps = default_reps();
    println!(
        "table3: group lasso real-like ({} mode, {reps} reps)",
        if full { "paper-scale" } else { "scaled" }
    );

    // GRVS-like.
    let (n_grvs, g_grvs) = if full { (697, 3_205) } else { (400, 800) };
    let grvs: Vec<GroupedDataset> = (0..reps)
        .map(|rep| realistic::grvs_like(n_grvs, g_grvs, if full { 30 } else { 12 }, 10, 7 + rep as u64))
        .collect();
    let grvs_rows = bench_dataset("GRVS-like", &grvs, reps);

    // GENE-SPLINE-like.
    let (n_gs, p_gs) = if full { (536, 17_322) } else { (300, 1_500) };
    let spline: Vec<GroupedDataset> = (0..reps)
        .map(|rep| {
            let base = DataSpec::gene_like(n_gs, p_gs).generate(900 + rep as u64);
            bspline::expand_dataset(&base, 5)
        })
        .collect();
    let spline_rows = bench_dataset("GENE-SPLINE-like", &spline, reps);

    let mut table = Table::new(
        "Table 3 — group lasso: time (SE) and speedup vs Basic GD",
        &["Method", "GRVS time", "GRVS speedup", "SPLINE time", "SPLINE speedup"],
    );
    let base_grvs = grvs_rows[0].1;
    let base_spline = spline_rows[0].1;
    for i in 0..METHODS.len() {
        table.push_row(vec![
            grvs_rows[i].0.clone(),
            grvs_rows[i].1.paper_format(),
            format!("{:.1}", grvs_rows[i].1.speedup_vs(&base_grvs)),
            spline_rows[i].1.paper_format(),
            format!("{:.1}", spline_rows[i].1.speedup_vs(&base_spline)),
        ]);
    }
    table.emit("table3_group_real").expect("emit");
}
