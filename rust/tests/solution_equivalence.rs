//! Theorem 3.1 at integration scale: every screening strategy must produce
//! the same solution path as unscreened pathwise coordinate descent, across
//! penalties, workload families, and λ grids — plus randomized property
//! sweeps via the in-crate prop harness.

use hssr::data::DataSpec;
use hssr::prop::{check, PropConfig};
use hssr::prop_assert;
use hssr::screening::RuleKind;
use hssr::solver::lambda::GridKind;
use hssr::solver::path::{fit_lasso_path, PathConfig, PathFit};
use hssr::solver::Penalty;

const ALL_RULES: [RuleKind; 7] = [
    RuleKind::ActiveCycling,
    RuleKind::Ssr,
    RuleKind::Sedpp,
    RuleKind::SsrBedpp,
    RuleKind::SsrDome,
    RuleKind::SsrBedppSedpp,
    RuleKind::SsrGapSafe,
];

fn max_beta_diff(a: &PathFit, b: &PathFit) -> f64 {
    let mut worst = 0.0f64;
    for k in 0..a.lambdas.len() {
        let da = a.beta_dense(k);
        let db = b.beta_dense(k);
        for j in 0..da.len() {
            worst = worst.max((da[j] - db[j]).abs());
        }
    }
    worst
}

fn assert_all_agree(ds: &hssr::data::Dataset, base_cfg: PathConfig, tol: f64) {
    let baseline = fit_lasso_path(
        ds,
        &PathConfig { rule: RuleKind::BasicPcd, ..base_cfg.clone() },
    )
    .expect("baseline fit");
    for rule in ALL_RULES {
        let fit =
            fit_lasso_path(ds, &PathConfig { rule, ..base_cfg.clone() }).expect("fit");
        let d = max_beta_diff(&baseline, &fit);
        assert!(d < tol, "{rule:?} deviates by {d} on {}", ds.name);
    }
}

#[test]
fn gene_like_workload() {
    let ds = DataSpec::gene_like(150, 400).generate(1);
    assert_all_agree(&ds, PathConfig { n_lambda: 50, tol: 1e-9, ..PathConfig::default() }, 1e-5);
}

#[test]
fn mnist_like_workload() {
    let ds = DataSpec::mnist_like(120, 300).generate(2);
    assert_all_agree(&ds, PathConfig { n_lambda: 40, tol: 1e-9, ..PathConfig::default() }, 1e-5);
}

#[test]
fn gwas_like_workload() {
    let ds = DataSpec::gwas_like(150, 500).generate(3);
    assert_all_agree(&ds, PathConfig { n_lambda: 40, tol: 1e-9, ..PathConfig::default() }, 1e-5);
}

#[test]
fn nyt_like_workload() {
    let ds = DataSpec::nyt_like(150, 300).generate(4);
    assert_all_agree(&ds, PathConfig { n_lambda: 40, tol: 1e-9, ..PathConfig::default() }, 1e-5);
}

#[test]
fn log_grid_also_agrees() {
    let ds = DataSpec::synthetic(100, 200, 8).generate(5);
    assert_all_agree(
        &ds,
        PathConfig {
            n_lambda: 40,
            grid: GridKind::Log,
            lambda_min_ratio: 0.05,
            tol: 1e-9,
            ..PathConfig::default()
        },
        1e-5,
    );
}

#[test]
fn elastic_net_alphas_agree() {
    let ds = DataSpec::synthetic(90, 180, 8).generate(6);
    for alpha in [0.9, 0.5, 0.25] {
        assert_all_agree(
            &ds,
            PathConfig {
                penalty: Penalty::ElasticNet { alpha },
                n_lambda: 30,
                tol: 1e-9,
                ..PathConfig::default()
            },
            1e-5,
        );
    }
}

/// Randomized sweep: random shapes, sparsity, and seeds.
#[test]
fn property_random_problems_agree() {
    check(PropConfig { cases: 12, seed: 77 }, |rng, scale| {
        let n = 40 + (rng.below(80) as f64 * scale) as usize;
        let p = 50 + (rng.below(200) as f64 * scale) as usize;
        let s = 1 + rng.below(10) as usize;
        let ds = DataSpec::synthetic(n, p, s).generate(rng.next_u64());
        let cfg = PathConfig { n_lambda: 20, tol: 1e-9, ..PathConfig::default() };
        let base = fit_lasso_path(
            &ds,
            &PathConfig { rule: RuleKind::BasicPcd, ..cfg.clone() },
        )
        .map_err(|e| e.to_string())?;
        for rule in [RuleKind::SsrBedpp, RuleKind::SsrDome, RuleKind::Sedpp] {
            let fit = fit_lasso_path(&ds, &PathConfig { rule, ..cfg.clone() })
                .map_err(|e| e.to_string())?;
            let d = max_beta_diff(&base, &fit);
            prop_assert!(d < 1e-5, "{rule:?} deviates by {d} (n={n}, p={p}, s={s})");
        }
        Ok(())
    });
}

/// Regression under the pool engine: the explicit pool-backed
/// `NativeEngine` (fused and unfused drivers) must reproduce the default
/// path bit-for-bit — the default `fit_lasso_path` is itself pool-backed,
/// so this pins the engine plumbing and both driver variants together.
#[test]
fn pool_engine_reproduces_solution_paths() {
    use hssr::runtime::native::NativeEngine;
    use hssr::solver::path::fit_lasso_path_with_engine;
    let ds = DataSpec::gene_like(100, 260).generate(9);
    let engine = NativeEngine::new();
    for rule in ALL_RULES {
        let cfg = PathConfig { rule, n_lambda: 25, tol: 1e-9, ..PathConfig::default() };
        let default_fit = fit_lasso_path(&ds, &cfg).expect("default fit");
        let pooled = fit_lasso_path_with_engine(&ds, &cfg, &engine).expect("pool fit");
        assert_eq!(default_fit.betas, pooled.betas, "{rule:?} pool-engine mismatch");
        let unfused = fit_lasso_path_with_engine(
            &ds,
            &PathConfig { fused: false, ..cfg },
            &engine,
        )
        .expect("unfused pool fit");
        assert_eq!(
            default_fit.betas, unfused.betas,
            "{rule:?} unfused pool-engine mismatch"
        );
    }
}

/// Regression under the out-of-core engine: serving every screening/KKT
/// scan from the disk-backed column store — through a cache budget of a
/// single chunk, forcing eviction throughout — must reproduce the default
/// path bit-for-bit for every rule, fused and unfused.
#[test]
fn ooc_engine_reproduces_solution_paths() {
    use hssr::data::store::write_dataset;
    use hssr::runtime::ooc::OocEngine;
    use hssr::solver::path::fit_lasso_path_with_engine;
    let ds = DataSpec::gene_like(90, 220).generate(10);
    let store_path = std::env::temp_dir().join("hssr-solution-equiv.store");
    let chunk = 32;
    write_dataset(&ds, chunk, &store_path).expect("store write");
    let budget = chunk * ds.n() * 8; // one chunk ≪ the 220-column matrix
    for rule in ALL_RULES {
        let cfg = PathConfig { rule, n_lambda: 25, tol: 1e-9, ..PathConfig::default() };
        let default_fit = fit_lasso_path(&ds, &cfg).expect("default fit");
        let ooc = OocEngine::open(&store_path, budget).expect("store open");
        let ooc_fit = fit_lasso_path_with_engine(&ds, &cfg, &ooc).expect("ooc fit");
        assert_eq!(default_fit.betas, ooc_fit.betas, "{rule:?} ooc-engine mismatch");
        let unfused = fit_lasso_path_with_engine(
            &ds,
            &PathConfig { fused: false, ..cfg },
            &ooc,
        )
        .expect("unfused ooc fit");
        assert_eq!(
            default_fit.betas, unfused.betas,
            "{rule:?} unfused ooc-engine mismatch"
        );
    }
}

/// Warm starts + screening must not leak state across λ: refitting with a
/// truncated grid reproduces the prefix of the full-path solution.
#[test]
fn grid_prefix_consistency() {
    let ds = DataSpec::synthetic(80, 150, 6).generate(8);
    let full = fit_lasso_path(
        &ds,
        &PathConfig { n_lambda: 30, tol: 1e-10, ..PathConfig::default() },
    )
    .unwrap();
    let prefix_lams: Vec<f64> = full.lambdas[..10].to_vec();
    let prefix = fit_lasso_path(
        &ds,
        &PathConfig { lambdas: Some(prefix_lams), tol: 1e-10, ..PathConfig::default() },
    )
    .unwrap();
    for k in 0..10 {
        let a = full.beta_dense(k);
        let b = prefix.beta_dense(k);
        for j in 0..a.len() {
            assert!((a[j] - b[j]).abs() < 1e-6, "prefix mismatch at λ#{k}");
        }
    }
}
