//! The fault-tolerance acceptance bar, end to end.
//!
//! * **Injection masking** — with the deterministic [`FaultInjector`]
//!   armed (transient read errors, short reads, bit flips), OOC fits for
//!   all three families are **bit-identical** to native-engine fits, and
//!   the store's retry counters prove faults actually fired and were
//!   absorbed rather than never happening.
//! * **Corruption detection** — a single flipped byte in a store chunk
//!   turns a fit into a typed [`HssrError::Corrupt`], never silent wrong
//!   numbers; same for a flipped byte in a resume checkpoint.
//!
//! The injector never faults attempt ≥ [`FaultInjector::MAX_FAULT_ATTEMPTS`],
//! and the reader retries more times than that, so every injected fault is
//! deterministically recoverable — which is what makes bit-identity a
//! provable property rather than a lucky run.

use hssr::data::store::{write_dataset, ColumnStore, FaultInjector, FaultSpec, HEADER_LEN};
use hssr::data::synth::generate_grouped;
use hssr::data::DataSpec;
use hssr::error::HssrError;
use hssr::runtime::native::NativeEngine;
use hssr::runtime::ooc::OocEngine;
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path_with_engine, GroupPathConfig};
use hssr::solver::logistic::{
    fit_logistic_path_with_engine, synthetic_logistic, LogisticPathConfig,
};
use hssr::solver::path::{fit_lasso_path, fit_lasso_path_with_engine, PathConfig};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("hssr_fault_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Mount `path` with an aggressive deterministic fault mix attached via
/// the test hook (the `HSSR_FAULTS` env path is exercised by the CI
/// fault-injection leg, which runs the whole suite under it).
fn faulted_engine(path: &std::path::Path, budget: usize, seed: u64) -> OocEngine {
    let mut store = ColumnStore::open(path, budget).unwrap();
    let spec =
        FaultSpec::parse(&format!("seed={seed},transient=0.2,short=0.15,flip=0.1")).unwrap();
    store.set_faults(Some(FaultInjector::new(spec)));
    OocEngine::from_store(store)
}

/// Lasso, every rule: injected faults are absorbed bit-identically — the
/// faulted OOC path equals the native path in coefficients and in every
/// per-λ screening statistic — and the retry counters show the faults
/// really fired.
#[test]
fn lasso_fits_bit_identical_under_injected_faults() {
    let ds = DataSpec::gene_like(70, 180).generate(31);
    let path = tmp("flt-lasso.store");
    let chunk = 16;
    write_dataset(&ds, chunk, &path).unwrap();
    let budget = chunk * ds.n() * 8; // one chunk resident: every scan re-reads
    let native = NativeEngine::new();
    let mut total_retries = 0;
    for (i, rule) in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::Sedpp,
        RuleKind::SsrBedpp,
        RuleKind::SsrDome,
        RuleKind::SsrBedppSedpp,
        RuleKind::SsrGapSafe,
    ]
    .into_iter()
    .enumerate()
    {
        let cfg = PathConfig { rule, n_lambda: 15, tol: 1e-8, ..PathConfig::default() };
        let ooc = faulted_engine(&path, budget, 41 + i as u64);
        let a = fit_lasso_path_with_engine(&ds, &cfg, &ooc).unwrap();
        let b = fit_lasso_path_with_engine(&ds, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: faulted betas differ from native");
        for (k, (ma, mb)) in a.metrics.iter().zip(b.metrics.iter()).enumerate() {
            assert_eq!(ma.safe_size, mb.safe_size, "{rule:?} |S| at λ#{k}");
            assert_eq!(ma.strong_size, mb.strong_size, "{rule:?} |H| at λ#{k}");
            assert_eq!(ma.violations, mb.violations, "{rule:?} viols at λ#{k}");
        }
        let c = ooc.store().counters();
        total_retries += c.retries();
    }
    assert!(
        total_retries > 0,
        "fault rates this high must trigger retries — injection is not wired"
    );
}

/// Group lasso under the same fault mix: bit-identical group selections
/// and coefficients for every supported rule.
#[test]
fn group_fits_bit_identical_under_injected_faults() {
    let gds = generate_grouped(60, 24, 4, 4, 33);
    let path = tmp("flt-group.store");
    let chunk = 8;
    let zeros = vec![0.0; gds.p()];
    let ones = vec![1.0; gds.p()];
    hssr::data::store::write_matrix(&gds.x, &gds.y, &zeros, &ones, true, chunk, &path)
        .unwrap();
    let budget = chunk * gds.n() * 8;
    let native = NativeEngine::new();
    let mut total_retries = 0;
    for (i, rule) in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::Sedpp,
        RuleKind::SsrBedpp,
        RuleKind::SsrGapSafe,
    ]
    .into_iter()
    .enumerate()
    {
        let cfg =
            GroupPathConfig { rule, n_lambda: 12, tol: 1e-8, ..GroupPathConfig::default() };
        let ooc = faulted_engine(&path, budget, 61 + i as u64);
        let a = fit_group_path_with_engine(&gds, &cfg, &ooc).unwrap();
        let b = fit_group_path_with_engine(&gds, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: faulted group betas differ");
        total_retries += ooc.store().counters().retries();
    }
    assert!(total_retries > 0, "group fault injection never fired");
}

/// Logistic (the safe-screened GLM) under the same fault mix:
/// bit-identical coefficients and intercepts for every supported rule.
#[test]
fn logistic_fits_bit_identical_under_injected_faults() {
    let (x, y, _) = synthetic_logistic(80, 60, 4, 35);
    let path = tmp("flt-logit.store");
    let chunk = 8;
    let zeros = vec![0.0; x.ncols()];
    let ones = vec![1.0; x.ncols()];
    hssr::data::store::write_matrix(&x, &y, &zeros, &ones, true, chunk, &path).unwrap();
    let budget = chunk * x.nrows() * 8;
    let native = NativeEngine::new();
    let mut total_retries = 0;
    for (i, rule) in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::SsrGapSafe,
    ]
    .into_iter()
    .enumerate()
    {
        let cfg = LogisticPathConfig {
            rule,
            n_lambda: 12,
            tol: 1e-8,
            ..LogisticPathConfig::default()
        };
        let ooc = faulted_engine(&path, budget, 81 + i as u64);
        let a = fit_logistic_path_with_engine(&x, &y, &cfg, &ooc).unwrap();
        let b = fit_logistic_path_with_engine(&x, &y, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: faulted logistic betas differ");
        assert_eq!(a.intercepts, b.intercepts, "{rule:?}: intercepts differ");
        total_retries += ooc.store().counters().retries();
    }
    assert!(total_retries > 0, "logistic fault injection never fired");
}

/// One flipped byte in a chunk payload is a typed corruption error at fit
/// time — the CRC gate catches what a retry cannot fix, and the fit
/// refuses to produce numbers from the damaged chunk.
#[test]
fn flipped_store_byte_is_detected_not_served() {
    let ds = DataSpec::gene_like(50, 90).generate(17);
    let path = tmp("flt-corrupt.store");
    let chunk = 16;
    write_dataset(&ds, chunk, &path).unwrap();
    // Flip one bit inside the first chunk's payload (chunks start right
    // after the fixed header).
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[HEADER_LEN + 40] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let budget = chunk * ds.n() * 8;
    let ooc = OocEngine::open(&path, budget).unwrap();
    let cfg =
        PathConfig { rule: RuleKind::SsrBedpp, n_lambda: 10, tol: 1e-8, ..PathConfig::default() };
    let err = fit_lasso_path_with_engine(&ds, &cfg, &ooc).unwrap_err();
    assert!(matches!(err, HssrError::Corrupt(_)), "wrong error kind: {err}");
    assert!(
        ooc.store().counters().checksum_failures() > 0,
        "the CRC gate never rejected the damaged chunk"
    );
}

/// A flipped byte in a resume checkpoint is refused with a typed
/// corruption error — a damaged checkpoint must never silently seed a fit.
#[test]
fn flipped_checkpoint_byte_is_refused_on_resume() {
    let ds = DataSpec::gene_like(50, 90).generate(7);
    let ckpt = tmp("flt-corrupt.ckpt");
    let _ = std::fs::remove_file(&ckpt);
    let cfg = PathConfig {
        rule: RuleKind::SsrBedpp,
        n_lambda: 12,
        tol: 1e-8,
        checkpoint: Some(ckpt.clone()),
        ..PathConfig::default()
    };
    fit_lasso_path(&ds, &cfg).unwrap();
    let mut bytes = std::fs::read(&ckpt).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&ckpt, &bytes).unwrap();
    let err = fit_lasso_path(&ds, &cfg).unwrap_err();
    assert!(matches!(err, HssrError::Corrupt(_)), "wrong error kind: {err}");
    std::fs::remove_file(&ckpt).unwrap();
}
