//! Observability-layer integration tests: the Chrome-trace golden schema
//! and the delta-exactness property — per-λ span deltas must sum to the
//! fit's `LambdaMetrics` totals, and a store-backed fit's span I/O deltas
//! (including the constructor-time `setup` span) must sum to the store's
//! own counters.
//!
//! The trace sink is process-global, so every test serializes on one
//! lock, drains the sink at entry, and filters drained events by its own
//! fit's `fit_seq`.

use std::sync::Mutex;

use hssr::data::DataSpec;
use hssr::obs::json::Json;
use hssr::obs::summary::summarize_trace_text;
use hssr::obs::trace::{self, chrome_trace_json, Event};
use hssr::runtime::ooc::OocEngine;
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path, fit_lasso_path_with_engine, PathConfig};

static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// The single fit span among `events` (tests drain before fitting, so
/// exactly one fit runs per capture) and its fit sequence number.
fn the_fit_seq(events: &[Event]) -> u64 {
    let fits: Vec<&Event> =
        events.iter().filter(|e| e.name == "fit" && e.cat == "fit").collect();
    assert_eq!(fits.len(), 1, "expected exactly one fit span, got {}", fits.len());
    fits[0].arg_u64("fit_seq").expect("fit span carries fit_seq")
}

/// Sum one u64 arg over this fit's spans, `setup` included when
/// `with_setup` (the per-λ metric deltas live only on `lambda` spans; the
/// I/O deltas also live on the constructor's `setup` span).
fn span_sum(events: &[Event], fit_seq: u64, key: &str, with_setup: bool) -> u64 {
    events
        .iter()
        .filter(|e| e.arg_u64("fit_seq") == Some(fit_seq))
        .filter(|e| e.cat == "lambda" || (with_setup && e.name == "setup"))
        .filter_map(|e| e.arg_u64(key))
        .sum()
}

fn small_cfg(rule: RuleKind) -> PathConfig {
    PathConfig { rule, n_lambda: 25, tol: 1e-8, ..PathConfig::default() }
}

/// Golden schema: a traced fit renders to Chrome trace-event JSON that
/// our own zero-dep parser round-trips, with the `ph:"X"` complete-event
/// shape and the full phase-span taxonomy present.
#[test]
fn chrome_trace_schema_golden() {
    let _g = lock();
    trace::set_enabled(true);
    trace::drain();
    let ds = DataSpec::synthetic(50, 80, 5).generate(3);
    fit_lasso_path(&ds, &small_cfg(RuleKind::SsrBedpp)).unwrap();
    let events = trace::drain();
    trace::set_enabled(false);
    assert!(!events.is_empty(), "a traced fit must emit spans");

    let text = chrome_trace_json(&events);
    let doc = hssr::obs::json::parse(&text).expect("own chrome output must parse");
    let arr = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("top level is {traceEvents: [...]}");
    assert_eq!(arr.len(), events.len());
    for ev in arr {
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"), "complete events only");
        assert_eq!(ev.get("pid").and_then(Json::as_u64), Some(1));
        assert!(ev.get("name").and_then(Json::as_str).is_some_and(|n| !n.is_empty()));
        assert!(ev.get("cat").and_then(Json::as_str).is_some());
        assert!(ev.get("ts").and_then(Json::as_u64).is_some());
        assert!(ev.get("dur").and_then(Json::as_u64).is_some());
        assert!(ev.get("tid").and_then(Json::as_u64).is_some());
        assert!(matches!(ev.get("args"), Some(Json::Obj(_))));
    }
    for required in ["fit", "setup", "screen", "prefetch", "solve", "kkt", "finalize"] {
        assert!(
            events.iter().any(|e| e.name == required),
            "span taxonomy is missing '{required}'"
        );
    }
    let fit = events.iter().find(|e| e.name == "fit").unwrap();
    assert!(fit.arg_str("rule").is_some(), "fit span carries its rule label");

    // The `hssr trace` summarizer digests the same file: one rule row,
    // keyed by the fit span's rule label.
    let table = summarize_trace_text(&text).unwrap();
    let label = RuleKind::SsrBedpp.label();
    assert!(
        table.rows.iter().any(|r| r[0] == label),
        "summary table has no row for {label}"
    );
}

/// Delta exactness, metrics side: for each rule (static-hybrid and
/// dynamic), summing every per-λ span's counter deltas reproduces the
/// fit's own `LambdaMetrics` totals exactly — no phase mutates a metric
/// outside a span.
#[test]
fn span_deltas_sum_to_lambda_metrics_totals() {
    let _g = lock();
    for rule in [RuleKind::SsrBedpp, RuleKind::SsrGapSafe] {
        trace::set_enabled(true);
        trace::drain();
        let ds = DataSpec::gene_like(60, 150).generate(9);
        let fit = fit_lasso_path(&ds, &small_cfg(rule)).unwrap();
        let events = trace::drain();
        trace::set_enabled(false);
        let seq = the_fit_seq(&events);

        let m = &fit.metrics;
        let totals: [(&str, u64); 6] = [
            ("cols_scanned", m.iter().map(|m| m.cols_scanned).sum()),
            ("kkt_checked", m.iter().map(|m| m.kkt_checked as u64).sum()),
            ("violations", m.iter().map(|m| m.violations as u64).sum()),
            ("cd_cycles", m.iter().map(|m| m.cd_cycles as u64).sum()),
            ("coord_updates", m.iter().map(|m| m.coord_updates).sum()),
            ("rescreen_discards", m.iter().map(|m| m.rescreen_discards as u64).sum()),
        ];
        for (key, total) in totals {
            assert_eq!(
                span_sum(&events, seq, key, false),
                total,
                "{rule:?}: span '{key}' deltas must sum to the fit total"
            );
        }
        let screens =
            events.iter().filter(|e| e.name == "screen" && e.cat == "lambda").count();
        assert_eq!(screens, fit.lambdas.len(), "{rule:?}: one screen span per λ");
    }
}

/// Delta exactness, I/O side: against a real disk-backed store (prefetch
/// off), summing the span I/O deltas — per-λ spans plus the
/// constructor-time `setup` span — reproduces the store's `StoreCounters`
/// totals, and the store/metrics cross-invariant still holds.
#[test]
fn ooc_span_io_deltas_sum_to_store_counters() {
    let _g = lock();
    trace::set_enabled(true);
    trace::drain();
    let ds = DataSpec::gene_like(60, 200).generate(5);
    let engine = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
    let io0 = engine.store().counters().snapshot();
    let fit = fit_lasso_path_with_engine(&ds, &small_cfg(RuleKind::SsrBedpp), &engine).unwrap();
    let events = trace::drain();
    trace::set_enabled(false);
    let seq = the_fit_seq(&events);

    let d = engine.store().counters().snapshot().delta_since(&io0);
    assert!(d.cols_fetched > 0 && d.chunk_loads > 0, "the fit must touch the store");
    for (key, total) in [
        ("cols_fetched", d.cols_fetched),
        ("chunk_loads", d.chunk_loads),
        ("bytes_read", d.bytes_read),
        ("cache_hits", d.cache_hits),
        ("solver_cols", d.solver_cols),
    ] {
        assert_eq!(
            span_sum(&events, seq, key, true),
            total,
            "span '{key}' I/O deltas (incl. setup) must sum to the store total"
        );
    }
    // The pre-existing accounting invariant survives instrumentation.
    assert_eq!(d.cols_fetched, fit.total_cols_scanned(), "store/metrics cross-check");
}
