//! Safety invariants: a *safe* rule must never discard a feature that is
//! active in the exact solution — the defining property the paper's hybrid
//! construction rests on. Verified against fully converged solutions over
//! randomized problems (the in-crate property harness), for BEDPP, Dome,
//! SEDPP, the frozen-SEDPP rehybrid, the group-lasso rules, and the
//! dynamic gap-safe rules of all three families (sequential *and*
//! same-λ/dynamic usage, native and chunked engines).

use hssr::data::chunked::{ChunkedMatrix, ChunkedScanEngine};
use hssr::data::synth::generate_grouped;
use hssr::data::DataSpec;
use hssr::prop::{check, PropConfig};
use hssr::prop_assert;
use hssr::screening::bedpp::Bedpp;
use hssr::screening::dome::DomeTest;
use hssr::screening::gapsafe::{logistic_context, GapSafe, GroupGapSafe};
use hssr::screening::group::{GroupBedpp, GroupSafeContext, GroupSedpp};
use hssr::screening::sedpp::Sedpp;
use hssr::screening::{PrevSolution, RuleKind, SafeContext, SafeRule};
use hssr::solver::path::{fit_lasso_path, PathConfig};
use hssr::solver::Penalty;

/// Exact-solution support at every λ of a dense grid, via Basic PCD.
fn exact_path(ds: &hssr::data::Dataset, k: usize) -> hssr::solver::path::PathFit {
    fit_lasso_path(
        ds,
        &PathConfig { rule: RuleKind::BasicPcd, n_lambda: k, tol: 1e-10, ..PathConfig::default() },
    )
    .expect("exact fit")
}

#[test]
fn bedpp_and_dome_never_discard_active_features() {
    check(PropConfig { cases: 10, seed: 101 }, |rng, _| {
        let ds = DataSpec::synthetic(60 + rng.below(60) as usize, 80 + rng.below(120) as usize, 5)
            .generate(rng.next_u64());
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        let fit = exact_path(&ds, 25);
        for (k, &lam) in fit.lambdas.iter().enumerate() {
            let active: Vec<usize> = fit.betas[k].iter().map(|&(j, _)| j).collect();
            let mut survive_b = vec![true; ds.p()];
            Bedpp::screen_at(&ctx, lam, &mut survive_b);
            let mut survive_d = vec![true; ds.p()];
            DomeTest::screen_at(&ctx, lam, &mut survive_d);
            for &j in &active {
                prop_assert!(survive_b[j], "BEDPP discarded active {j} at λ#{k}");
                prop_assert!(survive_d[j], "Dome discarded active {j} at λ#{k}");
            }
        }
        Ok(())
    });
}

#[test]
fn sedpp_never_discards_active_features() {
    check(PropConfig { cases: 8, seed: 202 }, |rng, _| {
        let ds = DataSpec::gene_like(80, 150 + rng.below(150) as usize).generate(rng.next_u64());
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        let fit = exact_path(&ds, 20);
        // Sequential screening: use the exact solution at λ_k to screen λ_{k+1}.
        for k in 0..fit.lambdas.len() - 1 {
            let beta = fit.beta_dense(k);
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
            let mut survive = vec![true; ds.p()];
            let mut rule = Sedpp::new();
            rule.screen_with(&ds.x, &ctx, &prev, fit.lambdas[k + 1], &mut survive);
            for &(j, _) in &fit.betas[k + 1] {
                prop_assert!(survive[j], "SEDPP discarded active {j} at λ#{}", k + 1);
            }
        }
        Ok(())
    });
}

#[test]
fn enet_bedpp_never_discards_active_features() {
    check(PropConfig { cases: 8, seed: 303 }, |rng, _| {
        let alpha = 0.3 + 0.7 * rng.uniform();
        let ds = DataSpec::synthetic(70, 140, 6).generate(rng.next_u64());
        let pen = Penalty::ElasticNet { alpha };
        let ctx = SafeContext::build(&ds.x, &ds.y, pen, true);
        let fit = fit_lasso_path(
            &ds,
            &PathConfig {
                rule: RuleKind::BasicPcd,
                penalty: pen,
                n_lambda: 20,
                tol: 1e-10,
                ..PathConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        for (k, &lam) in fit.lambdas.iter().enumerate() {
            let mut survive = vec![true; ds.p()];
            Bedpp::screen_at(&ctx, lam, &mut survive);
            for &(j, _) in &fit.betas[k] {
                prop_assert!(survive[j], "enet BEDPP (α={alpha:.2}) discarded active {j} at λ#{k}");
            }
        }
        Ok(())
    });
}

#[test]
fn group_rules_never_discard_active_groups() {
    check(PropConfig { cases: 6, seed: 404 }, |rng, _| {
        let g_total = 10 + rng.below(15) as usize;
        let ds = generate_grouped(80, g_total, 4, 3, rng.next_u64());
        // Random ℓ1 mixing weight for the elastic-net sweep.
        let alpha = 0.4 + 0.5 * rng.uniform();
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let ctx = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, penalty);
            let fit = hssr::solver::group_path::fit_group_path(
                &ds,
                &hssr::solver::group_path::GroupPathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 20,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let active: Vec<usize> = (0..ds.num_groups())
                    .filter(|&g| ds.layout.range(g).any(|j| beta[j] != 0.0))
                    .collect();
                // group BEDPP (non-sequential; enet form when α < 1)
                let mut survive = vec![true; ds.num_groups()];
                GroupBedpp::screen_at(&ctx, fit.lambdas[k], &mut survive);
                for &g in &active {
                    prop_assert!(
                        survive[g],
                        "gBEDPP/{penalty:?} discarded active group {g} at λ#{k}"
                    );
                }
                // group SEDPP (sequential, from previous exact solution;
                // falls back to the basic rule under the elastic net)
                if k > 0 {
                    let bprev = fit.beta_dense(k - 1);
                    let xb = ds.x.matvec(&bprev);
                    let r: Vec<f64> =
                        ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
                    let prev =
                        PrevSolution { lambda: fit.lambdas[k - 1], r: &r, beta: Some(&bprev) };
                    let mut survive = vec![true; ds.num_groups()];
                    GroupSedpp::new().screen_with(
                        &ds.x,
                        &ctx,
                        &prev,
                        fit.lambdas[k],
                        &mut survive,
                    );
                    for &g in &active {
                        prop_assert!(
                            survive[g],
                            "gSEDPP/{penalty:?} discarded active group {g} at λ#{k}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// Gap-safe (columns, lasso + elastic net): screening λ_{k+1} from the
/// exact solution at λ_k — and *dynamically* re-screening λ_k from its own
/// solution — must never discard a feature active in the exact solution.
#[test]
fn gapsafe_never_discards_active_features() {
    check(PropConfig { cases: 6, seed: 505 }, |rng, _| {
        let alpha = 0.4 + 0.5 * rng.uniform();
        let ds = DataSpec::synthetic(70, 120, 6).generate(rng.next_u64());
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let ctx = SafeContext::build(&ds.x, &ds.y, penalty, false);
            let fit = fit_lasso_path(
                &ds,
                &PathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 20,
                    tol: 1e-10,
                    ..PathConfig::default()
                },
            )
            .map_err(|e| e.to_string())?;
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let xb = ds.x.matvec(&beta);
                let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
                let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
                // dynamic usage: re-screen λ_k at its own solution
                let mut s_dyn = vec![true; ds.p()];
                GapSafe::quadratic().screen(&ds.x, &ctx, &prev, fit.lambdas[k], &mut s_dyn);
                for &(j, _) in &fit.betas[k] {
                    prop_assert!(
                        s_dyn[j],
                        "gap-safe/{penalty:?} discarded active {j} dynamically at λ#{k}"
                    );
                }
                // sequential usage: screen λ_{k+1} from λ_k's solution
                if k + 1 < fit.lambdas.len() {
                    let mut s_seq = vec![true; ds.p()];
                    GapSafe::quadratic().screen(
                        &ds.x,
                        &ctx,
                        &prev,
                        fit.lambdas[k + 1],
                        &mut s_seq,
                    );
                    for &(j, _) in &fit.betas[k + 1] {
                        prop_assert!(
                            s_seq[j],
                            "gap-safe/{penalty:?} discarded active {j} at λ#{}",
                            k + 1
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// Gap-safe (groups, lasso + elastic net): same invariant at group
/// granularity.
#[test]
fn group_gapsafe_never_discards_active_groups() {
    check(PropConfig { cases: 5, seed: 606 }, |rng, _| {
        let g_total = 10 + rng.below(12) as usize;
        let ds = generate_grouped(80, g_total, 4, 3, rng.next_u64());
        let alpha = 0.4 + 0.5 * rng.uniform();
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let ctx = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, penalty);
            let fit = hssr::solver::group_path::fit_group_path(
                &ds,
                &hssr::solver::group_path::GroupPathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 18,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let active: Vec<usize> = (0..ds.num_groups())
                    .filter(|&g| ds.layout.range(g).any(|j| beta[j] != 0.0))
                    .collect();
                let xb = ds.x.matvec(&beta);
                let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
                let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
                let mut s_dyn = vec![true; ds.num_groups()];
                GroupGapSafe::new().screen(&ds.x, &ctx, &prev, fit.lambdas[k], &mut s_dyn);
                for &g in &active {
                    prop_assert!(
                        s_dyn[g],
                        "group gap-safe/{penalty:?} discarded active group {g} at λ#{k}"
                    );
                }
                if k + 1 < fit.lambdas.len() {
                    let bnext = fit.beta_dense(k + 1);
                    let mut s_seq = vec![true; ds.num_groups()];
                    GroupGapSafe::new().screen(
                        &ds.x,
                        &ctx,
                        &prev,
                        fit.lambdas[k + 1],
                        &mut s_seq,
                    );
                    for g in 0..ds.num_groups() {
                        if ds.layout.range(g).any(|j| bnext[j] != 0.0) {
                            prop_assert!(
                                s_seq[g],
                                "group gap-safe/{penalty:?} discarded active group {g} at λ#{}",
                                k + 1
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Gap-safe (logistic, lasso + elastic net): screening from the exact
/// IRLS solution must keep every feature active at the screened λ — the
/// invariant that makes this the repo's first safe-screened GLM.
#[test]
fn logistic_gapsafe_never_discards_active_features() {
    use hssr::solver::logistic::{
        fit_logistic_path, synthetic_logistic, LogisticPathConfig,
    };
    check(PropConfig { cases: 5, seed: 707 }, |rng, _| {
        let (x, y, _) = synthetic_logistic(120, 50, 4, rng.next_u64());
        let alpha = 0.5 + 0.4 * rng.uniform();
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let fit = fit_logistic_path(
                &x,
                &y,
                &LogisticPathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 15,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            let ctx = logistic_context(&y, x.ncols(), fit.lambda_max, penalty);
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let probs = fit.predict_proba(&x, k);
                let resid: Vec<f64> =
                    y.iter().zip(&probs).map(|(yi, pi)| yi - pi).collect();
                let prev =
                    PrevSolution { lambda: fit.lambdas[k], r: &resid, beta: Some(&beta) };
                let mut s_dyn = vec![true; x.ncols()];
                GapSafe::logistic().screen(&x, &ctx, &prev, fit.lambdas[k], &mut s_dyn);
                for &(j, _) in &fit.betas[k] {
                    prop_assert!(
                        s_dyn[j],
                        "logistic gap-safe/{penalty:?} discarded active {j} at λ#{k}"
                    );
                }
                if k + 1 < fit.lambdas.len() {
                    let mut s_seq = vec![true; x.ncols()];
                    GapSafe::logistic().screen(
                        &x,
                        &ctx,
                        &prev,
                        fit.lambdas[k + 1],
                        &mut s_seq,
                    );
                    for &(j, _) in &fit.betas[k + 1] {
                        prop_assert!(
                            s_seq[j],
                            "logistic gap-safe/{penalty:?} discarded active {j} at λ#{}",
                            k + 1
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// Full-path integration across engines: the SSR-GapSafe paths driven
/// through the counting chunked engine (trait-default fused passes) must
/// equal the native one-traversal paths bit-for-bit — columns, groups, and
/// logistic — and match the exact baseline.
#[test]
fn gapsafe_paths_agree_across_engines() {
    use hssr::runtime::native::NativeEngine;
    use hssr::solver::group_path::{fit_group_path_with_engine, GroupPathConfig};
    use hssr::solver::logistic::{
        fit_logistic_path_with_engine, synthetic_logistic, LogisticPathConfig,
    };
    use hssr::solver::path::fit_lasso_path_with_engine;
    let native = NativeEngine::new();

    // columns
    let ds = DataSpec::gene_like(80, 200).generate(18);
    let cfg = PathConfig {
        rule: RuleKind::SsrGapSafe,
        n_lambda: 20,
        tol: 1e-9,
        fused: true,
        ..PathConfig::default()
    };
    let store = ChunkedMatrix::from_dense(&ds.x, 32);
    let chunked = ChunkedScanEngine::new(&store);
    let a = fit_lasso_path_with_engine(&ds, &cfg, &chunked).unwrap();
    let b = fit_lasso_path_with_engine(&ds, &cfg, &native).unwrap();
    assert_eq!(a.betas, b.betas, "gap-safe column paths differ across engines");
    let exact = fit_lasso_path(
        &ds,
        &PathConfig { rule: RuleKind::BasicPcd, ..cfg.clone() },
    )
    .unwrap();
    for k in 0..a.lambdas.len() {
        let da = a.beta_dense(k);
        let de = exact.beta_dense(k);
        for j in 0..ds.p() {
            assert!((da[j] - de[j]).abs() < 1e-5, "λ#{k} β[{j}] deviates from exact");
        }
    }

    // groups
    let gds = generate_grouped(70, 20, 4, 4, 19);
    let gcfg = GroupPathConfig {
        rule: RuleKind::SsrGapSafe,
        n_lambda: 15,
        tol: 1e-9,
        fused: true,
        ..GroupPathConfig::default()
    };
    let gstore = ChunkedMatrix::from_dense(&gds.x, 16);
    let gchunked = ChunkedScanEngine::new(&gstore);
    let ga = fit_group_path_with_engine(&gds, &gcfg, &gchunked).unwrap();
    let gb = fit_group_path_with_engine(&gds, &gcfg, &native).unwrap();
    assert_eq!(ga.betas, gb.betas, "gap-safe group paths differ across engines");

    // logistic
    let (x, y, _) = synthetic_logistic(100, 60, 4, 20);
    let lcfg = LogisticPathConfig {
        rule: RuleKind::SsrGapSafe,
        n_lambda: 15,
        tol: 1e-9,
        fused: true,
        ..LogisticPathConfig::default()
    };
    let lstore = ChunkedMatrix::from_dense(&x, 16);
    let lchunked = ChunkedScanEngine::new(&lstore);
    let la = fit_logistic_path_with_engine(&x, &y, &lcfg, &lchunked).unwrap();
    let lb = fit_logistic_path_with_engine(&x, &y, &lcfg, &native).unwrap();
    assert_eq!(la.betas, lb.betas, "gap-safe logistic paths differ across engines");
    assert_eq!(la.intercepts, lb.intercepts);
}

/// SSR *can* err (it is heuristic); what must hold is that the KKT loop
/// catches every violation — i.e. the final solution satisfies KKT even
/// when violations occurred. Force violations with a coarse grid.
#[test]
fn ssr_violations_are_caught_by_kkt_loop() {
    let ds = DataSpec::mnist_like(80, 300).generate(11);
    // A very coarse grid makes 2λ_{k+1} − λ_k aggressive → violations.
    let fit = fit_lasso_path(
        &ds,
        &PathConfig { rule: RuleKind::Ssr, n_lambda: 5, tol: 1e-10, ..PathConfig::default() },
    )
    .unwrap();
    for (k, &lam) in fit.lambdas.iter().enumerate() {
        let b = fit.beta_dense(k);
        let xb = ds.x.matvec(&b);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let z = hssr::linalg::blocked::scan_all_vec(&ds.x, &r);
        for j in 0..ds.p() {
            assert!(
                z[j].abs() <= lam * (1.0 + 1e-3) + 1e-8,
                "KKT violated at λ#{k}, feature {j}"
            );
        }
    }
}
