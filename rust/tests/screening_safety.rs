//! Safety invariants: a *safe* rule must never discard a feature that is
//! active in the exact solution — the defining property the paper's hybrid
//! construction rests on. Verified against fully converged solutions over
//! randomized problems (the in-crate property harness), for BEDPP, Dome,
//! SEDPP, the frozen-SEDPP rehybrid, and the group-lasso rules.

use hssr::data::synth::generate_grouped;
use hssr::data::DataSpec;
use hssr::prop::{check, PropConfig};
use hssr::prop_assert;
use hssr::screening::bedpp::Bedpp;
use hssr::screening::dome::DomeTest;
use hssr::screening::group::{GroupBedpp, GroupSafeContext, GroupSedpp};
use hssr::screening::sedpp::Sedpp;
use hssr::screening::{PrevSolution, RuleKind, SafeContext};
use hssr::solver::path::{fit_lasso_path, PathConfig};
use hssr::solver::Penalty;

/// Exact-solution support at every λ of a dense grid, via Basic PCD.
fn exact_path(ds: &hssr::data::Dataset, k: usize) -> hssr::solver::path::PathFit {
    fit_lasso_path(
        ds,
        &PathConfig { rule: RuleKind::BasicPcd, n_lambda: k, tol: 1e-10, ..PathConfig::default() },
    )
    .expect("exact fit")
}

#[test]
fn bedpp_and_dome_never_discard_active_features() {
    check(PropConfig { cases: 10, seed: 101 }, |rng, _| {
        let ds = DataSpec::synthetic(60 + rng.below(60) as usize, 80 + rng.below(120) as usize, 5)
            .generate(rng.next_u64());
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        let fit = exact_path(&ds, 25);
        for (k, &lam) in fit.lambdas.iter().enumerate() {
            let active: Vec<usize> = fit.betas[k].iter().map(|&(j, _)| j).collect();
            let mut survive_b = vec![true; ds.p()];
            Bedpp::screen_at(&ctx, lam, &mut survive_b);
            let mut survive_d = vec![true; ds.p()];
            DomeTest::screen_at(&ctx, lam, &mut survive_d);
            for &j in &active {
                prop_assert!(survive_b[j], "BEDPP discarded active {j} at λ#{k}");
                prop_assert!(survive_d[j], "Dome discarded active {j} at λ#{k}");
            }
        }
        Ok(())
    });
}

#[test]
fn sedpp_never_discards_active_features() {
    check(PropConfig { cases: 8, seed: 202 }, |rng, _| {
        let ds = DataSpec::gene_like(80, 150 + rng.below(150) as usize).generate(rng.next_u64());
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        let fit = exact_path(&ds, 20);
        // Sequential screening: use the exact solution at λ_k to screen λ_{k+1}.
        for k in 0..fit.lambdas.len() - 1 {
            let beta = fit.beta_dense(k);
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let prev = PrevSolution { lambda: fit.lambdas[k], r: &r };
            let mut survive = vec![true; ds.p()];
            let mut rule = Sedpp::new();
            rule.screen_with(&ds.x, &ctx, &prev, fit.lambdas[k + 1], &mut survive);
            for &(j, _) in &fit.betas[k + 1] {
                prop_assert!(survive[j], "SEDPP discarded active {j} at λ#{}", k + 1);
            }
        }
        Ok(())
    });
}

#[test]
fn enet_bedpp_never_discards_active_features() {
    check(PropConfig { cases: 8, seed: 303 }, |rng, _| {
        let alpha = 0.3 + 0.7 * rng.uniform();
        let ds = DataSpec::synthetic(70, 140, 6).generate(rng.next_u64());
        let pen = Penalty::ElasticNet { alpha };
        let ctx = SafeContext::build(&ds.x, &ds.y, pen, true);
        let fit = fit_lasso_path(
            &ds,
            &PathConfig {
                rule: RuleKind::BasicPcd,
                penalty: pen,
                n_lambda: 20,
                tol: 1e-10,
                ..PathConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        for (k, &lam) in fit.lambdas.iter().enumerate() {
            let mut survive = vec![true; ds.p()];
            Bedpp::screen_at(&ctx, lam, &mut survive);
            for &(j, _) in &fit.betas[k] {
                prop_assert!(survive[j], "enet BEDPP (α={alpha:.2}) discarded active {j} at λ#{k}");
            }
        }
        Ok(())
    });
}

#[test]
fn group_rules_never_discard_active_groups() {
    check(PropConfig { cases: 6, seed: 404 }, |rng, _| {
        let g_total = 10 + rng.below(15) as usize;
        let ds = generate_grouped(80, g_total, 4, 3, rng.next_u64());
        // Random ℓ1 mixing weight for the elastic-net sweep.
        let alpha = 0.4 + 0.5 * rng.uniform();
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let ctx = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, penalty);
            let fit = hssr::solver::group_path::fit_group_path(
                &ds,
                &hssr::solver::group_path::GroupPathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 20,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let active: Vec<usize> = (0..ds.num_groups())
                    .filter(|&g| ds.layout.range(g).any(|j| beta[j] != 0.0))
                    .collect();
                // group BEDPP (non-sequential; enet form when α < 1)
                let mut survive = vec![true; ds.num_groups()];
                GroupBedpp::screen_at(&ctx, fit.lambdas[k], &mut survive);
                for &g in &active {
                    prop_assert!(
                        survive[g],
                        "gBEDPP/{penalty:?} discarded active group {g} at λ#{k}"
                    );
                }
                // group SEDPP (sequential, from previous exact solution;
                // falls back to the basic rule under the elastic net)
                if k > 0 {
                    let bprev = fit.beta_dense(k - 1);
                    let xb = ds.x.matvec(&bprev);
                    let r: Vec<f64> =
                        ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
                    let prev = PrevSolution { lambda: fit.lambdas[k - 1], r: &r };
                    let mut survive = vec![true; ds.num_groups()];
                    GroupSedpp::new().screen_with(
                        &ds.x,
                        &ctx,
                        &prev,
                        fit.lambdas[k],
                        &mut survive,
                    );
                    for &g in &active {
                        prop_assert!(
                            survive[g],
                            "gSEDPP/{penalty:?} discarded active group {g} at λ#{k}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// SSR *can* err (it is heuristic); what must hold is that the KKT loop
/// catches every violation — i.e. the final solution satisfies KKT even
/// when violations occurred. Force violations with a coarse grid.
#[test]
fn ssr_violations_are_caught_by_kkt_loop() {
    let ds = DataSpec::mnist_like(80, 300).generate(11);
    // A very coarse grid makes 2λ_{k+1} − λ_k aggressive → violations.
    let fit = fit_lasso_path(
        &ds,
        &PathConfig { rule: RuleKind::Ssr, n_lambda: 5, tol: 1e-10, ..PathConfig::default() },
    )
    .unwrap();
    for (k, &lam) in fit.lambdas.iter().enumerate() {
        let b = fit.beta_dense(k);
        let xb = ds.x.matvec(&b);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let z = hssr::linalg::blocked::scan_all_vec(&ds.x, &r);
        for j in 0..ds.p() {
            assert!(
                z[j].abs() <= lam * (1.0 + 1e-3) + 1e-8,
                "KKT violated at λ#{k}, feature {j}"
            );
        }
    }
}
