//! Safety invariants: a *safe* rule must never discard a feature that is
//! active in the exact solution — the defining property the paper's hybrid
//! construction rests on. Verified against fully converged solutions over
//! randomized problems (the in-crate property harness), for BEDPP, Dome,
//! SEDPP, the frozen-SEDPP rehybrid, the group-lasso rules, and the
//! dynamic gap-safe rules of all three families (sequential *and*
//! same-λ/dynamic usage, native and chunked engines).

use hssr::data::chunked::{ChunkedMatrix, ChunkedScanEngine};
use hssr::data::synth::generate_grouped;
use hssr::data::DataSpec;
use hssr::prop::{check, PropConfig};
use hssr::prop_assert;
use hssr::screening::bedpp::Bedpp;
use hssr::screening::dome::DomeTest;
use hssr::screening::gapsafe::{logistic_context, GapSafe, GroupGapSafe};
use hssr::screening::group::{GroupBedpp, GroupSafeContext, GroupSedpp};
use hssr::screening::sedpp::Sedpp;
use hssr::screening::{PrevSolution, RuleKind, SafeContext, SafeRule};
use hssr::solver::path::{fit_lasso_path, PathConfig};
use hssr::solver::Penalty;

/// Exact-solution support at every λ of a dense grid, via Basic PCD.
fn exact_path(ds: &hssr::data::Dataset, k: usize) -> hssr::solver::path::PathFit {
    fit_lasso_path(
        ds,
        &PathConfig { rule: RuleKind::BasicPcd, n_lambda: k, tol: 1e-10, ..PathConfig::default() },
    )
    .expect("exact fit")
}

#[test]
fn bedpp_and_dome_never_discard_active_features() {
    check(PropConfig { cases: 10, seed: 101 }, |rng, _| {
        let ds = DataSpec::synthetic(60 + rng.below(60) as usize, 80 + rng.below(120) as usize, 5)
            .generate(rng.next_u64());
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        let fit = exact_path(&ds, 25);
        for (k, &lam) in fit.lambdas.iter().enumerate() {
            let active: Vec<usize> = fit.betas[k].iter().map(|&(j, _)| j).collect();
            let mut survive_b = vec![true; ds.p()];
            Bedpp::screen_at(&ctx, lam, &mut survive_b);
            let mut survive_d = vec![true; ds.p()];
            DomeTest::screen_at(&ctx, lam, &mut survive_d);
            for &j in &active {
                prop_assert!(survive_b[j], "BEDPP discarded active {j} at λ#{k}");
                prop_assert!(survive_d[j], "Dome discarded active {j} at λ#{k}");
            }
        }
        Ok(())
    });
}

#[test]
fn sedpp_never_discards_active_features() {
    check(PropConfig { cases: 8, seed: 202 }, |rng, _| {
        let ds = DataSpec::gene_like(80, 150 + rng.below(150) as usize).generate(rng.next_u64());
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        let fit = exact_path(&ds, 20);
        // Sequential screening: use the exact solution at λ_k to screen λ_{k+1}.
        for k in 0..fit.lambdas.len() - 1 {
            let beta = fit.beta_dense(k);
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
            let mut survive = vec![true; ds.p()];
            let mut rule = Sedpp::new();
            rule.screen_with(&ds.x, &ctx, &prev, fit.lambdas[k + 1], &mut survive);
            for &(j, _) in &fit.betas[k + 1] {
                prop_assert!(survive[j], "SEDPP discarded active {j} at λ#{}", k + 1);
            }
        }
        Ok(())
    });
}

#[test]
fn enet_bedpp_never_discards_active_features() {
    check(PropConfig { cases: 8, seed: 303 }, |rng, _| {
        let alpha = 0.3 + 0.7 * rng.uniform();
        let ds = DataSpec::synthetic(70, 140, 6).generate(rng.next_u64());
        let pen = Penalty::ElasticNet { alpha };
        let ctx = SafeContext::build(&ds.x, &ds.y, pen, true);
        let fit = fit_lasso_path(
            &ds,
            &PathConfig {
                rule: RuleKind::BasicPcd,
                penalty: pen,
                n_lambda: 20,
                tol: 1e-10,
                ..PathConfig::default()
            },
        )
        .map_err(|e| e.to_string())?;
        for (k, &lam) in fit.lambdas.iter().enumerate() {
            let mut survive = vec![true; ds.p()];
            Bedpp::screen_at(&ctx, lam, &mut survive);
            for &(j, _) in &fit.betas[k] {
                prop_assert!(survive[j], "enet BEDPP (α={alpha:.2}) discarded active {j} at λ#{k}");
            }
        }
        Ok(())
    });
}

#[test]
fn group_rules_never_discard_active_groups() {
    check(PropConfig { cases: 6, seed: 404 }, |rng, _| {
        let g_total = 10 + rng.below(15) as usize;
        let ds = generate_grouped(80, g_total, 4, 3, rng.next_u64());
        // Random ℓ1 mixing weight for the elastic-net sweep.
        let alpha = 0.4 + 0.5 * rng.uniform();
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let ctx = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, penalty);
            let fit = hssr::solver::group_path::fit_group_path(
                &ds,
                &hssr::solver::group_path::GroupPathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 20,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let active: Vec<usize> = (0..ds.num_groups())
                    .filter(|&g| ds.layout.range(g).any(|j| beta[j] != 0.0))
                    .collect();
                // group BEDPP (non-sequential; enet form when α < 1)
                let mut survive = vec![true; ds.num_groups()];
                GroupBedpp::screen_at(&ctx, fit.lambdas[k], &mut survive);
                for &g in &active {
                    prop_assert!(
                        survive[g],
                        "gBEDPP/{penalty:?} discarded active group {g} at λ#{k}"
                    );
                }
                // group SEDPP (sequential, from previous exact solution;
                // falls back to the basic rule under the elastic net)
                if k > 0 {
                    let bprev = fit.beta_dense(k - 1);
                    let xb = ds.x.matvec(&bprev);
                    let r: Vec<f64> =
                        ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
                    let prev =
                        PrevSolution { lambda: fit.lambdas[k - 1], r: &r, beta: Some(&bprev) };
                    let mut survive = vec![true; ds.num_groups()];
                    GroupSedpp::new().screen_with(
                        &ds.x,
                        &ctx,
                        &prev,
                        fit.lambdas[k],
                        &mut survive,
                    );
                    for &g in &active {
                        prop_assert!(
                            survive[g],
                            "gSEDPP/{penalty:?} discarded active group {g} at λ#{k}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// Gap-safe (columns, lasso + elastic net): screening λ_{k+1} from the
/// exact solution at λ_k — and *dynamically* re-screening λ_k from its own
/// solution — must never discard a feature active in the exact solution.
#[test]
fn gapsafe_never_discards_active_features() {
    check(PropConfig { cases: 6, seed: 505 }, |rng, _| {
        let alpha = 0.4 + 0.5 * rng.uniform();
        let ds = DataSpec::synthetic(70, 120, 6).generate(rng.next_u64());
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let ctx = SafeContext::build(&ds.x, &ds.y, penalty, false);
            let fit = fit_lasso_path(
                &ds,
                &PathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 20,
                    tol: 1e-10,
                    ..PathConfig::default()
                },
            )
            .map_err(|e| e.to_string())?;
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let xb = ds.x.matvec(&beta);
                let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
                let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
                // dynamic usage: re-screen λ_k at its own solution
                let mut s_dyn = vec![true; ds.p()];
                GapSafe::quadratic().screen(&ds.x, &ctx, &prev, fit.lambdas[k], &mut s_dyn);
                for &(j, _) in &fit.betas[k] {
                    prop_assert!(
                        s_dyn[j],
                        "gap-safe/{penalty:?} discarded active {j} dynamically at λ#{k}"
                    );
                }
                // sequential usage: screen λ_{k+1} from λ_k's solution
                if k + 1 < fit.lambdas.len() {
                    let mut s_seq = vec![true; ds.p()];
                    GapSafe::quadratic().screen(
                        &ds.x,
                        &ctx,
                        &prev,
                        fit.lambdas[k + 1],
                        &mut s_seq,
                    );
                    for &(j, _) in &fit.betas[k + 1] {
                        prop_assert!(
                            s_seq[j],
                            "gap-safe/{penalty:?} discarded active {j} at λ#{}",
                            k + 1
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// Gap-safe (groups, lasso + elastic net): same invariant at group
/// granularity.
#[test]
fn group_gapsafe_never_discards_active_groups() {
    check(PropConfig { cases: 5, seed: 606 }, |rng, _| {
        let g_total = 10 + rng.below(12) as usize;
        let ds = generate_grouped(80, g_total, 4, 3, rng.next_u64());
        let alpha = 0.4 + 0.5 * rng.uniform();
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let ctx = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, penalty);
            let fit = hssr::solver::group_path::fit_group_path(
                &ds,
                &hssr::solver::group_path::GroupPathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 18,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let active: Vec<usize> = (0..ds.num_groups())
                    .filter(|&g| ds.layout.range(g).any(|j| beta[j] != 0.0))
                    .collect();
                let xb = ds.x.matvec(&beta);
                let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
                let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
                let mut s_dyn = vec![true; ds.num_groups()];
                GroupGapSafe::new().screen(&ds.x, &ctx, &prev, fit.lambdas[k], &mut s_dyn);
                for &g in &active {
                    prop_assert!(
                        s_dyn[g],
                        "group gap-safe/{penalty:?} discarded active group {g} at λ#{k}"
                    );
                }
                if k + 1 < fit.lambdas.len() {
                    let bnext = fit.beta_dense(k + 1);
                    let mut s_seq = vec![true; ds.num_groups()];
                    GroupGapSafe::new().screen(
                        &ds.x,
                        &ctx,
                        &prev,
                        fit.lambdas[k + 1],
                        &mut s_seq,
                    );
                    for g in 0..ds.num_groups() {
                        if ds.layout.range(g).any(|j| bnext[j] != 0.0) {
                            prop_assert!(
                                s_seq[g],
                                "group gap-safe/{penalty:?} discarded active group {g} at λ#{}",
                                k + 1
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Gap-safe (logistic, lasso + elastic net): screening from the exact
/// IRLS solution must keep every feature active at the screened λ — the
/// invariant that makes this the repo's first safe-screened GLM.
#[test]
fn logistic_gapsafe_never_discards_active_features() {
    use hssr::solver::logistic::{
        fit_logistic_path, synthetic_logistic, LogisticPathConfig,
    };
    check(PropConfig { cases: 5, seed: 707 }, |rng, _| {
        let (x, y, _) = synthetic_logistic(120, 50, 4, rng.next_u64());
        let alpha = 0.5 + 0.4 * rng.uniform();
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
            let fit = fit_logistic_path(
                &x,
                &y,
                &LogisticPathConfig {
                    rule: RuleKind::BasicPcd,
                    penalty,
                    n_lambda: 15,
                    tol: 1e-10,
                    ..Default::default()
                },
            )
            .map_err(|e| e.to_string())?;
            let ctx = logistic_context(&y, x.ncols(), fit.lambda_max, penalty);
            for k in 0..fit.lambdas.len() {
                let beta = fit.beta_dense(k);
                let probs = fit.predict_proba(&x, k);
                let resid: Vec<f64> =
                    y.iter().zip(&probs).map(|(yi, pi)| yi - pi).collect();
                let prev =
                    PrevSolution { lambda: fit.lambdas[k], r: &resid, beta: Some(&beta) };
                let mut s_dyn = vec![true; x.ncols()];
                GapSafe::logistic().screen(&x, &ctx, &prev, fit.lambdas[k], &mut s_dyn);
                for &(j, _) in &fit.betas[k] {
                    prop_assert!(
                        s_dyn[j],
                        "logistic gap-safe/{penalty:?} discarded active {j} at λ#{k}"
                    );
                }
                if k + 1 < fit.lambdas.len() {
                    let mut s_seq = vec![true; x.ncols()];
                    GapSafe::logistic().screen(
                        &x,
                        &ctx,
                        &prev,
                        fit.lambdas[k + 1],
                        &mut s_seq,
                    );
                    for &(j, _) in &fit.betas[k + 1] {
                        prop_assert!(
                            s_seq[j],
                            "logistic gap-safe/{penalty:?} discarded active {j} at λ#{}",
                            k + 1
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// Full-path integration across engines: the SSR-GapSafe paths driven
/// through the counting chunked engine (trait-default fused passes) must
/// equal the native one-traversal paths bit-for-bit — columns, groups, and
/// logistic — and match the exact baseline.
#[test]
fn gapsafe_paths_agree_across_engines() {
    use hssr::runtime::native::NativeEngine;
    use hssr::solver::group_path::{fit_group_path_with_engine, GroupPathConfig};
    use hssr::solver::logistic::{
        fit_logistic_path_with_engine, synthetic_logistic, LogisticPathConfig,
    };
    use hssr::solver::path::fit_lasso_path_with_engine;
    let native = NativeEngine::new();

    // columns
    let ds = DataSpec::gene_like(80, 200).generate(18);
    let cfg = PathConfig {
        rule: RuleKind::SsrGapSafe,
        n_lambda: 20,
        tol: 1e-9,
        fused: true,
        ..PathConfig::default()
    };
    let store = ChunkedMatrix::from_dense(&ds.x, 32);
    let chunked = ChunkedScanEngine::new(&store);
    let a = fit_lasso_path_with_engine(&ds, &cfg, &chunked).unwrap();
    let b = fit_lasso_path_with_engine(&ds, &cfg, &native).unwrap();
    assert_eq!(a.betas, b.betas, "gap-safe column paths differ across engines");
    let exact = fit_lasso_path(
        &ds,
        &PathConfig { rule: RuleKind::BasicPcd, ..cfg.clone() },
    )
    .unwrap();
    for k in 0..a.lambdas.len() {
        let da = a.beta_dense(k);
        let de = exact.beta_dense(k);
        for j in 0..ds.p() {
            assert!((da[j] - de[j]).abs() < 1e-5, "λ#{k} β[{j}] deviates from exact");
        }
    }

    // groups
    let gds = generate_grouped(70, 20, 4, 4, 19);
    let gcfg = GroupPathConfig {
        rule: RuleKind::SsrGapSafe,
        n_lambda: 15,
        tol: 1e-9,
        fused: true,
        ..GroupPathConfig::default()
    };
    let gstore = ChunkedMatrix::from_dense(&gds.x, 16);
    let gchunked = ChunkedScanEngine::new(&gstore);
    let ga = fit_group_path_with_engine(&gds, &gcfg, &gchunked).unwrap();
    let gb = fit_group_path_with_engine(&gds, &gcfg, &native).unwrap();
    assert_eq!(ga.betas, gb.betas, "gap-safe group paths differ across engines");

    // logistic
    let (x, y, _) = synthetic_logistic(100, 60, 4, 20);
    let lcfg = LogisticPathConfig {
        rule: RuleKind::SsrGapSafe,
        n_lambda: 15,
        tol: 1e-9,
        fused: true,
        ..LogisticPathConfig::default()
    };
    let lstore = ChunkedMatrix::from_dense(&x, 16);
    let lchunked = ChunkedScanEngine::new(&lstore);
    let la = fit_logistic_path_with_engine(&x, &y, &lcfg, &lchunked).unwrap();
    let lb = fit_logistic_path_with_engine(&x, &y, &lcfg, &native).unwrap();
    assert_eq!(la.betas, lb.betas, "gap-safe logistic paths differ across engines");
    assert_eq!(la.intercepts, lb.intercepts);
}

/// Mixed-precision safety at the rule level: the f32-widened screen must
/// (a) make **exactly** the decisions the f64 screen makes — the widened
/// interval only routes columns to an exact confirm pass, never decides —
/// and (b) in particular never discard a feature active in the exact
/// solution. Checked for the two f32-capable column rules (gap-safe,
/// SEDPP) in both sequential and dynamic usage.
#[test]
fn f32_screen_decisions_match_f64_and_never_discard_active() {
    use hssr::runtime::native::NativeEngine;
    use hssr::runtime::Precision;
    check(PropConfig { cases: 5, seed: 0xF32A }, |rng, _| {
        let ds = DataSpec::synthetic(70, 130, 6).generate(rng.next_u64());
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        let native = NativeEngine::new();
        let fit = exact_path(&ds, 18);
        for k in 0..fit.lambdas.len() - 1 {
            let beta = fit.beta_dense(k);
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
            // Dynamic (λ_k at its own solution) and sequential (λ_{k+1}).
            for lam in [fit.lambdas[k], fit.lambdas[k + 1]] {
                let mut gs64 = GapSafe::quadratic();
                let mut gs32 = GapSafe::quadratic();
                gs32.set_precision(Precision::F32);
                let mut sp64 = Sedpp::new();
                let mut sp32 = Sedpp::new();
                sp32.set_precision(Precision::F32);
                let pairs: [(&mut dyn SafeRule, &mut dyn SafeRule, &str); 2] = [
                    (&mut gs64, &mut gs32, "gap-safe"),
                    (&mut sp64, &mut sp32, "sedpp"),
                ];
                for (r64, r32, name) in pairs {
                    let mut s64 = vec![true; ds.p()];
                    let mut s32 = vec![true; ds.p()];
                    let mut sc = 0u64;
                    r64.screen_routed(&native, &ds.x, &ctx, &prev, lam, &mut s64, &mut sc)
                        .map_err(|e| e.to_string())?;
                    r32.screen_routed(&native, &ds.x, &ctx, &prev, lam, &mut s32, &mut sc)
                        .map_err(|e| e.to_string())?;
                    prop_assert!(
                        s64 == s32,
                        "{name}: f32 and f64 survivor sets differ at λ#{k}"
                    );
                    let active =
                        if lam == fit.lambdas[k] { &fit.betas[k] } else { &fit.betas[k + 1] };
                    for &(j, _) in active {
                        prop_assert!(
                            s32[j],
                            "{name}: f32 screen discarded active {j} at λ#{k}"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

/// Group granularity: the f32 group-norm prefilter must reproduce the f64
/// group decisions exactly and keep every active group.
#[test]
fn f32_group_screen_decisions_match_f64() {
    use hssr::runtime::native::NativeEngine;
    use hssr::runtime::Precision;
    check(PropConfig { cases: 4, seed: 0xF32B }, |rng, _| {
        let ds = generate_grouped(80, 14, 4, 3, rng.next_u64());
        let ctx = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, Penalty::Lasso);
        let native = NativeEngine::new();
        let fit = hssr::solver::group_path::fit_group_path(
            &ds,
            &hssr::solver::group_path::GroupPathConfig {
                rule: RuleKind::BasicPcd,
                n_lambda: 15,
                tol: 1e-10,
                ..Default::default()
            },
        )
        .map_err(|e| e.to_string())?;
        for k in 0..fit.lambdas.len() {
            let beta = fit.beta_dense(k);
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
            let mut g64 = GroupGapSafe::new();
            let mut g32 = GroupGapSafe::new();
            g32.set_precision(Precision::F32);
            let mut s64 = vec![true; ds.num_groups()];
            let mut s32 = vec![true; ds.num_groups()];
            let mut sc = 0u64;
            g64.screen_routed(&native, &ds.x, &ctx, &prev, fit.lambdas[k], &mut s64, &mut sc)
                .map_err(|e| e.to_string())?;
            g32.screen_routed(&native, &ds.x, &ctx, &prev, fit.lambdas[k], &mut s32, &mut sc)
                .map_err(|e| e.to_string())?;
            prop_assert!(s64 == s32, "group survivor sets differ at λ#{k}");
            for g in 0..ds.num_groups() {
                if ds.layout.range(g).any(|j| beta[j] != 0.0) {
                    prop_assert!(
                        s32[g],
                        "f32 group screen discarded active group {g} at λ#{k}"
                    );
                }
            }
        }
        Ok(())
    });
}

/// Out-of-core mixed precision: with the store's persisted f32 shadow
/// section feeding the screening scans, a `--precision f32` fit from the
/// store must stay bit-identical to the all-f64 *native* fit — the full
/// chain (shadow chunk → widened prefilter → exact confirm → CD) crosses
/// both the engine and precision boundaries without changing a bit.
#[test]
fn f32_store_shadow_fit_is_bit_identical_to_f64_native() {
    use hssr::data::store::{append_f32_shadow, write_dataset};
    use hssr::runtime::native::NativeEngine;
    use hssr::runtime::ooc::OocEngine;
    use hssr::runtime::Precision;
    use hssr::solver::path::fit_lasso_path_with_engine;
    let ds = DataSpec::gene_like(70, 160).generate(77);
    let dir = std::env::temp_dir().join("hssr_precision_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("f32-shadow.store");
    let chunk = 16;
    write_dataset(&ds, chunk, &path).unwrap();
    let header = append_f32_shadow(&path).unwrap();
    assert!(header.f32_shadow, "shadow append did not set the header flag");
    let budget = 4 * chunk * ds.n() * 8;
    let native = NativeEngine::new();
    for rule in [RuleKind::Sedpp, RuleKind::SsrGapSafe] {
        let cfg64 = PathConfig {
            rule,
            n_lambda: 14,
            tol: 1e-8,
            precision: Precision::F64,
            ..PathConfig::default()
        };
        let cfg32 = PathConfig { precision: Precision::F32, ..cfg64.clone() };
        let ooc = OocEngine::open(&path, budget).unwrap();
        let a = fit_lasso_path_with_engine(&ds, &cfg32, &ooc).unwrap();
        let b = fit_lasso_path_with_engine(&ds, &cfg64, &native).unwrap();
        assert_eq!(
            a.betas, b.betas,
            "{rule:?}: f32-shadow store fit differs from f64 native fit"
        );
        let c = ooc.store().counters();
        assert!(c.cols_fetched() > 0, "{rule:?}: store fit never touched the store");
    }
}

/// SSR *can* err (it is heuristic); what must hold is that the KKT loop
/// catches every violation — i.e. the final solution satisfies KKT even
/// when violations occurred. Force violations with a coarse grid.
#[test]
fn ssr_violations_are_caught_by_kkt_loop() {
    let ds = DataSpec::mnist_like(80, 300).generate(11);
    // A very coarse grid makes 2λ_{k+1} − λ_k aggressive → violations.
    let fit = fit_lasso_path(
        &ds,
        &PathConfig { rule: RuleKind::Ssr, n_lambda: 5, tol: 1e-10, ..PathConfig::default() },
    )
    .unwrap();
    for (k, &lam) in fit.lambdas.iter().enumerate() {
        let b = fit.beta_dense(k);
        let xb = ds.x.matvec(&b);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let z = hssr::linalg::blocked::scan_all_vec(&ds.x, &r);
        for j in 0..ds.p() {
            assert!(
                z[j].abs() <= lam * (1.0 + 1e-3) + 1e-8,
                "KKT violated at λ#{k}, feature {j}"
            );
        }
    }
}
