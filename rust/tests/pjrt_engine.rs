//! Integration: the PJRT engine (AOT JAX/Pallas artifacts) must agree with
//! the native engine to float tolerance. Requires `make artifacts` and the
//! `pjrt` cargo feature (the default build compiles a stub engine).
#![cfg(feature = "pjrt")]

use hssr::data::DataSpec;
use hssr::linalg::blocked;
use hssr::runtime::{pjrt::PjrtEngine, ScanEngine};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts").is_dir()
        && std::fs::read_dir("artifacts").map(|d| d.count() > 0).unwrap_or(false)
}

#[test]
fn pjrt_scan_matches_native() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        return;
    }
    let engine = PjrtEngine::load("artifacts").expect("load artifacts");
    assert!(engine.is_pallas(), "pallas artifact should be preferred");
    // Odd, non-tile-multiple shape to exercise the padding path.
    let ds = DataSpec::synthetic(173, 517, 10).generate(3);
    let v = ds.y.clone();
    let mut got = vec![0.0; ds.p()];
    engine.scan_all(&ds.x, &v, &mut got).unwrap();
    let want = blocked::scan_all_vec(&ds.x, &v);
    for j in 0..ds.p() {
        assert!(
            (got[j] - want[j]).abs() < 1e-9,
            "col {j}: pjrt {} vs native {}",
            got[j],
            want[j]
        );
    }
    // subset path
    let idx = vec![0usize, 5, 99, 516];
    let mut sub = vec![0.0; idx.len()];
    engine.scan_subset(&ds.x, &v, &idx, &mut sub).unwrap();
    for (k, &j) in idx.iter().enumerate() {
        assert!((sub[k] - want[j]).abs() < 1e-9);
    }
}
