//! Screening-power regression against committed goldens (paper Fig. 1/4):
//! on fixed seeded workloads, the per-λ BEDPP rejection counts, the
//! dynamic gap-safe rejection counts (screen-time |S| and mid-λ re-fires),
//! and the path's safe/strong set sizes must match
//! `tests/goldens/screening_power.json` **exactly**. Counts are integers
//! produced by deterministic arithmetic, so any drift means a screening
//! bound silently loosened (fewer rejections) or became unsafe (more).
//!
//! Bootstrap: if the golden file does not exist yet (fresh checkout before
//! the first CI run commits it), the test writes it and passes; CI uploads
//! the generated file as an artifact so it can be committed. On mismatch
//! the freshly computed counts are written next to the golden as
//! `screening_power.json.new` for diffing.

use std::fmt::Write as _;
use std::path::PathBuf;

use hssr::data::synth::generate_grouped;
use hssr::data::DataSpec;
use hssr::screening::bedpp::Bedpp;
use hssr::screening::group::{GroupBedpp, GroupSafeContext};
use hssr::screening::{RuleKind, SafeContext};
use hssr::solver::group_path::{fit_group_path, GroupPathConfig};
use hssr::solver::path::{fit_lasso_path, PathConfig};
use hssr::solver::Penalty;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens/screening_power.json")
}

fn ints(out: &mut String, key: &str, vals: &[usize]) {
    write!(out, "    \"{key}\": [").unwrap();
    for (i, v) in vals.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        write!(out, "{v}").unwrap();
    }
    out.push(']');
}

/// Compute the canonical golden document for the two fixed workloads.
fn compute_golden() -> String {
    // ---- lasso workload: gene-like n=80, p=200, seed 7, SSR-BEDPP ----
    let ds = DataSpec::gene_like(80, 200).generate(7);
    let cfg = PathConfig {
        rule: RuleKind::SsrBedpp,
        n_lambda: 40,
        tol: 1e-9,
        fused: true,
        ..PathConfig::default()
    };
    let fit = fit_lasso_path(&ds, &cfg).expect("lasso fit");
    let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
    let mut bedpp_rej = Vec::with_capacity(fit.lambdas.len());
    for &lam in &fit.lambdas {
        let mut survive = vec![true; ds.p()];
        bedpp_rej.push(Bedpp::screen_at(&ctx, lam, &mut survive));
    }
    let safe: Vec<usize> = fit.metrics.iter().map(|m| m.safe_size).collect();
    let strong: Vec<usize> = fit.metrics.iter().map(|m| m.strong_size).collect();

    // ---- gap-safe lasso workload: same data/grid, SSR-GapSafe ----
    let gap_fit = fit_lasso_path(
        &ds,
        &PathConfig { rule: RuleKind::SsrGapSafe, ..cfg.clone() },
    )
    .expect("gap-safe lasso fit");
    let gap_rej: Vec<usize> =
        gap_fit.metrics.iter().map(|m| ds.p() - m.safe_size).collect();
    let gap_refires: Vec<usize> =
        gap_fit.metrics.iter().map(|m| m.rescreen_discards).collect();
    let gap_strong: Vec<usize> =
        gap_fit.metrics.iter().map(|m| m.strong_size).collect();

    // ---- group workload: synth n=80, G=30, W=4, seed 14, SSR-BEDPP ----
    let gds = generate_grouped(80, 30, 4, 4, 14);
    let gcfg = GroupPathConfig {
        rule: RuleKind::SsrBedpp,
        n_lambda: 25,
        tol: 1e-9,
        fused: true,
        ..GroupPathConfig::default()
    };
    let gfit = fit_group_path(&gds, &gcfg).expect("group fit");
    let gctx = GroupSafeContext::build(&gds.x, &gds.y, &gds.layout, Penalty::Lasso);
    let mut gbedpp_rej = Vec::with_capacity(gfit.lambdas.len());
    for &lam in &gfit.lambdas {
        let mut survive = vec![true; gds.num_groups()];
        gbedpp_rej.push(GroupBedpp::screen_at(&gctx, lam, &mut survive));
    }
    let gsafe: Vec<usize> = gfit.metrics.iter().map(|m| m.safe_size).collect();
    let gstrong: Vec<usize> = gfit.metrics.iter().map(|m| m.strong_size).collect();

    // ---- group elastic net (α = 0.6): pins the new enet bounds ----
    let ecfg = GroupPathConfig {
        penalty: Penalty::ElasticNet { alpha: 0.6 },
        ..gcfg.clone()
    };
    let efit = fit_group_path(&gds, &ecfg).expect("group enet fit");
    let ectx = GroupSafeContext::build(
        &gds.x,
        &gds.y,
        &gds.layout,
        Penalty::ElasticNet { alpha: 0.6 },
    );
    let mut ebedpp_rej = Vec::with_capacity(efit.lambdas.len());
    for &lam in &efit.lambdas {
        let mut survive = vec![true; gds.num_groups()];
        ebedpp_rej.push(GroupBedpp::screen_at(&ectx, lam, &mut survive));
    }
    let esafe: Vec<usize> = efit.metrics.iter().map(|m| m.safe_size).collect();
    let estrong: Vec<usize> = efit.metrics.iter().map(|m| m.strong_size).collect();

    // ---- gap-safe group workload: same data/grid, SSR-GapSafe ----
    let ggap_fit = fit_group_path(
        &gds,
        &GroupPathConfig { rule: RuleKind::SsrGapSafe, ..gcfg.clone() },
    )
    .expect("gap-safe group fit");
    let ggap_rej: Vec<usize> = ggap_fit
        .metrics
        .iter()
        .map(|m| gds.num_groups() - m.safe_size)
        .collect();
    let ggap_refires: Vec<usize> =
        ggap_fit.metrics.iter().map(|m| m.rescreen_discards).collect();

    let mut out = String::new();
    out.push_str("{\n  \"lasso_gene_n80_p200_seed7_ssrbedpp_k40\": {\n");
    ints(&mut out, "bedpp_rejected", &bedpp_rej);
    out.push_str(",\n");
    ints(&mut out, "safe_size", &safe);
    out.push_str(",\n");
    ints(&mut out, "strong_size", &strong);
    out.push_str("\n  },\n  \"group_synth_n80_G30_W4_seed14_ssrbedpp_k25\": {\n");
    ints(&mut out, "bedpp_rejected", &gbedpp_rej);
    out.push_str(",\n");
    ints(&mut out, "safe_size", &gsafe);
    out.push_str(",\n");
    ints(&mut out, "strong_size", &gstrong);
    out.push_str("\n  },\n  \"group_enet_a0.6_n80_G30_W4_seed14_ssrbedpp_k25\": {\n");
    ints(&mut out, "bedpp_rejected", &ebedpp_rej);
    out.push_str(",\n");
    ints(&mut out, "safe_size", &esafe);
    out.push_str(",\n");
    ints(&mut out, "strong_size", &estrong);
    out.push_str("\n  },\n  \"lasso_gene_n80_p200_seed7_ssrgapsafe_k40\": {\n");
    ints(&mut out, "gapsafe_rejected", &gap_rej);
    out.push_str(",\n");
    ints(&mut out, "rescreen_discards", &gap_refires);
    out.push_str(",\n");
    ints(&mut out, "strong_size", &gap_strong);
    out.push_str("\n  },\n  \"group_synth_n80_G30_W4_seed14_ssrgapsafe_k25\": {\n");
    ints(&mut out, "gapsafe_rejected", &ggap_rej);
    out.push_str(",\n");
    ints(&mut out, "rescreen_discards", &ggap_refires);
    out.push_str("\n  }\n}\n");
    out
}

#[test]
fn screening_power_matches_golden_json() {
    let got = compute_golden();
    let path = golden_path();
    match std::fs::read_to_string(&path) {
        Ok(want) => {
            if want != got {
                let new_path = path.with_extension("json.new");
                std::fs::write(&new_path, &got).expect("write .new golden");
                panic!(
                    "screening-power counts drifted from {} — a screening bound \
                     changed. Fresh counts written to {}; diff them, and update \
                     the golden only if the change is intended.",
                    path.display(),
                    new_path.display()
                );
            }
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("mkdir goldens");
            std::fs::write(&path, &got).expect("bootstrap golden");
            eprintln!(
                "bootstrapped screening-power golden at {} — commit this file",
                path.display()
            );
        }
    }
}
