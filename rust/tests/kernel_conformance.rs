//! Kernel conformance: every SIMD micro-kernel against its scalar
//! reference, across lane-remainder classes, unaligned offsets, and
//! adversarial values — with the dispatcher forced both off and on
//! (`HSSR_SIMD=0|1` in-process via `simd::force`).
//!
//! The contract under test is the one the solver's bit-identity guarantees
//! rest on:
//!
//! * **f64** kernels (`dot`, `axpy`, `axpy_dot`, and the blocked/fused
//!   kernels built on them) are *bit-identical* to the scalar reference at
//!   every dispatch level — same products, same accumulation tree, same
//!   sequential tail, no FMA.
//! * **f32** kernels may re-associate freely; every variant must land
//!   within the proven error bound [`simd::f32_scan_error_bound`], which
//!   holds for any summation order.

use hssr::data::DataSpec;
use hssr::linalg::{blocked, ops, simd};
use hssr::rng::Pcg64;
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path, PathConfig};

use std::sync::Mutex;

/// The dispatch override is process-global; tests that toggle it hold this
/// lock so the default multi-threaded test runner cannot interleave two
/// tests' `force` states. (A stray toggle cannot make the f64 assertions
/// fail — they hold at every level — but it *would* change which f32
/// kernel a dispatched call picks mid-test.)
static SIMD_GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SIMD_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restore the env-derived dispatch level on drop, panics included.
struct ResetOnDrop;
impl Drop for ResetOnDrop {
    fn drop(&mut self) {
        simd::reset();
    }
}

/// Every lane-remainder class for both the 8-lane f64 and 16-lane f32
/// kernels (`n mod 16 ∈ 0..16`), plus blocked/large sizes.
const SIZES: &[usize] = &[
    0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17, 23, 31, 32, 33, 63, 64,
    65, 100, 127, 128, 129, 257, 1000, 1031,
];

fn vecs(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::new(seed);
    (rng.normal_vec(n), rng.normal_vec(n))
}

/// Adversarial f64 inputs: subnormals, ±0.0, sign flips, and mixes of
/// magnitudes far enough apart that any re-association would change the
/// rounding — if a kernel's tree deviates from the reference, these catch
/// it where well-scaled Gaussians might round identically.
fn adversarial(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|i| {
            let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            match i % 7 {
                0 => sign * 1e-310,             // subnormal
                1 => sign * 0.0,                // ±0.0
                2 => sign * 1e30,               // large
                3 => sign * 1e-30,              // tiny normal
                4 => sign * (1.0 + rng.uniform()),
                5 => sign * f64::EPSILON,
                _ => sign * rng.uniform() * 3.0,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// f64: bit-identity at every dispatch level
// ---------------------------------------------------------------------------

#[test]
fn dot_bit_identical_across_levels_and_remainders() {
    let _g = lock();
    let _r = ResetOnDrop;
    for &n in SIZES {
        let (a, b) = vecs(n, 0xD07 + n as u64);
        let want = ops::dot_scalar(&a, &b);
        assert_eq!(simd::dot_lanes(&a, &b).to_bits(), want.to_bits(), "lanes, n={n}");
        for on in [false, true] {
            simd::force(on);
            assert_eq!(
                simd::dot(&a, &b).to_bits(),
                want.to_bits(),
                "dispatched dot, n={n}, simd={on}, level={:?}",
                simd::level()
            );
            assert_eq!(
                ops::dot(&a, &b).to_bits(),
                want.to_bits(),
                "ops::dot, n={n}, simd={on}"
            );
        }
    }
}

#[test]
fn axpy_bit_identical_across_levels_and_remainders() {
    let _g = lock();
    let _r = ResetOnDrop;
    for &n in SIZES {
        let (x, y0) = vecs(n, 0xA10 + n as u64);
        for alpha in [0.0, -1.75, 0.37, 1e-8, -3e12] {
            let mut want = y0.clone();
            ops::axpy_scalar(alpha, &x, &mut want);
            let mut got = y0.clone();
            simd::axpy_lanes(alpha, &x, &mut got);
            assert!(bits_eq(&want, &got), "lanes axpy, n={n}, alpha={alpha}");
            for on in [false, true] {
                simd::force(on);
                let mut got = y0.clone();
                simd::axpy(alpha, &x, &mut got);
                assert!(bits_eq(&want, &got), "dispatched axpy, n={n}, alpha={alpha}, simd={on}");
                let mut got = y0.clone();
                ops::axpy(alpha, &x, &mut got);
                assert!(bits_eq(&want, &got), "ops::axpy, n={n}, alpha={alpha}, simd={on}");
            }
        }
    }
}

#[test]
fn axpy_dot_equals_composition_across_levels() {
    let _g = lock();
    let _r = ResetOnDrop;
    for &n in SIZES {
        let mut rng = Pcg64::new(0xAD07 + n as u64);
        let x = rng.normal_vec(n);
        let w = rng.normal_vec(n);
        let y0 = rng.normal_vec(n);
        let mut yref = y0.clone();
        ops::axpy_scalar(-0.61, &x, &mut yref);
        let want = ops::dot_scalar(&w, &yref);
        for on in [false, true] {
            simd::force(on);
            let mut y = y0.clone();
            let got = simd::axpy_dot(-0.61, &x, &w, &mut y);
            assert!(bits_eq(&yref, &y), "axpy_dot residual, n={n}, simd={on}");
            assert_eq!(got.to_bits(), want.to_bits(), "axpy_dot value, n={n}, simd={on}");
        }
    }
}

#[test]
fn unaligned_offsets_stay_bit_identical() {
    let _g = lock();
    let _r = ResetOnDrop;
    let (a, b) = vecs(1041, 0x0FF5E7);
    for off in 1..9usize {
        let (sa, sb) = (&a[off..], &b[off..]);
        let want = ops::dot_scalar(sa, sb);
        for on in [false, true] {
            simd::force(on);
            assert_eq!(
                simd::dot(sa, sb).to_bits(),
                want.to_bits(),
                "unaligned dot, off={off}, simd={on}"
            );
            let mut yw: Vec<f64> = b[off..].to_vec();
            ops::axpy_scalar(0.93, sa, &mut yw);
            let mut yg: Vec<f64> = b[off..].to_vec();
            simd::axpy(0.93, sa, &mut yg);
            assert!(bits_eq(&yw, &yg), "unaligned axpy, off={off}, simd={on}");
        }
    }
}

#[test]
fn adversarial_values_bit_identical() {
    let _g = lock();
    let _r = ResetOnDrop;
    for &n in &[7usize, 16, 29, 64, 67, 255, 1000] {
        let a = adversarial(n, 0xBAD + n as u64);
        let b = adversarial(n, 0xDAB + n as u64);
        let want = ops::dot_scalar(&a, &b);
        assert_eq!(simd::dot_lanes(&a, &b).to_bits(), want.to_bits(), "lanes, n={n}");
        for on in [false, true] {
            simd::force(on);
            assert_eq!(
                simd::dot(&a, &b).to_bits(),
                want.to_bits(),
                "adversarial dot, n={n}, simd={on}, level={:?}",
                simd::level()
            );
            let mut yw = b.clone();
            ops::axpy_scalar(-1e-300, &a, &mut yw);
            let mut yg = b.clone();
            simd::axpy(-1e-300, &a, &mut yg);
            assert!(bits_eq(&yw, &yg), "adversarial axpy, n={n}, simd={on}");
        }
    }
}

// ---------------------------------------------------------------------------
// f32: every kernel within the proven error bound
// ---------------------------------------------------------------------------

#[test]
fn f32_kernels_within_proven_bound() {
    let _g = lock();
    let _r = ResetOnDrop;
    for &n in SIZES {
        if n == 0 {
            continue;
        }
        let mut rng = Pcg64::new(0xF32 + n as u64);
        let a = rng.normal_vec(n);
        let r = rng.normal_vec(n);
        let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let r32: Vec<f32> = r.iter().map(|&v| v as f32).collect();
        let exact = ops::dot_scalar(&a, &r) / n as f64;
        // The bound is stated for a standardized column (‖x‖ = √n);
        // rescale it for this column's actual norm.
        let bound =
            simd::f32_scan_error_bound(n, ops::nrm2(&r)) * ops::nrm2(&a) / (n as f64).sqrt();
        let mut got = vec![
            ("scalar", simd::dot_f32_scalar(&a32, &r32)),
            ("lanes", simd::dot_f32_lanes(&a32, &r32)),
        ];
        for on in [false, true] {
            simd::force(on);
            got.push(("dispatched", simd::dot_f32(&a32, &r32)));
        }
        for (kernel, g) in got {
            let g = g as f64 / n as f64;
            assert!(
                (g - exact).abs() <= bound,
                "{kernel} f32 dot out of bound at n={n}: |{g} - {exact}| > {bound}"
            );
        }
    }
}

#[test]
fn f32_kernels_handle_subnormals_and_zeros() {
    let _g = lock();
    let _r = ResetOnDrop;
    let n = 103usize;
    let mut rng = Pcg64::new(0x5AB);
    // f32-exact inputs (round-trip through f32) laced with f32 subnormals
    // and ±0.0, so the only error source is the summation itself.
    let a32: Vec<f32> = (0..n)
        .map(|i| match i % 5 {
            0 => 1.0e-41f32,  // subnormal
            1 => -0.0f32,
            2 => -1.0e-41f32, // subnormal, opposite sign
            3 => 0.0f32,
            _ => (rng.uniform() as f32) - 0.5,
        })
        .collect();
    let r32: Vec<f32> = (0..n).map(|_| (rng.uniform() as f32) - 0.5).collect();
    let a: Vec<f64> = a32.iter().map(|&v| v as f64).collect();
    let r: Vec<f64> = r32.iter().map(|&v| v as f64).collect();
    let exact = ops::dot_scalar(&a, &r) / n as f64;
    let bound =
        simd::f32_scan_error_bound(n, ops::nrm2(&r)) * ops::nrm2(&a).max(1e-30) / (n as f64).sqrt()
            + (n as f64) * (f32::MIN_POSITIVE as f64);
    for on in [false, true] {
        simd::force(on);
        let g = simd::dot_f32(&a32, &r32) as f64 / n as f64;
        assert!(
            (g - exact).abs() <= bound,
            "subnormal f32 dot out of bound (simd={on}): |{g} - {exact}| > {bound}"
        );
    }
}

#[test]
fn f32_error_bound_is_monotone_and_positive() {
    let mut prev = 0.0;
    for n in [1usize, 8, 64, 512, 4096] {
        let b = simd::f32_scan_error_bound(n, 1.0);
        assert!(b > 0.0, "bound must be positive at n={n}");
        assert!(b >= prev * 0.1, "bound collapsed at n={n}");
        prev = b;
    }
    // Scales linearly in the residual norm (the η term aside).
    let b1 = simd::f32_scan_error_bound(256, 1.0);
    let b2 = simd::f32_scan_error_bound(256, 2.0);
    assert!(b2 > b1 && b2 < 2.0 * b1 + 1e-30, "bound must scale with r_norm");
}

// ---------------------------------------------------------------------------
// Blocked / fused kernels and the full solver, SIMD off vs on
// ---------------------------------------------------------------------------

#[test]
fn blocked_scan_bit_identical_under_simd_toggle() {
    let _g = lock();
    let _r = ResetOnDrop;
    let ds = DataSpec::synthetic(67, 90, 5).generate(0xB10C);
    simd::force(false);
    let off = blocked::scan_all_vec(&ds.x, &ds.y);
    simd::force(true);
    let on = blocked::scan_all_vec(&ds.x, &ds.y);
    assert!(bits_eq(&off, &on), "blocked scan differs between SIMD off and on");
}

/// The end-to-end conformance statement: a full screened path fit — blocked
/// screening kernels, fused screen/KKT, the CD inner loop — produces
/// bit-identical coefficient paths with SIMD off and on, for a static and
/// a dynamic rule.
#[test]
fn full_fit_bit_identical_under_simd_toggle() {
    let _g = lock();
    let _r = ResetOnDrop;
    let ds = DataSpec::gene_like(70, 140).generate(0x51D);
    for rule in [RuleKind::SsrBedpp, RuleKind::SsrGapSafe] {
        let cfg = PathConfig { rule, n_lambda: 12, tol: 1e-8, ..PathConfig::default() };
        simd::force(false);
        let off = fit_lasso_path(&ds, &cfg).unwrap();
        simd::force(true);
        let on = fit_lasso_path(&ds, &cfg).unwrap();
        assert_eq!(off.betas, on.betas, "{rule:?}: fit differs between SIMD off and on");
        assert_eq!(off.lambdas, on.lambdas, "{rule:?}: λ grid differs");
    }
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}
