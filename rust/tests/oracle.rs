//! Reference-oracle suite: tiny **fixed** datasets whose lasso /
//! elastic-net / group solutions are known in closed form, asserted against
//! every screening strategy and both scan engines (native one-pass kernels
//! and the chunked scan-then-filter engine).
//!
//! The designs are built from ±1 Hadamard columns, so `XᵀX/n = I` exactly
//! and the path solution decouples per unit:
//!
//! * columns: `β_j(λ) = S(z_j, αλ) / (1 + (1−α)λ)` with `z_j = x_jᵀy/n`;
//! * groups (condition (19) holds globally):
//!   `β_g(λ) = (1 − αλ√W_g/‖z_g‖)₊ · z_g / (1 + (1−α)λ)`.
//!
//! Every fitted path is compared coordinate-wise against the closed form
//! and KKT-verified to **1e-8** — deterministic goldens pinning the whole
//! screening stack (rules × engines × penalties) so backend work cannot
//! silently drift. A second family of checks runs the same sweep on small
//! *correlated* problems, where the oracle is the KKT system itself plus
//! agreement with the exact (Basic PCD/GD) baseline.

use hssr::data::chunked::{ChunkedMatrix, ChunkedScanEngine};
use hssr::data::synth::generate_grouped;
use hssr::data::{DataSpec, Dataset, GroupLayout, GroupedDataset};
use hssr::linalg::{ops, DenseMatrix};
use hssr::runtime::{native::NativeEngine, ScanEngine};
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path_with_engine, GroupPathConfig};
use hssr::solver::path::{fit_lasso_path_with_engine, PathConfig};
use hssr::solver::Penalty;

const ORACLE_TOL: f64 = 1e-8;

const COLUMN_RULES: [RuleKind; 8] = [
    RuleKind::BasicPcd,
    RuleKind::ActiveCycling,
    RuleKind::Ssr,
    RuleKind::Sedpp,
    RuleKind::SsrBedpp,
    RuleKind::SsrDome,
    RuleKind::SsrBedppSedpp,
    RuleKind::SsrGapSafe,
];

const GROUP_RULES: [RuleKind; 6] = [
    RuleKind::BasicPcd,
    RuleKind::ActiveCycling,
    RuleKind::Ssr,
    RuleKind::Sedpp,
    RuleKind::SsrBedpp,
    RuleKind::SsrGapSafe,
];

/// Entry `(i, k)` of the 8×8 Sylvester–Hadamard matrix.
fn hadamard8(i: usize, k: usize) -> f64 {
    if (i & k).count_ones() % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Soft threshold.
fn soft(z: f64, t: f64) -> f64 {
    if z > t {
        z - t
    } else if z < -t {
        z + t
    } else {
        0.0
    }
}

/// Build the fixed column-oracle dataset: Hadamard columns 1..=4 of H8
/// (each ⊥ 1, norm² = n = 8) and `y = Σ a_j x_j`, so `x_jᵀy/n = a_j`.
fn hadamard_dataset(a: &[f64]) -> Dataset {
    let n = 8;
    let p = a.len();
    assert!(p <= 7);
    let x = DenseMatrix::from_fn(n, p, |i, j| hadamard8(i, j + 1));
    let mut y = vec![0.0; n];
    for (j, &aj) in a.iter().enumerate() {
        for (i, yi) in y.iter_mut().enumerate() {
            *yi += aj * hadamard8(i, j + 1);
        }
    }
    Dataset {
        x,
        y,
        centers: vec![0.0; p],
        scales: vec![1.0; p],
        name: "hadamard-oracle".into(),
        truth: None,
    }
}

/// The fixed group-oracle dataset: H8 columns 1..=6 in three width-2
/// groups. Condition (19) holds exactly and groups decouple.
fn hadamard_grouped(a: &[f64]) -> GroupedDataset {
    assert_eq!(a.len(), 6);
    let ds = hadamard_dataset(a);
    GroupedDataset {
        x: ds.x,
        y: ds.y,
        layout: GroupLayout::from_sizes(vec![2, 2, 2]),
        back_transforms: vec![vec![1.0, 0.0, 0.0, 1.0]; 3],
        raw_sizes: vec![2, 2, 2],
        name: "hadamard-group-oracle".into(),
        truth: None,
    }
}

/// Column KKT residual check at `(1 + slack)`-free tolerance `tol`:
/// inactive `|x_jᵀr/n| ≤ αλ + tol`, active
/// `x_jᵀr/n = αλ·sign(β_j) + (1−α)λ·β_j ± tol`.
fn assert_column_kkt(ds: &Dataset, beta: &[f64], penalty: Penalty, lam: f64, tol: f64, what: &str) {
    let f = ds.x.matvec(beta);
    let r: Vec<f64> = ds.y.iter().zip(&f).map(|(y, v)| y - v).collect();
    let n = ds.n() as f64;
    let alpha = penalty.alpha();
    for j in 0..ds.p() {
        let z = ops::dot(ds.x.col(j), &r) / n;
        if beta[j] == 0.0 {
            assert!(
                z.abs() <= alpha * lam + tol,
                "{what}: inactive KKT at j={j}: |z|={} > αλ={}",
                z.abs(),
                alpha * lam
            );
        } else {
            let want = alpha * lam * beta[j].signum() + (1.0 - alpha) * lam * beta[j];
            assert!(
                (z - want).abs() <= tol,
                "{what}: active KKT at j={j}: z={z} want {want}"
            );
        }
    }
}

/// Group KKT residual check: inactive `‖X_gᵀr/n‖ ≤ αλ√W_g + tol`, active
/// `X_gᵀr/n = αλ√W_g·β_g/‖β_g‖ + (1−α)λ·β_g ± tol` per coordinate.
fn assert_group_kkt(
    ds: &GroupedDataset,
    beta: &[f64],
    penalty: Penalty,
    lam: f64,
    tol: f64,
    what: &str,
) {
    let f = ds.x.matvec(beta);
    let r: Vec<f64> = ds.y.iter().zip(&f).map(|(y, v)| y - v).collect();
    let n = ds.n() as f64;
    let alpha = penalty.alpha();
    for g in 0..ds.num_groups() {
        let zg: Vec<f64> =
            ds.layout.range(g).map(|j| ops::dot(ds.x.col(j), &r) / n).collect();
        let bg: Vec<f64> = ds.layout.range(g).map(|j| beta[j]).collect();
        let bnorm = ops::nrm2(&bg);
        let w_sqrt = (ds.layout.sizes[g] as f64).sqrt();
        if bnorm == 0.0 {
            let zn = ops::nrm2(&zg);
            assert!(
                zn <= alpha * lam * w_sqrt + tol,
                "{what}: inactive group KKT at g={g}: ‖z‖={zn} > αλ√W={}",
                alpha * lam * w_sqrt
            );
        } else {
            for (i, (&z, &b)) in zg.iter().zip(&bg).enumerate() {
                let want = alpha * lam * w_sqrt * b / bnorm + (1.0 - alpha) * lam * b;
                assert!(
                    (z - want).abs() <= tol,
                    "{what}: active group KKT at g={g} coord {i}: z={z} want {want}"
                );
            }
        }
    }
}

/// Run a closure against both engines (the chunked store wraps the same
/// design so selections must match the native kernels exactly).
fn with_both_engines(x: &DenseMatrix, mut run: impl FnMut(&dyn ScanEngine, &str)) {
    let native = NativeEngine::new();
    run(&native, "native");
    let store = ChunkedMatrix::from_dense(x, 4);
    let chunked = ChunkedScanEngine::new(&store);
    run(&chunked, "chunked");
}

/// Hand-computed lasso / elastic-net paths on the Hadamard design: every
/// rule and both engines must reproduce `S(a_j, αλ)/(1+(1−α)λ)` to 1e-8,
/// KKT-verified.
#[test]
fn column_oracle_closed_form_all_rules_both_engines() {
    let a = [0.9, -0.55, 0.3, 0.1];
    let ds = hadamard_dataset(&a);
    for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha: 0.5 }] {
        let alpha = penalty.alpha();
        let denom_of = |lam: f64| 1.0 + (1.0 - alpha) * lam;
        let lam_max = a.iter().fold(0.0f64, |m, v| m.max(v.abs())) / alpha;
        let lambdas: Vec<f64> =
            [1.0, 0.75, 0.5, 0.3, 0.1].iter().map(|f| f * lam_max).collect();
        for rule in COLUMN_RULES {
            with_both_engines(&ds.x, |engine, ename| {
                let cfg = PathConfig {
                    rule,
                    penalty,
                    lambdas: Some(lambdas.clone()),
                    tol: 1e-12,
                    ..PathConfig::default()
                };
                let fit = fit_lasso_path_with_engine(&ds, &cfg, engine).unwrap();
                assert!(
                    (fit.lambda_max - lam_max).abs() < 1e-10,
                    "{rule:?}/{ename}/{penalty:?}: λmax {} want {lam_max}",
                    fit.lambda_max
                );
                for (k, &lam) in fit.lambdas.iter().enumerate() {
                    let beta = fit.beta_dense(k);
                    for (j, &aj) in a.iter().enumerate() {
                        let want = soft(aj, alpha * lam) / denom_of(lam);
                        assert!(
                            (beta[j] - want).abs() <= ORACLE_TOL,
                            "{rule:?}/{ename}/{penalty:?}: β[{j}](λ#{k})={} want {want}",
                            beta[j]
                        );
                    }
                    assert_column_kkt(
                        &ds,
                        &beta,
                        penalty,
                        lam,
                        ORACLE_TOL,
                        &format!("{rule:?}/{ename}/{penalty:?} λ#{k}"),
                    );
                }
            });
        }
    }
}

/// Hand-computed group lasso / group elastic-net paths on the grouped
/// Hadamard design: every group rule and both engines must reproduce the
/// multivariate soft threshold to 1e-8, KKT-verified.
#[test]
fn group_oracle_closed_form_all_rules_both_engines() {
    let a = [0.8, 0.6, 0.3, -0.4, 0.1, 0.05];
    let ds = hadamard_grouped(&a);
    let znorms: Vec<f64> = (0..3)
        .map(|g| (a[2 * g] * a[2 * g] + a[2 * g + 1] * a[2 * g + 1]).sqrt())
        .collect();
    let w_sqrt = 2.0f64.sqrt();
    for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha: 0.6 }] {
        let alpha = penalty.alpha();
        let lam_max = znorms.iter().fold(0.0f64, |m, &v| m.max(v)) / (alpha * w_sqrt);
        let lambdas: Vec<f64> =
            [1.0, 0.75, 0.5, 0.3, 0.1].iter().map(|f| f * lam_max).collect();
        for rule in GROUP_RULES {
            with_both_engines(&ds.x, |engine, ename| {
                let cfg = GroupPathConfig {
                    rule,
                    penalty,
                    lambdas: Some(lambdas.clone()),
                    tol: 1e-12,
                    ..GroupPathConfig::default()
                };
                let fit = fit_group_path_with_engine(&ds, &cfg, engine).unwrap();
                assert!(
                    (fit.lambda_max - lam_max).abs() < 1e-10,
                    "{rule:?}/{ename}/{penalty:?}: group λmax {} want {lam_max}",
                    fit.lambda_max
                );
                for (k, &lam) in fit.lambdas.iter().enumerate() {
                    let beta = fit.beta_dense(k);
                    for g in 0..3 {
                        let thresh = alpha * lam * w_sqrt;
                        let scale = if znorms[g] > thresh {
                            (1.0 - thresh / znorms[g]) / (1.0 + (1.0 - alpha) * lam)
                        } else {
                            0.0
                        };
                        for dj in 0..2 {
                            let want = scale * a[2 * g + dj];
                            let got = beta[2 * g + dj];
                            assert!(
                                (got - want).abs() <= ORACLE_TOL,
                                "{rule:?}/{ename}/{penalty:?}: group β[{g}.{dj}](λ#{k})={got} want {want}"
                            );
                        }
                    }
                    assert_group_kkt(
                        &ds,
                        &beta,
                        penalty,
                        lam,
                        ORACLE_TOL,
                        &format!("{rule:?}/{ename}/{penalty:?} λ#{k}"),
                    );
                }
            });
        }
    }
}

/// Duality-gap oracle on the Hadamard design: at the closed-form solution
/// `β_j(λ) = S(a_j, αλ)/(1+(1−α)λ)` the gap of [`quadratic_ball`] must be
/// (numerically) zero, and at deliberately suboptimal points it must be
/// strictly positive — for the lasso, the elastic net, and the grouped
/// form of the same design.
#[test]
fn duality_gap_matches_hadamard_closed_form() {
    use hssr::solver::duality::quadratic_ball;
    let a = [0.9, -0.55, 0.3, 0.1];
    let ds = hadamard_dataset(&a);
    let n = ds.n() as f64;
    for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha: 0.5 }] {
        let alpha = penalty.alpha();
        let ridge_of = |lam: f64| (1.0 - alpha) * lam;
        let lam_max = a.iter().fold(0.0f64, |m, v| m.max(v.abs())) / alpha;
        for frac in [1.0, 0.75, 0.5, 0.2] {
            let lam = frac * lam_max;
            // closed-form solution and its residual
            let beta: Vec<f64> = a
                .iter()
                .map(|&aj| soft(aj, alpha * lam) / (1.0 + ridge_of(lam)))
                .collect();
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let l1: f64 = beta.iter().map(|b| b.abs()).sum();
            let sq: f64 = beta.iter().map(|b| b * b).sum();
            // z̃_j = x_jᵀr/n − (1−α)λβ_j; on this orthonormal design
            // x_jᵀr/n = a_j − β_j·(1 + ridge·…) decouples exactly.
            let feas = (0..4).fold(0.0f64, |m, j| {
                let z = ops::dot(ds.x.col(j), &r) / n;
                m.max((z - ridge_of(lam) * beta[j]).abs())
            });
            let ball = quadratic_ball(&ds.y, &r, sq, l1, feas, lam, penalty);
            assert!(
                ball.gap <= 1e-12,
                "{penalty:?} frac={frac}: gap {} at the closed-form optimum",
                ball.gap
            );
            assert!((ball.scaling - 1.0).abs() < 1e-9, "{penalty:?}: scaling");
            // a suboptimal point (β = 0 at λ < λmax) has a positive gap
            if frac < 1.0 {
                let zball =
                    quadratic_ball(&ds.y, &ds.y, 0.0, 0.0, alpha * lam_max, lam, penalty);
                assert!(zball.gap > 1e-6, "{penalty:?} frac={frac}: zero-β gap");
                assert!(zball.rho > 0.0);
            }
        }
    }

    // Grouped form: the multivariate soft threshold is the optimum.
    let ag = [0.8, 0.6, 0.3, -0.4, 0.1, 0.05];
    let gds = hadamard_grouped(&ag);
    let w_sqrt = 2.0f64.sqrt();
    let znorms: Vec<f64> = (0..3)
        .map(|g| (ag[2 * g] * ag[2 * g] + ag[2 * g + 1] * ag[2 * g + 1]).sqrt())
        .collect();
    let lam_max = znorms.iter().fold(0.0f64, |m, &v| m.max(v)) / w_sqrt;
    for frac in [0.8, 0.4] {
        let lam = frac * lam_max;
        let mut beta = vec![0.0; 6];
        for g in 0..3 {
            let thresh = lam * w_sqrt;
            let scale =
                if znorms[g] > thresh { 1.0 - thresh / znorms[g] } else { 0.0 };
            beta[2 * g] = scale * ag[2 * g];
            beta[2 * g + 1] = scale * ag[2 * g + 1];
        }
        let xb = gds.x.matvec(&beta);
        let r: Vec<f64> = gds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let nf = gds.n() as f64;
        let pen: f64 = (0..3)
            .map(|g| {
                w_sqrt
                    * (beta[2 * g] * beta[2 * g] + beta[2 * g + 1] * beta[2 * g + 1]).sqrt()
            })
            .sum();
        let feas = (0..3).fold(0.0f64, |m, g| {
            let z0 = ops::dot(gds.x.col(2 * g), &r) / nf;
            let z1 = ops::dot(gds.x.col(2 * g + 1), &r) / nf;
            m.max((z0 * z0 + z1 * z1).sqrt() / w_sqrt)
        });
        let ball = hssr::solver::duality::quadratic_ball(
            &gds.y,
            &r,
            beta.iter().map(|b| b * b).sum(),
            pen,
            feas,
            lam,
            Penalty::Lasso,
        );
        assert!(ball.gap <= 1e-12, "group frac={frac}: gap {}", ball.gap);
    }
}

/// Correlated-design oracle (columns): the KKT system is the reference.
/// Every rule × engine × penalty must satisfy KKT and agree with the exact
/// Basic PCD baseline.
#[test]
fn column_oracle_correlated_kkt_and_baseline_agreement() {
    let ds = DataSpec::gene_like(60, 120).generate(33);
    for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha: 0.7 }] {
        let base_cfg = PathConfig {
            rule: RuleKind::BasicPcd,
            penalty,
            n_lambda: 12,
            tol: 1e-12,
            ..PathConfig::default()
        };
        let base = fit_lasso_path_with_engine(&ds, &base_cfg, &NativeEngine::new()).unwrap();
        for rule in COLUMN_RULES {
            with_both_engines(&ds.x, |engine, ename| {
                let cfg = PathConfig { rule, ..base_cfg.clone() };
                let fit = fit_lasso_path_with_engine(&ds, &cfg, engine).unwrap();
                for (k, &lam) in fit.lambdas.iter().enumerate() {
                    let beta = fit.beta_dense(k);
                    let bref = base.beta_dense(k);
                    for j in 0..ds.p() {
                        assert!(
                            (beta[j] - bref[j]).abs() < 1e-7,
                            "{rule:?}/{ename}/{penalty:?}: β[{j}](λ#{k}) deviates from exact"
                        );
                    }
                    assert_column_kkt(
                        &ds,
                        &beta,
                        penalty,
                        lam,
                        1e-6,
                        &format!("{rule:?}/{ename}/{penalty:?} λ#{k}"),
                    );
                }
            });
        }
    }
}

/// Correlated-design oracle (groups): KKT + agreement with exact Basic GD,
/// for the group lasso and the group elastic net.
#[test]
fn group_oracle_correlated_kkt_and_baseline_agreement() {
    let ds = generate_grouped(60, 12, 3, 3, 34);
    for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha: 0.6 }] {
        let base_cfg = GroupPathConfig {
            rule: RuleKind::BasicPcd,
            penalty,
            n_lambda: 12,
            tol: 1e-12,
            ..GroupPathConfig::default()
        };
        let base =
            fit_group_path_with_engine(&ds, &base_cfg, &NativeEngine::new()).unwrap();
        for rule in GROUP_RULES {
            with_both_engines(&ds.x, |engine, ename| {
                let cfg = GroupPathConfig { rule, ..base_cfg.clone() };
                let fit = fit_group_path_with_engine(&ds, &cfg, engine).unwrap();
                for (k, &lam) in fit.lambdas.iter().enumerate() {
                    let beta = fit.beta_dense(k);
                    let bref = base.beta_dense(k);
                    for j in 0..ds.p() {
                        assert!(
                            (beta[j] - bref[j]).abs() < 1e-7,
                            "{rule:?}/{ename}/{penalty:?}: group β[{j}](λ#{k}) deviates"
                        );
                    }
                    assert_group_kkt(
                        &ds,
                        &beta,
                        penalty,
                        lam,
                        1e-6,
                        &format!("{rule:?}/{ename}/{penalty:?} λ#{k}"),
                    );
                }
            });
        }
    }
}
