//! Serve-mode and CV engine-routing pins: many concurrent λ-paths
//! multiplexed onto **one** shared column store must be bit-identical to
//! standalone fits while the shared chunk cache stays inside its budget
//! and records cross-fit hits; k-fold CV routed out-of-core must stream
//! fold spills (never k dense in-flight fold copies) and reproduce the
//! in-memory route bitwise, with fold failures surfaced as typed errors.

use std::sync::Arc;

use hssr::coordinator::cv::cv_lasso_routed;
use hssr::coordinator::serve::FitService;
use hssr::data::store::{write_dataset, ColumnStore};
use hssr::data::DataSpec;
use hssr::error::HssrError;
use hssr::runtime::ooc::OocEngine;
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path_store, PathConfig};
use hssr::solver::Penalty;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("hssr_serve_cv_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn serve_cfg(rule: RuleKind) -> PathConfig {
    PathConfig {
        rule,
        n_lambda: 12,
        lambda_min_ratio: 0.15,
        tol: 1e-7,
        ..PathConfig::default()
    }
}

/// The tentpole pin: a concurrent batch over one shared store/cache is
/// bit-identical to standalone fits of the same configs, the shared
/// cache's peak resident bytes never outgrow its budget even with
/// multiple fits pinning solver chunks, and sharing is measurable as
/// cross-fit cache hits.
#[test]
fn concurrent_fits_share_one_bounded_cache_bit_identically() {
    let ds = DataSpec::gene_like(60, 200).generate(17);
    let path = tmp("serve.store");
    let chunk = 16;
    write_dataset(&ds, chunk, &path).unwrap();
    let budget = 6 * chunk * ds.n() * 8; // 6 chunks ≪ 200 columns
    let engine = OocEngine::from_store(ColumnStore::open(&path, budget).unwrap());
    let svc = FitService::new(engine.shared_store(), 2);

    let rules = [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe];
    let cfgs: Vec<PathConfig> =
        (0..6).map(|i| serve_cfg(rules[i % rules.len()])).collect();
    let out = svc.run_batch(&cfgs).unwrap();
    assert_eq!(out.len(), 6);

    for (cfg, resp) in cfgs.iter().zip(&out) {
        assert!(resp.fit.error.is_none(), "{:?} degraded in serve mode", cfg.rule);
        let solo = Arc::new(ColumnStore::open(&path, budget).unwrap());
        let (want, _) = fit_lasso_path_store(solo, cfg, None).unwrap();
        assert_eq!(resp.fit.lambdas, want.lambdas, "{:?}: λ grid differs", cfg.rule);
        assert_eq!(resp.fit.betas, want.betas, "{:?}: served βs differ", cfg.rule);
    }

    assert!(
        svc.cross_fit_hits() > 0,
        "concurrent fits over one cache never hit each other's chunks"
    );
    assert!(svc.peak_in_flight() <= 2, "admission bound violated");
    assert!(
        svc.store().counters().peak_resident() <= budget as u64,
        "shared cache outgrew its budget: {} > {budget}",
        svc.store().counters().peak_resident()
    );
    let _ = std::fs::remove_file(&path);
}

/// Warm-start service across requests: a repeated config key with an
/// extended λ grid resumes from the registry and stays bit-identical to
/// a cold fit over the extended grid.
#[test]
fn serve_warm_start_resume_is_bit_identical() {
    let ds = DataSpec::synthetic(40, 60, 4).generate(23);
    let path = tmp("warm.store");
    write_dataset(&ds, 16, &path).unwrap();
    let budget = 1 << 20;
    let engine = OocEngine::from_store(ColumnStore::open(&path, budget).unwrap());
    let svc = FitService::new(engine.shared_store(), 2);

    let mut cfg = serve_cfg(RuleKind::SsrBedpp);
    cfg.n_lambda = 8;
    let first = svc.run_one(&cfg).unwrap();
    assert!(!first.warm_hit);
    let mut grid = first.fit.lambdas.clone();
    grid.push(grid.last().unwrap() * 0.6);
    grid.push(grid.last().unwrap() * 0.6);
    cfg.lambdas = Some(grid.clone());
    let second = svc.run_one(&cfg).unwrap();
    assert!(second.warm_hit, "registry never offered the completed prefix");
    let k = first.fit.betas.len();
    assert_eq!(&second.fit.betas[..k], &first.fit.betas[..]);

    let solo = Arc::new(ColumnStore::open(&path, budget).unwrap());
    let (cold, _) = fit_lasso_path_store(solo, &cfg, None).unwrap();
    assert_eq!(second.fit.betas, cold.betas, "warm resume deviates from cold fit");
    let _ = std::fs::remove_file(&path);
}

/// CV engine routing: the out-of-core route (streamed fold spills) must
/// reproduce the in-memory route bit for bit — selections included.
#[test]
fn ooc_cv_route_matches_dense_route_bitwise() {
    let ds = DataSpec::synthetic(60, 50, 5).generate(31);
    let cfg = PathConfig { n_lambda: 15, tol: 1e-6, ..PathConfig::default() };
    let dense = cv_lasso_routed(&ds, &cfg, 5, 7, false).unwrap();
    let ooc = cv_lasso_routed(&ds, &cfg, 5, 7, true).unwrap();
    assert_eq!(dense.lambdas, ooc.lambdas);
    assert_eq!(dense.cv_mean, ooc.cv_mean, "fold MSE means diverge across routes");
    assert_eq!(dense.cv_se, ooc.cv_se);
    assert_eq!((dense.idx_min, dense.idx_1se), (ooc.idx_min, ooc.idx_1se));
}

/// A failing fold fit must surface as a typed CV error carrying the fold
/// index — on both engine routes, with no panic.
#[test]
fn failing_fold_is_a_typed_cv_error_on_both_routes() {
    let ds = DataSpec::synthetic(40, 30, 3).generate(5);
    let cfg = PathConfig {
        penalty: Penalty::ElasticNet { alpha: 0.0 },
        n_lambda: 8,
        ..PathConfig::default()
    };
    for ooc in [false, true] {
        match cv_lasso_routed(&ds, &cfg, 4, 3, ooc) {
            Err(HssrError::Cv { fold: Some(f), message }) => {
                assert!(f < 4, "fold index out of range (route ooc={ooc})");
                assert!(!message.is_empty());
            }
            other => panic!("expected typed Cv error on route ooc={ooc}, got {other:?}"),
        }
    }
}
