//! End-to-end behavioural tests: CLI-level config plumbing, the coordinator
//! sweep machinery, failure injection, and cross-layer consistency checks
//! that the benches rely on.

use hssr::coordinator::config::{parse_rule, Config};
use hssr::coordinator::metrics::screening_power;
use hssr::coordinator::{run_method_sweep, speedup_table, timing_table};
use hssr::data::DataSpec;
use hssr::error::HssrError;
use hssr::screening::RuleKind;
use hssr::solver::path::{fit_lasso_path, PathConfig};

#[test]
fn coordinator_sweep_produces_full_grid() {
    let specs = [DataSpec::synthetic(50, 60, 4), DataSpec::gene_like(50, 60)];
    let methods = [RuleKind::BasicPcd, RuleKind::Ssr, RuleKind::SsrBedpp];
    let cfg = PathConfig { n_lambda: 12, ..PathConfig::default() };
    let cells = run_method_sweep(&specs, &methods, 2, &cfg, 1).unwrap();
    assert_eq!(cells.len(), 6);
    let t = timing_table("x", &cells);
    assert_eq!(t.rows.len(), 3);
    assert_eq!(t.headers.len(), 3);
    let s = speedup_table("y", &cells, RuleKind::BasicPcd);
    // Basic PCD speedup vs itself is 1.0x
    assert_eq!(s.rows[0][1], "1.0x");
}

#[test]
fn screening_power_curves_complete() {
    let ds = DataSpec::gene_like(60, 120).generate(2);
    let curves =
        screening_power(&ds, &PathConfig { n_lambda: 15, ..PathConfig::default() }).unwrap();
    assert_eq!(curves.len(), 6); // Dome, BEDPP, SEDPP, SSR, SSR-BEDPP, SSR-GapSafe
    for c in &curves {
        assert_eq!(c.lambda_frac.len(), 15);
        assert!(c.discarded_frac.iter().all(|&d| (0.0..=1.0).contains(&d)), "{}", c.rule);
    }
}

#[test]
fn nonconvergence_error_propagates() {
    let ds = DataSpec::synthetic(40, 30, 3).generate(3);
    let cfg = PathConfig {
        rule: RuleKind::BasicPcd,
        max_iter: 1,
        tol: 0.0,
        n_lambda: 5,
        ..PathConfig::default()
    };
    match fit_lasso_path(&ds, &cfg) {
        Err(HssrError::NoConvergence { max_iter: 1, .. }) => {}
        other => panic!("expected NoConvergence, got {other:?}"),
    }
}

#[test]
fn invalid_penalty_rejected() {
    let ds = DataSpec::synthetic(30, 20, 2).generate(4);
    let cfg = PathConfig {
        penalty: hssr::solver::Penalty::ElasticNet { alpha: -0.5 },
        ..PathConfig::default()
    };
    assert!(matches!(fit_lasso_path(&ds, &cfg), Err(HssrError::Config(_))));
}

#[test]
fn config_cli_round_trip() {
    let mut cfg = Config::from_str_body("rule = ssr\nn = 100").unwrap();
    cfg.apply_args(["--rule", "ssr-bedpp", "--nlambda=50"].map(String::from)).unwrap();
    assert_eq!(parse_rule(&cfg.get_str("rule", "")), Some(RuleKind::SsrBedpp));
    assert_eq!(cfg.get_parse("nlambda", 0usize).unwrap(), 50);
    assert_eq!(cfg.get_parse("n", 0usize).unwrap(), 100);
}

/// The metrics that benches aggregate must be internally consistent.
#[test]
fn metrics_invariants_hold() {
    let ds = DataSpec::gene_like(100, 300).generate(5);
    for rule in [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::Sedpp, RuleKind::SsrBedppSedpp] {
        let fit = fit_lasso_path(
            &ds,
            &PathConfig { rule, n_lambda: 25, ..PathConfig::default() },
        )
        .unwrap();
        for (k, m) in fit.metrics.iter().enumerate() {
            assert!(m.safe_size <= ds.p(), "{rule:?} λ#{k}");
            assert!(m.strong_size <= m.safe_size, "{rule:?} λ#{k}: |H| > |S|");
            assert!(m.nonzero <= m.strong_size, "{rule:?} λ#{k}: nnz > |H|");
            assert!(m.kkt_checked <= ds.p(), "{rule:?} λ#{k}");
            assert_eq!(m.nonzero, fit.betas[k].len());
        }
        // λ grid is strictly decreasing and spans the configured range
        for w in fit.lambdas.windows(2) {
            assert!(w[0] > w[1]);
        }
    }
}

/// Support sizes must agree across rules λ-by-λ (stronger than coefficient
/// agreement tolerance: the *sets* match).
#[test]
fn support_sets_identical_across_rules() {
    let ds = DataSpec::synthetic(80, 120, 6).generate(6);
    let cfg = PathConfig { n_lambda: 20, tol: 1e-10, ..PathConfig::default() };
    let base = fit_lasso_path(&ds, &PathConfig { rule: RuleKind::BasicPcd, ..cfg.clone() })
        .unwrap();
    for rule in [RuleKind::SsrBedpp, RuleKind::Sedpp] {
        let fit = fit_lasso_path(&ds, &PathConfig { rule, ..cfg.clone() }).unwrap();
        for k in 0..base.lambdas.len() {
            let sa: Vec<usize> = base.betas[k]
                .iter()
                .filter(|&&(_, v)| v.abs() > 1e-8)
                .map(|&(j, _)| j)
                .collect();
            let sb: Vec<usize> = fit.betas[k]
                .iter()
                .filter(|&&(_, v)| v.abs() > 1e-8)
                .map(|&(j, _)| j)
                .collect();
            assert_eq!(sa, sb, "{rule:?} support differs at λ#{k}");
        }
    }
}
