//! Group-lasso integration: solution equivalence across strategies on the
//! realistic grouped workloads (GRVS-like, GENE-SPLINE-like), rank-deficient
//! groups, and back-transform correctness.

use hssr::data::synth::generate_grouped;
use hssr::data::{bspline, realistic, DataSpec};
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path, GroupPathConfig, GroupPathFit};

const METHODS: [RuleKind; 4] =
    [RuleKind::ActiveCycling, RuleKind::Ssr, RuleKind::Sedpp, RuleKind::SsrBedpp];

fn max_beta_diff(a: &GroupPathFit, b: &GroupPathFit) -> f64 {
    let mut worst = 0.0f64;
    for k in 0..a.lambdas.len() {
        let da = a.beta_dense(k);
        let db = b.beta_dense(k);
        for j in 0..da.len() {
            worst = worst.max((da[j] - db[j]).abs());
        }
    }
    worst
}

fn assert_agree(ds: &hssr::data::GroupedDataset, n_lambda: usize) {
    for penalty in
        [hssr::solver::Penalty::Lasso, hssr::solver::Penalty::ElasticNet { alpha: 0.7 }]
    {
        let cfg = GroupPathConfig { penalty, n_lambda, tol: 1e-9, ..Default::default() };
        let base =
            fit_group_path(ds, &GroupPathConfig { rule: RuleKind::BasicPcd, ..cfg.clone() })
                .expect("baseline");
        for rule in METHODS {
            let fit =
                fit_group_path(ds, &GroupPathConfig { rule, ..cfg.clone() }).expect("fit");
            let d = max_beta_diff(&base, &fit);
            assert!(d < 1e-5, "{rule:?}/{penalty:?} deviates by {d} on {}", ds.name);
        }
    }
}

#[test]
fn grvs_like_equivalence() {
    let ds = realistic::grvs_like(150, 40, 8, 6, 1);
    assert_agree(&ds, 30);
}

#[test]
fn gene_spline_equivalence() {
    let base = DataSpec::gene_like(120, 60).generate(2);
    let ds = bspline::expand_dataset(&base, 5);
    assert_agree(&ds, 30);
}

#[test]
fn synthetic_group_equivalence_various_widths() {
    for w in [1usize, 3, 10] {
        let ds = generate_grouped(100, 20, w, 4, 3 + w as u64);
        assert_agree(&ds, 25);
    }
}

#[test]
fn rank_deficient_groups_are_handled() {
    // GRVS-like data with rare variants regularly produces monomorphic
    // (constant) columns → rank-deficient groups after standardization.
    let ds = realistic::grvs_like(100, 30, 10, 5, 4);
    let total_raw: usize = ds.raw_sizes.iter().sum();
    assert!(
        ds.p() <= total_raw,
        "orthonormalization must not grow the design"
    );
    // fit succeeds and the KKT conditions hold at λmin
    let fit = fit_group_path(
        &ds,
        &GroupPathConfig { rule: RuleKind::SsrBedpp, n_lambda: 20, tol: 1e-9, ..Default::default() },
    )
    .unwrap();
    let k = fit.lambdas.len() - 1;
    let beta = fit.beta_dense(k);
    let xb = ds.x.matvec(&beta);
    let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
    let n = ds.n() as f64;
    for g in 0..ds.num_groups() {
        let active = ds.layout.range(g).any(|j| beta[j] != 0.0);
        if !active {
            let mut ss = 0.0;
            for j in ds.layout.range(g) {
                let d = hssr::linalg::ops::dot(ds.x.col(j), &r) / n;
                ss += d * d;
            }
            let w_sqrt = (ds.layout.sizes[g] as f64).sqrt();
            assert!(ss.sqrt() <= fit.lambdas[k] * w_sqrt * (1.0 + 1e-3) + 1e-8);
        }
    }
}

#[test]
fn group_sizes_weight_the_penalty() {
    // A group of width 9 needs ‖X_gᵀy/n‖ ≥ 3λ to enter; width 1 needs λ.
    // Construct a layout with mixed widths and check entry ordering is
    // governed by ‖X_gᵀy‖/(n√W_g) — i.e. λmax is attained by the right group.
    let ds = generate_grouped(120, 12, 4, 3, 9);
    let ctx = hssr::screening::group::GroupSafeContext::build(
        &ds.x,
        &ds.y,
        &ds.layout,
        hssr::solver::Penalty::Lasso,
    );
    let n = ds.n() as f64;
    for g in 0..ds.num_groups() {
        let crit = ctx.group_xty_sq[g].sqrt() / (n * (ds.layout.sizes[g] as f64).sqrt());
        assert!(crit <= ctx.lambda_max + 1e-12);
    }
    // the star group attains it
    let star_crit = ctx.group_xty_sq[ctx.star].sqrt()
        / (n * (ds.layout.sizes[ctx.star] as f64).sqrt());
    assert!((star_crit - ctx.lambda_max).abs() < 1e-12);
}

#[test]
fn fitted_values_invariant_under_back_transform() {
    // Xβ̂ in the orthonormal basis equals X_raw·(T β̂) per group — the
    // round-trip a user needs to interpret coefficients.
    let base = DataSpec::gene_like(90, 30).generate(5);
    let ds = bspline::expand_dataset(&base, 5);
    let fit = fit_group_path(
        &ds,
        &GroupPathConfig { rule: RuleKind::SsrBedpp, n_lambda: 15, ..Default::default() },
    )
    .unwrap();
    let beta = fit.beta_dense(fit.lambdas.len() - 1);
    // reconstruct fitted values group-by-group through the back transform
    // and the raw spline design
    let mut cols_raw: Vec<Vec<f64>> = Vec::new();
    for j in 0..base.p() {
        cols_raw.extend(bspline::expand_column(base.x.col(j), 5));
    }
    // standardize raw expansion the same way expand_dataset did
    let mut x_raw = hssr::linalg::DenseMatrix::from_columns(&cols_raw).unwrap();
    let mut y_tmp = base.y.clone();
    hssr::data::standardize::standardize_in_place(&mut x_raw, &mut y_tmp);
    let fit_ortho = ds.x.matvec(&beta);
    let mut fit_raw = vec![0.0; ds.n()];
    for g in 0..ds.num_groups() {
        let t = &ds.back_transforms[g];
        let w_raw = ds.raw_sizes[g];
        let mut braw = vec![0.0; w_raw];
        for (k, j) in ds.layout.range(g).enumerate() {
            for a in 0..w_raw {
                braw[a] += t[k * w_raw + a] * beta[j];
            }
        }
        for (a, &b) in braw.iter().enumerate() {
            if b != 0.0 {
                hssr::linalg::ops::axpy(b, x_raw.col(g * w_raw + a), &mut fit_raw);
            }
        }
    }
    for i in 0..ds.n() {
        assert!(
            (fit_ortho[i] - fit_raw[i]).abs() < 1e-6,
            "fitted value mismatch at obs {i}"
        );
    }
}
