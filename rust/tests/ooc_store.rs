//! The out-of-core storage subsystem, pinned end to end: store round-trips
//! are exact, conversion matches the in-memory CSV loader, and — the
//! acceptance bar — an `OocEngine` fit with a cache budget far below the
//! matrix footprint produces **bit-identical** selections and coefficients
//! to the native engine for all three families × every applicable rule,
//! with the store's fetch counters equal to the path's own accounting and
//! peak resident bytes bounded by the budget.

use hssr::data::store::{convert_csv, write_dataset, ColumnStore};
use hssr::data::synth::generate_grouped;
use hssr::data::DataSpec;
use hssr::prop::{check, PropConfig};
use hssr::prop_assert;
use hssr::runtime::native::NativeEngine;
use hssr::runtime::ooc::OocEngine;
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path_with_engine, GroupPathConfig};
use hssr::solver::logistic::{
    fit_logistic_path_with_engine, synthetic_logistic, LogisticPathConfig,
};
use hssr::solver::path::{fit_lasso_path_with_engine, PathConfig};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("hssr_ooc_store_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// dense → store → dense is byte-exact, across random shapes and chunk
/// widths (including widths that do not divide p, and single-column
/// chunks).
#[test]
fn store_roundtrip_property() {
    check(PropConfig { cases: 8, seed: 0x570E }, |rng, scale| {
        let n = 5 + (rng.below(40) as f64 * scale) as usize;
        let p = 3 + (rng.below(60) as f64 * scale) as usize;
        let chunk = 1 + rng.below(p as u64 + 2) as usize;
        let ds = DataSpec::synthetic(n, p, 2).generate(rng.next_u64());
        let path = tmp(&format!("rt-{n}-{p}-{chunk}.store"));
        write_dataset(&ds, chunk, &path).map_err(|e| e.to_string())?;
        let store = ColumnStore::open(&path, 1 << 16).map_err(|e| e.to_string())?;
        let back = store.to_dataset().map_err(|e| e.to_string())?;
        prop_assert!(
            back.x.as_slice() == ds.x.as_slice(),
            "matrix drifted (n={n}, p={p}, chunk={chunk})"
        );
        prop_assert!(back.y == ds.y, "y drifted");
        prop_assert!(back.centers == ds.centers && back.scales == ds.scales, "stats drifted");
        Ok(())
    });
}

/// CSV → store (streaming Welford standardization) agrees with the
/// in-memory CSV loader to numerical precision.
#[test]
fn convert_csv_matches_load_csv() {
    let csv = tmp("conv.csv");
    let mut body = String::from("y,a,b,c\n# comment line\n");
    let mut rng = hssr::rng::Pcg64::new(11);
    for _ in 0..60 {
        let a = rng.normal() * 3.0 + 1.0;
        let b = rng.normal() * 0.2 - 5.0;
        let c = rng.normal();
        let y = 2.0 * a - b + 0.1 * rng.normal();
        body.push_str(&format!("{y},{a},{b},{c}\n"));
    }
    std::fs::write(&csv, body).unwrap();
    let out = tmp("conv.store");
    let summary = convert_csv(&csv, 2, &out).unwrap();
    assert_eq!((summary.header.n, summary.header.p), (60, 3));
    assert!(!summary.header.standardized, "csv stores raw + read-time transform");
    let store = ColumnStore::open(&out, 1 << 20).unwrap();
    let from_store = store.to_dataset().unwrap();
    let direct = hssr::data::io::load_csv(&csv).unwrap();
    for j in 0..3 {
        assert!(
            (from_store.centers[j] - direct.centers[j]).abs() < 1e-10,
            "center {j} drifted"
        );
        assert!(
            (from_store.scales[j] - direct.scales[j]).abs() < 1e-10,
            "scale {j} drifted"
        );
        for i in 0..60 {
            assert!(
                (from_store.x.get(i, j) - direct.x.get(i, j)).abs() < 1e-10,
                "x[{i},{j}] drifted"
            );
        }
    }
    for i in 0..60 {
        assert!((from_store.y[i] - direct.y[i]).abs() < 1e-10, "y[{i}] drifted");
    }
}

/// Load-time validation at the conversion boundary: constant (zero
/// variance) feature columns and non-finite values are typed errors for
/// both the streaming converter and the in-memory loader — bad data never
/// reaches a store file or a fit.
#[test]
fn convert_csv_rejects_constant_and_nonfinite_columns() {
    let csv = tmp("conv-bad-const.csv");
    std::fs::write(&csv, "y,a,const\n1.0,2.0,7.5\n-1.0,3.0,7.5\n0.5,0.25,7.5\n").unwrap();
    let out = tmp("conv-bad-const.store");
    let err = convert_csv(&csv, 2, &out).unwrap_err();
    assert!(err.to_string().contains("zero variance"), "got {err}");
    assert!(
        hssr::data::io::load_csv(&csv)
            .unwrap_err()
            .to_string()
            .contains("zero variance")
    );
    let csv = tmp("conv-bad-nan.csv");
    std::fs::write(&csv, "y,a,b\n1.0,2.0,3.0\n-1.0,nan,1.0\n0.5,0.25,2.0\n").unwrap();
    let out = tmp("conv-bad-nan.store");
    let err = convert_csv(&csv, 2, &out).unwrap_err();
    assert!(err.to_string().contains("non-finite"), "got {err}");
}

/// The acceptance bar, column family: OOC fits under a one-chunk cache
/// budget (far below the matrix footprint, forcing eviction on every
/// scan) are bit-identical to native for every RuleKind, and the store's
/// fetch counters equal the path's own `cols_scanned` accounting —
/// including SSR-GapSafe, whose in-rule scans are engine-routed.
#[test]
fn ooc_lasso_bit_identical_to_native_under_pressure() {
    let ds = DataSpec::gene_like(70, 180).generate(31);
    let path = tmp("lasso.store");
    let chunk = 16;
    write_dataset(&ds, chunk, &path).unwrap();
    let budget = chunk * ds.n() * 8; // exactly one chunk resident
    assert!(budget < ds.n() * ds.p() * 8, "budget must be below the matrix");
    let native = NativeEngine::new();
    for rule in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::Sedpp,
        RuleKind::SsrBedpp,
        RuleKind::SsrDome,
        RuleKind::SsrBedppSedpp,
        RuleKind::SsrGapSafe,
    ] {
        let cfg = PathConfig { rule, n_lambda: 15, tol: 1e-8, ..PathConfig::default() };
        let ooc = OocEngine::open(&path, budget).unwrap();
        let a = fit_lasso_path_with_engine(&ds, &cfg, &ooc).unwrap();
        let b = fit_lasso_path_with_engine(&ds, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: ooc betas differ from native");
        for (k, (ma, mb)) in a.metrics.iter().zip(b.metrics.iter()).enumerate() {
            assert_eq!(ma.safe_size, mb.safe_size, "{rule:?} |S| at λ#{k}");
            assert_eq!(ma.strong_size, mb.strong_size, "{rule:?} |H| at λ#{k}");
            assert_eq!(ma.violations, mb.violations, "{rule:?} viols at λ#{k}");
        }
        let counters = ooc.store().counters();
        assert_eq!(
            counters.cols_fetched(),
            a.total_cols_scanned(),
            "{rule:?}: store fetches != path accounting"
        );
        assert!(
            counters.peak_resident() <= budget as u64,
            "{rule:?}: peak resident {} exceeded budget {budget}",
            counters.peak_resident()
        );
        if counters.cols_fetched() > 0 {
            assert!(counters.chunk_loads() > 0, "{rule:?}: no real reads happened");
        }
    }
}

/// Group family under the same one-chunk budget: bit-identical paths and
/// exact counter agreement for every supported rule.
#[test]
fn ooc_group_bit_identical_to_native_under_pressure() {
    let gds = generate_grouped(60, 24, 4, 4, 33);
    let path = tmp("group.store");
    let chunk = 8;
    let zeros = vec![0.0; gds.p()];
    let ones = vec![1.0; gds.p()];
    hssr::data::store::write_matrix(&gds.x, &gds.y, &zeros, &ones, true, chunk, &path)
        .unwrap();
    let budget = chunk * gds.n() * 8;
    let native = NativeEngine::new();
    for rule in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::Sedpp,
        RuleKind::SsrBedpp,
        RuleKind::SsrGapSafe,
    ] {
        let cfg =
            GroupPathConfig { rule, n_lambda: 12, tol: 1e-8, ..GroupPathConfig::default() };
        let ooc = OocEngine::open(&path, budget).unwrap();
        let a = fit_group_path_with_engine(&gds, &cfg, &ooc).unwrap();
        let b = fit_group_path_with_engine(&gds, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: ooc group betas differ");
        let counters = ooc.store().counters();
        assert_eq!(
            counters.cols_fetched(),
            a.total_cols_scanned(),
            "{rule:?}: group store fetches != path accounting"
        );
        assert!(counters.peak_resident() <= budget as u64, "{rule:?}: budget exceeded");
    }
}

/// Logistic family: bit-identical paths and intercepts for every
/// supported rule under a one-chunk budget. The constructor's λmax and
/// standardization preamble scans are folded into the first λ's
/// `cols_scanned` by the driver, so the counter check is exact equality —
/// not merely activity.
#[test]
fn ooc_logistic_bit_identical_to_native_under_pressure() {
    let (x, y, _) = synthetic_logistic(80, 60, 4, 35);
    let path = tmp("logit.store");
    let chunk = 8;
    let zeros = vec![0.0; x.ncols()];
    let ones = vec![1.0; x.ncols()];
    hssr::data::store::write_matrix(&x, &y, &zeros, &ones, true, chunk, &path).unwrap();
    let budget = chunk * x.nrows() * 8;
    let native = NativeEngine::new();
    for rule in [
        RuleKind::BasicPcd,
        RuleKind::ActiveCycling,
        RuleKind::Ssr,
        RuleKind::SsrGapSafe,
    ] {
        let cfg = LogisticPathConfig {
            rule,
            n_lambda: 12,
            tol: 1e-8,
            ..LogisticPathConfig::default()
        };
        let ooc = OocEngine::open(&path, budget).unwrap();
        let a = fit_logistic_path_with_engine(&x, &y, &cfg, &ooc).unwrap();
        let b = fit_logistic_path_with_engine(&x, &y, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: ooc logistic betas differ");
        assert_eq!(a.intercepts, b.intercepts, "{rule:?}: intercepts differ");
        let counters = ooc.store().counters();
        assert_eq!(
            counters.cols_fetched(),
            a.total_cols_scanned(),
            "{rule:?}: logistic store fetches != path accounting (preamble)"
        );
        assert!(
            counters.cols_fetched() > 0,
            "{rule:?}: logistic fit never touched the store"
        );
        assert!(
            counters.peak_resident() <= budget as u64,
            "{rule:?}: budget exceeded"
        );
    }
}

/// Randomized engine-independence sweep: OOC ≡ native across random
/// shapes, penalties, and chunk/budget mixes for the headline hybrid and
/// the dynamic rule.
#[test]
fn property_ooc_selects_same_as_native() {
    check(PropConfig { cases: 4, seed: 0x00C5 }, |rng, scale| {
        let n = 30 + (rng.below(40) as f64 * scale) as usize;
        let p = 40 + (rng.below(100) as f64 * scale) as usize;
        let ds = DataSpec::synthetic(n, p, 4).generate(rng.next_u64());
        let chunk = 1 + rng.below(24) as usize;
        let path = tmp(&format!("prop-{n}-{p}-{chunk}.store"));
        write_dataset(&ds, chunk, &path).map_err(|e| e.to_string())?;
        let budget = (1 + rng.below(3) as usize) * chunk * n * 8;
        let native = NativeEngine::new();
        for rule in [RuleKind::SsrBedpp, RuleKind::SsrGapSafe] {
            let cfg = PathConfig { rule, n_lambda: 10, tol: 1e-8, ..PathConfig::default() };
            let ooc = OocEngine::open(&path, budget).map_err(|e| e.to_string())?;
            let a = fit_lasso_path_with_engine(&ds, &cfg, &ooc).map_err(|e| e.to_string())?;
            let b =
                fit_lasso_path_with_engine(&ds, &cfg, &native).map_err(|e| e.to_string())?;
            prop_assert!(
                a.betas == b.betas,
                "{rule:?}: ooc path differs (n={n}, p={p}, chunk={chunk})"
            );
            prop_assert!(
                ooc.store().counters().cols_fetched() == a.total_cols_scanned(),
                "{rule:?}: accounting drift (n={n}, p={p}, chunk={chunk})"
            );
        }
        Ok(())
    });
}
