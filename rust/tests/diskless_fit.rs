//! Fully diskless fits, pinned end to end: the inner solvers (CD, GD, the
//! logistic IRLS loop) consume store-backed column views through the
//! pinned-chunk cursor, so `--engine ooc` no longer materializes the
//! dense design for the solve — and the result is still **bit-identical**
//! to a resident fit for all three families under a one-chunk cache
//! budget. The λ-ahead prefetcher overlaps I/O with the current solve and
//! must never push resident bytes past the budget, stay correct under
//! injected storage faults, and show up in the prefetch counters.

use hssr::data::store::{write_dataset, ColumnStore, FaultInjector, FaultSpec};
use hssr::data::synth::generate_grouped;
use hssr::data::DataSpec;
use hssr::prop::{check, PropConfig};
use hssr::prop_assert;
use hssr::runtime::native::NativeEngine;
use hssr::runtime::ooc::OocEngine;
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path_with_engine, GroupPathConfig};
use hssr::solver::logistic::{
    fit_logistic_path_with_engine, synthetic_logistic, LogisticPathConfig,
};
use hssr::solver::path::{fit_lasso_path_with_engine, PathConfig};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("hssr_diskless_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Gaussian family: with a one-chunk budget the solve itself is served
/// from the store (the `solver_cols` counter proves the inner CD loop ran
/// store-backed, not against a resident matrix), the coefficients are
/// bit-identical to a native fit, and scan accounting stays exact.
#[test]
fn gaussian_pinned_fit_is_diskless_and_bit_identical() {
    let ds = DataSpec::gene_like(70, 180).generate(41);
    let path = tmp("dl-lasso.store");
    let chunk = 16;
    write_dataset(&ds, chunk, &path).unwrap();
    let budget = chunk * ds.n() * 8; // exactly one chunk resident
    let native = NativeEngine::new();
    for rule in [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe] {
        let cfg = PathConfig { rule, n_lambda: 15, tol: 1e-8, ..PathConfig::default() };
        let ooc = OocEngine::open(&path, budget).unwrap();
        let a = fit_lasso_path_with_engine(&ds, &cfg, &ooc).unwrap();
        let b = fit_lasso_path_with_engine(&ds, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: pinned fit differs from resident fit");
        let c = ooc.store().counters();
        assert!(c.solver_cols() > 0, "{rule:?}: the solve never used the store");
        assert_eq!(
            c.cols_fetched(),
            a.total_cols_scanned(),
            "{rule:?}: solver traffic leaked into scan accounting"
        );
        assert!(
            c.peak_resident() <= budget as u64,
            "{rule:?}: peak resident {} exceeded budget {budget} with pins",
            c.peak_resident()
        );
    }
}

/// Group family: the GD inner loop walks store-backed group columns
/// through the same pinned cursor, bit-identically.
#[test]
fn group_pinned_fit_is_diskless_and_bit_identical() {
    let gds = generate_grouped(60, 24, 4, 4, 43);
    let path = tmp("dl-group.store");
    let chunk = 8;
    let zeros = vec![0.0; gds.p()];
    let ones = vec![1.0; gds.p()];
    hssr::data::store::write_matrix(&gds.x, &gds.y, &zeros, &ones, true, chunk, &path)
        .unwrap();
    let budget = chunk * gds.n() * 8;
    let native = NativeEngine::new();
    for rule in [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe] {
        let cfg =
            GroupPathConfig { rule, n_lambda: 12, tol: 1e-8, ..GroupPathConfig::default() };
        let ooc = OocEngine::open(&path, budget).unwrap();
        let a = fit_group_path_with_engine(&gds, &cfg, &ooc).unwrap();
        let b = fit_group_path_with_engine(&gds, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: pinned group fit differs");
        let c = ooc.store().counters();
        assert!(c.solver_cols() > 0, "{rule:?}: group solve never used the store");
        assert!(c.peak_resident() <= budget as u64, "{rule:?}: budget exceeded");
    }
}

/// Logistic family: the IRLS loop (curvature refresh, weighted CD, and
/// the η refresh) runs store-backed and bit-identical.
#[test]
fn logistic_pinned_fit_is_diskless_and_bit_identical() {
    let (x, y, _) = synthetic_logistic(80, 60, 4, 45);
    let path = tmp("dl-logit.store");
    let chunk = 8;
    let zeros = vec![0.0; x.ncols()];
    let ones = vec![1.0; x.ncols()];
    hssr::data::store::write_matrix(&x, &y, &zeros, &ones, true, chunk, &path).unwrap();
    let budget = chunk * x.nrows() * 8;
    let native = NativeEngine::new();
    for rule in [RuleKind::Ssr, RuleKind::SsrGapSafe] {
        let cfg = LogisticPathConfig {
            rule,
            n_lambda: 12,
            tol: 1e-8,
            ..LogisticPathConfig::default()
        };
        let ooc = OocEngine::open(&path, budget).unwrap();
        let a = fit_logistic_path_with_engine(&x, &y, &cfg, &ooc).unwrap();
        let b = fit_logistic_path_with_engine(&x, &y, &cfg, &native).unwrap();
        assert_eq!(a.betas, b.betas, "{rule:?}: pinned logistic fit differs");
        assert_eq!(a.intercepts, b.intercepts, "{rule:?}: intercepts differ");
        let c = ooc.store().counters();
        assert!(c.solver_cols() > 0, "{rule:?}: IRLS never used the store");
        assert!(c.peak_resident() <= budget as u64, "{rule:?}: budget exceeded");
    }
}

/// With the async prefetcher armed the fit stays bit-identical, the
/// prefetcher demonstrably ran (issued > 0, hits + waste ≤ issued), and —
/// the core guarantee — peak resident bytes never exceed the budget even
/// though a background thread is staging chunks while the solver pins.
#[test]
fn prefetch_fit_is_bit_identical_and_budget_bounded() {
    let ds = DataSpec::gene_like(70, 180).generate(47);
    let path = tmp("dl-prefetch.store");
    let chunk = 16;
    write_dataset(&ds, chunk, &path).unwrap();
    let budget = 4 * chunk * ds.n() * 8; // room for pins + staged chunks
    let native = NativeEngine::new();
    let cfg = PathConfig {
        rule: RuleKind::SsrBedpp,
        n_lambda: 15,
        tol: 1e-8,
        ..PathConfig::default()
    };
    let mut ooc = OocEngine::open(&path, budget).unwrap();
    ooc.enable_prefetch();
    assert!(ooc.prefetch_enabled());
    let a = fit_lasso_path_with_engine(&ds, &cfg, &ooc).unwrap();
    let b = fit_lasso_path_with_engine(&ds, &cfg, &native).unwrap();
    assert_eq!(a.betas, b.betas, "prefetching changed the fit");
    // The service is async: wait (bounded) for it to drain issued jobs.
    for _ in 0..400 {
        if ooc.store().counters().prefetch_issued() > 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let c = ooc.store().counters();
    assert!(c.prefetch_issued() > 0, "the λ-ahead prefetcher never ran");
    assert!(
        c.prefetch_hits() + c.prefetch_wasted() <= c.prefetch_issued(),
        "prefetch accounting drift: {} hits + {} wasted > {} issued",
        c.prefetch_hits(),
        c.prefetch_wasted(),
        c.prefetch_issued()
    );
    assert!(
        c.peak_resident() <= budget as u64,
        "prefetcher pushed resident {} past budget {budget}",
        c.peak_resident()
    );
}

/// Prefetch under injected storage faults: a staged chunk that fails its
/// read or CRC is simply not admitted (never quarantined, never served),
/// the demand path retries fresh, and the fit stays bit-identical.
#[test]
fn prefetch_fit_survives_injected_faults() {
    let ds = DataSpec::gene_like(70, 180).generate(53);
    let path = tmp("dl-prefetch-faults.store");
    let chunk = 16;
    write_dataset(&ds, chunk, &path).unwrap();
    let budget = 4 * chunk * ds.n() * 8;
    let native = NativeEngine::new();
    let cfg = PathConfig {
        rule: RuleKind::SsrBedpp,
        n_lambda: 15,
        tol: 1e-8,
        ..PathConfig::default()
    };
    let mut store = ColumnStore::open(&path, budget).unwrap();
    let spec =
        FaultSpec::parse("seed=97,transient=0.2,short=0.15,flip=0.1").unwrap();
    store.set_faults(Some(FaultInjector::new(spec)));
    let mut ooc = OocEngine::from_store(store);
    ooc.enable_prefetch();
    let a = fit_lasso_path_with_engine(&ds, &cfg, &ooc).unwrap();
    let b = fit_lasso_path_with_engine(&ds, &cfg, &native).unwrap();
    assert_eq!(a.betas, b.betas, "faulted prefetching fit differs from native");
    let c = ooc.store().counters();
    assert!(c.retries() > 0, "fault rates this high must trigger retries");
    assert!(c.peak_resident() <= budget as u64, "budget exceeded under faults");
}

/// Property: across random shapes, chunk widths, and budget multiples —
/// prefetch on and off — a store-backed fit never exceeds its byte budget
/// and matches the native fit bit for bit.
#[test]
fn property_peak_resident_never_exceeds_budget() {
    check(PropConfig { cases: 4, seed: 0xD15C }, |rng, scale| {
        let n = 30 + (rng.below(40) as f64 * scale) as usize;
        let p = 40 + (rng.below(100) as f64 * scale) as usize;
        let ds = DataSpec::synthetic(n, p, 4).generate(rng.next_u64());
        let chunk = 1 + rng.below(24) as usize;
        let budget = (1 + rng.below(4) as usize) * chunk * n * 8;
        let prefetch = rng.below(2) == 1;
        let path = tmp(&format!("dl-prop-{n}-{p}-{chunk}-{prefetch}.store"));
        write_dataset(&ds, chunk, &path).map_err(|e| e.to_string())?;
        let native = NativeEngine::new();
        let cfg = PathConfig {
            rule: RuleKind::SsrBedpp,
            n_lambda: 10,
            tol: 1e-8,
            ..PathConfig::default()
        };
        let mut ooc = OocEngine::open(&path, budget).map_err(|e| e.to_string())?;
        if prefetch {
            ooc.enable_prefetch();
        }
        let a = fit_lasso_path_with_engine(&ds, &cfg, &ooc).map_err(|e| e.to_string())?;
        let b =
            fit_lasso_path_with_engine(&ds, &cfg, &native).map_err(|e| e.to_string())?;
        prop_assert!(
            a.betas == b.betas,
            "diskless fit differs (n={n}, p={p}, chunk={chunk}, prefetch={prefetch})"
        );
        let c = ooc.store().counters();
        prop_assert!(
            c.peak_resident() <= budget as u64,
            "peak resident {} > budget {budget} (n={n}, p={p}, chunk={chunk}, \
             prefetch={prefetch})",
            c.peak_resident()
        );
        prop_assert!(c.solver_cols() > 0, "solve never touched the store");
        Ok(())
    });
}
