//! Deterministic pseudo-random generation for data synthesis and property
//! tests.
//!
//! The offline build environment does not provide the `rand` crate, so this
//! module implements a small, well-tested PCG64 (XSL-RR 128/64) generator
//! plus the distributions the data generators need: uniform, normal
//! (Box–Muller with caching), Bernoulli/binomial, Poisson, and Zipf.
//!
//! All dataset generation in [`crate::data`] is keyed by an explicit `u64`
//! seed so every experiment in EXPERIMENTS.md is exactly reproducible.

/// PCG64 (XSL-RR 128/64) pseudo-random generator.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal deviate from Box–Muller.
    gauss_cache: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (the stream id is derived from the seed with SplitMix).
    pub fn new(seed: u64) -> Self {
        let s0 = splitmix64(seed);
        let s1 = splitmix64(s0);
        let s2 = splitmix64(s1);
        let s3 = splitmix64(s2);
        let state = ((s0 as u128) << 64) | s1 as u128;
        let inc = ((((s2 as u128) << 64) | s3 as u128) << 1) | 1;
        let mut rng = Pcg64 { state: state.wrapping_add(inc), inc, gauss_cache: None };
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` via Lemire's method (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_cache = Some(r * s);
            return r * c;
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Binomial(n, p) by summed Bernoulli draws (n is small in our use —
    /// allele dosages with n = 2).
    pub fn binomial(&mut self, n: u32, p: f64) -> u32 {
        (0..n).filter(|_| self.bernoulli(p)).count() as u32
    }

    /// Poisson(λ) via Knuth for small λ and normal approximation for large λ.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal();
            let x = lambda + lambda.sqrt() * z;
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }

    /// Zipf-distributed rank in `[1, n]` with exponent `s` (inverse-CDF over
    /// the precomputed harmonic table is avoided: rejection sampling after
    /// Devroye, fine for the bag-of-words generator).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1 && s > 0.0);
        // Rejection from the continuous Pareto envelope.
        let b = 2f64.powf(s - 1.0);
        loop {
            let u = self.uniform();
            let v = self.uniform();
            let x = (n as f64).powf(u.max(f64::MIN_POSITIVE)); // not exact envelope; clamp below
            let k = x.floor().max(1.0).min(n as f64);
            let t = (1.0 + 1.0 / k).powf(s - 1.0);
            if v * k * (t - 1.0) / (b - 1.0) <= t / b {
                return k as u64;
            }
        }
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of i.i.d. standard normals.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.normal()).collect()
    }
}

/// SplitMix64 — used only for seeding PCG streams.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn binomial_dosage_range() {
        let mut r = Pcg64::new(13);
        for _ in 0..1000 {
            let d = r.binomial(2, 0.3);
            assert!(d <= 2);
        }
    }

    #[test]
    fn poisson_small_mean() {
        let mut r = Pcg64::new(17);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "mean={m}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Pcg64::new(19);
        let n = 20_000;
        let ones = (0..n).filter(|_| r.zipf(1000, 1.2) == 1).count() as f64 / n as f64;
        // rank-1 mass for zipf(1.2, 1000) is about 1/H ≈ 0.17; just check it dominates
        assert!(ones > 0.05, "p(rank 1) = {ones}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(23);
        let idx = r.sample_indices(100, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(29);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
