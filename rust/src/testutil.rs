//! Shared fixtures for in-crate unit tests.

use crate::data::{DataSpec, Dataset};

/// A small, well-conditioned lasso problem.
pub fn small_lasso(seed: u64) -> Dataset {
    DataSpec::synthetic(60, 40, 5).generate(seed)
}

/// Max coefficient deviation between two dense vectors.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).fold(0.0f64, |m, (x, y)| m.max((x - y).abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_work() {
        let ds = small_lasso(1);
        assert_eq!(ds.n(), 60);
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }
}
