//! Report writers: aligned console tables (the paper's table format) and
//! CSV output under `bench_out/` for plotting.
//!
//! The [`Table`] type itself (and the shared cell formatters the
//! `*_table` builders use) lives in [`crate::coordinator::table`]; this
//! re-export keeps the historical `report::Table` path working.

pub use crate::coordinator::table::Table;
