//! Configuration: a dependency-free `key = value` config format plus a CLI
//! argument parser (the offline registry has neither `serde` nor `clap`).
//!
//! Config files are line-oriented: `key = value`, `#` comments, blank lines
//! ignored. CLI flags `--key value` (or `--key=value`) override file values.

use std::collections::BTreeMap;

use crate::error::{HssrError, Result};

/// A flat string→string configuration with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
    /// Positional (non-flag) arguments, in order.
    pub positional: Vec<String>,
}

impl Config {
    /// Parse a config file body.
    pub fn from_str_body(body: &str) -> Result<Config> {
        let mut cfg = Config::default();
        for (lineno, raw) in body.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(HssrError::Config(format!(
                    "line {}: expected `key = value`, got '{raw}'",
                    lineno + 1
                )));
            };
            cfg.values.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Config> {
        Config::from_str_body(&std::fs::read_to_string(path)?)
    }

    /// Parse CLI args (`--key value`, `--key=value`, `--flag`, positionals),
    /// overriding any values already present.
    pub fn apply_args<I: IntoIterator<Item = String>>(&mut self, args: I) -> Result<()> {
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    self.values.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    // The peek above proved a next token exists; bind it
                    // instead of unwrapping so a racing/odd iterator can
                    // never panic the parser.
                    let Some(v) = it.next() else {
                        return Err(HssrError::Config(format!(
                            "flag '--{stripped}' expects a value"
                        )));
                    };
                    self.values.insert(stripped.to_string(), v);
                } else {
                    self.values.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                self.positional.push(arg);
            }
        }
        Ok(())
    }

    /// Set a value programmatically.
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Raw string getter.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// String with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed getter with default; errors on malformed values.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HssrError::Config(format!("bad value for '{key}': '{v}'"))
            }),
        }
    }

    /// Boolean getter (`true/1/yes` are truthy).
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some(v) => matches!(v.to_ascii_lowercase().as_str(), "true" | "1" | "yes"),
        }
    }

    /// All keys (for diagnostics).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// Parse a method name as used in the paper's tables and our CLI.
pub fn parse_rule(s: &str) -> Option<crate::screening::RuleKind> {
    use crate::screening::RuleKind::*;
    match s.to_ascii_lowercase().replace('_', "-").as_str() {
        "basic" | "basic-pcd" | "basic-gd" | "none" => Some(BasicPcd),
        "ac" | "active" | "active-cycling" => Some(ActiveCycling),
        "ssr" | "strong" => Some(Ssr),
        "sedpp" => Some(Sedpp),
        "ssr-bedpp" | "hssr" | "hybrid" => Some(SsrBedpp),
        "ssr-dome" => Some(SsrDome),
        "ssr-bedpp-sedpp" | "rehybrid" => Some(SsrBedppSedpp),
        "ssr-gapsafe" | "gapsafe" | "gap-safe" => Some(SsrGapSafe),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::RuleKind;

    #[test]
    fn parses_file_body() {
        let cfg = Config::from_str_body("n = 100\np=200 # inline comment\n\n# c\nrule = ssr\n")
            .unwrap();
        assert_eq!(cfg.get_parse("n", 0usize).unwrap(), 100);
        assert_eq!(cfg.get_parse("p", 0usize).unwrap(), 200);
        assert_eq!(cfg.get_str("rule", ""), "ssr");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::from_str_body("oops").is_err());
        // `=`-less junk, keys without values, and bare separators are all
        // typed Config errors — never panics.
        for body in ["key", "a b c", "=", " = ", "x = 1\nbroken line\n"] {
            match Config::from_str_body(body) {
                Ok(cfg) => {
                    // `=` with empty key/value parses to empty strings;
                    // what matters is that nothing panicked.
                    let _ = cfg.get_str("x", "");
                }
                Err(HssrError::Config(_)) => {}
                Err(other) => panic!("unexpected error type: {other}"),
            }
        }
    }

    /// Trailing value-less flags and `--`-prefixed lookalikes must parse
    /// without panicking (regression: `it.next().unwrap()`).
    #[test]
    fn malformed_args_never_panic() {
        let mut cfg = Config::default();
        cfg.apply_args(["--alone"].map(String::from)).unwrap();
        assert!(cfg.get_bool("alone", false));
        let mut cfg = Config::default();
        cfg.apply_args(["--a", "--b", "--c="].map(String::from)).unwrap();
        assert!(cfg.get_bool("a", false) && cfg.get_bool("b", false));
        assert_eq!(cfg.get_str("c", "miss"), "");
        let mut cfg = Config::default();
        cfg.apply_args(["--k", "v", "--end"].map(String::from)).unwrap();
        assert_eq!(cfg.get_str("k", ""), "v");
        assert!(cfg.get_bool("end", false));
    }

    #[test]
    fn args_override_and_positional() {
        let mut cfg = Config::from_str_body("n = 1").unwrap();
        cfg.apply_args(
            ["fit", "--n", "5", "--flag", "--k=7", "data.csv"].map(String::from),
        )
        .unwrap();
        assert_eq!(cfg.get_parse("n", 0usize).unwrap(), 5);
        assert!(cfg.get_bool("flag", false));
        assert_eq!(cfg.get_parse("k", 0usize).unwrap(), 7);
        assert_eq!(cfg.positional, vec!["fit", "data.csv"]);
    }

    #[test]
    fn bad_typed_value_is_config_error() {
        let cfg = Config::from_str_body("n = banana").unwrap();
        assert!(cfg.get_parse("n", 0usize).is_err());
    }

    #[test]
    fn rule_parsing_aliases() {
        assert_eq!(parse_rule("SSR-BEDPP"), Some(RuleKind::SsrBedpp));
        assert_eq!(parse_rule("hssr"), Some(RuleKind::SsrBedpp));
        assert_eq!(parse_rule("basic_pcd"), Some(RuleKind::BasicPcd));
        assert_eq!(parse_rule("rehybrid"), Some(RuleKind::SsrBedppSedpp));
        assert_eq!(parse_rule("ssr-gapsafe"), Some(RuleKind::SsrGapSafe));
        assert_eq!(parse_rule("GapSafe"), Some(RuleKind::SsrGapSafe));
        assert_eq!(parse_rule("nope"), None);
    }
}
