//! Rule-level analyses over path instrumentation — the screening-power
//! curves of the paper's **Figure 1** and the §3.2.3 out-of-core
//! scan-traffic report.

use super::table::{mb1, mb2, ratio_vs, Table};
use crate::data::chunked::{ChunkedMatrix, ChunkedScanEngine};
use crate::data::store::{write_dataset, ColumnStore};
use crate::data::{Dataset, GroupedDataset};
use crate::error::Result;
use crate::runtime::ooc::OocEngine;
use crate::screening::bedpp::Bedpp;
use crate::screening::dome::DomeTest;
use crate::screening::{RuleKind, SafeContext};
use crate::solver::group_path::{fit_group_path_with_engine, GroupPathConfig};
use crate::solver::path::{fit_lasso_path, fit_lasso_path_with_engine, PathConfig};
use crate::solver::Penalty;

/// One screening-power curve: fraction of features discarded at each λ.
#[derive(Clone, Debug)]
pub struct PowerCurve {
    /// Rule label.
    pub rule: String,
    /// λ/λmax for each grid point.
    pub lambda_frac: Vec<f64>,
    /// Fraction of the `p` features discarded at each grid point.
    pub discarded_frac: Vec<f64>,
}

/// Compute Figure 1: percent of features discarded per λ for the
/// non-sequential safe rules (evaluated directly) and the sequential /
/// hybrid strategies (measured from an instrumented path fit).
pub fn screening_power(ds: &Dataset, cfg: &PathConfig) -> Result<Vec<PowerCurve>> {
    let p = ds.p() as f64;
    let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
    let lambdas = match &cfg.lambdas {
        Some(ls) => ls.clone(),
        None => crate::solver::lambda::grid(
            ctx.lambda_max,
            cfg.lambda_min_ratio,
            cfg.n_lambda,
            cfg.grid,
        ),
    };
    let fracs: Vec<f64> = lambdas.iter().map(|l| l / ctx.lambda_max).collect();
    let mut curves = Vec::new();

    // Non-sequential safe rules: evaluate the rule directly at each λ.
    for (label, f) in [
        ("Dome", DomeTest::screen_at as fn(&SafeContext, f64, &mut [bool]) -> usize),
        ("BEDPP", Bedpp::screen_at as fn(&SafeContext, f64, &mut [bool]) -> usize),
    ] {
        let mut curve = Vec::with_capacity(lambdas.len());
        for &lam in &lambdas {
            let mut survive = vec![true; ds.p()];
            let d = f(&ctx, lam, &mut survive);
            curve.push(d as f64 / p);
        }
        curves.push(PowerCurve {
            rule: label.to_string(),
            lambda_frac: fracs.clone(),
            discarded_frac: curve,
        });
    }

    // Sequential strategies: fraction excluded from the optimizer set.
    for rule in
        [RuleKind::Sedpp, RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe]
    {
        let mut c = cfg.clone();
        c.rule = rule;
        c.lambdas = Some(lambdas.clone());
        let fit = fit_lasso_path(ds, &c)?;
        let curve: Vec<f64> = fit
            .metrics
            .iter()
            .map(|m| 1.0 - m.strong_size as f64 / p)
            .collect();
        curves.push(PowerCurve {
            rule: rule.label().to_string(),
            lambda_frac: fracs.clone(),
            discarded_frac: curve,
        });
    }
    Ok(curves)
}

/// One row of the §3.2.3 out-of-core scan-traffic report: measured column
/// fetches against a chunked store for one screening strategy.
#[derive(Clone, Debug)]
pub struct ScanTraffic {
    /// Strategy measured.
    pub rule: RuleKind,
    /// Columns fetched from the store over the whole path.
    pub cols_fetched: u64,
    /// Chunk faults (fetches landing on a chunk's first column — the
    /// would-be chunk loads of a disk-backed store).
    pub chunk_faults: u64,
    /// Bytes fetched (`cols_fetched · n · 8`).
    pub bytes_fetched: u64,
    /// The path's own `cols_scanned` accounting (must equal
    /// `cols_fetched`; reported so the table exposes the cross-check).
    pub metric_cols: u64,
}

/// Measure the §3.2.3 memory-efficiency claim: run each strategy's path
/// with every screening/KKT scan dispatched through a counting
/// [`ChunkedScanEngine`] over a [`ChunkedMatrix`] split into `chunk_cols`
/// column chunks, and report the measured fetch traffic. SSR must fetch
/// `Θ(pK)` columns while HSSR fetches only `Σ_k |S_k|`.
pub fn scan_traffic(
    ds: &Dataset,
    cfg: &PathConfig,
    chunk_cols: usize,
    rules: &[RuleKind],
) -> Result<Vec<ScanTraffic>> {
    let store = ChunkedMatrix::from_dense(&ds.x, chunk_cols);
    let mut rows = Vec::with_capacity(rules.len());
    for &rule in rules {
        store.reset_counters();
        let engine = ChunkedScanEngine::new(&store);
        let mut c = cfg.clone();
        c.rule = rule;
        let fit = fit_lasso_path_with_engine(ds, &c, &engine)?;
        rows.push(ScanTraffic {
            rule,
            cols_fetched: store.cols_fetched(),
            chunk_faults: store.chunk_faults(),
            bytes_fetched: store.bytes_fetched(),
            metric_cols: fit.total_cols_scanned(),
        });
    }
    Ok(rows)
}

/// Group-path analogue of [`scan_traffic`]: run each strategy's *group*
/// path (group lasso, or group elastic net via `cfg.penalty`) through the
/// counting chunked-store engine and report measured fetch traffic. The
/// chunked engine uses the trait's scan-then-filter fused defaults, so
/// every group-norm read decomposes into counted column fetches — the
/// cross-check that the native one-traversal `fused_group_screen` kernel
/// accounts exactly the bytes a real out-of-core store would move.
pub fn group_scan_traffic(
    ds: &GroupedDataset,
    cfg: &GroupPathConfig,
    chunk_cols: usize,
    rules: &[RuleKind],
) -> Result<Vec<ScanTraffic>> {
    let store = ChunkedMatrix::from_dense(&ds.x, chunk_cols);
    let mut rows = Vec::with_capacity(rules.len());
    for &rule in rules {
        store.reset_counters();
        let engine = ChunkedScanEngine::new(&store);
        let mut c = cfg.clone();
        c.rule = rule;
        let fit = fit_group_path_with_engine(ds, &c, &engine)?;
        rows.push(ScanTraffic {
            rule,
            cols_fetched: store.cols_fetched(),
            chunk_faults: store.chunk_faults(),
            bytes_fetched: store.bytes_fetched(),
            metric_cols: fit.total_cols_scanned(),
        });
    }
    Ok(rows)
}

/// One row of the **real** out-of-core I/O report: a path fit with every
/// screening/KKT scan served by [`OocEngine`] from a disk-backed store
/// under a bounded cache budget.
#[derive(Clone, Debug)]
pub struct OocTraffic {
    /// Strategy measured.
    pub rule: RuleKind,
    /// Columns served by the store over the whole path.
    pub cols_fetched: u64,
    /// Disk chunk loads (cache misses that hit the file).
    pub chunk_loads: u64,
    /// Payload bytes actually read from disk.
    pub bytes_read: u64,
    /// Chunk-cache hits.
    pub cache_hits: u64,
    /// Cache hits on chunks loaded by a *different* fit — nonzero only
    /// in serve mode, where concurrent paths share one chunk cache
    /// (single-fit runs report 0).
    pub cross_fit_hits: u64,
    /// Peak cache-resident bytes (must stay within the budget).
    pub peak_resident: u64,
    /// Read attempts beyond the first (transient faults absorbed by the
    /// retry policy; 0 unless faults were injected or the disk misbehaved).
    pub retries: u64,
    /// Chunk loads rejected by CRC verification and retried.
    pub checksum_failures: u64,
    /// Reads that returned fewer bytes than requested and were retried.
    pub short_reads: u64,
    /// The path's own `cols_scanned` accounting (must equal
    /// `cols_fetched` — every scan, including the gap-safe and SEDPP
    /// rules' in-rule traversals, is engine-routed).
    pub metric_cols: u64,
    /// Columns served to the inner solvers through the pinned-chunk
    /// cursor (diskless fit traffic; separate from scan `cols_fetched`).
    pub solver_cols: u64,
    /// Demand chunk loads that blocked compute (cache misses on the
    /// synchronous path).
    pub stalls: u64,
    /// Chunks the async λ-ahead prefetcher was asked to stage.
    pub prefetch_issued: u64,
    /// Prefetched chunks that were later used by a demand access.
    pub prefetch_hits: u64,
    /// Prefetched chunks evicted or refused before any demand use.
    pub prefetch_wasted: u64,
}

/// Measure §3.2.3 as **actual read traffic**: spill `ds` to a temp store
/// (`chunk_cols`-wide chunks), then run each strategy's path through an
/// [`OocEngine`] bounded by `budget_bytes`, resetting the cache and
/// counters between rules. With a budget far below the matrix footprint,
/// the SSR/HSSR gap in bytes-scanned becomes a gap in real disk reads.
pub fn ooc_scan_traffic(
    ds: &Dataset,
    cfg: &PathConfig,
    chunk_cols: usize,
    budget_bytes: usize,
    rules: &[RuleKind],
) -> Result<Vec<OocTraffic>> {
    ooc_fit_traffic(ds, cfg, chunk_cols, budget_bytes, rules, false)
}

/// [`ooc_scan_traffic`] with the async λ-ahead prefetcher optionally
/// armed, so the same store/budget can be measured prefetch-on vs
/// prefetch-off (hit rate, waste, and demand-stall counts per rule).
pub fn ooc_fit_traffic(
    ds: &Dataset,
    cfg: &PathConfig,
    chunk_cols: usize,
    budget_bytes: usize,
    rules: &[RuleKind],
    prefetch: bool,
) -> Result<Vec<OocTraffic>> {
    let path = std::env::temp_dir().join(format!(
        "hssr-traffic-{}-{chunk_cols}-{}.store",
        std::process::id(),
        prefetch as u8,
    ));
    write_dataset(ds, chunk_cols, &path)?;
    let mut engine = OocEngine::from_store(ColumnStore::open(&path, budget_bytes)?);
    if prefetch {
        engine.enable_prefetch();
    }
    // Unlink early where the platform allows (the open handle keeps the
    // store readable); the post-drop removal below covers the rest.
    #[cfg(unix)]
    let _ = std::fs::remove_file(&path);
    let mut rows = Vec::with_capacity(rules.len());
    for &rule in rules {
        engine.store().reset();
        let mut c = cfg.clone();
        c.rule = rule;
        let fit = fit_lasso_path_with_engine(ds, &c, &engine)?;
        let counters = engine.store().counters();
        rows.push(OocTraffic {
            rule,
            cols_fetched: counters.cols_fetched(),
            chunk_loads: counters.chunk_loads(),
            bytes_read: counters.bytes_read(),
            cache_hits: counters.cache_hits(),
            cross_fit_hits: counters.cross_fit_hits(),
            peak_resident: counters.peak_resident(),
            retries: counters.retries(),
            checksum_failures: counters.checksum_failures(),
            short_reads: counters.short_reads(),
            metric_cols: fit.total_cols_scanned(),
            solver_cols: counters.solver_cols(),
            stalls: counters.stalls(),
            prefetch_issued: counters.prefetch_issued(),
            prefetch_hits: counters.prefetch_hits(),
            prefetch_wasted: counters.prefetch_wasted(),
        });
    }
    drop(engine); // close the handle so the removal works everywhere
    let _ = std::fs::remove_file(&path);
    Ok(rows)
}

/// Render [`ooc_scan_traffic`] rows as a report table (relative disk
/// traffic is against the first row, conventionally SSR).
pub fn ooc_traffic_table(title: &str, rows: &[OocTraffic]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "Method",
            "cols served",
            "solver cols",
            "chunk loads",
            "MB read (disk)",
            "cache hits",
            "xfit hits",
            "peak res MB",
            "stalls",
            "pf hit/iss/waste",
            "retries",
            "crc fail",
            "vs first",
        ],
    );
    let base = rows.first().map(|r| r.bytes_read).unwrap_or(0);
    for r in rows {
        debug_assert_eq!(r.cols_fetched, r.metric_cols, "ooc accounting drift");
        t.push_row(vec![
            r.rule.label().to_string(),
            r.cols_fetched.to_string(),
            r.solver_cols.to_string(),
            r.chunk_loads.to_string(),
            mb1(r.bytes_read),
            r.cache_hits.to_string(),
            r.cross_fit_hits.to_string(),
            mb2(r.peak_resident),
            r.stalls.to_string(),
            format!("{}/{}/{}", r.prefetch_hits, r.prefetch_issued, r.prefetch_wasted),
            r.retries.to_string(),
            r.checksum_failures.to_string(),
            ratio_vs(base, r.bytes_read),
        ]);
    }
    t
}

/// Render [`scan_traffic`] rows as a coordinator report table (relative
/// traffic is against the first row, conventionally SSR).
pub fn scan_traffic_table(title: &str, rows: &[ScanTraffic]) -> Table {
    let mut t = Table::new(
        title,
        &["Method", "cols fetched", "chunk faults", "MB fetched", "vs first"],
    );
    let base = rows.first().map(|r| r.bytes_fetched).unwrap_or(0);
    for r in rows {
        debug_assert_eq!(r.cols_fetched, r.metric_cols, "accounting drift");
        t.push_row(vec![
            r.rule.label().to_string(),
            r.cols_fetched.to_string(),
            r.chunk_faults.to_string(),
            mb1(r.bytes_fetched),
            ratio_vs(base, r.bytes_fetched),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;

    /// §3.2.3 measured: HSSR must fetch strictly fewer columns than SSR
    /// from the chunked store, and the engine-level fetch counters must
    /// agree with the path's own scan accounting.
    #[test]
    fn scan_traffic_hssr_below_ssr() {
        let ds = DataSpec::gene_like(100, 300).generate(4);
        let cfg = PathConfig { n_lambda: 30, tol: 1e-9, ..PathConfig::default() };
        let rows =
            scan_traffic(&ds, &cfg, 64, &[RuleKind::Ssr, RuleKind::SsrBedpp]).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.cols_fetched, r.metric_cols, "{:?} accounting drift", r.rule);
            assert!(r.chunk_faults > 0 && r.chunk_faults <= r.cols_fetched);
        }
        assert!(
            rows[1].cols_fetched < rows[0].cols_fetched,
            "HSSR fetched {} vs SSR {}",
            rows[1].cols_fetched,
            rows[0].cols_fetched
        );
        let t = scan_traffic_table("traffic", &rows);
        assert_eq!(t.rows.len(), 2);
    }

    /// Group-path §3.2.3 analogue: HSSR fetches no more group columns than
    /// SSR, the accounting cross-checks, and the elastic-net path routes
    /// through the same counted engine.
    #[test]
    fn group_scan_traffic_accounts_and_orders() {
        use crate::data::synth::generate_grouped;
        let ds = generate_grouped(80, 40, 4, 4, 6);
        for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha: 0.7 }] {
            let cfg = GroupPathConfig {
                penalty,
                n_lambda: 25,
                tol: 1e-9,
                ..GroupPathConfig::default()
            };
            let rows =
                group_scan_traffic(&ds, &cfg, 16, &[RuleKind::Ssr, RuleKind::SsrBedpp])
                    .unwrap();
            assert_eq!(rows.len(), 2);
            for r in &rows {
                assert_eq!(
                    r.cols_fetched, r.metric_cols,
                    "{:?}/{penalty:?} group accounting drift",
                    r.rule
                );
                assert!(r.chunk_faults > 0 && r.chunk_faults <= r.cols_fetched);
            }
            assert!(
                rows[1].cols_fetched <= rows[0].cols_fetched,
                "{penalty:?}: group HSSR fetched {} vs SSR {}",
                rows[1].cols_fetched,
                rows[0].cols_fetched
            );
        }
    }

    /// §3.2.3 measured against the *real* store: with a cache budget far
    /// below the matrix footprint, HSSR reads strictly fewer bytes from
    /// disk than SSR, the store's fetch counters equal the path's own
    /// accounting (including the gap-safe rule's in-rule scans, now
    /// engine-routed), and the cache never outgrows its budget.
    #[test]
    fn ooc_traffic_hssr_below_ssr_with_real_reads() {
        let ds = DataSpec::gene_like(60, 240).generate(4);
        let cfg = PathConfig { n_lambda: 20, tol: 1e-9, ..PathConfig::default() };
        let chunk_cols = 32;
        let budget = 4 * chunk_cols * ds.n() * 8; // 4 chunks ≪ 240 columns
        let rules = [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe];
        let rows = ooc_scan_traffic(&ds, &cfg, chunk_cols, budget, &rules).unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert_eq!(r.cols_fetched, r.metric_cols, "{:?} ooc accounting drift", r.rule);
            assert!(r.chunk_loads > 0 && r.bytes_read > 0, "{:?} read nothing", r.rule);
            assert!(
                r.peak_resident <= budget as u64,
                "{:?} cache outgrew its budget ({} > {budget})",
                r.rule,
                r.peak_resident
            );
        }
        // Columns served is the exact measure (strictly fewer for HSSR);
        // disk bytes are chunk-granular, so a sparse safe set can still
        // touch every chunk — the gap must be ≥ 0 and usually strict.
        assert!(
            rows[1].cols_fetched < rows[0].cols_fetched,
            "HSSR served {} cols vs SSR {}",
            rows[1].cols_fetched,
            rows[0].cols_fetched
        );
        assert!(
            rows[1].bytes_read <= rows[0].bytes_read,
            "HSSR read {} bytes vs SSR {}",
            rows[1].bytes_read,
            rows[0].bytes_read
        );
        let t = ooc_traffic_table("ooc traffic", &rows);
        assert_eq!(t.rows.len(), 3);
    }

    #[test]
    fn figure1_qualitative_shape() {
        let ds = DataSpec::gene_like(80, 200).generate(7);
        let cfg = PathConfig { n_lambda: 40, ..PathConfig::default() };
        let curves = screening_power(&ds, &cfg).unwrap();
        let by_name = |n: &str| curves.iter().find(|c| c.rule == n).unwrap();
        let dome = by_name("Dome");
        let bedpp = by_name("BEDPP");
        let ssr = by_name("SSR");
        let hssr = by_name("SSR-BEDPP");
        let sedpp = by_name("SEDPP");
        let last = cfg.n_lambda - 1;
        // Non-sequential rules die by the end of the path…
        assert!(bedpp.discarded_frac[last] == 0.0);
        assert!(dome.discarded_frac[last] == 0.0);
        // …while the sequential rules keep discarding.
        assert!(ssr.discarded_frac[last] > 0.5);
        assert!(sedpp.discarded_frac[last] > 0.5);
        // HSSR ≥ SSR everywhere (§3.2.1 "by construction").
        for k in 0..=last {
            assert!(
                hssr.discarded_frac[k] >= ssr.discarded_frac[k] - 1e-12,
                "HSSR below SSR at k={k}"
            );
        }
        // The dynamic gap-safe hybrid is also an HSSR: ≥ SSR everywhere,
        // and still discarding at λmin (it is never flag-shut).
        let gap = by_name("SSR-GapSafe");
        for k in 0..=last {
            assert!(
                gap.discarded_frac[k] >= ssr.discarded_frac[k] - 1e-12,
                "SSR-GapSafe below SSR at k={k}"
            );
        }
        assert!(gap.discarded_frac[last] > 0.5);
        // Dome is weaker than BEDPP in aggregate.
        let sum = |c: &PowerCurve| c.discarded_frac.iter().sum::<f64>();
        assert!(sum(dome) <= sum(bedpp) + 1e-9);
    }
}
