//! Rule-level analyses over path instrumentation — including the
//! screening-power curves of the paper's **Figure 1**.

use crate::data::Dataset;
use crate::error::Result;
use crate::screening::bedpp::Bedpp;
use crate::screening::dome::DomeTest;
use crate::screening::{RuleKind, SafeContext};
use crate::solver::path::{fit_lasso_path, PathConfig};
use crate::solver::Penalty;

/// One screening-power curve: fraction of features discarded at each λ.
#[derive(Clone, Debug)]
pub struct PowerCurve {
    /// Rule label.
    pub rule: String,
    /// λ/λmax for each grid point.
    pub lambda_frac: Vec<f64>,
    /// Fraction of the `p` features discarded at each grid point.
    pub discarded_frac: Vec<f64>,
}

/// Compute Figure 1: percent of features discarded per λ for the
/// non-sequential safe rules (evaluated directly) and the sequential /
/// hybrid strategies (measured from an instrumented path fit).
pub fn screening_power(ds: &Dataset, cfg: &PathConfig) -> Result<Vec<PowerCurve>> {
    let p = ds.p() as f64;
    let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
    let lambdas = match &cfg.lambdas {
        Some(ls) => ls.clone(),
        None => crate::solver::lambda::grid(
            ctx.lambda_max,
            cfg.lambda_min_ratio,
            cfg.n_lambda,
            cfg.grid,
        ),
    };
    let fracs: Vec<f64> = lambdas.iter().map(|l| l / ctx.lambda_max).collect();
    let mut curves = Vec::new();

    // Non-sequential safe rules: evaluate the rule directly at each λ.
    for (label, f) in [
        ("Dome", DomeTest::screen_at as fn(&SafeContext, f64, &mut [bool]) -> usize),
        ("BEDPP", Bedpp::screen_at as fn(&SafeContext, f64, &mut [bool]) -> usize),
    ] {
        let mut curve = Vec::with_capacity(lambdas.len());
        for &lam in &lambdas {
            let mut survive = vec![true; ds.p()];
            let d = f(&ctx, lam, &mut survive);
            curve.push(d as f64 / p);
        }
        curves.push(PowerCurve {
            rule: label.to_string(),
            lambda_frac: fracs.clone(),
            discarded_frac: curve,
        });
    }

    // Sequential strategies: fraction excluded from the optimizer set.
    for rule in [RuleKind::Sedpp, RuleKind::Ssr, RuleKind::SsrBedpp] {
        let mut c = cfg.clone();
        c.rule = rule;
        c.lambdas = Some(lambdas.clone());
        let fit = fit_lasso_path(ds, &c)?;
        let curve: Vec<f64> = fit
            .metrics
            .iter()
            .map(|m| 1.0 - m.strong_size as f64 / p)
            .collect();
        curves.push(PowerCurve {
            rule: rule.label().to_string(),
            lambda_frac: fracs.clone(),
            discarded_frac: curve,
        });
    }
    Ok(curves)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;

    #[test]
    fn figure1_qualitative_shape() {
        let ds = DataSpec::gene_like(80, 200).generate(7);
        let cfg = PathConfig { n_lambda: 40, ..PathConfig::default() };
        let curves = screening_power(&ds, &cfg).unwrap();
        let by_name = |n: &str| curves.iter().find(|c| c.rule == n).unwrap();
        let dome = by_name("Dome");
        let bedpp = by_name("BEDPP");
        let ssr = by_name("SSR");
        let hssr = by_name("SSR-BEDPP");
        let sedpp = by_name("SEDPP");
        let last = cfg.n_lambda - 1;
        // Non-sequential rules die by the end of the path…
        assert!(bedpp.discarded_frac[last] == 0.0);
        assert!(dome.discarded_frac[last] == 0.0);
        // …while the sequential rules keep discarding.
        assert!(ssr.discarded_frac[last] > 0.5);
        assert!(sedpp.discarded_frac[last] > 0.5);
        // HSSR ≥ SSR everywhere (§3.2.1 "by construction").
        for k in 0..=last {
            assert!(
                hssr.discarded_frac[k] >= ssr.discarded_frac[k] - 1e-12,
                "HSSR below SSR at k={k}"
            );
        }
        // Dome is weaker than BEDPP in aggregate.
        let sum = |c: &PowerCurve| c.discarded_frac.iter().sum::<f64>();
        assert!(sum(dome) <= sum(bedpp) + 1e-9);
    }
}
