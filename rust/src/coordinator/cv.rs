//! K-fold cross-validation for λ selection — the standard downstream
//! workflow around a path solver (cv.biglasso / cv.glmnet).
//!
//! Folds are deterministic given the seed; fold fits run as parallel jobs
//! on the shared worker pool via [`super::jobs::try_parallel_map`], so a
//! failing fold surfaces as a typed [`HssrError::Cv`] carrying its fold
//! index (and the failing λ, when the path degraded) instead of poisoning
//! the whole run. The λ grid is fixed globally (computed on the full
//! data) so fold errors are comparable per λ.
//!
//! CV is **engine-routed**: under `HSSR_ENGINE=ooc` each fold streams its
//! restandardized training view straight into a temp column store — one
//! column in flight, never an `n×p` fold copy — and fits it through
//! [`fit_lasso_path_store`], so `k` concurrent fold fits keep peak
//! resident bytes bounded by the chunk-cache budget. The dense route
//! materializes the fold as before.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::data::store::{self, write_columns, ColumnSpill, ColumnStore};
use crate::data::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::{ops, DenseMatrix};
use crate::solver::path::{
    fit_lasso_path, fit_lasso_path_store, PathConfig, PathFit,
};

/// Cross-validation result.
#[derive(Clone, Debug)]
pub struct CvResult {
    /// The common λ grid.
    pub lambdas: Vec<f64>,
    /// Mean held-out MSE per λ.
    pub cv_mean: Vec<f64>,
    /// Standard error of the fold means per λ.
    pub cv_se: Vec<f64>,
    /// Index of the λ minimizing CV error.
    pub idx_min: usize,
    /// Largest λ within one SE of the minimum (the "1-SE rule").
    pub idx_1se: usize,
    /// Number of folds.
    pub folds: usize,
}

impl CvResult {
    /// λ at the CV minimum.
    pub fn lambda_min(&self) -> f64 {
        self.lambdas[self.idx_min]
    }

    /// λ under the 1-SE rule.
    pub fn lambda_1se(&self) -> f64 {
        self.lambdas[self.idx_1se]
    }
}

/// Deterministic fold assignment: a seeded permutation cut into `k` blocks.
pub fn fold_assignment(n: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut rng = crate::rng::Pcg64::new(seed);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut fold = vec![0usize; n];
    for (pos, &i) in order.iter().enumerate() {
        fold[i] = pos % k;
    }
    fold
}

/// Run k-fold CV of the lasso/enet path on a standardized dataset.
///
/// Each training fold is restandardized (centering/scaling is part of the
/// estimator), the model fitted over the *global* λ grid, and held-out MSE
/// computed on the raw held-out rows of the standardized full design.
/// Fold fits route through the configured engine: see the module docs for
/// the `HSSR_ENGINE=ooc` streaming path.
pub fn cv_lasso(ds: &Dataset, cfg: &PathConfig, k: usize, seed: u64) -> Result<CvResult> {
    let ooc = matches!(
        std::env::var("HSSR_ENGINE"),
        Ok(v) if v.eq_ignore_ascii_case("ooc")
    );
    cv_lasso_routed(ds, cfg, k, seed, ooc)
}

/// [`cv_lasso`] with the engine route pinned explicitly instead of read
/// from `HSSR_ENGINE`: `ooc = true` streams every training fold through a
/// disk spill (never materializing k in-flight dense fold copies),
/// `false` materializes fold designs in memory. Both routes are
/// bit-identical; tests pin that equivalence without touching the
/// process environment.
pub fn cv_lasso_routed(
    ds: &Dataset,
    cfg: &PathConfig,
    k: usize,
    seed: u64,
    ooc: bool,
) -> Result<CvResult> {
    if k < 2 || k > ds.n() / 2 {
        return Err(HssrError::Config(format!("cv folds must be in [2, n/2], got {k}")));
    }
    // Global grid from the full data.
    let full_ctx = crate::screening::SafeContext::build(&ds.x, &ds.y, cfg.penalty, false);
    let lambdas = crate::solver::lambda::grid(
        full_ctx.lambda_max,
        cfg.lambda_min_ratio,
        cfg.n_lambda,
        cfg.grid,
    );
    let fold_of = fold_assignment(ds.n(), k, seed);

    let fold_mse: Vec<Vec<f64>> =
        super::jobs::try_parallel_map(k, super::jobs::default_threads(), |f| {
            fold_mse_for(ds, cfg, &lambdas, &fold_of, f, ooc).map_err(|e| match e {
                e @ HssrError::Cv { .. } => e,
                other => HssrError::Cv { fold: Some(f), message: other.to_string() },
            })
        })?;

    let kl = lambdas.len();
    let mut cv_mean = vec![0.0; kl];
    let mut cv_se = vec![0.0; kl];
    for li in 0..kl {
        let vals: Vec<f64> = fold_mse.iter().map(|fm| fm[li]).collect();
        let mean = vals.iter().sum::<f64>() / k as f64;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / (k as f64 - 1.0);
        cv_mean[li] = mean;
        cv_se[li] = (var / k as f64).sqrt();
    }
    let (idx_min, idx_1se) = select_lambda(&cv_mean, &cv_se)?;
    Ok(CvResult { lambdas, cv_mean, cv_se, idx_min, idx_1se, folds: k })
}

/// Pick `(idx_min, idx_1se)` from the per-λ CV means: a total-order argmin
/// over the *finite* means only — a non-finite fold mean (overflowed MSE
/// at an extreme λ) can never win the argmin, and never panics the
/// comparator. When every mean is non-finite there is no λ to select:
/// typed [`HssrError::Cv`] with no fold attribution.
fn select_lambda(cv_mean: &[f64], cv_se: &[f64]) -> Result<(usize, usize)> {
    let idx_min = cv_mean
        .iter()
        .enumerate()
        .filter(|(_, m)| m.is_finite())
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .ok_or_else(|| HssrError::Cv {
            fold: None,
            message: format!(
                "all {} per-λ CV means are non-finite — no λ can be selected",
                cv_mean.len()
            ),
        })?;
    let threshold = cv_mean[idx_min] + cv_se[idx_min];
    // NaN means fail the `<=` and are skipped, as they must be.
    let idx_1se = (0..=idx_min).find(|&i| cv_mean[i] <= threshold).unwrap_or(idx_min);
    Ok((idx_min, idx_1se))
}

/// Fit fold `f` over the global grid and return its per-λ held-out MSE.
fn fold_mse_for(
    ds: &Dataset,
    cfg: &PathConfig,
    lambdas: &[f64],
    fold_of: &[usize],
    f: usize,
    ooc: bool,
) -> Result<Vec<f64>> {
    // --- split ---
    let train_rows: Vec<usize> = (0..ds.n()).filter(|&i| fold_of[i] != f).collect();
    let test_rows: Vec<usize> = (0..ds.n()).filter(|&i| fold_of[i] == f).collect();
    let mut fold_cfg = cfg.clone();
    fold_cfg.lambdas = Some(lambdas.to_vec());
    let y_mean_shift: f64 =
        train_rows.iter().map(|&i| ds.y[i]).sum::<f64>() / train_rows.len() as f64;

    let (fit, centers, scales) = if ooc {
        fit_fold_store(ds, &train_rows, &fold_cfg, f)?
    } else {
        fit_fold_dense(ds, &train_rows, &fold_cfg, f)?
    };
    if let Some(perr) = &fit.error {
        return Err(HssrError::Cv {
            fold: Some(f),
            message: format!(
                "path degraded at λ#{} (λ = {:.6e}): {}",
                perr.lambda_index, perr.lambda, perr.reason
            ),
        });
    }

    // --- evaluate on held-out rows ---
    Ok(lambdas
        .iter()
        .enumerate()
        .map(|(li, _)| {
            let beta = fit.beta_dense(li);
            let mut mse = 0.0;
            for &i in &test_rows {
                let mut eta = y_mean_shift;
                for (j, &b) in beta.iter().enumerate() {
                    if b != 0.0 && scales[j] > 0.0 {
                        eta += b * (ds.x.get(i, j) - centers[j]) / scales[j];
                    }
                }
                let e = ds.y[i] - eta;
                mse += e * e;
            }
            mse / test_rows.len() as f64
        })
        .collect())
}

/// Dense fold route: materialize and restandardize the training rows
/// (re-centered/scaled to keep condition (2) on the subsample), then fit
/// through the default engine.
fn fit_fold_dense(
    ds: &Dataset,
    train_rows: &[usize],
    fold_cfg: &PathConfig,
    f: usize,
) -> Result<(PathFit, Vec<f64>, Vec<f64>)> {
    let mut xtr = DenseMatrix::zeros(train_rows.len(), ds.p());
    for j in 0..ds.p() {
        let col = ds.x.col(j);
        let dst = xtr.col_mut(j);
        for (a, &i) in train_rows.iter().enumerate() {
            dst[a] = col[i];
        }
    }
    let mut ytr: Vec<f64> = train_rows.iter().map(|&i| ds.y[i]).collect();
    let (centers, scales) =
        crate::data::standardize::standardize_in_place(&mut xtr, &mut ytr);
    let sub = Dataset {
        x: xtr,
        y: ytr,
        centers: centers.clone(),
        scales: scales.clone(),
        name: format!("{}-fold{f}", ds.name),
        truth: None,
    };
    let fit = fit_lasso_path(&sub, fold_cfg)?;
    Ok((fit, centers, scales))
}

/// Out-of-core fold route: stream the restandardized training view of the
/// fold straight into a temp column store — one column in flight, never an
/// `n×p` copy — and fit it from the store under the cache budget. The
/// arithmetic per column is identical to
/// [`crate::data::standardize::standardize_in_place`] on the materialized
/// fold, so both routes produce bit-identical fits.
fn fit_fold_store(
    ds: &Dataset,
    train_rows: &[usize],
    fold_cfg: &PathConfig,
    f: usize,
) -> Result<(PathFit, Vec<f64>, Vec<f64>)> {
    let n = train_rows.len();
    let p = ds.p();
    let mut ytr: Vec<f64> = train_rows.iter().map(|&i| ds.y[i]).collect();
    crate::data::standardize::center(&mut ytr);
    // Pass 1: per-column centers/scales of the training view.
    let mut centers = vec![0.0; p];
    let mut scales = vec![0.0; p];
    let mut buf = vec![0.0; n];
    for j in 0..p {
        let col = ds.x.col(j);
        for (a, &i) in train_rows.iter().enumerate() {
            buf[a] = col[i];
        }
        let m = ops::mean(&buf);
        for v in buf.iter_mut() {
            *v -= m;
        }
        let sd = (ops::nrm2_sq(&buf) / n as f64).sqrt();
        centers[j] = m;
        scales[j] = if sd > 1e-12 { sd } else { 0.0 };
    }
    // Pass 2: stream the standardized columns into the spill.
    let path = fold_spill_path(f);
    let spec = ColumnSpill {
        n,
        p,
        y: &ytr,
        centers: &centers,
        scales: &scales,
        standardized: true,
        chunk_cols: store::chunk_cols_for(n, p, store::DEFAULT_CHUNK_BYTES),
    };
    let written = write_columns(
        &spec,
        |j, out| {
            out.clear();
            let col = ds.x.col(j);
            out.extend(train_rows.iter().map(|&i| col[i]));
            let m = centers[j];
            if scales[j] > 0.0 {
                let inv = 1.0 / scales[j];
                for v in out.iter_mut() {
                    *v -= m;
                    *v *= inv;
                }
            } else {
                for v in out.iter_mut() {
                    *v = 0.0;
                }
            }
            Ok(())
        },
        &path,
    );
    if let Err(e) = written {
        let _ = std::fs::remove_file(&path);
        return Err(e);
    }
    let opened = ColumnStore::open(&path, store::cache_budget_bytes());
    // Unix: unlink immediately — the open handle keeps it readable and
    // the spill can never outlive the process.
    #[cfg(unix)]
    let _ = std::fs::remove_file(&path);
    let store = match opened {
        Ok(s) => Arc::new(s),
        Err(e) => {
            let _ = std::fs::remove_file(&path);
            return Err(e);
        }
    };
    let res = fit_lasso_path_store(store, fold_cfg, None);
    let _ = std::fs::remove_file(&path);
    let (fit, _) = res?;
    Ok((fit, centers, scales))
}

fn fold_spill_path(f: usize) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir()
        .join(format!("hssr-cvfold-{}-{f}-{seq}.store", std::process::id()))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::screening::RuleKind;
    use crate::solver::Penalty;

    #[test]
    fn folds_partition_evenly() {
        let f = fold_assignment(103, 5, 1);
        assert_eq!(f.len(), 103);
        let mut counts = [0usize; 5];
        for &fi in &f {
            counts[fi] += 1;
        }
        assert!(counts.iter().all(|&c| (20..=21).contains(&c)), "{counts:?}");
        // deterministic
        assert_eq!(f, fold_assignment(103, 5, 1));
        assert_ne!(f, fold_assignment(103, 5, 2));
    }

    #[test]
    fn cv_selects_reasonable_lambda() {
        let ds = DataSpec::synthetic(150, 60, 5).generate(3);
        let cfg = PathConfig { rule: RuleKind::SsrBedpp, n_lambda: 30, ..PathConfig::default() };
        let cv = cv_lasso(&ds, &cfg, 5, 7).unwrap();
        assert_eq!(cv.cv_mean.len(), 30);
        assert!(cv.cv_mean.iter().all(|m| m.is_finite() && *m >= 0.0));
        // λmin improves on the null model (index 0 ≈ λmax)
        assert!(cv.cv_mean[cv.idx_min] < cv.cv_mean[0]);
        // 1-SE rule picks a λ at least as large as λmin
        assert!(cv.lambda_1se() >= cv.lambda_min());
    }

    #[test]
    fn bad_fold_count_rejected() {
        let ds = DataSpec::synthetic(30, 10, 2).generate(4);
        let cfg = PathConfig::default();
        assert!(cv_lasso(&ds, &cfg, 1, 1).is_err());
        assert!(cv_lasso(&ds, &cfg, 20, 1).is_err());
    }

    /// The streamed out-of-core fold route must reproduce the dense route
    /// exactly: same standardization arithmetic, same fits, same CV curve.
    #[test]
    fn ooc_fold_route_matches_dense_bitwise() {
        let ds = DataSpec::synthetic(90, 30, 4).generate(6);
        let cfg = PathConfig { n_lambda: 12, ..PathConfig::default() };
        let dense = cv_lasso_routed(&ds, &cfg, 3, 9, false).unwrap();
        let ooc = cv_lasso_routed(&ds, &cfg, 3, 9, true).unwrap();
        assert_eq!(dense.cv_mean, ooc.cv_mean, "ooc CV curve deviates from dense");
        assert_eq!(dense.cv_se, ooc.cv_se);
        assert_eq!((dense.idx_min, dense.idx_1se), (ooc.idx_min, ooc.idx_1se));
    }

    /// An injected fold-fit failure (invalid penalty caught in the fold's
    /// problem constructor) surfaces as a typed [`HssrError::Cv`] carrying
    /// the first failing fold's index — never a panic.
    #[test]
    fn fold_fit_failure_is_typed_with_fold_index() {
        let ds = DataSpec::synthetic(60, 20, 3).generate(5);
        let cfg = PathConfig {
            penalty: Penalty::ElasticNet { alpha: 0.0 },
            n_lambda: 5,
            ..PathConfig::default()
        };
        match cv_lasso(&ds, &cfg, 3, 1) {
            Err(HssrError::Cv { fold: Some(0), message }) => {
                assert!(!message.is_empty());
            }
            other => panic!("expected Cv error for fold 0, got {other:?}"),
        }
    }

    /// Non-finite per-λ means sink in the selection order; when every mean
    /// is non-finite the failure is typed, with no fold attribution.
    #[test]
    fn lambda_selection_sinks_non_finite_means() {
        let se = vec![0.0; 4];
        // NaN and +inf can never win the argmin.
        let (idx_min, idx_1se) =
            select_lambda(&[f64::NAN, 3.0, 2.0, f64::INFINITY], &se).unwrap();
        assert_eq!(idx_min, 2);
        assert!(idx_1se <= idx_min);
        // A NaN inside the 1-SE prefix is skipped, not selected.
        let (_, idx_1se) = select_lambda(&[f64::NAN, 2.5, 2.0, 9.0], &[0.0, 0.6, 0.6, 0.6])
            .unwrap();
        assert_eq!(idx_1se, 1);
        // All non-finite: typed error, no fold index.
        match select_lambda(&[f64::NAN, f64::INFINITY], &[0.0, 0.0]) {
            Err(HssrError::Cv { fold: None, message }) => {
                assert!(message.contains("non-finite"), "{message}");
            }
            other => panic!("expected Cv error, got {other:?}"),
        }
    }
}
