//! K-fold cross-validation for λ selection — the standard downstream
//! workflow around a path solver (cv.biglasso / cv.glmnet).
//!
//! Folds are deterministic given the seed; fold fits run across worker
//! threads via [`super::jobs::parallel_map`]; the λ grid is fixed globally
//! (computed on the full data) so fold errors are comparable per λ. Each
//! fold fit runs through the unified Algorithm-1 driver
//! ([`crate::solver::driver::drive`]) via [`fit_lasso_path`], so engine
//! and screening improvements land here automatically.

use crate::data::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::DenseMatrix;
use crate::solver::path::{fit_lasso_path, PathConfig};

/// Cross-validation result.
#[derive(Clone, Debug)]
pub struct CvResult {
    /// The common λ grid.
    pub lambdas: Vec<f64>,
    /// Mean held-out MSE per λ.
    pub cv_mean: Vec<f64>,
    /// Standard error of the fold means per λ.
    pub cv_se: Vec<f64>,
    /// Index of the λ minimizing CV error.
    pub idx_min: usize,
    /// Largest λ within one SE of the minimum (the "1-SE rule").
    pub idx_1se: usize,
    /// Number of folds.
    pub folds: usize,
}

impl CvResult {
    /// λ at the CV minimum.
    pub fn lambda_min(&self) -> f64 {
        self.lambdas[self.idx_min]
    }

    /// λ under the 1-SE rule.
    pub fn lambda_1se(&self) -> f64 {
        self.lambdas[self.idx_1se]
    }
}

/// Deterministic fold assignment: a seeded permutation cut into `k` blocks.
pub fn fold_assignment(n: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut rng = crate::rng::Pcg64::new(seed);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut fold = vec![0usize; n];
    for (pos, &i) in order.iter().enumerate() {
        fold[i] = pos % k;
    }
    fold
}

/// Run k-fold CV of the lasso/enet path on a standardized dataset.
///
/// Each training fold is restandardized (centering/scaling is part of the
/// estimator), the model fitted over the *global* λ grid, and held-out MSE
/// computed on the raw held-out rows of the standardized full design.
pub fn cv_lasso(ds: &Dataset, cfg: &PathConfig, k: usize, seed: u64) -> Result<CvResult> {
    if k < 2 || k > ds.n() / 2 {
        return Err(HssrError::Config(format!("cv folds must be in [2, n/2], got {k}")));
    }
    // Global grid from the full data.
    let full_ctx = crate::screening::SafeContext::build(&ds.x, &ds.y, cfg.penalty, false);
    let lambdas = crate::solver::lambda::grid(
        full_ctx.lambda_max,
        cfg.lambda_min_ratio,
        cfg.n_lambda,
        cfg.grid,
    );
    let fold_of = fold_assignment(ds.n(), k, seed);

    let fold_mse: Vec<Vec<f64>> =
        super::jobs::parallel_map(k, super::jobs::default_threads(), |f| {
            // --- split ---
            let train_rows: Vec<usize> =
                (0..ds.n()).filter(|&i| fold_of[i] != f).collect();
            let test_rows: Vec<usize> = (0..ds.n()).filter(|&i| fold_of[i] == f).collect();
            // training design (rows of the standardized full design are
            // re-centered/scaled to keep condition (2) on the subsample)
            let mut xtr = DenseMatrix::zeros(train_rows.len(), ds.p());
            for j in 0..ds.p() {
                let col = ds.x.col(j);
                let dst = xtr.col_mut(j);
                for (a, &i) in train_rows.iter().enumerate() {
                    dst[a] = col[i];
                }
            }
            let mut ytr: Vec<f64> = train_rows.iter().map(|&i| ds.y[i]).collect();
            let (centers, scales) =
                crate::data::standardize::standardize_in_place(&mut xtr, &mut ytr);
            let y_mean_shift: f64 = {
                // standardize_in_place centered ytr; recover the shift
                let orig_mean: f64 = train_rows.iter().map(|&i| ds.y[i]).sum::<f64>()
                    / train_rows.len() as f64;
                orig_mean
            };
            let sub = Dataset {
                x: xtr,
                y: ytr,
                centers: centers.clone(),
                scales: scales.clone(),
                name: format!("{}-fold{f}", ds.name),
                truth: None,
            };
            let mut fold_cfg = cfg.clone();
            fold_cfg.lambdas = Some(lambdas.clone());
            let fit = fit_lasso_path(&sub, &fold_cfg).expect("fold fit");
            // --- evaluate on held-out rows ---
            lambdas
                .iter()
                .enumerate()
                .map(|(li, _)| {
                    let beta = fit.beta_dense(li);
                    let mut mse = 0.0;
                    for &i in &test_rows {
                        let mut eta = y_mean_shift;
                        for (j, &b) in beta.iter().enumerate() {
                            if b != 0.0 && scales[j] > 0.0 {
                                eta += b * (ds.x.get(i, j) - centers[j]) / scales[j];
                            }
                        }
                        let e = ds.y[i] - eta;
                        mse += e * e;
                    }
                    mse / test_rows.len() as f64
                })
                .collect()
        });

    let kl = lambdas.len();
    let mut cv_mean = vec![0.0; kl];
    let mut cv_se = vec![0.0; kl];
    for li in 0..kl {
        let vals: Vec<f64> = fold_mse.iter().map(|fm| fm[li]).collect();
        let mean = vals.iter().sum::<f64>() / k as f64;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / (k as f64 - 1.0);
        cv_mean[li] = mean;
        cv_se[li] = (var / k as f64).sqrt();
    }
    let idx_min = cv_mean
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    let threshold = cv_mean[idx_min] + cv_se[idx_min];
    let idx_1se = (0..=idx_min).find(|&i| cv_mean[i] <= threshold).unwrap_or(idx_min);
    Ok(CvResult { lambdas, cv_mean, cv_se, idx_min, idx_1se, folds: k })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::screening::RuleKind;

    #[test]
    fn folds_partition_evenly() {
        let f = fold_assignment(103, 5, 1);
        assert_eq!(f.len(), 103);
        let mut counts = [0usize; 5];
        for &fi in &f {
            counts[fi] += 1;
        }
        assert!(counts.iter().all(|&c| (20..=21).contains(&c)), "{counts:?}");
        // deterministic
        assert_eq!(f, fold_assignment(103, 5, 1));
        assert_ne!(f, fold_assignment(103, 5, 2));
    }

    #[test]
    fn cv_selects_reasonable_lambda() {
        let ds = DataSpec::synthetic(150, 60, 5).generate(3);
        let cfg = PathConfig { rule: RuleKind::SsrBedpp, n_lambda: 30, ..PathConfig::default() };
        let cv = cv_lasso(&ds, &cfg, 5, 7).unwrap();
        assert_eq!(cv.cv_mean.len(), 30);
        assert!(cv.cv_mean.iter().all(|m| m.is_finite() && *m >= 0.0));
        // λmin improves on the null model (index 0 ≈ λmax)
        assert!(cv.cv_mean[cv.idx_min] < cv.cv_mean[0]);
        // 1-SE rule picks a λ at least as large as λmin
        assert!(cv.lambda_1se() >= cv.lambda_min());
    }

    #[test]
    fn bad_fold_count_rejected() {
        let ds = DataSpec::synthetic(30, 10, 2).generate(4);
        let cfg = PathConfig::default();
        assert!(cv_lasso(&ds, &cfg, 1, 1).is_err());
        assert!(cv_lasso(&ds, &cfg, 20, 1).is_err());
    }
}
