//! The one table helper: every `*_table` report builder (timing, speedup,
//! scan/ooc traffic, serve telemetry, trace summaries) renders through
//! this aligned-console/CSV [`Table`] and the shared cell formatters —
//! the hand-rolled per-module ASCII formatters it replaced lived in
//! `coordinator/metrics.rs` and `coordinator/report.rs`.

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// A simple table: header row + string cells.
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table title (printed above; used as the CSV file stem).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows of cells (each row must match `headers` length).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row.
    pub fn push_row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    /// Render as an aligned console table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Write as CSV (simple quoting: fields containing commas are quoted).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        let quote = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        writeln!(
            f,
            "{}",
            self.headers.iter().map(|h| quote(h)).collect::<Vec<_>>().join(",")
        )?;
        for row in &self.rows {
            writeln!(f, "{}", row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","))?;
        }
        Ok(())
    }

    /// Print to stdout and persist under `bench_out/<stem>.csv`.
    pub fn emit(&self, stem: &str) -> Result<()> {
        println!("{}", self.render());
        let path = Path::new("bench_out").join(format!("{stem}.csv"));
        self.write_csv(&path)?;
        println!("[csv written to {}]\n", path.display());
        Ok(())
    }
}

/// Bytes as megabytes with one decimal (`"12.3"`) — the traffic tables'
/// MB cells.
pub fn mb1(bytes: u64) -> String {
    format!("{:.1}", bytes as f64 / 1e6)
}

/// Bytes as megabytes with two decimals (`"0.25"`) — peak-resident cells.
pub fn mb2(bytes: u64) -> String {
    format!("{:.2}", bytes as f64 / 1e6)
}

/// Relative-traffic cell against a baseline (`"3.20x less"`; guards a
/// zero denominator).
pub fn ratio_vs(base: u64, v: u64) -> String {
    format!("{:.2}x less", base as f64 / v.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_is_aligned() {
        let mut t = Table::new("demo", &["method", "time"]);
        t.push_row(vec!["SSR".into(), "1.13 (0.01)".into()]);
        t.push_row(vec!["SSR-BEDPP".into(), "0.69 (0.01)".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("| SSR       |"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_roundtrip_quoting() {
        let dir = std::env::temp_dir().join("hssr_table_test");
        let mut t = Table::new("q", &["a", "b"]);
        t.push_row(vec!["x,y".into(), "plain".into()]);
        let p = dir.join("t.csv");
        t.write_csv(&p).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("\"x,y\",plain"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_cell_formatters() {
        assert_eq!(mb1(12_300_000), "12.3");
        assert_eq!(mb2(250_000), "0.25");
        assert_eq!(ratio_vs(320, 100), "3.20x less");
        assert_eq!(ratio_vs(100, 0), "100.00x less", "zero denominator guarded");
    }
}
