//! The fitting coordinator: configuration, replication job running,
//! rule-level analyses, and report generation.
//!
//! This is the framework layer a downstream user scripts against: declare
//! datasets ([`crate::data::DataSpec`]), pick methods
//! ([`crate::screening::RuleKind`]), and run timed method×dataset sweeps
//! with the paper's measurement protocol.

pub mod config;
pub mod cv;
pub mod jobs;
pub mod metrics;
pub mod report;
pub mod serve;
pub mod table;

use crate::bench_harness::{measure, Timing};
use crate::data::DataSpec;
use crate::error::Result;
use crate::screening::RuleKind;
use crate::solver::path::{fit_lasso_path, PathConfig};

/// Timed result of one method×dataset cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Method.
    pub rule: RuleKind,
    /// Dataset name.
    pub dataset: String,
    /// Mean(SE) seconds over replications.
    pub timing: Timing,
}

/// Run a timed method×dataset sweep: for every dataset spec and method,
/// fit the full path over `reps` replications (fresh data each rep, as the
/// paper does) and record mean(SE) wall-clock seconds.
///
/// Dataset generation is excluded from the timings (it happens in the
/// harness's untimed setup phase).
pub fn run_method_sweep(
    specs: &[DataSpec],
    methods: &[RuleKind],
    reps: usize,
    base_cfg: &PathConfig,
    seed0: u64,
) -> Result<Vec<CellResult>> {
    let mut out = Vec::new();
    for spec in specs {
        // Pre-generate datasets in parallel (untimed).
        let datasets = jobs::parallel_map(reps, jobs::default_threads(), |rep| {
            spec.generate(seed0 + rep as u64)
        });
        for &rule in methods {
            let mut cfg = base_cfg.clone();
            cfg.rule = rule;
            let timing = measure(
                reps,
                |rep| &datasets[rep],
                |ds| fit_lasso_path(ds, &cfg).expect("fit failed"),
            );
            out.push(CellResult { rule, dataset: spec.name(), timing });
        }
    }
    Ok(out)
}

/// Build the paper-style timing table (rows = methods, columns = datasets)
/// from sweep cells.
pub fn timing_table(title: &str, cells: &[CellResult]) -> report::Table {
    let mut datasets: Vec<String> = Vec::new();
    let mut methods: Vec<RuleKind> = Vec::new();
    for c in cells {
        if !datasets.contains(&c.dataset) {
            datasets.push(c.dataset.clone());
        }
        if !methods.contains(&c.rule) {
            methods.push(c.rule);
        }
    }
    let mut headers = vec!["Method".to_string()];
    headers.extend(datasets.iter().cloned());
    let mut table = report::Table {
        title: title.to_string(),
        headers,
        rows: Vec::new(),
    };
    for &m in &methods {
        let mut row = vec![m.label().to_string()];
        for d in &datasets {
            let cell = cells
                .iter()
                .find(|c| c.rule == m && &c.dataset == d)
                .map(|c| c.timing.paper_format())
                .unwrap_or_else(|| "—".to_string());
            row.push(cell);
        }
        table.rows.push(row);
    }
    table
}

/// Derive the Figure-3-style speedup table (vs `baseline`, normally
/// Basic PCD / Basic GD).
pub fn speedup_table(title: &str, cells: &[CellResult], baseline: RuleKind) -> report::Table {
    let mut datasets: Vec<String> = Vec::new();
    let mut methods: Vec<RuleKind> = Vec::new();
    for c in cells {
        if !datasets.contains(&c.dataset) {
            datasets.push(c.dataset.clone());
        }
        if !methods.contains(&c.rule) {
            methods.push(c.rule);
        }
    }
    let mut headers = vec!["Method".to_string()];
    headers.extend(datasets.iter().cloned());
    let mut table = report::Table { title: title.to_string(), headers, rows: Vec::new() };
    for &m in &methods {
        let mut row = vec![m.label().to_string()];
        for d in &datasets {
            let base = cells.iter().find(|c| c.rule == baseline && &c.dataset == d);
            let cell = cells.iter().find(|c| c.rule == m && &c.dataset == d);
            let s = match (base, cell) {
                (Some(b), Some(c)) => format!("{:.1}x", c.timing.speedup_vs(&b.timing)),
                _ => "—".to_string(),
            };
            row.push(s);
        }
        table.rows.push(row);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lambda::GridKind;
    use crate::solver::Penalty;

    #[test]
    fn sweep_and_tables() {
        let specs = [DataSpec::synthetic(40, 30, 3)];
        let methods = [RuleKind::BasicPcd, RuleKind::SsrBedpp];
        let cfg = PathConfig {
            rule: RuleKind::SsrBedpp,
            penalty: Penalty::Lasso,
            n_lambda: 10,
            lambda_min_ratio: 0.1,
            grid: GridKind::Linear,
            tol: 1e-7,
            max_iter: 100_000,
            lambdas: None,
            fused: true,
            rescreen_every: 10,
            checkpoint: None,
            ..PathConfig::default()
        };
        let cells = run_method_sweep(&specs, &methods, 2, &cfg, 5).unwrap();
        assert_eq!(cells.len(), 2);
        let t = timing_table("t", &cells);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.headers.len(), 2);
        let s = speedup_table("s", &cells, RuleKind::BasicPcd);
        assert!(s.rows[0][1].ends_with('x'));
    }
}
