//! Replication job runner: fans independent jobs (dataset generation,
//! non-timed fits, sweep cells) across the **persistent scan-worker pool**
//! ([`crate::linalg::pool`]) instead of spawning ad-hoc
//! `std::thread::scope` workers per call. Timed benchmark bodies run
//! sequentially to avoid interference; this runner covers the *untimed*
//! bulk work around them.
//!
//! Jobs that themselves issue screening scans are safe: a scan submitted
//! from inside a pool worker runs inline on that worker (the pool's
//! reentrancy guard), so the machine is never oversubscribed the way
//! nested `thread::scope` fan-outs were.

use crate::error::Result;
use crate::linalg::pool;

/// Run `f(i)` for `i in 0..jobs` across the shared worker pool, returning
/// results in index order. `threads <= 1` forces the serial path; larger
/// values defer to the pool's size (`available_parallelism()` or
/// `HSSR_THREADS`), claiming jobs by work stealing.
pub fn parallel_map<T, F>(jobs: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(jobs.max(1));
    if threads == 1 || jobs <= 1 {
        return (0..jobs).map(f).collect();
    }
    pool::global().map(jobs, f)
}

/// Fallible [`parallel_map`]: every job returns a `Result`, all jobs run
/// to completion (no cancellation mid-pool-dispatch), and the call returns
/// either every success in index order or the **first error by job index**
/// — deterministic regardless of which worker hit its error first. A fold
/// fit that fails must surface typed, never `panic!` a pool worker.
pub fn try_parallel_map<T, F>(jobs: usize, threads: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    let results: Vec<Result<T>> = parallel_map(jobs, threads, &f);
    let mut out = Vec::with_capacity(jobs);
    for r in results {
        out.push(r?);
    }
    Ok(out)
}

/// Default worker-thread count for untimed work: the shared pool's size
/// (no more 8-thread cap; `HSSR_THREADS` overrides).
pub fn default_threads() -> usize {
    pool::global().threads()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let out = parallel_map(20, 4, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_jobs() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn heavy_closure_runs_all() {
        let out = parallel_map(64, 8, |i| {
            let mut acc = 0u64;
            for k in 0..1000 {
                acc = acc.wrapping_add((i as u64).wrapping_mul(k));
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    /// Jobs that scan through the pool must not deadlock (reentrancy): the
    /// scan must be large enough (n·p ≥ PAR_THRESHOLD) that
    /// `blocked::scan_all_vec` really submits to the pool from inside a
    /// pool worker, exercising the inline fallback.
    #[test]
    fn jobs_with_nested_scans_complete() {
        use crate::data::DataSpec;
        use crate::linalg::blocked;
        use crate::linalg::blocked::PAR_THRESHOLD;
        let n = 600;
        let p = PAR_THRESHOLD / n + 50;
        let ds = DataSpec::synthetic(n, p, 4).generate(9);
        let reference = blocked::scan_all_vec(&ds.x, &ds.y);
        let out = parallel_map(6, 4, |i| {
            let z = blocked::scan_all_vec(&ds.x, &ds.y);
            z[i * 7 % z.len()]
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, reference[i * 7 % reference.len()]);
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn try_map_collects_successes_in_order() {
        let out = try_parallel_map(16, 4, |i| Ok(i * 3)).unwrap();
        assert_eq!(out, (0..16).map(|i| i * 3).collect::<Vec<_>>());
    }

    /// The error surfaced is the first *by job index*, not by wall-clock
    /// completion order — deterministic under work stealing.
    #[test]
    fn try_map_returns_first_error_by_index() {
        use crate::error::HssrError;
        let err = try_parallel_map(12, 4, |i| -> crate::error::Result<usize> {
            if i == 3 || i == 9 {
                Err(HssrError::Config(format!("job {i} failed")))
            } else {
                Ok(i)
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("job 3 failed"), "got {err}");
    }

    #[test]
    fn try_map_serial_path_matches() {
        let out = try_parallel_map(5, 1, |i| Ok(i)).unwrap();
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }
}
