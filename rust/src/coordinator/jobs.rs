//! Replication job runner: fans independent jobs (dataset generation,
//! non-timed fits, sweep cells) across the **persistent scan-worker pool**
//! ([`crate::linalg::pool`]) instead of spawning ad-hoc
//! `std::thread::scope` workers per call. Timed benchmark bodies run
//! sequentially to avoid interference; this runner covers the *untimed*
//! bulk work around them.
//!
//! Jobs that themselves issue screening scans are safe: a scan submitted
//! from inside a pool worker runs inline on that worker (the pool's
//! reentrancy guard), so the machine is never oversubscribed the way
//! nested `thread::scope` fan-outs were.

use crate::linalg::pool;

/// Run `f(i)` for `i in 0..jobs` across the shared worker pool, returning
/// results in index order. `threads <= 1` forces the serial path; larger
/// values defer to the pool's size (`available_parallelism()` or
/// `HSSR_THREADS`), claiming jobs by work stealing.
pub fn parallel_map<T, F>(jobs: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(jobs.max(1));
    if threads == 1 || jobs <= 1 {
        return (0..jobs).map(f).collect();
    }
    pool::global().map(jobs, f)
}

/// Default worker-thread count for untimed work: the shared pool's size
/// (no more 8-thread cap; `HSSR_THREADS` overrides).
pub fn default_threads() -> usize {
    pool::global().threads()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let out = parallel_map(20, 4, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_jobs() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn heavy_closure_runs_all() {
        let out = parallel_map(64, 8, |i| {
            let mut acc = 0u64;
            for k in 0..1000 {
                acc = acc.wrapping_add((i as u64).wrapping_mul(k));
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    /// Jobs that scan through the pool must not deadlock (reentrancy): the
    /// scan must be large enough (n·p ≥ PAR_THRESHOLD) that
    /// `blocked::scan_all_vec` really submits to the pool from inside a
    /// pool worker, exercising the inline fallback.
    #[test]
    fn jobs_with_nested_scans_complete() {
        use crate::data::DataSpec;
        use crate::linalg::blocked;
        use crate::linalg::blocked::PAR_THRESHOLD;
        let n = 600;
        let p = PAR_THRESHOLD / n + 50;
        let ds = DataSpec::synthetic(n, p, 4).generate(9);
        let reference = blocked::scan_all_vec(&ds.x, &ds.y);
        let out = parallel_map(6, 4, |i| {
            let z = blocked::scan_all_vec(&ds.x, &ds.y);
            z[i * 7 % z.len()]
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, reference[i * 7 % reference.len()]);
        }
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }
}
