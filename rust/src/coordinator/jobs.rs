//! Replication job runner: fans independent jobs (dataset generation,
//! non-timed fits, sweep cells) across worker threads with
//! `std::thread::scope`. Timed benchmark bodies run sequentially to avoid
//! interference; this runner covers the *untimed* bulk work around them.

/// Run `f(i)` for `i in 0..jobs` across up to `threads` workers, returning
/// results in index order.
pub fn parallel_map<T, F>(jobs: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(jobs.max(1));
    if threads == 1 || jobs <= 1 {
        return (0..jobs).map(f).collect();
    }
    let mut results: Vec<Option<T>> = (0..jobs).map(|_| None).collect();
    // Work-stealing queue of (index, &mut slot): each slot is popped (and
    // hence written) by exactly one worker — no unsafe needed.
    let work = std::sync::Mutex::new(results.iter_mut().enumerate().collect::<Vec<_>>());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let item = work.lock().unwrap().pop();
                let Some((i, slot)) = item else { break };
                *slot = Some(f(i));
            });
        }
    });
    results.into_iter().map(|r| r.expect("job completed")).collect()
}

/// Default worker-thread count for untimed work.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let out = parallel_map(20, 4, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_jobs() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn heavy_closure_runs_all() {
        let out = parallel_map(64, 8, |i| {
            let mut acc = 0u64;
            for k in 0..1000 {
                acc = acc.wrapping_add((i as u64).wrapping_mul(k));
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }
}
