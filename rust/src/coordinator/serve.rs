//! The concurrent fit service: many λ-paths, one worker pool, one cache.
//!
//! [`FitService`] multiplexes many concurrent [`PathConfig`] fits onto a
//! **single** shared [`ColumnStore`] — one bounded chunk cache, one set of
//! I/O counters — instead of giving every fit its own spill and cache.
//! Three mechanisms make that safe and fast:
//!
//! * **Admission control.** A counting semaphore (`max_concurrent`
//!   permits) bounds how many fits are in flight at once, so a burst of
//!   requests degrades into an orderly queue instead of thrashing the
//!   shared cache. Queued fits park on a condvar; permits are RAII so an
//!   erroring fit can never leak its slot.
//! * **Fit tagging.** Every admitted fit gets a process-unique id
//!   (starting at 1; 0 means untagged) installed as the thread's
//!   [`FitTag`]. The store stamps cached chunks with the id that loaded
//!   them, so a cache hit on another fit's chunk is counted as a
//!   *cross-fit* hit ([`crate::data::store::StoreCounters::cross_fit_hits`])
//!   — the measurable payoff of sharing one cache.
//! * **Warm-start registry.** Completed fits deposit their
//!   [`WarmStart`] (final solver state + λ-prefix) keyed by everything
//!   that affects the solution *except* the λ grid. A later request with
//!   a compatible grid prefix resumes from the registry instead of
//!   re-solving from λmax — bit-identical to a cold fit by the driver's
//!   adoption contract (see [`WarmStart::compatible`]).
//!
//! Batches run on the shared worker pool via
//! [`super::jobs::try_parallel_map`]; the pool's inline-reentrancy rule
//! means fits waiting on a permit can never deadlock the scans of the
//! fits that hold one.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::jobs;
use crate::coordinator::table::Table;
use crate::data::store::{ColumnStore, FitTag};
use crate::error::Result;
use crate::obs::registry::{Gauge, Histogram};
use crate::obs::trace::Span;
use crate::solver::path::{fit_lasso_path_store, PathConfig, PathFit, WarmStart};

/// Lock with poison recovery: a fit that panicked while holding the lock
/// must not wedge the whole service (the guarded state — a permit count
/// and a warm-start map — is valid at every step).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// One completed fit from the service.
#[derive(Clone, Debug)]
pub struct FitResponse {
    /// The fitted path (identical to a standalone [`fit_lasso_path_store`]
    /// run of the same config).
    pub fit: PathFit,
    /// The process-unique fit id this job ran under (chunk-cache owner
    /// tag; ids start at 1).
    pub fit_id: u64,
    /// Whether the warm-start registry held an entry for this config's
    /// key and offered it to the driver (adoption is still subject to
    /// [`WarmStart::compatible`] — an incompatible grid falls back to a
    /// cold start silently).
    pub warm_hit: bool,
}

/// A long-running fit service over one shared [`ColumnStore`].
///
/// The service is `Sync`: call [`FitService::run_one`] from any number of
/// threads, or hand a whole batch to [`FitService::run_batch`].
pub struct FitService {
    store: Arc<ColumnStore>,
    /// Free admission permits; waiters park on `available`.
    slots: Mutex<usize>,
    available: Condvar,
    /// Best known warm start per config key (longest λ-prefix wins).
    registry: Mutex<HashMap<String, WarmStart>>,
    /// Monotone fit-id source; also counts fits admitted so far.
    next_fit: AtomicU64,
    in_flight: AtomicU64,
    peak_in_flight: AtomicU64,
    max_concurrent: usize,
    /// Per-fit wall-clock latency in µs (always-on — recording is a few
    /// relaxed atomic adds; [`FitService::stats_report`] reads
    /// p50/p95/p99 out of it).
    fit_latency_us: Histogram,
    /// Fits currently parked waiting for an admission permit (with its
    /// high-water mark).
    queue_depth: Gauge,
}

/// RAII admission permit: returns the slot (and decrements the in-flight
/// gauge) on drop, even when the fit errors.
struct Permit<'a> {
    svc: &'a FitService,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.svc.in_flight.fetch_sub(1, Ordering::Relaxed);
        let mut slots = lock(&self.svc.slots);
        *slots += 1;
        self.svc.available.notify_one();
    }
}

/// The registry key: everything that affects the solution path *except*
/// the λ grid, so a request extending an earlier grid still hits. Floats
/// are keyed by bit pattern ([`WarmStart::compatible`] re-checks the
/// grid prefix bitwise at adoption time).
fn registry_key(cfg: &PathConfig) -> String {
    format!(
        "{:?}|a{:016x}|t{:016x}|i{}|r{}|f{}",
        cfg.rule,
        cfg.penalty.alpha().to_bits(),
        cfg.tol.to_bits(),
        cfg.max_iter,
        cfg.rescreen_every,
        cfg.fused
    )
}

impl FitService {
    /// Stand up a service over an already-mounted store. `max_concurrent`
    /// bounds in-flight fits (clamped to at least 1).
    pub fn new(store: Arc<ColumnStore>, max_concurrent: usize) -> FitService {
        let max_concurrent = max_concurrent.max(1);
        FitService {
            store,
            slots: Mutex::new(max_concurrent),
            available: Condvar::new(),
            registry: Mutex::new(HashMap::new()),
            next_fit: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            peak_in_flight: AtomicU64::new(0),
            max_concurrent,
            fit_latency_us: Histogram::new(),
            queue_depth: Gauge::new(),
        }
    }

    /// Block until an admission permit is free, then claim it. The wait
    /// is gauged (queue depth) and, when tracing is on, spanned — queue
    /// time is the serve-mode latency component a bigger `max_concurrent`
    /// or a second replica would buy back.
    fn acquire(&self) -> Permit<'_> {
        let mut wait_span = Span::begin("queue_wait", "serve");
        self.queue_depth.add(1);
        let mut slots = lock(&self.slots);
        while *slots == 0 {
            slots = self
                .available
                .wait(slots)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        *slots -= 1;
        drop(slots);
        self.queue_depth.add(-1);
        let now = self.in_flight.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_in_flight.fetch_max(now, Ordering::Relaxed);
        wait_span.arg_u64("in_flight", now);
        Permit { svc: self }
    }

    /// Run one fit: wait for admission, tag the thread with a fresh fit
    /// id, consult the warm-start registry, fit against the shared store,
    /// and deposit the resulting warm start (longest λ-prefix per key
    /// wins).
    pub fn run_one(&self, cfg: &PathConfig) -> Result<FitResponse> {
        let _permit = self.acquire();
        let fit_id = self.next_fit.fetch_add(1, Ordering::Relaxed) + 1;
        let _tag = FitTag::set(fit_id);
        let mut fit_span = Span::begin("serve_fit", "serve");
        fit_span.arg_u64("fit_id", fit_id);
        // Counter hygiene: the store's counters are shared by every
        // in-flight fit, so per-fit traffic is *never* measured by
        // resetting them (that would steal concurrent fits' traffic) —
        // snapshot deltas bound this fit's window, and true per-fit
        // attribution comes from the `FitTag` set above (cross_fit_hits).
        let io0 = if fit_span.is_on() { Some(self.store.counters().snapshot()) } else { None };
        let t0 = std::time::Instant::now();
        let key = registry_key(cfg);
        let warm = lock(&self.registry).get(&key).cloned();
        let warm_hit = warm.is_some();
        let out = fit_lasso_path_store(Arc::clone(&self.store), cfg, warm.as_ref());
        self.fit_latency_us.record(t0.elapsed().as_micros() as u64);
        if let Some(io0) = io0 {
            let d = self.store.counters().snapshot().delta_since(&io0);
            fit_span.arg_u64("cols_fetched_window", d.cols_fetched);
            fit_span.arg_u64("chunk_loads_window", d.chunk_loads);
            fit_span.arg_u64("cross_fit_hits_window", d.cross_fit_hits);
        }
        drop(fit_span);
        let (fit, warm_out) = out?;
        if let Some(w) = warm_out {
            let mut reg = lock(&self.registry);
            let keep = match reg.get(&key) {
                Some(prev) => prev.prefix_len() < w.prefix_len(),
                None => true,
            };
            if keep {
                reg.insert(key, w);
            }
        }
        Ok(FitResponse { fit, fit_id, warm_hit })
    }

    /// Run a batch of fits concurrently on the shared worker pool. All
    /// jobs run to completion; the first error (by batch index) is
    /// returned, otherwise responses come back in batch order.
    pub fn run_batch(&self, cfgs: &[PathConfig]) -> Result<Vec<FitResponse>> {
        jobs::try_parallel_map(cfgs.len(), jobs::default_threads(), |i| self.run_one(&cfgs[i]))
    }

    /// The shared store (shape, cache budget, counters).
    pub fn store(&self) -> &ColumnStore {
        &self.store
    }

    /// Cache hits on chunks loaded by a *different* fit — the measurable
    /// benefit of one shared chunk cache across concurrent paths.
    pub fn cross_fit_hits(&self) -> u64 {
        self.store.counters().cross_fit_hits()
    }

    /// Total fits admitted so far (equals the highest fit id handed out).
    pub fn fits_served(&self) -> u64 {
        self.next_fit.load(Ordering::Relaxed)
    }

    /// High-water mark of concurrently running fits (≤ `max_concurrent`).
    pub fn peak_in_flight(&self) -> u64 {
        self.peak_in_flight.load(Ordering::Relaxed)
    }

    /// The admission bound this service was built with.
    pub fn max_concurrent(&self) -> usize {
        self.max_concurrent
    }

    /// Number of distinct warm-start registry entries currently held.
    pub fn registry_len(&self) -> usize {
        lock(&self.registry).len()
    }

    /// The per-fit latency histogram (µs) — always recording.
    pub fn fit_latency_us(&self) -> &Histogram {
        &self.fit_latency_us
    }

    /// Fits currently parked waiting for admission.
    pub fn queue_depth(&self) -> i64 {
        self.queue_depth.get()
    }

    /// High-water mark of the admission queue.
    pub fn peak_queue_depth(&self) -> i64 {
        self.queue_depth.peak()
    }

    /// Live telemetry table: fit-latency percentiles, queue depth, and
    /// shared-cache effectiveness — the `hssr serve` stats report.
    pub fn stats_report(&self) -> Table {
        let h = &self.fit_latency_us;
        let c = self.store.counters();
        let demand = c.cache_hits() + c.chunk_loads();
        let hit_rate = if demand == 0 {
            "—".to_string()
        } else {
            format!("{:.1}%", 100.0 * c.cache_hits() as f64 / demand as f64)
        };
        let q_ms = |q: f64| format!("{:.2}", h.quantile(q) as f64 / 1e3);
        let mut t = Table::new("Serve telemetry", &["stat", "value"]);
        t.push_row(vec!["fits served".into(), self.fits_served().to_string()]);
        t.push_row(vec!["in flight (peak)".into(), self.peak_in_flight().to_string()]);
        t.push_row(vec!["queue depth".into(), self.queue_depth().to_string()]);
        t.push_row(vec!["queue depth (peak)".into(), self.peak_queue_depth().to_string()]);
        t.push_row(vec!["fit latency p50 (ms)".into(), q_ms(0.50)]);
        t.push_row(vec!["fit latency p95 (ms)".into(), q_ms(0.95)]);
        t.push_row(vec!["fit latency p99 (ms)".into(), q_ms(0.99)]);
        t.push_row(vec!["fit latency mean (ms)".into(), format!("{:.2}", h.mean() / 1e3)]);
        t.push_row(vec!["cache hit rate".into(), hit_rate]);
        t.push_row(vec!["cross-fit hits".into(), self.cross_fit_hits().to_string()]);
        t.push_row(vec!["warm registry entries".into(), self.registry_len().to_string()]);
        t
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::runtime::ooc::OocEngine;
    use crate::screening::RuleKind;

    fn cfg_for(rule: RuleKind) -> PathConfig {
        PathConfig {
            rule,
            n_lambda: 8,
            lambda_min_ratio: 0.2,
            tol: 1e-6,
            max_iter: 2_000,
            ..PathConfig::default()
        }
    }

    /// A concurrent batch over one shared store must be bit-identical to
    /// standalone fits of the same configs, while the shared cache
    /// records cross-fit hits and admission stays within its bound.
    #[test]
    fn concurrent_batch_matches_standalone_and_shares_cache() {
        let ds = DataSpec::gene_like(40, 120).generate(7);
        let engine = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
        let svc = FitService::new(engine.shared_store(), 2);
        let cfgs: Vec<PathConfig> =
            [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe, RuleKind::Ssr]
                .iter()
                .map(|&r| cfg_for(r))
                .collect();
        let out = svc.run_batch(&cfgs).unwrap();
        assert_eq!(out.len(), 4);
        for (cfg, resp) in cfgs.iter().zip(&out) {
            let fresh = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
            let (want, _) = fit_lasso_path_store(fresh.shared_store(), cfg, None).unwrap();
            assert_eq!(resp.fit.lambdas, want.lambdas, "{:?}: λ grid differs", cfg.rule);
            assert_eq!(resp.fit.betas, want.betas, "{:?}: betas differ", cfg.rule);
            assert!(resp.fit.error.is_none());
        }
        let mut ids: Vec<u64> = out.iter().map(|r| r.fit_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "fit ids must be unique");
        assert!(ids.iter().all(|&id| id >= 1), "fit ids start at 1");
        assert!(svc.cross_fit_hits() > 0, "shared cache never crossed fits");
        assert_eq!(svc.fits_served(), 4);
        assert!(svc.peak_in_flight() <= 2, "admission bound violated");
    }

    /// A second request with the same config key and an extended λ grid
    /// resumes from the registry: the prefix is served verbatim and the
    /// extended fit is bit-identical to a cold fit over the full grid.
    #[test]
    fn warm_start_registry_serves_prefixes() {
        let ds = DataSpec::synthetic(30, 40, 3).generate(3);
        let engine = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
        let svc = FitService::new(engine.shared_store(), 1);
        let mut cfg = cfg_for(RuleKind::SsrBedpp);
        cfg.n_lambda = 6;
        let first = svc.run_one(&cfg).unwrap();
        assert!(!first.warm_hit, "empty registry cannot hit");
        assert_eq!(svc.registry_len(), 1);
        let mut grid = first.fit.lambdas.clone();
        grid.push(grid.last().unwrap() * 0.5);
        cfg.lambdas = Some(grid.clone());
        let second = svc.run_one(&cfg).unwrap();
        assert!(second.warm_hit, "registry entry was not offered");
        assert_eq!(second.fit.lambdas, grid);
        let k = first.fit.betas.len();
        assert_eq!(&second.fit.betas[..k], &first.fit.betas[..], "prefix not served verbatim");
        let fresh = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
        let (cold, _) = fit_lasso_path_store(fresh.shared_store(), &cfg, None).unwrap();
        assert_eq!(second.fit.betas, cold.betas, "warm resume deviates from cold fit");
    }

    /// Counter-drain hygiene: the service never resets the shared store's
    /// counters — totals accumulate monotonically across batches (so no
    /// fit's traffic is silently stolen from another's report), the
    /// latency histogram records every fit, and the queue drains back to
    /// zero depth.
    #[test]
    fn serve_never_resets_shared_counters_and_reports_stats() {
        let ds = DataSpec::synthetic(30, 60, 3).generate(17);
        let engine = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
        let svc = FitService::new(engine.shared_store(), 2);
        let cfgs = vec![cfg_for(RuleKind::Ssr), cfg_for(RuleKind::SsrBedpp)];
        svc.run_batch(&cfgs).unwrap();
        let after_first = svc.store().counters().snapshot();
        assert!(after_first.cols_fetched > 0);
        svc.run_batch(&cfgs).unwrap();
        let after_second = svc.store().counters().snapshot();
        assert!(
            after_second.cols_fetched > after_first.cols_fetched,
            "second batch must accumulate on top of the first — a reset \
             mid-serve would break shared-cache accounting"
        );
        assert_eq!(svc.fit_latency_us().count(), 4, "every fit records a latency sample");
        assert!(svc.fit_latency_us().quantile(0.99) >= svc.fit_latency_us().quantile(0.50));
        assert_eq!(svc.queue_depth(), 0, "queue must drain");
        assert!(svc.peak_queue_depth() >= 0);
        let report = svc.stats_report();
        assert!(report.rows.iter().any(|r| r[0] == "fit latency p95 (ms)"));
        assert!(report.rows.iter().any(|r| r[0] == "queue depth"));
    }

    /// Different rules key different registry entries; a narrower
    /// admission bound still completes every job in the batch.
    #[test]
    fn registry_keys_are_config_scoped() {
        let ds = DataSpec::synthetic(25, 30, 2).generate(11);
        let engine = OocEngine::spill(&ds.x, &ds.y, 1 << 20).unwrap();
        let svc = FitService::new(engine.shared_store(), 1);
        let cfgs = vec![cfg_for(RuleKind::Ssr), cfg_for(RuleKind::SsrBedpp)];
        let out = svc.run_batch(&cfgs).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(svc.registry_len(), 2, "distinct rules must not share a key");
        assert!(svc.peak_in_flight() <= 1);
        let mut tol_cfg = cfg_for(RuleKind::Ssr);
        tol_cfg.tol = 1e-8;
        assert_ne!(
            registry_key(&cfgs[0]),
            registry_key(&tol_cfg),
            "tolerance must be part of the key"
        );
    }
}
