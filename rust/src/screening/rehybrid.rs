//! Re-hybridization — the paper's §6 "future work" rule, implemented.
//!
//! SSR-BEDPP loses its safe half once BEDPP's RHS goes non-positive
//! (≈ 0.45·λmax on GENE-like data). The paper sketches the fix: at that
//! point, *freeze* an SEDPP rule at the current solution `β̂(λ_ref)`. Rule
//! (10) with `λ_k = λ_ref` fixed needs `O(np)` once — the scan
//! `u_j = x_jᵀr(λ_ref)` and the projection weights — and then only `O(p)`
//! per subsequent λ, because only the scalar `c = (λ_ref−λ)/(λ_ref·λ)`
//! varies. The result is a safe rule that stays powerful deep into the path
//! at BEDPP's asymptotic cost.

use super::bedpp::Bedpp;
use super::{PrevSolution, SafeContext, SafeRule};
use crate::linalg::{blocked, DenseMatrix};
use crate::serialize::{ByteReader, ByteWriter};

/// Per-feature constants of the frozen rule.
struct Frozen {
    /// λ_ref the rule was frozen at.
    lam_ref: f64,
    /// `u_j = x_jᵀ r(λ_ref) / λ_ref`.
    u: Vec<f64>,
    /// `w_j = x_jᵀy − a·x_jᵀXβ̂/‖Xβ̂‖²`.
    w: Vec<f64>,
    /// `√(n‖y‖² − n·a²/‖Xβ̂‖²)`.
    rhs_root: f64,
}

impl Frozen {
    /// Freeze rule (10) at the previous solution. `O(np)` (one scan, via
    /// the in-process blocked kernels — the unrouted path).
    #[cfg(test)]
    fn build(x: &DenseMatrix, ctx: &SafeContext, prev: &PrevSolution<'_>) -> Option<Frozen> {
        // The in-process blocked scan cannot fail.
        match Frozen::build_with(ctx, prev, |z| {
            blocked::scan_all(x, prev.r, z);
            Ok(())
        }) {
            Ok(Some((f, _))) => Some(f),
            _ => None,
        }
    }

    /// Freeze-time body with the `O(np)` scan abstracted: `scan` fills
    /// `z = Xᵀr/n`; the second return value is the number of columns that
    /// pass read (0 when freezing is impossible), so routed callers can
    /// account the traffic.
    fn build_with<F>(
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        scan: F,
    ) -> crate::error::Result<Option<(Frozen, u64)>>
    where
        F: FnOnce(&mut [f64]) -> crate::error::Result<()>,
    {
        let n = ctx.n as f64;
        let mut xb_sq = 0.0;
        let mut a = 0.0;
        for (yi, ri) in ctx.y.iter().zip(prev.r) {
            let f = yi - ri;
            xb_sq += f * f;
            a += yi * f;
        }
        if xb_sq < 1e-12 {
            return Ok(None); // no solution mass yet; cannot freeze
        }
        let mut z = vec![0.0; ctx.p];
        scan(&mut z)?;
        let mut u = Vec::with_capacity(ctx.p);
        let mut w = Vec::with_capacity(ctx.p);
        for j in 0..ctx.p {
            let xjr = n * z[j];
            let xjxb = ctx.xty[j] - xjr;
            u.push(xjr / prev.lambda);
            w.push(ctx.xty[j] - a * xjxb / xb_sq);
        }
        let rhs_root = (n * ctx.y_sq - n * a * a / xb_sq).max(0.0).sqrt();
        Ok(Some((Frozen { lam_ref: prev.lambda, u, w, rhs_root }, ctx.p as u64)))
    }

    /// `O(p)` evaluation at `lam < lam_ref`.
    fn screen_at(&self, ctx: &SafeContext, lam: f64, survive: &mut [bool]) -> usize {
        let n = ctx.n as f64;
        let c = (self.lam_ref - lam) / (self.lam_ref * lam);
        let rhs = n - 0.5 * c * self.rhs_root;
        if rhs <= 0.0 {
            return 0;
        }
        let mut discarded = 0;
        for j in 0..ctx.p {
            if survive[j] && (self.u[j] + 0.5 * c * self.w[j]).abs() < rhs {
                survive[j] = false;
                discarded += 1;
            }
        }
        discarded
    }
}

/// BEDPP until it dies, then a frozen SEDPP ("SSR-BEDPP-SEDPP" when hybridized
/// with SSR by Algorithm 1).
#[derive(Default)]
pub struct BedppThenFrozenSedpp {
    bedpp_alive: bool,
    frozen: Option<Frozen>,
    dead: bool,
}

impl BedppThenFrozenSedpp {
    /// Create a fresh rule.
    pub fn new() -> Self {
        BedppThenFrozenSedpp { bedpp_alive: true, frozen: None, dead: false }
    }

    /// Whether the rule has entered its frozen-SEDPP phase.
    pub fn is_frozen(&self) -> bool {
        self.frozen.is_some()
    }

    /// Phase machine shared by the dense and engine-routed screens. `scan`
    /// fills `z = Xᵀr/n` at freeze time; `scanned` receives the columns it
    /// read. Every other phase — BEDPP, the frozen rule — is `O(p)` over
    /// precomputed constants and reads no columns.
    fn screen_core<F>(
        &mut self,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        scan: F,
        scanned: &mut u64,
    ) -> crate::error::Result<usize>
    where
        F: FnOnce(&mut [f64]) -> crate::error::Result<()>,
    {
        if self.dead {
            return Ok(0);
        }
        if self.bedpp_alive {
            let d = Bedpp::screen_at(ctx, lam_next, survive);
            if d > 0 {
                return Ok(d);
            }
            // BEDPP just died — re-hybridize by freezing SEDPP here. The
            // frozen rule is rule (10), which is derived for the lasso
            // only (the enet's augmented design varies with λ), so under
            // an elastic-net penalty we simply shut off like plain BEDPP.
            self.bedpp_alive = false;
            self.frozen = if matches!(ctx.penalty, crate::solver::Penalty::Lasso) {
                match Frozen::build_with(ctx, prev, scan)? {
                    Some((f, cols)) => {
                        *scanned += cols;
                        Some(f)
                    }
                    None => None,
                }
            } else {
                None
            };
            if self.frozen.is_none() {
                self.dead = true;
                return Ok(0);
            }
        }
        let frozen = self.frozen.as_ref().expect("frozen phase");
        let d = frozen.screen_at(ctx, lam_next, survive);
        if d == 0 {
            // The frozen rule's power decays too; once it discards nothing
            // it never will again at smaller λ-to-λ_ref gaps that only grow,
            // so shut off (Algorithm 1 Flag semantics).
            self.dead = true;
        }
        Ok(d)
    }
}

impl SafeRule for BedppThenFrozenSedpp {
    fn name(&self) -> &'static str {
        "BEDPP→SEDPP"
    }

    fn screen(
        &mut self,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        let mut scanned = 0u64;
        // The in-process blocked scan cannot fail.
        self.screen_core(
            ctx,
            prev,
            lam_next,
            survive,
            |z| {
                blocked::scan_all(x, prev.r, z);
                Ok(())
            },
            &mut scanned,
        )
        .unwrap_or(0)
    }

    fn screen_routed(
        &mut self,
        engine: &dyn crate::runtime::ScanEngine,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        scanned: &mut u64,
    ) -> crate::error::Result<usize> {
        self.screen_core(
            ctx,
            prev,
            lam_next,
            survive,
            |z| engine.scan_all(x, prev.r, z),
            scanned,
        )
    }

    fn plan_routed<'s>(
        &'s mut self,
        engine: &dyn crate::runtime::ScanEngine,
        x: &DenseMatrix,
        ctx: &'s SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        masked_discards: &mut usize,
        scanned: &mut u64,
    ) -> crate::error::Result<Option<Box<dyn Fn(usize) -> bool + Sync + 's>>> {
        *masked_discards =
            self.screen_routed(engine, x, ctx, prev, lam_next, survive, scanned)?;
        Ok(None)
    }

    fn dead(&self) -> bool {
        self.dead
    }

    /// The re-hybridized rule's phase machine *is* path state: whether
    /// BEDPP is still alive, and — once frozen — the `O(p)` constants of
    /// rule (10) at λ_ref. A resumed fit must not re-freeze at a different
    /// λ (the frozen rule would screen differently), so the whole frozen
    /// block rides in the checkpoint.
    fn save_state(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(self.bedpp_alive as u8);
        w.put_u8(self.dead as u8);
        match &self.frozen {
            None => w.put_u8(0),
            Some(f) => {
                w.put_u8(1);
                w.put_f64(f.lam_ref);
                w.put_f64s(&f.u);
                w.put_f64s(&f.w);
                w.put_f64(f.rhs_root);
            }
        }
        w.into_bytes()
    }

    fn load_state(&mut self, state: &[u8]) -> crate::error::Result<()> {
        let mut r = ByteReader::new(state);
        self.bedpp_alive = r.get_u8()? != 0;
        self.dead = r.get_u8()? != 0;
        self.frozen = if r.get_u8()? != 0 {
            Some(Frozen {
                lam_ref: r.get_f64()?,
                u: r.get_f64s()?,
                w: r.get_f64s()?,
                rhs_root: r.get_f64()?,
            })
        } else {
            None
        };
        if r.remaining() != 0 {
            return Err(crate::error::HssrError::Corrupt(
                "BEDPP→SEDPP: trailing bytes in safe-rule checkpoint state".into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::screening::sedpp::Sedpp;
    use crate::solver::Penalty;

    fn setup(seed: u64) -> (crate::data::Dataset, SafeContext) {
        let ds = DataSpec::synthetic(60, 40, 4).generate(seed);
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        (ds, ctx)
    }

    /// The frozen rule at its freeze point must agree exactly with a live
    /// SEDPP screen from the same previous solution.
    #[test]
    fn frozen_matches_live_sedpp() {
        let (ds, ctx) = setup(1);
        let mut beta = vec![0.0; ctx.p];
        beta[2] = 0.15;
        beta[7] = -0.1;
        let xb = ds.x.matvec(&beta);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let lam_ref = 0.5 * ctx.lambda_max;
        let prev = PrevSolution { lambda: lam_ref, r: &r, beta: Some(&beta) };
        let frozen = Frozen::build(&ds.x, &ctx, &prev).unwrap();
        for frac in [0.45, 0.4, 0.3] {
            let lam = frac * ctx.lambda_max;
            let mut s_frozen = vec![true; ctx.p];
            frozen.screen_at(&ctx, lam, &mut s_frozen);
            let mut s_live = vec![true; ctx.p];
            let mut live = Sedpp::new();
            live.screen_with(&ds.x, &ctx, &prev, lam, &mut s_live);
            assert_eq!(s_frozen, s_live, "mismatch at λ = {frac}·λmax");
        }
    }

    #[test]
    fn phase_transition_happens() {
        let (ds, ctx) = setup(2);
        let mut rule = BedppThenFrozenSedpp::new();
        // Simulate a previous solution mid-path.
        let mut beta = vec![0.0; ctx.p];
        beta[1] = 0.2;
        let xb = ds.x.matvec(&beta);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        // High λ: BEDPP phase.
        let prev_hi = PrevSolution { lambda: 0.95 * ctx.lambda_max, r: &ds.y, beta: None };
        let mut s = vec![true; ctx.p];
        rule.screen(&ds.x, &ctx, &prev_hi, 0.9 * ctx.lambda_max, &mut s);
        assert!(!rule.is_frozen());
        // Low λ: BEDPP dies, freeze kicks in.
        let prev_lo = PrevSolution { lambda: 0.2 * ctx.lambda_max, r: &r, beta: Some(&beta) };
        let mut s2 = vec![true; ctx.p];
        rule.screen(&ds.x, &ctx, &prev_lo, 0.18 * ctx.lambda_max, &mut s2);
        assert!(rule.is_frozen() || rule.dead());
    }

    #[test]
    fn cannot_freeze_without_solution_mass() {
        let (ds, ctx) = setup(3);
        let mut rule = BedppThenFrozenSedpp::new();
        // Residual = y (β̂ = 0) at tiny λ: BEDPP dead, freeze impossible.
        let prev = PrevSolution { lambda: 0.05 * ctx.lambda_max, r: &ds.y, beta: None };
        let mut s = vec![true; ctx.p];
        let d = rule.screen(&ds.x, &ctx, &prev, 0.04 * ctx.lambda_max, &mut s);
        assert_eq!(d, 0);
        assert!(rule.dead());
    }
}
