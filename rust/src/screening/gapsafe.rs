//! **Dynamic gap-safe sphere screening** — Fercoq, Gramfort & Salmon
//! (2015), "Mind the duality gap", instantiated as the safe half of a
//! hybrid safe-strong rule (Definition 3.1) for all three problem
//! families.
//!
//! Where the static rules (BEDPP, Dome, SEDPP) bound the dual optimum from
//! per-fit precomputes — and die as λ shrinks — the gap-safe rule builds
//! its ball from **any primal/dual pair**: given a point `β` with residual
//! `r` and duality gap `G` at the λ being screened, the dual optimum lies
//! in a ball of radius `√(2G/μ)` around the scaled residual
//! ([`crate::solver::duality`]). The rule therefore
//!
//! * works at *every* λ (its power grows as the path warm start improves),
//! * applies to any loss with a computable gap — including the logistic
//!   family, which has **no** static safe rule, and the group elastic net,
//!   where SEDPP falls back to the basic rule — and
//! * is *dynamic* ([`SafeRule::dynamic`]): Algorithm 1 re-fires it
//!   mid-optimization through
//!   [`crate::solver::driver::Problem::rescreen`] and the families'
//!   bounded-burst inner solves, where the shrinking gap makes it
//!   strictly stronger than at screen time.
//!
//! The unit test is identical across families (see
//! [`crate::solver::duality::DualBall`]):
//!
//! ```text
//! discard u  ⇔  ‖z̃_u‖/s + ρ < αλ·w_u,      z̃_u = X_uᵀr/n − (1−α)λ·β_u,
//! ```
//!
//! with `w_u = 1` for columns and `√W_g` for groups. One full `O(np)` scan
//! per invocation (exactly SEDPP's cost class, Table 1) computes every
//! `z̃_u` and the dual feasibility scaling `s` at once.

use super::{group::GroupSafeContext, PrevSolution, SafeContext, SafeRule};
use crate::error::Result;
use crate::linalg::{ops, simd, DenseMatrix};
use crate::runtime::{native::NativeEngine, Precision, ScanEngine};
use crate::solver::duality;
use crate::solver::Penalty;

/// Loss family a [`GapSafe`] ball is computed for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GapLoss {
    /// Quadratic loss — lasso / elastic net columns.
    Quadratic,
    /// Logistic loss — the ℓ1/elastic-net logistic path.
    Logistic,
}

/// Per-invocation scalars of the pointwise gap-safe test.
#[derive(Clone, Copy)]
struct Scalars {
    /// Dual feasibility scaling `s ≥ 1`.
    s: f64,
    /// Ball term `ρ = √(2·aug·γ·gap)`.
    rho: f64,
    /// Constraint level `αλ` (per-unit weight applied by the caller).
    thresh: f64,
}

/// The column-unit gap-safe sphere rule (`SafeRule<SafeContext>`), shared
/// by the Gaussian and logistic families via [`GapLoss`].
///
/// Contract: `prev.r` must be the residual of `prev.beta` (`y − Xβ` for
/// [`GapLoss::Quadratic`], the score residual `y − p̂` for
/// [`GapLoss::Logistic`]); `prev.beta = None` means `β = 0`. For the
/// logistic loss, `ctx` must be built by [`logistic_context`] so `ctx.y`
/// holds the 0/1 labels.
#[derive(Debug)]
pub struct GapSafe {
    loss: GapLoss,
    // |z̃_j| at the most recently prepared dual point.
    zt: Vec<f64>,
    // Scan precision: F32 routes the full scan through the engine's f32
    // shadow with an error-widened interval test + exact confirm pass.
    precision: Precision,
    // Raw signed `Xᵀr/n` of the last full-f64 quadratic prepare, for the
    // fused-epoch z-cache handoff ([`SafeRule::last_scan`]).
    last_scan: Option<Vec<f64>>,
}

impl GapSafe {
    /// Gap-safe rule for the quadratic-loss column families.
    pub fn quadratic() -> Self {
        GapSafe {
            loss: GapLoss::Quadratic,
            zt: Vec::new(),
            precision: Precision::F64,
            last_scan: None,
        }
    }

    /// Gap-safe rule for the ℓ1/elastic-net logistic family.
    pub fn logistic() -> Self {
        GapSafe {
            loss: GapLoss::Logistic,
            zt: Vec::new(),
            precision: Precision::F64,
            last_scan: None,
        }
    }

    /// One full scan at `prev`'s iterate: fill `self.zt` with `|z̃_j|`,
    /// build the dual ball, and return the test scalars. The scan is
    /// dispatched through `engine` (and its `p` columns added to
    /// `*scanned`) so chunked/OOC accounting sees the rule's own
    /// traversal. `Ok(None)` ⇔ no valid dual point exists at this iterate
    /// (the rule is powerless, never unsafe).
    fn prepare(
        &mut self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam: f64,
        scanned: &mut u64,
    ) -> Result<Option<Scalars>> {
        let p = ctx.p;
        self.zt.resize(p, 0.0);
        self.last_scan = None;
        if self.precision == Precision::F32
            && self.loss == GapLoss::Quadratic
            && engine.scan_all_f32(x, prev.r, &mut self.zt)?
        {
            *scanned += p as u64;
            return self.prepare_f32(engine, x, ctx, prev, lam, scanned).map(Some);
        }
        engine.scan_all(x, prev.r, &mut self.zt)?;
        *scanned += p as u64;
        if self.loss == GapLoss::Quadratic {
            // Raw signed scan at the current residual: exactly the values
            // the fused KKT pass would recompute — published for the
            // fused-epoch z-cache handoff.
            self.last_scan = Some(self.zt.clone());
        }
        let ridge = ctx.penalty.l2_weight() * lam;
        let mut pen_l1 = 0.0;
        let mut beta_sq = 0.0;
        if let Some(beta) = prev.beta {
            assert_eq!(beta.len(), p, "gap-safe: beta length must equal p");
            for (zj, &bj) in self.zt.iter_mut().zip(beta.iter()) {
                *zj -= ridge * bj;
                pen_l1 += bj.abs();
                beta_sq += bj * bj;
            }
        }
        let mut feas = 0.0f64;
        for zj in self.zt.iter_mut() {
            *zj = zj.abs();
            feas = feas.max(*zj);
        }
        let ball = match self.loss {
            GapLoss::Quadratic => duality::quadratic_ball(
                &ctx.y, prev.r, beta_sq, pen_l1, feas, lam, ctx.penalty,
            ),
            GapLoss::Logistic => {
                match duality::logistic_ball(
                    &ctx.y, prev.r, beta_sq, pen_l1, feas, lam, ctx.penalty,
                ) {
                    Some(b) => b,
                    None => return Ok(None),
                }
            }
        };
        Ok(Some(Scalars {
            s: ball.scaling,
            rho: ball.rho,
            thresh: ctx.penalty.alpha() * lam,
        }))
    }

    /// Finish a prepare whose full scan ran in f32 (`self.zt` holds the
    /// raw f32 shadow scan). The screen's *decisions* stay exactly the
    /// f64 path's:
    ///
    /// * each exact `|z̃_j|` lies in `[|z̃32_j| − ε, |z̃32_j| + ε]` with
    ///   `ε` from [`simd::f32_scan_error_bound`];
    /// * every column whose interval could reach the feasibility max is
    ///   confirmed with an exact counted f64 subset scan (replicating the
    ///   f64 path's arithmetic operation for operation), so `feas` — and
    ///   with it the ball scalars — are bit-identical to the f64 path;
    /// * every column whose widened upper bound survives the ball test is
    ///   confirmed exactly too, so its survive/discard decision is the
    ///   exact one; the rest keep their upper bound in `zt`, and since
    ///   `exact ≤ ub < discard threshold`, both the f32 and f64 paths
    ///   discard them.
    ///
    /// Only quadratic loss reaches here ([`duality::quadratic_ball`] is
    /// total, hence the non-optional return).
    fn prepare_f32(
        &mut self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam: f64,
        scanned: &mut u64,
    ) -> Result<Scalars> {
        let p = ctx.p;
        let ridge = ctx.penalty.l2_weight() * lam;
        let eps = simd::f32_scan_error_bound(ctx.n, ops::nrm2(prev.r));
        let mut pen_l1 = 0.0;
        let mut beta_sq = 0.0;
        if let Some(beta) = prev.beta {
            assert_eq!(beta.len(), p, "gap-safe: beta length must equal p");
            // Same accumulation order as the f64 path: pen_l1/beta_sq are
            // pure-β f64 quantities, so they come out bit-identical.
            for (zj, &bj) in self.zt.iter_mut().zip(beta.iter()) {
                *zj -= ridge * bj;
                pen_l1 += bj.abs();
                beta_sq += bj * bj;
            }
        }
        let mut lower_max = 0.0f64;
        for zj in self.zt.iter_mut() {
            *zj = zj.abs();
            lower_max = lower_max.max(*zj - eps);
        }
        let mut confirmed = vec![false; p];
        // Feasibility candidates: every interval that could contain the
        // max. Their exact max IS the global exact max (any other column
        // has exact ≤ ub < lower_max ≤ exact max).
        let c1: Vec<usize> = (0..p).filter(|&j| self.zt[j] + eps >= lower_max).collect();
        let exact1 = confirm_abs(engine, x, prev, ridge, &c1)?;
        *scanned += c1.len() as u64;
        let mut feas = 0.0f64;
        for (&j, &ej) in c1.iter().zip(exact1.iter()) {
            self.zt[j] = ej;
            confirmed[j] = true;
            feas = feas.max(ej);
        }
        let ball =
            duality::quadratic_ball(&ctx.y, prev.r, beta_sq, pen_l1, feas, lam, ctx.penalty);
        let sc = Scalars { s: ball.scaling, rho: ball.rho, thresh: ctx.penalty.alpha() * lam };
        // Boundary classification: confirm every unconfirmed column whose
        // widened bound survives the ball test.
        let c2: Vec<usize> = (0..p)
            .filter(|&j| !confirmed[j] && (self.zt[j] + eps) / sc.s + sc.rho >= sc.thresh)
            .collect();
        let exact2 = confirm_abs(engine, x, prev, ridge, &c2)?;
        *scanned += c2.len() as u64;
        for (&j, &ej) in c2.iter().zip(exact2.iter()) {
            self.zt[j] = ej;
            confirmed[j] = true;
        }
        // Sure-discards keep their upper bound: still below the discard
        // threshold, and ≥ the exact value, so both paths discard.
        for (zj, &cj) in self.zt.iter_mut().zip(confirmed.iter()) {
            if !cj {
                *zj += eps;
            }
        }
        Ok(sc)
    }
}

/// Exact `|z̃_j| = |x_jᵀ r / n − ridge·β_j|` for the columns in `idx`,
/// through a counted f64 subset scan — operation-for-operation the f64
/// prepare's arithmetic, so the confirmed values are bit-identical to a
/// full-f64 screen's.
fn confirm_abs(
    engine: &dyn ScanEngine,
    x: &DenseMatrix,
    prev: &PrevSolution<'_>,
    ridge: f64,
    idx: &[usize],
) -> Result<Vec<f64>> {
    if idx.is_empty() {
        return Ok(Vec::new());
    }
    let mut buf = vec![0.0; idx.len()];
    engine.scan_subset(x, prev.r, idx, &mut buf)?;
    match prev.beta {
        Some(beta) => {
            for (bk, &j) in buf.iter_mut().zip(idx.iter()) {
                *bk = (*bk - ridge * beta[j]).abs();
            }
        }
        None => {
            for bk in buf.iter_mut() {
                *bk = bk.abs();
            }
        }
    }
    Ok(buf)
}

impl SafeRule for GapSafe {
    fn name(&self) -> &'static str {
        match self.loss {
            GapLoss::Quadratic => "GapSafe",
            GapLoss::Logistic => "GapSafe-logistic",
        }
    }

    fn screen(
        &mut self,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        let mut scanned = 0u64;
        self.screen_routed(&NativeEngine::new(), x, ctx, prev, lam_next, survive, &mut scanned)
            .expect("native scans are infallible")
    }

    fn dead(&self) -> bool {
        false // dynamic: the ball tightens again as the solver converges
    }

    fn dynamic(&self) -> bool {
        true
    }

    fn set_precision(&mut self, precision: Precision) {
        self.precision = precision;
    }

    fn last_scan(&self) -> Option<&[f64]> {
        self.last_scan.as_deref()
    }

    /// Point-wise plan: the scan and the ball are computed here; the
    /// returned predicate is a scalar comparison per column, evaluated by
    /// the fused engine kernels with decisions bit-identical to
    /// [`GapSafe::screen`].
    fn plan<'s>(
        &'s mut self,
        x: &DenseMatrix,
        ctx: &'s SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        masked_discards: &mut usize,
    ) -> Option<Box<dyn Fn(usize) -> bool + Sync + 's>> {
        let mut scanned = 0u64;
        self.plan_routed(
            &NativeEngine::new(),
            x,
            ctx,
            prev,
            lam_next,
            survive,
            masked_discards,
            &mut scanned,
        )
        .expect("native scans are infallible")
    }

    /// The engine-routed screen: one counted `O(np)` traversal through
    /// `engine`, then the pointwise ball test.
    fn screen_routed(
        &mut self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        scanned: &mut u64,
    ) -> Result<usize> {
        let Some(sc) = self.prepare(engine, x, ctx, prev, lam_next, scanned)? else {
            return Ok(0);
        };
        let mut discarded = 0;
        for (zj, sj) in self.zt.iter().zip(survive.iter_mut()) {
            if *sj && zj / sc.s + sc.rho < sc.thresh {
                *sj = false;
                discarded += 1;
            }
        }
        Ok(discarded)
    }

    /// The engine-routed plan — decisions bit-identical to
    /// [`GapSafe::screen`], traversal counted like
    /// [`SafeRule::screen_routed`].
    fn plan_routed<'s>(
        &'s mut self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &'s SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        _survive: &mut [bool],
        masked_discards: &mut usize,
        scanned: &mut u64,
    ) -> Result<Option<Box<dyn Fn(usize) -> bool + Sync + 's>>> {
        *masked_discards = 0;
        match self.prepare(engine, x, ctx, prev, lam_next, scanned)? {
            None => Ok(Some(Box::new(|_| true))), // powerless: keep everything
            Some(sc) => {
                let zt = &self.zt;
                // exact complement of `screen`'s discard test
                Ok(Some(Box::new(move |j: usize| zt[j] / sc.s + sc.rho >= sc.thresh)))
            }
        }
    }
}

/// The group-unit gap-safe sphere rule (`SafeRule<GroupSafeContext>`), for
/// the group lasso and group elastic net. Same ball as [`GapSafe`], tested
/// at group granularity: discard `g` ⇔ `‖z̃_g‖/s + ρ < αλ√W_g`.
#[derive(Debug, Default)]
pub struct GroupGapSafe {
    // Column-level z̃ scratch for the O(np) scan.
    cols: Vec<f64>,
    // ‖z̃_g‖ per group at the most recently prepared dual point.
    zt: Vec<f64>,
    // Scan precision (see [`GapSafe`]); F64 is the `Default` default.
    precision: Precision,
}

impl GroupGapSafe {
    /// Create a fresh rule.
    pub fn new() -> Self {
        GroupGapSafe::default()
    }

    /// Group analogue of [`GapSafe::prepare`]: fill `self.zt` with
    /// `‖z̃_g‖` and return the test scalars. The column traversal goes
    /// through `engine` and is added to `*scanned`.
    fn prepare(
        &mut self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &GroupSafeContext,
        prev: &PrevSolution<'_>,
        lam: f64,
        scanned: &mut u64,
    ) -> Result<Scalars> {
        let p = ctx.p;
        let g_count = ctx.layout.num_groups();
        self.cols.resize(p, 0.0);
        let f32_scan = self.precision == Precision::F32
            && engine.scan_all_f32(x, prev.r, &mut self.cols)?;
        if !f32_scan {
            engine.scan_all(x, prev.r, &mut self.cols)?;
        }
        *scanned += p as u64;
        let ridge = ctx.penalty.l2_weight() * lam;
        let mut pen_l1 = 0.0;
        let mut beta_sq = 0.0;
        if let Some(beta) = prev.beta {
            assert_eq!(beta.len(), p, "group gap-safe: beta length must equal p");
            for (cj, &bj) in self.cols.iter_mut().zip(beta.iter()) {
                *cj -= ridge * bj;
                beta_sq += bj * bj;
            }
            for g in 0..g_count {
                let ss: f64 = ctx.layout.range(g).map(|j| beta[j] * beta[j]).sum();
                pen_l1 += (ctx.layout.sizes[g] as f64).sqrt() * ss.sqrt();
            }
        }
        self.zt.resize(g_count, 0.0);
        let mut feas = 0.0f64;
        for g in 0..g_count {
            let ss: f64 = ctx.layout.range(g).map(|j| self.cols[j] * self.cols[j]).sum();
            let zn = ss.sqrt();
            self.zt[g] = zn;
            feas = feas.max(zn / (ctx.layout.sizes[g] as f64).sqrt());
        }
        if f32_scan {
            return self.finish_f32(engine, x, ctx, prev, lam, ridge, pen_l1, beta_sq, scanned);
        }
        let ball =
            duality::quadratic_ball(&ctx.y, prev.r, beta_sq, pen_l1, feas, lam, ctx.penalty);
        Ok(Scalars { s: ball.scaling, rho: ball.rho, thresh: ctx.penalty.alpha() * lam })
    }

    /// Group analogue of [`GapSafe::prepare_f32`]: `self.zt` holds group
    /// norms of the f32 shadow scan; each exact `‖z̃_g‖` lies within
    /// `√W_g · ε` of it (per-column error ≤ ε, so the error vector's
    /// 2-norm over a group of `W_g` columns is ≤ `√W_g · ε`). Feasibility
    /// candidates and ball-test boundary groups are confirmed with exact
    /// counted f64 subset scans replicating the f64 path's arithmetic, so
    /// the ball scalars and every survive/discard decision are the f64
    /// path's own.
    #[allow(clippy::too_many_arguments)]
    fn finish_f32(
        &mut self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &GroupSafeContext,
        prev: &PrevSolution<'_>,
        lam: f64,
        ridge: f64,
        pen_l1: f64,
        beta_sq: f64,
        scanned: &mut u64,
    ) -> Result<Scalars> {
        let g_count = ctx.layout.num_groups();
        let eps = simd::f32_scan_error_bound(ctx.n, ops::nrm2(prev.r));
        let geps: Vec<f64> =
            (0..g_count).map(|g| (ctx.layout.sizes[g] as f64).sqrt() * eps).collect();
        let mut lower_max = 0.0f64;
        for g in 0..g_count {
            let w_sqrt = (ctx.layout.sizes[g] as f64).sqrt();
            lower_max = lower_max.max((self.zt[g] - geps[g]) / w_sqrt);
        }
        let mut confirmed = vec![false; g_count];
        let c1: Vec<usize> = (0..g_count)
            .filter(|&g| (self.zt[g] + geps[g]) / (ctx.layout.sizes[g] as f64).sqrt() >= lower_max)
            .collect();
        let mut feas = 0.0f64;
        for &g in &c1 {
            let zn = self.confirm_group(engine, x, ctx, prev, ridge, g, scanned)?;
            self.zt[g] = zn;
            confirmed[g] = true;
            feas = feas.max(zn / (ctx.layout.sizes[g] as f64).sqrt());
        }
        let ball =
            duality::quadratic_ball(&ctx.y, prev.r, beta_sq, pen_l1, feas, lam, ctx.penalty);
        let sc = Scalars { s: ball.scaling, rho: ball.rho, thresh: ctx.penalty.alpha() * lam };
        for g in 0..g_count {
            if confirmed[g] {
                continue;
            }
            let w_sqrt = (ctx.layout.sizes[g] as f64).sqrt();
            if (self.zt[g] + geps[g]) / sc.s + sc.rho >= sc.thresh * w_sqrt {
                // Boundary group: confirm exactly.
                self.zt[g] = self.confirm_group(engine, x, ctx, prev, ridge, g, scanned)?;
            } else {
                // Sure-discard: keep the upper bound (≥ exact, still below
                // the threshold — both paths discard).
                self.zt[g] += geps[g];
            }
        }
        Ok(sc)
    }

    /// Exact `‖z̃_g‖` for one group through a counted f64 subset scan —
    /// the f64 prepare's arithmetic operation for operation (ascending
    /// column order, same ss-sum, same sqrt).
    #[allow(clippy::too_many_arguments)]
    fn confirm_group(
        &self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &GroupSafeContext,
        prev: &PrevSolution<'_>,
        ridge: f64,
        g: usize,
        scanned: &mut u64,
    ) -> Result<f64> {
        let idx: Vec<usize> = ctx.layout.range(g).collect();
        let mut buf = vec![0.0; idx.len()];
        engine.scan_subset(x, prev.r, &idx, &mut buf)?;
        *scanned += idx.len() as u64;
        if let Some(beta) = prev.beta {
            for (bk, &j) in buf.iter_mut().zip(idx.iter()) {
                *bk -= ridge * beta[j];
            }
        }
        let ss: f64 = buf.iter().map(|c| c * c).sum();
        Ok(ss.sqrt())
    }
}

impl SafeRule<GroupSafeContext> for GroupGapSafe {
    fn name(&self) -> &'static str {
        "gGapSafe"
    }

    fn screen(
        &mut self,
        x: &DenseMatrix,
        ctx: &GroupSafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        let mut scanned = 0u64;
        self.screen_routed(&NativeEngine::new(), x, ctx, prev, lam_next, survive, &mut scanned)
            .expect("native scans are infallible")
    }

    fn dead(&self) -> bool {
        false
    }

    fn dynamic(&self) -> bool {
        true
    }

    fn set_precision(&mut self, precision: Precision) {
        self.precision = precision;
    }

    /// Point-wise plan for the fused group screen; decisions bit-identical
    /// to [`GroupGapSafe::screen`] (same scalars, same comparison).
    fn plan<'s>(
        &'s mut self,
        x: &DenseMatrix,
        ctx: &'s GroupSafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        masked_discards: &mut usize,
    ) -> Option<Box<dyn Fn(usize) -> bool + Sync + 's>> {
        let mut scanned = 0u64;
        self.plan_routed(
            &NativeEngine::new(),
            x,
            ctx,
            prev,
            lam_next,
            survive,
            masked_discards,
            &mut scanned,
        )
        .expect("native scans are infallible")
    }

    /// Engine-routed group screen: one counted `O(np)` traversal.
    fn screen_routed(
        &mut self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &GroupSafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        scanned: &mut u64,
    ) -> Result<usize> {
        let sc = self.prepare(engine, x, ctx, prev, lam_next, scanned)?;
        let mut discarded = 0;
        for (g, sg) in survive.iter_mut().enumerate() {
            let w_sqrt = (ctx.layout.sizes[g] as f64).sqrt();
            if *sg && self.zt[g] / sc.s + sc.rho < sc.thresh * w_sqrt {
                *sg = false;
                discarded += 1;
            }
        }
        Ok(discarded)
    }

    /// Engine-routed group plan — decisions bit-identical to
    /// [`GroupGapSafe::screen`].
    fn plan_routed<'s>(
        &'s mut self,
        engine: &dyn ScanEngine,
        x: &DenseMatrix,
        ctx: &'s GroupSafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        _survive: &mut [bool],
        masked_discards: &mut usize,
        scanned: &mut u64,
    ) -> Result<Option<Box<dyn Fn(usize) -> bool + Sync + 's>>> {
        *masked_discards = 0;
        let sc = self.prepare(engine, x, ctx, prev, lam_next, scanned)?;
        let zt = &self.zt;
        let sizes = &ctx.layout.sizes;
        // exact complement of `screen`'s discard test
        Ok(Some(Box::new(move |g: usize| {
            let w_sqrt = (sizes[g] as f64).sqrt();
            zt[g] / sc.s + sc.rho >= sc.thresh * w_sqrt
        })))
    }
}

/// Build the minimal [`SafeContext`] the logistic gap-safe rule consumes:
/// `y` holds the **0/1 labels** (not a centered response), and the
/// `Xᵀy`/`Xᵀx*` precomputes of the static rules are left empty (the
/// gap-safe rule performs its own scan). `lambda_max` is the logistic
/// `‖Xᵀ(y − ȳ)‖∞/(nα)` computed by the caller.
pub fn logistic_context(
    labels: &[f64],
    p: usize,
    lambda_max: f64,
    penalty: Penalty,
) -> SafeContext {
    SafeContext {
        n: labels.len(),
        p,
        y: labels.to_vec(),
        xty: Vec::new(),
        xtx_star: Vec::new(),
        y_sq: ops::nrm2_sq(labels),
        lambda_max,
        star: 0,
        sign_star: 1.0,
        penalty,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate_grouped;
    use crate::data::DataSpec;
    use crate::linalg::blocked;

    fn ctx_for(seed: u64, penalty: Penalty) -> (crate::data::Dataset, SafeContext) {
        let ds = DataSpec::synthetic(60, 40, 4).generate(seed);
        let ctx = SafeContext::build(&ds.x, &ds.y, penalty, false);
        (ds, ctx)
    }

    /// At λ = λmax with β = 0 the gap is (numerically) zero, so the rule
    /// discards essentially everything; just below λmax the ball term is
    /// strictly positive and the argmax feature survives robustly.
    #[test]
    fn zero_gap_at_lambda_max_discards_all_but_argmax() {
        let (ds, ctx) = ctx_for(1, Penalty::Lasso);
        let prev = PrevSolution { lambda: ctx.lambda_max, r: &ds.y, beta: None };
        let mut survive = vec![true; ctx.p];
        let d = GapSafe::quadratic().screen(&ds.x, &ctx, &prev, ctx.lambda_max, &mut survive);
        assert_eq!(d, ctx.p - survive.iter().filter(|&&s| s).count());
        assert!(d >= ctx.p - 2, "near-degenerate designs aside, only the argmax stays");
        // Just below λmax: |z*|/s equals λ exactly, so x* always survives.
        let lam = 0.999 * ctx.lambda_max;
        let mut s2 = vec![true; ctx.p];
        let d2 = GapSafe::quadratic().screen(&ds.x, &ctx, &prev, lam, &mut s2);
        assert!(s2[ctx.star], "the argmax feature must survive just below λmax");
        assert!(d2 > 0, "gap-safe powerless just below λmax");
    }

    /// The rule keeps discarding deep in the path (where BEDPP is dead)
    /// when given a converged previous solution.
    #[test]
    fn discards_deep_in_path_with_good_primal_point() {
        use crate::screening::RuleKind;
        use crate::solver::path::{fit_lasso_path, PathConfig};
        let ds = DataSpec::gene_like(70, 150).generate(2);
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, false);
        let fit = fit_lasso_path(
            &ds,
            &PathConfig {
                rule: RuleKind::BasicPcd,
                n_lambda: 20,
                tol: 1e-10,
                ..PathConfig::default()
            },
        )
        .unwrap();
        let k = fit.lambdas.len() - 2; // deep in the path
        let beta = fit.beta_dense(k);
        let xb = ds.x.matvec(&beta);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let prev = PrevSolution { lambda: fit.lambdas[k], r: &r, beta: Some(&beta) };
        let mut rule = GapSafe::quadratic();
        let mut survive = vec![true; ds.p()];
        let d = rule.screen(&ds.x, &ctx, &prev, fit.lambdas[k + 1], &mut survive);
        assert!(d > 0, "gap-safe should stay powerful deep in the path");
        for &(j, _) in &fit.betas[k + 1] {
            assert!(survive[j], "active feature {j} discarded");
        }
    }

    /// The fused-pass predicate must agree with `screen` column by column.
    #[test]
    fn plan_predicate_matches_screen() {
        let (ds, ctx) = ctx_for(3, Penalty::ElasticNet { alpha: 0.6 });
        let mut beta = vec![0.0; ctx.p];
        beta[1] = 0.2;
        beta[5] = -0.1;
        let xb = ds.x.matvec(&beta);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        for frac in [0.9, 0.6, 0.3, 0.1] {
            let lam = frac * ctx.lambda_max;
            let prev = PrevSolution { lambda: lam, r: &r, beta: Some(&beta) };
            let mut mask = vec![true; ctx.p];
            GapSafe::quadratic().screen(&ds.x, &ctx, &prev, lam, &mut mask);
            let mut rule = GapSafe::quadratic();
            let mut untouched = vec![true; ctx.p];
            let mut d = 0usize;
            let keep = rule
                .plan(&ds.x, &ctx, &prev, lam, &mut untouched, &mut d)
                .expect("gap-safe plan is always pointwise");
            assert_eq!(d, 0);
            assert!(untouched.iter().all(|&s| s), "plan must not touch the mask");
            for j in 0..ctx.p {
                assert_eq!(keep(j), mask[j], "feature {j} at {frac}·λmax");
            }
        }
    }

    /// Group rule: zero gap at λmax keeps only the argmax group, and the
    /// plan predicate matches the mask screen.
    #[test]
    fn group_rule_lambda_max_and_plan_parity() {
        let ds = generate_grouped(80, 12, 4, 3, 4);
        let ctx = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, Penalty::Lasso);
        let g = ctx.layout.num_groups();
        let prev = PrevSolution { lambda: ctx.lambda_max, r: &ds.y, beta: None };
        let mut survive = vec![true; g];
        let d = GroupGapSafe::new().screen(&ds.x, &ctx, &prev, ctx.lambda_max, &mut survive);
        assert!(d >= g - 2);
        let mut s2 = vec![true; g];
        GroupGapSafe::new().screen(&ds.x, &ctx, &prev, 0.999 * ctx.lambda_max, &mut s2);
        assert!(s2[ctx.star], "the argmax group must survive just below λmax");
        // plan parity at a lower λ with a synthetic previous solution
        let mut beta = vec![0.0; ds.p()];
        for j in ctx.layout.range(ctx.star) {
            beta[j] = 0.1;
        }
        let xb = ds.x.matvec(&beta);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let lam = 0.7 * ctx.lambda_max;
        let prev = PrevSolution { lambda: lam, r: &r, beta: Some(&beta) };
        let mut mask = vec![true; g];
        GroupGapSafe::new().screen(&ds.x, &ctx, &prev, lam, &mut mask);
        let mut rule2 = GroupGapSafe::new();
        let mut untouched = vec![true; g];
        let mut md = 0usize;
        let keep = rule2.plan(&ds.x, &ctx, &prev, lam, &mut untouched, &mut md).unwrap();
        assert_eq!(md, 0);
        for gi in 0..g {
            assert_eq!(keep(gi), mask[gi], "group {gi}");
        }
    }

    /// Logistic rule at the null model: zero gap at λmax, argmax survives,
    /// and the dynamic/dead markers are as advertised.
    #[test]
    fn logistic_rule_null_model() {
        use crate::solver::logistic::synthetic_logistic;
        let (x, y, _) = synthetic_logistic(100, 30, 4, 5);
        let ybar = ops::mean(&y);
        let resid: Vec<f64> = y.iter().map(|yi| yi - ybar).collect();
        let z = blocked::scan_all_vec(&x, &resid);
        let lam_max = ops::inf_norm(&z);
        let ctx = logistic_context(&y, 30, lam_max, Penalty::Lasso);
        let mut rule = GapSafe::logistic();
        assert!(rule.dynamic());
        assert!(!rule.dead());
        let prev = PrevSolution { lambda: lam_max, r: &resid, beta: None };
        let mut survive = vec![true; 30];
        let d = rule.screen(&x, &ctx, &prev, lam_max, &mut survive);
        assert!(d >= 28, "zero gap at λmax must discard all but the argmax set");
        let mut s2 = vec![true; 30];
        rule.screen(&x, &ctx, &prev, 0.999 * lam_max, &mut s2);
        let (star, _) = ops::abs_argmax(&z);
        assert!(s2[star], "the argmax feature must survive just below λmax");
    }
}
