//! Basic EDPP (BEDPP) safe rule — Theorem 2.1 (lasso) and Theorem 4.1
//! (elastic net) of the paper, simplified under standardization (2).
//!
//! BEDPP is *non-sequential*: screening at any λ needs only the one-time
//! `O(np)` precompute (`Xᵀy`, `Xᵀx_*`, `‖y‖²`) held in
//! [`super::SafeContext`], then `O(p)` per λ — hence `O(np)` for the whole
//! path (Table 1). Its power decays as λ decreases and the right-hand side
//! of rule (9) eventually goes non-positive; [`Bedpp::dead`] reports this so
//! Algorithm 1 can stop invoking it (the `Flag` shutoff).

use super::{PrevSolution, SafeContext, SafeRule};
use crate::linalg::DenseMatrix;
use crate::solver::Penalty;

/// The BEDPP rule (lasso Thm 2.1; elastic net Thm 4.1).
#[derive(Debug, Default)]
pub struct Bedpp {
    dead: bool,
}

impl Bedpp {
    /// Create a fresh rule.
    pub fn new() -> Self {
        Bedpp { dead: false }
    }

    /// The per-column linear-test scalars `(a, b, rhs)` of rule (9) /
    /// Thm 4.1 at `lam`: feature `j ≠ *` is discarded iff
    /// `|a·xty_j − b·xtx*_j| < rhs`. Returns `None` when the RHS is
    /// non-positive (the rule is powerless at this λ). This is the
    /// point-wise form the fused scan kernel dispatches on.
    pub fn predicate_coeffs(ctx: &SafeContext, lam: f64) -> Option<(f64, f64, f64)> {
        assert!(
            !ctx.xtx_star.is_empty(),
            "BEDPP requires SafeContext built with need_star = true"
        );
        let n = ctx.n as f64;
        let lm = ctx.lambda_max;
        let s = ctx.sign_star;
        let (lhs_a, lhs_b, rhs) = match ctx.penalty {
            Penalty::Lasso => {
                // |(λm+λ)·xty_j − (λm−λ)·s·λm·xtx*_j| < 2nλλm − (λm−λ)√(n‖y‖²−n²λm²)
                let root = (n * ctx.y_sq - n * n * lm * lm).max(0.0).sqrt();
                ((lm + lam), (lm - lam) * s * lm, 2.0 * n * lam * lm - (lm - lam) * root)
            }
            Penalty::ElasticNet { alpha } => {
                // Thm 4.1: the x* coefficient picks up α/(1+λ(1−α)); the RHS
                // root picks up the augmented-row norm (see Appendix C).
                let aug = 1.0 + lam * (1.0 - alpha);
                let root = (n * ctx.y_sq * aug - n * n * alpha * alpha * lm * lm)
                    .max(0.0)
                    .sqrt();
                (
                    (lm + lam),
                    (lm - lam) * s * alpha * lm / aug,
                    2.0 * n * alpha * lam * lm - (lm - lam) * root,
                )
            }
        };
        if rhs <= 0.0 {
            None // rule is powerless at this λ
        } else {
            Some((lhs_a, lhs_b, rhs))
        }
    }

    /// Evaluate the rule at `lam`, clearing `survive[j]` for discarded
    /// features. Standalone entry point (also used by the hybrid rules and
    /// the Figure-1 power measurement).
    pub fn screen_at(ctx: &SafeContext, lam: f64, survive: &mut [bool]) -> usize {
        assert_eq!(survive.len(), ctx.p);
        let Some((lhs_a, lhs_b, rhs)) = Bedpp::predicate_coeffs(ctx, lam) else {
            return 0;
        };
        let mut discarded = 0;
        for j in 0..ctx.p {
            if !survive[j] || j == ctx.star {
                continue; // x* is never rejected (Thm 4.1 remark)
            }
            let lhs = (lhs_a * ctx.xty[j] - lhs_b * ctx.xtx_star[j]).abs();
            if lhs < rhs {
                survive[j] = false;
                discarded += 1;
            }
        }
        discarded
    }

    /// The λ below which the lasso rule's RHS is non-positive (the rule is
    /// provably powerless). Useful for tests and for the Figure-1 analysis.
    pub fn shutoff_lambda(ctx: &SafeContext) -> f64 {
        let n = ctx.n as f64;
        let lm = ctx.lambda_max;
        let root = (n * ctx.y_sq - n * n * lm * lm).max(0.0).sqrt();
        // 2nλλm = (λm−λ)·root  ⟺  λ(2nλm + root) = λm·root
        lm * root / (2.0 * n * lm + root)
    }
}

impl SafeRule for Bedpp {
    fn name(&self) -> &'static str {
        "BEDPP"
    }

    fn screen(
        &mut self,
        _x: &DenseMatrix,
        ctx: &SafeContext,
        _prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        let d = Bedpp::screen_at(ctx, lam_next, survive);
        if d == 0 {
            // RHS is monotone decreasing in λ for the lasso; once powerless,
            // always powerless. (For enet we use the same empirical shutoff,
            // mirroring Algorithm 1's |S| = p test.)
            self.dead = true;
        }
        d
    }

    fn dead(&self) -> bool {
        self.dead
    }

    fn save_state(&self) -> Vec<u8> {
        vec![self.dead as u8]
    }

    fn load_state(&mut self, state: &[u8]) -> crate::error::Result<()> {
        match state {
            [d] => {
                self.dead = *d != 0;
                Ok(())
            }
            _ => Err(crate::error::HssrError::Corrupt(
                "BEDPP: malformed safe-rule state in checkpoint".into(),
            )),
        }
    }

    /// Point-wise plan: BEDPP's test is a scalar linear form in the per-fit
    /// precomputes, so the fused kernel applies it per column with no mask
    /// traversal. Keep `j` iff `j = *` or `|a·xty_j − b·xtx*_j| ≥ rhs` —
    /// the exact complement of [`Bedpp::screen_at`]'s discard test.
    fn plan<'s>(
        &'s mut self,
        _x: &DenseMatrix,
        ctx: &'s SafeContext,
        _prev: &PrevSolution<'_>,
        lam_next: f64,
        _survive: &mut [bool],
        masked_discards: &mut usize,
    ) -> Option<Box<dyn Fn(usize) -> bool + Sync + 's>> {
        *masked_discards = 0;
        match Bedpp::predicate_coeffs(ctx, lam_next) {
            None => {
                // Powerless at this λ ⇒ powerless at all smaller λ (the RHS
                // is monotone); mirror `screen`'s dead flag.
                self.dead = true;
                None
            }
            Some((a, b, rhs)) => {
                let xty = &ctx.xty;
                let xs = &ctx.xtx_star;
                let star = ctx.star;
                Some(Box::new(move |j: usize| {
                    j == star || (a * xty[j] - b * xs[j]).abs() >= rhs
                }))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::linalg::ops;

    fn ctx_for(seed: u64, penalty: Penalty) -> (crate::data::Dataset, SafeContext) {
        let ds = DataSpec::synthetic(60, 40, 4).generate(seed);
        let ctx = SafeContext::build(&ds.x, &ds.y, penalty, true);
        (ds, ctx)
    }

    #[test]
    fn discards_at_high_lambda_not_at_low() {
        let (_, ctx) = ctx_for(1, Penalty::Lasso);
        let mut hi = vec![true; ctx.p];
        let d_hi = Bedpp::screen_at(&ctx, 0.95 * ctx.lambda_max, &mut hi);
        assert!(d_hi > 0, "BEDPP should discard near λmax");
        let mut lo = vec![true; ctx.p];
        let d_lo = Bedpp::screen_at(&ctx, 0.05 * ctx.lambda_max, &mut lo);
        assert_eq!(d_lo, 0, "BEDPP must be powerless at tiny λ");
    }

    #[test]
    fn shutoff_lambda_brackets_power() {
        let (_, ctx) = ctx_for(2, Penalty::Lasso);
        let cut = Bedpp::shutoff_lambda(&ctx);
        assert!(cut > 0.0 && cut < ctx.lambda_max);
        let mut below = vec![true; ctx.p];
        assert_eq!(Bedpp::screen_at(&ctx, cut * 0.999, &mut below), 0);
    }

    #[test]
    fn star_feature_never_rejected() {
        let (_, ctx) = ctx_for(3, Penalty::Lasso);
        let mut survive = vec![true; ctx.p];
        Bedpp::screen_at(&ctx, 0.99 * ctx.lambda_max, &mut survive);
        assert!(survive[ctx.star]);
    }

    /// Safety: BEDPP must keep every feature with |x_jᵀ θ̂(λ)| = λ active
    /// potential — verified against the *exact* dual test on a problem small
    /// enough to solve by brute coordinate descent elsewhere; here we check
    /// the weaker (but exact) implication with the known dual at λmax:
    /// screening at λ = λmax must keep x*.
    #[test]
    fn at_lambda_max_keeps_argmax() {
        let (_, ctx) = ctx_for(4, Penalty::Lasso);
        let mut survive = vec![true; ctx.p];
        Bedpp::screen_at(&ctx, ctx.lambda_max, &mut survive);
        assert!(survive[ctx.star]);
    }

    #[test]
    fn enet_rule_runs_and_keeps_star() {
        let (_, ctx) = ctx_for(5, Penalty::ElasticNet { alpha: 0.5 });
        let mut survive = vec![true; ctx.p];
        let d = Bedpp::screen_at(&ctx, 0.9 * ctx.lambda_max, &mut survive);
        assert!(d > 0);
        assert!(survive[ctx.star]);
    }

    /// The fused-pass predicate must agree with `screen_at` column by
    /// column at every λ (and be `None` exactly when the rule is
    /// powerless).
    #[test]
    fn plan_predicate_matches_screen_at() {
        use crate::screening::SafeRule;
        let (ds, ctx) = ctx_for(8, Penalty::Lasso);
        let r = ds.y.clone();
        let prev = PrevSolution { lambda: ctx.lambda_max, r: &r, beta: None };
        for frac in [0.95, 0.7, 0.5, 0.05] {
            let lam = frac * ctx.lambda_max;
            let mut rule = Bedpp::new();
            let mut survive = vec![true; ctx.p];
            let mut d = 0usize;
            let keep = rule.plan(&ds.x, &ctx, &prev, lam, &mut survive, &mut d);
            assert_eq!(d, 0);
            let mut mask = vec![true; ctx.p];
            let screened = Bedpp::screen_at(&ctx, lam, &mut mask);
            match keep {
                Some(pred) => {
                    for j in 0..ctx.p {
                        assert_eq!(pred(j), mask[j], "feature {j} at {frac}·λmax");
                    }
                }
                None => assert_eq!(screened, 0, "plan None but screen discards"),
            }
        }
    }

    #[test]
    fn dead_flag_sets_once_powerless() {
        let (ds, ctx) = ctx_for(6, Penalty::Lasso);
        let mut rule = Bedpp::new();
        let r = ds.y.clone();
        let prev = PrevSolution { lambda: ctx.lambda_max, r: &r, beta: None };
        let mut survive = vec![true; ctx.p];
        rule.screen(&ds.x, &ctx, &prev, 0.01 * ctx.lambda_max, &mut survive);
        assert!(rule.dead());
    }

    /// Directly verify rule (9) against its geometric origin: discarded j
    /// must satisfy sup over the EDPP ball of |x_jᵀθ| < 1, using
    /// θ ∈ B(y/(nλm) + v⊥/2, ‖v⊥‖/2) — recomputed from first principles.
    #[test]
    fn rule_matches_first_principles_ball() {
        let (ds, ctx) = ctx_for(7, Penalty::Lasso);
        let n = ctx.n as f64;
        let lam = 0.8 * ctx.lambda_max;
        let lm = ctx.lambda_max;
        // v2⊥ = (1/(nλ) − 1/(nλm)) (y − s·λm·x*)
        let coef = 1.0 / (n * lam) - 1.0 / (n * lm);
        let xstar = ds.x.col(ctx.star);
        let v2p: Vec<f64> = ds
            .y
            .iter()
            .zip(xstar)
            .map(|(yi, xs)| coef * (yi - ctx.sign_star * lm * xs))
            .collect();
        let v2p_norm = ops::nrm2(&v2p);
        let mut survive = vec![true; ctx.p];
        Bedpp::screen_at(&ctx, lam, &mut survive);
        for j in 0..ctx.p {
            let center_dot = ctx.xty[j] / (n * lm) + 0.5 * ops::dot(ds.x.col(j), &v2p);
            let sup = center_dot.abs() + 0.5 * v2p_norm * (n).sqrt();
            if !survive[j] {
                assert!(sup < 1.0 + 1e-9, "feature {j} discarded but sup = {sup}");
            }
        }
    }
}
