//! Group-lasso screening rules — §4.2 of the paper, extended to the group
//! elastic net (§5 applied at group granularity).
//!
//! Under the two-level standardization ((2) + group orthonormalization
//! (19), `X_gᵀX_g/n = I`), the paper derives:
//!
//! * group SSR (rule (20)) — see [`super::ssr::group_strong_set`];
//! * group BEDPP (Theorem 4.2, rule (22)) — [`GroupBedpp`];
//! * and the group-lasso analogue of the sequential EDPP rule (Wang et al.
//!   2015, Thm 20/Cor 21 applied to the group dual) — [`GroupSedpp`].
//!
//! Note on Thm 4.2: the paper's appendix asserts `‖X_g‖ = n` "implied by
//! (19)"; condition (19) makes every singular value of `X_g` equal `√n`, so
//! the operator norm is `√n`. Using `√n` reproduces the stated rule (22)
//! exactly, confirming `n` is a typo (see DESIGN.md §5).
//!
//! ## Elastic net
//!
//! The group elastic net `‖y − Xβ‖²/(2n) + αλΣ_g√W_g‖β_g‖ + (1−α)λ/2‖β‖²`
//! is the group lasso on the augmented design `X̃ = [X; √(n(1−α)λ)·I]`,
//! `ỹ = [y; 0]`, with penalty `αλ` — the same reduction behind Thm 4.1.
//! After renormalizing (`X̃_gᵀX̃_g/n = aug·I` with `aug = 1 + (1−α)λ`), the
//! BEDPP ball argument goes through verbatim because the augmented blocks
//! of distinct groups stay orthogonal (`X̃_gᵀX̃_* = X_gᵀX_*` for `g ≠ *`).
//! Rule (22) picks up the `aug` factors exactly where Thm 4.1 puts them:
//! `1/aug` on the `v̄` cross term, `1/aug²` on its square, and the
//! augmented-row norm inside the RHS root; at `α = 1` every factor is 1 and
//! the lasso rule is recovered bit-for-bit.

use super::{PrevSolution, RuleKind, SafeRule};
use crate::data::GroupLayout;
use crate::linalg::{blocked, ops, DenseMatrix};
use crate::solver::Penalty;

/// Quantities shared by the group safe rules, computed once per fit
/// (`O(np)`).
#[derive(Clone, Debug)]
pub struct GroupSafeContext {
    /// Observations.
    pub n: usize,
    /// Total columns.
    pub p: usize,
    /// Group layout.
    pub layout: GroupLayout,
    /// Centered response.
    pub y: Vec<f64>,
    /// `x_jᵀy` per column.
    pub xty: Vec<f64>,
    /// `‖X_gᵀy‖²` per group.
    pub group_xty_sq: Vec<f64>,
    /// `yᵀX_gX_gᵀv̄ = (X_gᵀy)·(X_gᵀv̄)` per group, with `v̄ = X_*X_*ᵀy`.
    pub yt_xg_xgt_vbar: Vec<f64>,
    /// `‖X_gᵀv̄‖²` per group.
    pub xgt_vbar_sq: Vec<f64>,
    /// `‖y‖²`.
    pub y_sq: f64,
    /// `λ_max = max_g ‖X_gᵀy‖/(αn√W_g)` (the α scaling covers the elastic
    /// net; α = 1 for the lasso).
    pub lambda_max: f64,
    /// Index of the maximizing group `*`.
    pub star: usize,
    /// `W_*` (size of the maximizing group).
    pub w_star: usize,
    /// Penalty (selects the elastic-net variants of the rules).
    pub penalty: Penalty,
}

impl GroupSafeContext {
    /// Build the context (two `O(np)` scans: `Xᵀy` and `Xᵀv̄`).
    pub fn build(
        x: &DenseMatrix,
        y: &[f64],
        layout: &GroupLayout,
        penalty: Penalty,
    ) -> GroupSafeContext {
        let n = x.nrows();
        let p = x.ncols();
        let g_count = layout.num_groups();
        let mut xty = vec![0.0; p];
        blocked::scan_all(x, y, &mut xty);
        for v in xty.iter_mut() {
            *v *= n as f64;
        }
        let mut group_xty_sq = vec![0.0; g_count];
        let mut lambda_max = 0.0;
        let mut star = 0;
        for g in 0..g_count {
            let ss: f64 = layout.range(g).map(|j| xty[j] * xty[j]).sum();
            group_xty_sq[g] = ss;
            let crit = ss.sqrt() / (n as f64 * (layout.sizes[g] as f64).sqrt());
            if crit > lambda_max {
                lambda_max = crit;
                star = g;
            }
        }
        // Elastic-net λmax: the first group enters when ‖X_gᵀy‖/(n√W_g) = αλ.
        lambda_max /= penalty.alpha();
        // v̄ = X_* X_*ᵀ y  (n-vector), then Xᵀv̄ scan.
        let mut vbar = vec![0.0; n];
        for j in layout.range(star) {
            ops::axpy(xty[j], x.col(j), &mut vbar);
        }
        let mut xtv = vec![0.0; p];
        blocked::scan_all(x, &vbar, &mut xtv);
        for v in xtv.iter_mut() {
            *v *= n as f64;
        }
        let mut yt_xg_xgt_vbar = vec![0.0; g_count];
        let mut xgt_vbar_sq = vec![0.0; g_count];
        for g in 0..g_count {
            let mut dotv = 0.0;
            let mut ssv = 0.0;
            for j in layout.range(g) {
                dotv += xty[j] * xtv[j];
                ssv += xtv[j] * xtv[j];
            }
            yt_xg_xgt_vbar[g] = dotv;
            xgt_vbar_sq[g] = ssv;
        }
        GroupSafeContext {
            n,
            p,
            layout: layout.clone(),
            y: y.to_vec(),
            xty,
            group_xty_sq,
            yt_xg_xgt_vbar,
            xgt_vbar_sq,
            y_sq: ops::nrm2_sq(y),
            lambda_max,
            star,
            w_star: layout.sizes[star],
            penalty,
        }
    }
}

/// Construct the group safe rule (if any) used by a [`RuleKind`] strategy.
/// Returns `None` both for strategies with no safe rule and for strategies
/// the group lasso does not support (callers validate the kind first).
pub fn make_group_safe_rule(kind: RuleKind) -> Option<Box<dyn SafeRule<GroupSafeContext>>> {
    match kind {
        RuleKind::SsrBedpp => Some(Box::new(GroupBedpp::new())),
        RuleKind::Sedpp => Some(Box::new(GroupSedpp::new())),
        RuleKind::SsrGapSafe => Some(Box::new(super::gapsafe::GroupGapSafe::new())),
        _ => None,
    }
}

/// Group BEDPP — Theorem 4.2, rule (22), with the elastic-net extension
/// described in the module docs. Non-sequential, `O(1)` per group per λ
/// after the context precompute.
#[derive(Debug, Default)]
pub struct GroupBedpp {
    dead: bool,
}

/// Per-λ scalars of the (elastic-net-general) rule (22): the augmentation
/// factor `aug = 1 + (1−α)λ` and the shared RHS root
/// `√(n‖y‖²·aug − n²α²λm²W_*)`. At α = 1 these are `1` and the lasso root.
#[derive(Clone, Copy, Debug)]
struct GroupBedppBounds {
    aug: f64,
    root: f64,
}

impl GroupBedpp {
    /// Create a fresh rule.
    pub fn new() -> Self {
        GroupBedpp { dead: false }
    }

    /// The per-λ shared scalars of rule (22) at `lam`.
    #[inline]
    fn bounds(ctx: &GroupSafeContext, lam: f64) -> GroupBedppBounds {
        let n = ctx.n as f64;
        let lm = ctx.lambda_max;
        let alpha = ctx.penalty.alpha();
        let aug = 1.0 + lam * (1.0 - alpha);
        let root = (n * ctx.y_sq * aug
            - n * n * alpha * alpha * lm * lm * ctx.w_star as f64)
            .max(0.0)
            .sqrt();
        GroupBedppBounds { aug, root }
    }

    /// The discard test of rule (22) for one group at `lam`, given the
    /// shared per-λ [`GroupBedppBounds`]. Point-wise in the per-fit
    /// precomputes — this is what the fused plan dispatches per group.
    #[inline]
    fn discards(ctx: &GroupSafeContext, lam: f64, b: GroupBedppBounds, g: usize) -> bool {
        if g == ctx.star {
            return false;
        }
        let n = ctx.n as f64;
        let lm = ctx.lambda_max;
        let alpha = ctx.penalty.alpha();
        let wg = ctx.layout.sizes[g] as f64;
        let rhs = 2.0 * n * alpha * lam * lm * wg.sqrt() - (lm - lam) * b.root;
        if rhs <= 0.0 {
            return false;
        }
        let lhs_sq = (lam + lm) * (lam + lm) * ctx.group_xty_sq[g]
            - 2.0 * (lm * lm - lam * lam) * ctx.yt_xg_xgt_vbar[g] / (n * b.aug)
            + (lm - lam) * (lm - lam) * ctx.xgt_vbar_sq[g] / (n * n * b.aug * b.aug);
        lhs_sq.max(0.0).sqrt() < rhs
    }

    /// Standalone evaluation at `lam` (used by Figure-1-style analyses).
    pub fn screen_at(ctx: &GroupSafeContext, lam: f64, survive: &mut [bool]) -> usize {
        assert_eq!(survive.len(), ctx.layout.num_groups());
        let b = GroupBedpp::bounds(ctx, lam);
        let mut discarded = 0;
        for g in 0..survive.len() {
            if survive[g] && GroupBedpp::discards(ctx, lam, b, g) {
                survive[g] = false;
                discarded += 1;
            }
        }
        discarded
    }
}

impl SafeRule<GroupSafeContext> for GroupBedpp {
    fn name(&self) -> &'static str {
        "gBEDPP"
    }

    fn screen(
        &mut self,
        _x: &DenseMatrix,
        ctx: &GroupSafeContext,
        _prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        let d = GroupBedpp::screen_at(ctx, lam_next, survive);
        if d == 0 {
            self.dead = true;
        }
        d
    }

    fn dead(&self) -> bool {
        self.dead
    }

    fn save_state(&self) -> Vec<u8> {
        vec![self.dead as u8]
    }

    fn load_state(&mut self, state: &[u8]) -> crate::error::Result<()> {
        match state {
            [d] => {
                self.dead = *d != 0;
                Ok(())
            }
            _ => Err(crate::error::HssrError::Corrupt(
                "gBEDPP: malformed safe-rule state in checkpoint".into(),
            )),
        }
    }

    /// Point-wise plan: rule (22) is a scalar form in the per-fit
    /// precomputes, so the fused group screen applies it per group. Keep
    /// `g` iff [`GroupBedpp::screen_at`] would not discard it.
    fn plan<'s>(
        &'s mut self,
        _x: &DenseMatrix,
        ctx: &'s GroupSafeContext,
        _prev: &PrevSolution<'_>,
        lam_next: f64,
        _survive: &mut [bool],
        masked_discards: &mut usize,
    ) -> Option<Box<dyn Fn(usize) -> bool + Sync + 's>> {
        *masked_discards = 0;
        let b = GroupBedpp::bounds(ctx, lam_next);
        Some(Box::new(move |g: usize| !GroupBedpp::discards(ctx, lam_next, b, g)))
    }
}

/// Group SEDPP — the sequential EDPP rule on the group dual. Needs a full
/// `O(np)` scan per λ, like its lasso counterpart.
#[derive(Debug, Default)]
pub struct GroupSedpp {
    scratch: Vec<f64>,
    dead: bool,
}

impl GroupSedpp {
    /// Create a fresh rule.
    pub fn new() -> Self {
        GroupSedpp { scratch: Vec::new(), dead: false }
    }

    /// Evaluate at `lam_next` given the previous residual; public for the
    /// power analyses.
    pub fn screen_with(
        &mut self,
        x: &DenseMatrix,
        ctx: &GroupSafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        // The sequential form is derived for the group lasso; under the
        // elastic net the augmented design depends on λ itself, so (like
        // the column-unit SEDPP) fall back to the basic rule, which Thm 4.1
        // extends exactly.
        if !matches!(ctx.penalty, Penalty::Lasso) {
            return GroupBedpp::screen_at(ctx, lam_next, survive);
        }
        let n = ctx.n as f64;
        let mut xb_sq = 0.0;
        let mut a = 0.0;
        for (yi, ri) in ctx.y.iter().zip(prev.r) {
            let f = yi - ri;
            xb_sq += f * f;
            a += yi * f;
        }
        if xb_sq < 1e-12 {
            return GroupBedpp::screen_at(ctx, lam_next, survive);
        }
        let lam_k = prev.lambda;
        let c = (lam_k - lam_next) / (lam_k * lam_next);
        let v2p_norm = (c / n) * (ctx.y_sq - a * a / xb_sq).max(0.0).sqrt();
        // z_j = x_jᵀr/n for all columns — the O(np) scan.
        self.scratch.resize(ctx.p, 0.0);
        blocked::scan_all(x, prev.r, &mut self.scratch);
        let mut discarded = 0;
        for g in 0..survive.len() {
            if !survive[g] {
                continue;
            }
            let wg = ctx.layout.sizes[g] as f64;
            let rhs = wg.sqrt() - 0.5 * v2p_norm * n.sqrt();
            if rhs <= 0.0 {
                continue;
            }
            // q_j = x_jᵀθ_k + ½ x_jᵀv2⊥
            //     = z_j/λ_k + (c/2n)(xty_j − a(xty_j − n·z_j)/‖Xβ̂‖²)
            let mut lhs_sq = 0.0;
            for j in ctx.layout.range(g) {
                let xjr = n * self.scratch[j];
                let xjxb = ctx.xty[j] - xjr;
                let q = self.scratch[j] / lam_k
                    + 0.5 * c / n * (ctx.xty[j] - a * xjxb / xb_sq);
                lhs_sq += q * q;
            }
            if lhs_sq.sqrt() < rhs {
                survive[g] = false;
                discarded += 1;
            }
        }
        discarded
    }
}

impl SafeRule<GroupSafeContext> for GroupSedpp {
    fn name(&self) -> &'static str {
        "gSEDPP"
    }

    fn screen(
        &mut self,
        x: &DenseMatrix,
        ctx: &GroupSafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        let d = self.screen_with(x, ctx, prev, lam_next, survive);
        self.dead = d == 0;
        d
    }

    fn dead(&self) -> bool {
        self.dead
    }

    fn save_state(&self) -> Vec<u8> {
        vec![self.dead as u8]
    }

    fn load_state(&mut self, state: &[u8]) -> crate::error::Result<()> {
        match state {
            [d] => {
                self.dead = *d != 0;
                Ok(())
            }
            _ => Err(crate::error::HssrError::Corrupt(
                "gSEDPP: malformed safe-rule state in checkpoint".into(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::generate_grouped;

    fn setup(seed: u64) -> (crate::data::GroupedDataset, GroupSafeContext) {
        let ds = generate_grouped(80, 12, 4, 3, seed);
        let ctx = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, Penalty::Lasso);
        (ds, ctx)
    }

    #[test]
    fn lambda_max_matches_naive() {
        let (ds, ctx) = setup(1);
        let n = ds.n() as f64;
        let mut lm = 0.0f64;
        for g in 0..ds.num_groups() {
            let mut ss = 0.0;
            for j in ds.layout.range(g) {
                let d = ops::dot(ds.x.col(j), &ds.y);
                ss += d * d;
            }
            lm = lm.max(ss.sqrt() / (n * (ds.layout.sizes[g] as f64).sqrt()));
        }
        assert!((ctx.lambda_max - lm).abs() < 1e-10);
    }

    #[test]
    fn bedpp_discards_high_lambda_not_low() {
        let (_, ctx) = setup(2);
        let mut hi = vec![true; ctx.layout.num_groups()];
        assert!(GroupBedpp::screen_at(&ctx, 0.95 * ctx.lambda_max, &mut hi) > 0);
        let mut lo = vec![true; ctx.layout.num_groups()];
        assert_eq!(GroupBedpp::screen_at(&ctx, 0.02 * ctx.lambda_max, &mut lo), 0);
    }

    #[test]
    fn star_group_never_discarded() {
        let (_, ctx) = setup(3);
        for f in [0.99, 0.9, 0.7] {
            let mut s = vec![true; ctx.layout.num_groups()];
            GroupBedpp::screen_at(&ctx, f * ctx.lambda_max, &mut s);
            assert!(s[ctx.star]);
        }
    }

    #[test]
    fn sedpp_reduces_to_bedpp_at_k0() {
        let (ds, ctx) = setup(4);
        let prev = PrevSolution { lambda: ctx.lambda_max, r: &ds.y, beta: None };
        let lam = 0.9 * ctx.lambda_max;
        let g = ctx.layout.num_groups();
        let mut s1 = vec![true; g];
        GroupSedpp::new().screen_with(&ds.x, &ctx, &prev, lam, &mut s1);
        let mut s2 = vec![true; g];
        GroupBedpp::screen_at(&ctx, lam, &mut s2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn enet_context_scales_lambda_max() {
        let ds = generate_grouped(60, 8, 3, 2, 6);
        let c1 = GroupSafeContext::build(&ds.x, &ds.y, &ds.layout, Penalty::Lasso);
        let c2 = GroupSafeContext::build(
            &ds.x,
            &ds.y,
            &ds.layout,
            Penalty::ElasticNet { alpha: 0.5 },
        );
        assert!((c2.lambda_max - 2.0 * c1.lambda_max).abs() < 1e-12);
        assert_eq!(c1.star, c2.star);
    }

    #[test]
    fn enet_rule_runs_and_keeps_star() {
        let ds = generate_grouped(80, 12, 4, 3, 7);
        let ctx = GroupSafeContext::build(
            &ds.x,
            &ds.y,
            &ds.layout,
            Penalty::ElasticNet { alpha: 0.6 },
        );
        let mut survive = vec![true; ctx.layout.num_groups()];
        let d = GroupBedpp::screen_at(&ctx, 0.95 * ctx.lambda_max, &mut survive);
        assert!(d > 0, "enet gBEDPP should discard near λmax");
        assert!(survive[ctx.star]);
        // powerless at tiny λ
        let mut lo = vec![true; ctx.layout.num_groups()];
        assert_eq!(GroupBedpp::screen_at(&ctx, 0.02 * ctx.lambda_max, &mut lo), 0);
    }

    /// The elastic-net rule must agree with evaluating the *lasso* rule on
    /// the augmented design `X̃ = [X; √(n(1−α)λ)I]`, `ỹ = [y; 0]` with
    /// penalty αλ — the reduction the enet bound is derived from. The
    /// augmented design is renormalized so condition (19) holds, which
    /// rescales the penalty by √aug.
    #[test]
    fn enet_rule_matches_augmented_lasso_rule() {
        let ds = generate_grouped(40, 6, 3, 2, 8);
        let alpha = 0.65;
        let ctx_en = GroupSafeContext::build(
            &ds.x,
            &ds.y,
            &ds.layout,
            Penalty::ElasticNet { alpha },
        );
        let n = ds.n();
        let p = ds.p();
        for frac in [0.95, 0.8, 0.6, 0.3] {
            let lam = frac * ctx_en.lambda_max;
            let aug = 1.0 + (1.0 - alpha) * lam;
            // X̃/√aug has n+p rows and satisfies (19) w.r.t. the original n
            // only after rescaling; build it literally and rescale dots by
            // keeping the row count at n in the formulas via the ball test.
            let ridge = (n as f64 * (1.0 - alpha) * lam).sqrt();
            let xt = DenseMatrix::from_fn(n + p, p, |i, j| {
                let v = if i < n {
                    ds.x.get(i, j)
                } else if i - n == j {
                    ridge
                } else {
                    0.0
                };
                v / aug.sqrt()
            });
            let mut yt = vec![0.0; n + p];
            yt[..n].copy_from_slice(&ds.y);
            // The augmented problem is a group lasso at penalty αλ/√aug,
            // with "n" still the original n in every 1/n normalization.
            // GroupSafeContext uses x.nrows() as n, so evaluate the ball
            // directly instead: discard iff
            //   sup_θ∈B ‖X̃_gᵀθ‖ < √W_g,  B = B(θm + v̄2⊥/2, ‖v̄2⊥‖/2)
            // with θm = ỹ/(nλ̃m), v̄2⊥ = (1/λ̃−1/λ̃m)(I−P)ỹ/n, ‖X̃_g‖ = √n.
            let lam_t = alpha * lam / aug.sqrt();
            let lam_tm = alpha * ctx_en.lambda_max / aug.sqrt();
            let nf = n as f64;
            // v̄ = X̃_* X̃_*ᵀ ỹ
            let mut xty_t = vec![0.0; p];
            for j in 0..p {
                let mut d = 0.0;
                for i in 0..n + p {
                    d += xt.get(i, j) * yt[i];
                }
                xty_t[j] = d;
            }
            let mut vbar = vec![0.0; n + p];
            for j in ds.layout.range(ctx_en.star) {
                for i in 0..n + p {
                    vbar[i] += xty_t[j] * xt.get(i, j);
                }
            }
            let coef = (1.0 / lam_t - 1.0 / lam_tm) / nf;
            let v2p: Vec<f64> =
                yt.iter().zip(&vbar).map(|(y, v)| coef * (y - v / nf)).collect();
            let v2p_norm = ops::nrm2(&v2p);
            let mut survive = vec![true; ds.num_groups()];
            GroupBedpp::screen_at(&ctx_en, lam, &mut survive);
            for g in 0..ds.num_groups() {
                let mut lhs_sq = 0.0;
                for j in ds.layout.range(g) {
                    let mut d = 0.0;
                    for i in 0..n + p {
                        d += xt.get(i, j) * (yt[i] / (nf * lam_tm) + 0.5 * v2p[i]);
                    }
                    lhs_sq += d * d;
                }
                let wg = ds.layout.sizes[g] as f64;
                let rhs = wg.sqrt() - 0.5 * v2p_norm * nf.sqrt();
                if (lhs_sq.sqrt() - rhs).abs() < 1e-9 {
                    continue; // boundary: both formulations may round either way
                }
                let should_discard = g != ctx_en.star && lhs_sq.sqrt() < rhs;
                assert_eq!(
                    !survive[g],
                    should_discard,
                    "α={alpha} frac={frac} group {g}: lhs={} rhs={rhs}",
                    lhs_sq.sqrt()
                );
            }
        }
    }

    #[test]
    fn enet_sedpp_falls_back_to_basic_rule() {
        let ds = generate_grouped(60, 8, 3, 2, 9);
        let ctx = GroupSafeContext::build(
            &ds.x,
            &ds.y,
            &ds.layout,
            Penalty::ElasticNet { alpha: 0.7 },
        );
        // Fake a previous solution with a nonzero fit so the sequential
        // branch would otherwise engage.
        let mut r = ds.y.clone();
        for v in r.iter_mut() {
            *v *= 0.9;
        }
        let prev = PrevSolution { lambda: 0.9 * ctx.lambda_max, r: &r, beta: None };
        let lam = 0.8 * ctx.lambda_max;
        let g = ctx.layout.num_groups();
        let mut s1 = vec![true; g];
        GroupSedpp::new().screen_with(&ds.x, &ctx, &prev, lam, &mut s1);
        let mut s2 = vec![true; g];
        GroupBedpp::screen_at(&ctx, lam, &mut s2);
        assert_eq!(s1, s2);
    }

    /// Rule (22) must agree with a direct evaluation of the dome-free ball
    /// form (24): ‖X_gᵀ(θ* + v̄2⊥/2)‖ < √Wg − ½‖v̄2⊥‖·√n.
    #[test]
    fn rule22_matches_first_principles() {
        let (ds, ctx) = setup(5);
        let n = ctx.n as f64;
        let lam = 0.8 * ctx.lambda_max;
        let lm = ctx.lambda_max;
        // v̄2⊥ = (1/n)(1/λ − 1/λm)(I − X*X*ᵀ/n) y
        let mut vbar = vec![0.0; ds.n()];
        for j in ctx.layout.range(ctx.star) {
            ops::axpy(ctx.xty[j], ds.x.col(j), &mut vbar);
        }
        let coef = (1.0 / lam - 1.0 / lm) / n;
        let v2p: Vec<f64> =
            ds.y.iter().zip(&vbar).map(|(y, v)| coef * (y - v / n)).collect();
        let v2p_norm = ops::nrm2(&v2p);
        let mut survive = vec![true; ctx.layout.num_groups()];
        GroupBedpp::screen_at(&ctx, lam, &mut survive);
        for g in 0..ctx.layout.num_groups() {
            let mut lhs_sq = 0.0;
            for j in ctx.layout.range(g) {
                let d = ctx.xty[j] / (n * lm) + 0.5 * ops::dot(ds.x.col(j), &v2p);
                lhs_sq += d * d;
            }
            let wg = ctx.layout.sizes[g] as f64;
            let rhs = wg.sqrt() - 0.5 * v2p_norm * n.sqrt();
            let should_discard = g != ctx.star && lhs_sq.sqrt() < rhs;
            assert_eq!(
                !survive[g],
                should_discard,
                "group {g}: lhs={} rhs={rhs}",
                lhs_sq.sqrt()
            );
        }
    }
}
