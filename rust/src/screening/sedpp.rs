//! Sequential EDPP (SEDPP) safe rule — Theorem 2.2 of the paper.
//!
//! SEDPP screens at `λ_{k+1}` using the solution at `λ_k`: it needs
//! `x_jᵀr(λ_k)` and `x_jᵀXβ̂(λ_k)` for *every* feature, i.e. a full `O(np)`
//! scan per λ — total `O(npK)` (Table 1). The scan products are shared:
//! `x_jᵀXβ̂ = x_jᵀy − x_jᵀr`, so one scan of `Xᵀr` suffices.
//!
//! At `k = 0` (previous point is `λ_max`, where `β̂ = 0`) the rule reduces
//! to BEDPP (Theorem 2.2, case 2).

use super::{bedpp::Bedpp, PrevSolution, SafeContext, SafeRule};
use crate::linalg::{blocked, ops, simd, DenseMatrix};
use crate::runtime::Precision;

/// The SEDPP rule. Holds a scratch buffer for the per-λ scan.
#[derive(Debug, Default)]
pub struct Sedpp {
    scratch: Vec<f64>,
    dead: bool,
    // Scan precision: F32 runs the O(np) pass on the engine's f32 shadow
    // with an error-widened decision band + exact confirm pass.
    precision: Precision,
}

impl Sedpp {
    /// Create a fresh rule.
    pub fn new() -> Self {
        Sedpp { scratch: Vec::new(), dead: false, precision: Precision::F64 }
    }

    /// Evaluate rule (10) given the previous residual. Public for reuse by
    /// the Figure-1 power measurement.
    ///
    /// Returns the number of features discarded.
    pub fn screen_with(
        &mut self,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        // The in-process blocked scan cannot fail.
        self.screen_core(ctx, prev, lam_next, survive, |scratch| {
            blocked::scan_all(x, prev.r, scratch);
            Ok(())
        })
        .map_or(0, |(d, _)| d)
    }

    /// Shared decision body of rule (10). `scan` fills `scratch` with
    /// `z = Xᵀr/n` when the rule actually needs its `O(np)` pass; the
    /// second return value is the number of columns that pass read (0 on
    /// the BEDPP-fallback and dead-RHS branches), so routed callers can
    /// account the traffic exactly.
    fn screen_core<F>(
        &mut self,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        scan: F,
    ) -> crate::error::Result<(usize, u64)>
    where
        F: FnOnce(&mut [f64]) -> crate::error::Result<()>,
    {
        // Rule (10) is derived for the lasso. For the elastic net the
        // augmented design X̃ depends on λ itself, so the sequential form
        // does not carry over (the paper, like Wang et al., derives only
        // the *basic* EDPP rule for the enet — Thm 4.1); fall back to it.
        if !matches!(ctx.penalty, crate::solver::Penalty::Lasso) {
            return Ok((Bedpp::screen_at(ctx, lam_next, survive), 0));
        }
        let n = ctx.n as f64;
        // Xβ̂ = y − r, ‖Xβ̂‖², a = yᵀXβ̂ — all O(n).
        let mut xb_sq = 0.0;
        let mut a = 0.0;
        for (yi, ri) in ctx.y.iter().zip(prev.r) {
            let f = yi - ri;
            xb_sq += f * f;
            a += yi * f;
        }
        if xb_sq < 1e-12 {
            // β̂(λ_k) = 0 ⇒ k = 0 case: BEDPP at lam_next.
            return Ok((Bedpp::screen_at(ctx, lam_next, survive), 0));
        }
        let lam_k = prev.lambda;
        let c = (lam_k - lam_next) / (lam_k * lam_next);
        let rhs = n - 0.5 * c * (n * ctx.y_sq - n * a * a / xb_sq).max(0.0).sqrt();
        if rhs <= 0.0 {
            return Ok((0, 0));
        }
        // z_j = x_jᵀ r / n for all features: the O(np) scan.
        self.scratch.resize(ctx.p, 0.0);
        scan(&mut self.scratch)?;
        let mut discarded = 0;
        for j in 0..ctx.p {
            if !survive[j] {
                continue;
            }
            let xjr = n * self.scratch[j];
            let xjxb = ctx.xty[j] - xjr;
            let lhs = (xjr / lam_k + 0.5 * c * (ctx.xty[j] - a * xjxb / xb_sq)).abs();
            if lhs < rhs {
                survive[j] = false;
                discarded += 1;
            }
        }
        Ok((discarded, ctx.p as u64))
    }

    /// Mixed-precision rule (10): the `O(np)` pass runs on the engine's
    /// f32 shadow, and the decision band is widened by the scan error
    /// bound. `lhs` is affine in `x_jᵀr` with slope
    /// `k₁ = 1/λ_k + c·a/(2‖Xβ̂‖²)`, so an f32 scan error of at most `ε`
    /// per `z_j` perturbs `lhs` by at most `δ = |k₁|·n·ε`:
    ///
    /// * `lhs32 + δ < rhs` — sure-discard (the exact `lhs` is below `rhs`
    ///   too);
    /// * `lhs32 − δ ≥ rhs` — sure-keep;
    /// * otherwise — confirm with an exact counted f64 subset scan
    ///   replicating the f64 path's expression, so every decision is the
    ///   f64 path's own.
    ///
    /// Returns `Ok(None)` when the f32 path does not apply (non-lasso /
    /// BEDPP-fallback branches, or an engine without an f32 shadow) — the
    /// caller then runs the exact path unchanged.
    #[allow(clippy::too_many_arguments)]
    fn screen_core_f32(
        &mut self,
        engine: &dyn crate::runtime::ScanEngine,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        scanned: &mut u64,
    ) -> crate::error::Result<Option<usize>> {
        if !matches!(ctx.penalty, crate::solver::Penalty::Lasso) {
            return Ok(None);
        }
        let n = ctx.n as f64;
        let mut xb_sq = 0.0;
        let mut a = 0.0;
        for (yi, ri) in ctx.y.iter().zip(prev.r) {
            let f = yi - ri;
            xb_sq += f * f;
            a += yi * f;
        }
        if xb_sq < 1e-12 {
            return Ok(None);
        }
        let lam_k = prev.lambda;
        let c = (lam_k - lam_next) / (lam_k * lam_next);
        let rhs = n - 0.5 * c * (n * ctx.y_sq - n * a * a / xb_sq).max(0.0).sqrt();
        if rhs <= 0.0 {
            return Ok(Some(0));
        }
        self.scratch.resize(ctx.p, 0.0);
        if !engine.scan_all_f32(x, prev.r, &mut self.scratch)? {
            return Ok(None);
        }
        *scanned += ctx.p as u64;
        let eps = simd::f32_scan_error_bound(ctx.n, ops::nrm2(prev.r));
        let delta = (1.0 / lam_k + 0.5 * c * a / xb_sq).abs() * n * eps;
        let mut boundary = Vec::new();
        let mut discarded = 0;
        for j in 0..ctx.p {
            if !survive[j] {
                continue;
            }
            let xjr = n * self.scratch[j];
            let xjxb = ctx.xty[j] - xjr;
            let lhs = (xjr / lam_k + 0.5 * c * (ctx.xty[j] - a * xjxb / xb_sq)).abs();
            if lhs + delta < rhs {
                survive[j] = false;
                discarded += 1;
            } else if lhs - delta < rhs {
                boundary.push(j);
            }
        }
        if !boundary.is_empty() {
            let mut buf = vec![0.0; boundary.len()];
            engine.scan_subset(x, prev.r, &boundary, &mut buf)?;
            *scanned += boundary.len() as u64;
            for (zk, &j) in buf.iter().zip(boundary.iter()) {
                let xjr = n * zk;
                let xjxb = ctx.xty[j] - xjr;
                let lhs = (xjr / lam_k + 0.5 * c * (ctx.xty[j] - a * xjxb / xb_sq)).abs();
                if lhs < rhs {
                    survive[j] = false;
                    discarded += 1;
                }
            }
        }
        Ok(Some(discarded))
    }
}

impl SafeRule for Sedpp {
    fn name(&self) -> &'static str {
        "SEDPP"
    }

    fn screen(
        &mut self,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        let d = self.screen_with(x, ctx, prev, lam_next, survive);
        // SEDPP stays powerful along the whole path (Figure 1); only flag
        // dead if it truly discarded nothing, mirroring Algorithm 1's
        // |S| = p test.
        self.dead = d == 0;
        d
    }

    /// Engine-routed screen: the rule's in-rule `O(np)` pass dispatches
    /// through `engine` — a chunked or out-of-core engine both serves and
    /// counts the reads — and `*scanned` gains `p` exactly when the pass
    /// ran (the BEDPP-fallback and dead-RHS branches read no columns).
    fn screen_routed(
        &mut self,
        engine: &dyn crate::runtime::ScanEngine,
        x: &DenseMatrix,
        ctx: &SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        scanned: &mut u64,
    ) -> crate::error::Result<usize> {
        if self.precision == Precision::F32 {
            if let Some(d) =
                self.screen_core_f32(engine, x, ctx, prev, lam_next, survive, scanned)?
            {
                self.dead = d == 0;
                return Ok(d);
            }
        }
        let (d, cols) = self.screen_core(ctx, prev, lam_next, survive, |scratch| {
            engine.scan_all(x, prev.r, scratch)
        })?;
        *scanned += cols;
        self.dead = d == 0;
        Ok(d)
    }

    fn set_precision(&mut self, precision: Precision) {
        self.precision = precision;
    }

    /// Engine-routed plan: SEDPP always screens into the mask (its test is
    /// not point-wise in per-fit precomputes), so the fused pipeline takes
    /// the scan-then-filter path with the scan routed and accounted.
    fn plan_routed<'s>(
        &'s mut self,
        engine: &dyn crate::runtime::ScanEngine,
        x: &DenseMatrix,
        ctx: &'s SafeContext,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        masked_discards: &mut usize,
        scanned: &mut u64,
    ) -> crate::error::Result<Option<Box<dyn Fn(usize) -> bool + Sync + 's>>> {
        *masked_discards =
            self.screen_routed(engine, x, ctx, prev, lam_next, survive, scanned)?;
        Ok(None)
    }

    fn dead(&self) -> bool {
        self.dead
    }

    fn save_state(&self) -> Vec<u8> {
        vec![self.dead as u8]
    }

    fn load_state(&mut self, state: &[u8]) -> crate::error::Result<()> {
        match state {
            [d] => {
                self.dead = *d != 0;
                Ok(())
            }
            _ => Err(crate::error::HssrError::Corrupt(
                "SEDPP: malformed safe-rule state in checkpoint".into(),
            )),
        }
    }
}

/// First-principles helper shared with tests: the EDPP dual ball at
/// `lam_next` given the previous dual point. Returns `(center_dot_j, radius)`
/// evaluated lazily per feature via a closure over `v2⊥`.
#[cfg(test)]
pub(crate) fn reference_ball(
    x: &DenseMatrix,
    ctx: &SafeContext,
    prev: &PrevSolution<'_>,
    lam_next: f64,
) -> (Vec<f64>, f64) {
    use crate::linalg::ops;
    let n = ctx.n as f64;
    let xb: Vec<f64> = ctx.y.iter().zip(prev.r).map(|(y, r)| y - r).collect();
    let xb_sq = ops::nrm2_sq(&xb);
    let a = ops::dot(&ctx.y, &xb);
    let lam_k = prev.lambda;
    let c = (lam_k - lam_next) / (lam_k * lam_next);
    // v2⊥ = (c/n)(y − a·Xβ̂/‖Xβ̂‖²)
    let v2p: Vec<f64> =
        ctx.y.iter().zip(&xb).map(|(y, f)| (c / n) * (y - a * f / xb_sq)).collect();
    let radius = 0.5 * ops::nrm2(&v2p);
    // center θ_c = r/(nλ_k) + v2⊥/2; sup |x_jᵀθ| = |x_jᵀθ_c| + ‖x_j‖·radius
    let center: Vec<f64> = (0..ctx.p)
        .map(|j| {
            let col = x.col(j);
            let mut d = 0.0;
            for i in 0..ctx.n {
                d += col[i] * (prev.r[i] / (n * lam_k) + 0.5 * v2p[i]);
            }
            d
        })
        .collect();
    (center, radius)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::solver::Penalty;

    fn setup(seed: u64) -> (crate::data::Dataset, SafeContext) {
        let ds = DataSpec::synthetic(60, 40, 4).generate(seed);
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        (ds, ctx)
    }

    #[test]
    fn reduces_to_bedpp_at_k0() {
        let (ds, ctx) = setup(1);
        let mut rule = Sedpp::new();
        let prev = PrevSolution { lambda: ctx.lambda_max, r: &ds.y, beta: None };
        let lam = 0.9 * ctx.lambda_max;
        let mut s_sedpp = vec![true; ctx.p];
        rule.screen_with(&ds.x, &ctx, &prev, lam, &mut s_sedpp);
        let mut s_bedpp = vec![true; ctx.p];
        Bedpp::screen_at(&ctx, lam, &mut s_bedpp);
        assert_eq!(s_sedpp, s_bedpp);
    }

    /// With a genuine previous solution, the discard decisions must agree
    /// with the first-principles dual ball: |x_jᵀθc| + √n·R < 1.
    #[test]
    fn matches_reference_ball() {
        let (ds, ctx) = setup(2);
        // Fake a plausible "previous solution" residual: project y onto the
        // span of 3 columns (a valid β̂ surrogate for geometry checking —
        // the rule only requires r = y − Xβ for the β we hand it... we use
        // exact optimization in integration tests; here geometry only).
        let mut beta = vec![0.0; ctx.p];
        beta[0] = 0.1;
        beta[3] = -0.2;
        let xb = ds.x.matvec(&beta);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let lam_k = 0.7 * ctx.lambda_max;
        let lam_next = 0.6 * ctx.lambda_max;
        let prev = PrevSolution { lambda: lam_k, r: &r, beta: Some(&beta) };
        let mut survive = vec![true; ctx.p];
        let mut rule = Sedpp::new();
        rule.screen_with(&ds.x, &ctx, &prev, lam_next, &mut survive);
        let (center, radius) = reference_ball(&ds.x, &ctx, &prev, lam_next);
        let n = ctx.n as f64;
        for j in 0..ctx.p {
            let sup = center[j].abs() + n.sqrt() * radius;
            let should_discard = sup < 1.0 - 1e-10;
            assert_eq!(
                !survive[j],
                should_discard,
                "feature {j}: sup={sup}, survive={}",
                survive[j]
            );
        }
    }

    #[test]
    fn discards_more_than_bedpp_deep_in_path() {
        let (ds, ctx) = setup(3);
        // Deep in the path BEDPP is dead but SEDPP still works, given a
        // previous solution with small residual. Build r by soft projection.
        let mut beta = vec![0.0; ctx.p];
        for (k, j) in ds.truth.clone().unwrap().into_iter().enumerate() {
            beta[j] = 0.05 * (k as f64 + 1.0);
        }
        let xb = ds.x.matvec(&beta);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let lam_k = 0.3 * ctx.lambda_max;
        let lam_next = 0.29 * ctx.lambda_max;
        let mut s_bedpp = vec![true; ctx.p];
        let b = Bedpp::screen_at(&ctx, lam_next, &mut s_bedpp);
        let mut s_sedpp = vec![true; ctx.p];
        let mut rule = Sedpp::new();
        let prev = PrevSolution { lambda: lam_k, r: &r, beta: Some(&beta) };
        let s = rule.screen_with(&ds.x, &ctx, &prev, lam_next, &mut s_sedpp);
        assert!(s >= b, "SEDPP ({s}) should not trail BEDPP ({b}) here");
    }
}
