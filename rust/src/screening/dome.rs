//! The Dome safe test (Xiang & Ramadge 2012; Xiang et al. 2016).
//!
//! The paper defers the simplified Dome derivation to its supplement; we
//! reconstruct it here in the paper's scaling. The dual optimum `θ̂(λ)` is
//! the projection of `y/(nλ)` onto the dual-feasible polytope, so it lies in
//! the **dome**
//!
//! ```text
//! D(λ) = B(c, R) ∩ { θ : s·x*ᵀθ ≤ 1 },     c = y/(nλ),
//!        R = ‖y‖(1/(nλ) − 1/(nλm)),        s = sign(x*ᵀy),
//! ```
//!
//! because (i) any feasible point is at least as far from `y/(nλ)` as the
//! projection, and `y/(nλm)` is feasible — giving the ball — and (ii) the
//! feasibility half-space through `x*`. Feature `j` is discarded when
//! `sup_{θ∈D} |x_jᵀθ| < 1` (the KKT certificate of §1).
//!
//! The sup of a linear functional `gᵀθ` over a ball-cap has the standard
//! closed form: with unit normal `n_h = s·x*/√n`, offset `ψ = 1/√n`, and
//! `t = (ψ − n_hᵀc)/R`, either the unconstrained ball maximizer already
//! satisfies the half-space (`gᵀn_h ≤ t‖g‖`), giving `gᵀc + R‖g‖`, or the
//! maximum sits on the cap rim:
//! `gᵀc + R(t·gᵀn_h + √(1−t²)·√(‖g‖² − (gᵀn_h)²))`.
//!
//! Under standardization (2), `t = −√n·λm/‖y‖` — independent of λ (a small
//! bonus of this scaling; Cauchy–Schwarz gives `|t| ≤ 1`).
//!
//! Like BEDPP, the Dome test needs only `Xᵀy` and `Xᵀx*` — `O(np)` once,
//! `O(p)` per λ — but it is strictly weaker in practice (Figure 1), dying
//! near `λ/λmax ≈ 0.6` where BEDPP lasts to ≈ 0.45.

use super::{PrevSolution, SafeContext, SafeRule};
use crate::linalg::DenseMatrix;

/// The Dome safe test.
#[derive(Debug, Default)]
pub struct DomeTest {
    dead: bool,
}

/// Sup of `gᵀθ` over the dome, parameterized by scalars (see module docs):
/// `gc = gᵀc`, `gn = gᵀn_h`, `gnorm = ‖g‖`, ball radius `r`, cap offset `t`.
#[inline]
fn dome_sup(gc: f64, gn: f64, gnorm: f64, r: f64, t: f64) -> f64 {
    if r <= 0.0 {
        return gc; // degenerate ball: the single point c
    }
    if gn <= t * gnorm {
        gc + r * gnorm
    } else {
        let cross = (gnorm * gnorm - gn * gn).max(0.0).sqrt();
        gc + r * (t * gn + (1.0 - t * t).max(0.0).sqrt() * cross)
    }
}

/// The λ-dependent scalars of the dome test, shared by the mask screen and
/// the fused point-wise predicate so both evaluate bit-identically.
#[derive(Clone, Copy)]
struct DomeScalars {
    n: f64,
    alpha: f64,
    gnorm: f64,
    r: f64,
    t: f64,
    s: f64,
}

impl DomeScalars {
    fn at(ctx: &SafeContext, lam: f64) -> DomeScalars {
        assert!(
            !ctx.xtx_star.is_empty(),
            "Dome requires SafeContext built with need_star = true"
        );
        let n = ctx.n as f64;
        let alpha = ctx.penalty.alpha();
        let aug = 1.0 + lam * (1.0 - alpha); // = 1 for the lasso
        let gnorm = (n * aug).sqrt();
        let lm = ctx.lambda_max;
        let y_norm = ctx.y_sq.sqrt();
        // ball: center ỹ/(nαλ), radius ‖y‖(λm−λ)/(nαλλm)
        let r = y_norm * (lm - lam) / (n * alpha * lam * lm);
        // cap offset t = −√n·αλm/(√aug·‖y‖)  (λ-independent for the lasso)
        let t = (-(n.sqrt()) * alpha * lm / (aug.sqrt() * y_norm)).max(-1.0);
        DomeScalars { n, alpha, gnorm, r, t, s: ctx.sign_star }
    }

    /// Whether the dome discards feature `j` (callers exclude `x*`).
    #[inline]
    fn discards(&self, xty_j: f64, xs_j: f64, lam: f64) -> bool {
        let gc = xty_j / (self.n * self.alpha * lam);
        let gn = self.s * xs_j / self.gnorm;
        let sup_pos = dome_sup(gc, gn, self.gnorm, self.r, self.t);
        let sup_neg = dome_sup(-gc, -gn, self.gnorm, self.r, self.t);
        sup_pos < 1.0 && sup_neg < 1.0
    }
}

impl DomeTest {
    /// Create a fresh rule.
    pub fn new() -> Self {
        DomeTest { dead: false }
    }

    /// Evaluate the test at `lam`, clearing `survive[j]` for discarded
    /// features; standalone entry point for the hybrid rule and Figure 1.
    ///
    /// For the elastic net the test runs in the Theorem-4.1 augmented design
    /// `x̃_j = (x_j, √(nλ(1−α))·e_j)`: the augmented column norm becomes
    /// `√(n·aug)` with `aug = 1 + λ(1−α)`, the dual scaling picks up α, and
    /// cross products `x̃_jᵀx̃_* = x_jᵀx_*` / `x̃_jᵀỹ = x_jᵀy` are unchanged
    /// (the augmented rows hit zeros). Everything else is the same dome.
    pub fn screen_at(ctx: &SafeContext, lam: f64, survive: &mut [bool]) -> usize {
        assert_eq!(survive.len(), ctx.p);
        let sc = DomeScalars::at(ctx, lam);
        let mut discarded = 0;
        for j in 0..ctx.p {
            if !survive[j] || j == ctx.star {
                continue;
            }
            if sc.discards(ctx.xty[j], ctx.xtx_star[j], lam) {
                survive[j] = false;
                discarded += 1;
            }
        }
        discarded
    }
}

impl SafeRule for DomeTest {
    fn name(&self) -> &'static str {
        "Dome"
    }

    fn screen(
        &mut self,
        _x: &DenseMatrix,
        ctx: &SafeContext,
        _prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize {
        let d = DomeTest::screen_at(ctx, lam_next, survive);
        if d == 0 {
            self.dead = true;
        }
        d
    }

    fn save_state(&self) -> Vec<u8> {
        vec![self.dead as u8]
    }

    fn load_state(&mut self, state: &[u8]) -> crate::error::Result<()> {
        match state {
            [d] => {
                self.dead = *d != 0;
                Ok(())
            }
            _ => Err(crate::error::HssrError::Corrupt(
                "Dome: malformed safe-rule state in checkpoint".into(),
            )),
        }
    }

    fn dead(&self) -> bool {
        self.dead
    }

    /// Point-wise plan: the dome test is per-column in the per-fit
    /// precomputes, so hand the fused kernel a `keep(j)` predicate that is
    /// the exact complement of [`DomeTest::screen_at`]'s discard test.
    fn plan<'s>(
        &'s mut self,
        _x: &DenseMatrix,
        ctx: &'s SafeContext,
        _prev: &PrevSolution<'_>,
        lam_next: f64,
        _survive: &mut [bool],
        masked_discards: &mut usize,
    ) -> Option<Box<dyn Fn(usize) -> bool + Sync + 's>> {
        *masked_discards = 0;
        let sc = DomeScalars::at(ctx, lam_next);
        let xty = &ctx.xty;
        let xs = &ctx.xtx_star;
        let star = ctx.star;
        Some(Box::new(move |j: usize| {
            j == star || !sc.discards(xty[j], xs[j], lam_next)
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::screening::bedpp::Bedpp;
    use crate::solver::Penalty;

    fn setup(seed: u64) -> SafeContext {
        let ds = DataSpec::synthetic(60, 40, 4).generate(seed);
        SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true)
    }

    #[test]
    fn sup_formula_ball_interior_case() {
        // g aligned away from the cap normal: unconstrained max.
        let sup = dome_sup(0.5, -10.0, 10.0, 1.0, -0.1);
        assert!((sup - 10.5).abs() < 1e-12);
    }

    #[test]
    fn sup_formula_rim_case_bounded_by_ball() {
        // Rim maximum is always ≤ unconstrained ball maximum.
        let rim = dome_sup(0.5, 9.0, 10.0, 1.0, -0.1);
        assert!(rim <= 0.5 + 10.0 + 1e-12);
        assert!(rim < 10.5); // strictly cut
    }

    #[test]
    fn discards_at_high_lambda_then_dies() {
        let ctx = setup(1);
        let mut hi = vec![true; ctx.p];
        assert!(DomeTest::screen_at(&ctx, 0.95 * ctx.lambda_max, &mut hi) > 0);
        let mut lo = vec![true; ctx.p];
        assert_eq!(DomeTest::screen_at(&ctx, 0.05 * ctx.lambda_max, &mut lo), 0);
    }

    /// The dome is a subset of the BEDPP analysis only in spirit; what must
    /// hold *exactly* is safety: every feature that the exact dual solution
    /// would keep is kept. Proxy check (exact at λmax): x* survives, and at
    /// λ = λmax nothing with |x_jᵀy|/n = λm is discarded.
    #[test]
    fn star_always_survives() {
        let ctx = setup(2);
        for f in [1.0, 0.9, 0.7, 0.5] {
            let mut survive = vec![true; ctx.p];
            DomeTest::screen_at(&ctx, f * ctx.lambda_max, &mut survive);
            assert!(survive[ctx.star], "star discarded at {f}λmax");
        }
    }

    /// Figure 1's qualitative ordering: Dome discards fewer features than
    /// BEDPP at moderate λ, and shuts off earlier.
    #[test]
    fn weaker_than_bedpp() {
        let ctx = setup(3);
        let mut total_dome = 0usize;
        let mut total_bedpp = 0usize;
        for i in 1..=20 {
            let lam = ctx.lambda_max * (1.0 - 0.045 * i as f64);
            let mut sd = vec![true; ctx.p];
            total_dome += DomeTest::screen_at(&ctx, lam, &mut sd);
            let mut sb = vec![true; ctx.p];
            total_bedpp += Bedpp::screen_at(&ctx, lam, &mut sb);
        }
        assert!(
            total_dome <= total_bedpp,
            "dome={total_dome} bedpp={total_bedpp}"
        );
    }

    /// The fused-pass predicate must agree with `screen_at` column by
    /// column at every λ.
    #[test]
    fn plan_predicate_matches_screen_at() {
        use crate::screening::SafeRule;
        let ds = DataSpec::synthetic(60, 40, 4).generate(6);
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        let prev = PrevSolution { lambda: ctx.lambda_max, r: &ds.y, beta: None };
        for frac in [0.99, 0.8, 0.5, 0.1] {
            let lam = frac * ctx.lambda_max;
            let mut rule = DomeTest::new();
            let mut survive = vec![true; ctx.p];
            let mut d = 0usize;
            let keep = rule
                .plan(&ds.x, &ctx, &prev, lam, &mut survive, &mut d)
                .expect("dome plan is always point-wise");
            assert_eq!(d, 0);
            let mut mask = vec![true; ctx.p];
            DomeTest::screen_at(&ctx, lam, &mut mask);
            for j in 0..ctx.p {
                assert_eq!(keep(j), mask[j], "feature {j} at {frac}·λmax");
            }
        }
    }

    #[test]
    fn degenerate_ball_at_lambda_max() {
        let ctx = setup(4);
        let mut survive = vec![true; ctx.p];
        // At λ = λmax the ball radius is 0; the test reduces to
        // |x_jᵀy|/(nλm) < 1, which discards every non-argmax feature with
        // strictly smaller correlation — all safe since β̂(λmax) = 0.
        let d = DomeTest::screen_at(&ctx, ctx.lambda_max, &mut survive);
        assert!(d > 0);
        assert!(survive[ctx.star]);
    }
}
