//! Hybrid safe-strong rules (HSSR) — Definition 3.1 of the paper.
//!
//! An HSSR composes a safe rule with SSR: at `λ_{k+1}`, feature `j` is
//! discarded iff
//!
//! ```text
//! j ∈ S⁠ᶜ_{k+1}  ∪  { j ∈ S_{k+1} : |x_jᵀ r(λ_k)|/n < 2λ_{k+1} − λ_k }   (11)
//! ```
//!
//! where `S_{k+1}` is the safe set. Features in `Sᶜ` are discarded *safely*
//! (no KKT checking ever needed for them); features discarded by the SSR
//! half must still be KKT-verified after convergence — but only the set
//! `S \ H` is checked, which is the source of the paper's speedup.
//!
//! The composition is *executed* inside Algorithm 1
//! ([`crate::solver::path`]); this module exposes the set-level combinator
//! for rule-level analysis (Figure 1) and unit testing, plus the named
//! instances SSR-BEDPP and SSR-Dome via [`super::make_safe_rule`].

use crate::solver::Penalty;

/// Outcome of applying formula (11) at one λ step.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HssrSets {
    /// Safe set `S` (indices surviving the safe rule).
    pub safe: Vec<usize>,
    /// Strong set `H ⊆ S` (survivors of SSR within the safe set) — the
    /// features handed to the optimizer.
    pub strong: Vec<usize>,
    /// `S \ H` — the only features that need post-convergence KKT checking.
    pub kkt_check: Vec<usize>,
}

/// Apply Definition 3.1 at one step: given the safe-survival mask and the
/// correlations `z_j = x_jᵀ r(λ_k)/n`, partition features into the sets of
/// interest.
pub fn hssr_discard_set(
    penalty: Penalty,
    lam_next: f64,
    lam_prev: f64,
    z: &[f64],
    safe_mask: &[bool],
) -> HssrSets {
    assert_eq!(z.len(), safe_mask.len());
    let t = super::ssr::threshold(penalty, lam_next, lam_prev);
    let mut safe = Vec::new();
    let mut strong = Vec::new();
    let mut kkt_check = Vec::new();
    for (j, &in_safe) in safe_mask.iter().enumerate() {
        if !in_safe {
            continue;
        }
        safe.push(j);
        if z[j].abs() >= t {
            strong.push(j);
        } else {
            kkt_check.push(j);
        }
    }
    HssrSets { safe, strong, kkt_check }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_is_consistent() {
        let z = vec![0.9, 0.1, 0.5, 0.0, 0.31];
        let safe = vec![true, true, false, true, true];
        // λ_prev=0.5, λ_next=0.4 → t=0.3
        let sets = hssr_discard_set(Penalty::Lasso, 0.4, 0.5, &z, &safe);
        assert_eq!(sets.safe, vec![0, 1, 3, 4]);
        assert_eq!(sets.strong, vec![0, 4]);
        assert_eq!(sets.kkt_check, vec![1, 3]);
        // strong ∪ kkt_check = safe, disjoint
        let mut u = sets.strong.clone();
        u.extend(&sets.kkt_check);
        u.sort_unstable();
        assert_eq!(u, sets.safe);
    }

    /// HSSR discards at least as much as SSR alone (paper §3.2.1): every
    /// feature SSR would discard is either outside the safe set (discarded)
    /// or fails the SSR threshold inside it (discarded).
    #[test]
    fn discards_superset_of_ssr() {
        let z = vec![0.05, 0.4, 0.2, 0.6];
        let all_safe = vec![true; 4];
        let trimmed_safe = vec![false, true, false, true];
        let ssr_only = hssr_discard_set(Penalty::Lasso, 0.4, 0.5, &z, &all_safe);
        let hybrid = hssr_discard_set(Penalty::Lasso, 0.4, 0.5, &z, &trimmed_safe);
        // optimizer set (strong) of hybrid ⊆ of ssr-only
        for j in &hybrid.strong {
            assert!(ssr_only.strong.contains(j));
        }
        // and KKT work strictly shrinks
        assert!(hybrid.kkt_check.len() <= ssr_only.kkt_check.len());
    }

    #[test]
    fn enet_threshold_used() {
        let z = vec![0.2];
        let sets = hssr_discard_set(
            Penalty::ElasticNet { alpha: 0.5 },
            0.4,
            0.5,
            &z,
            &[true],
        );
        // t = 0.5·(0.3) = 0.15 < 0.2 → strong
        assert_eq!(sets.strong, vec![0]);
    }
}
