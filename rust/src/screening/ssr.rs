//! The sequential strong rule (SSR) of Tibshirani et al. (2012).
//!
//! Given the solution at `λ_k` and its correlations `z_j = x_jᵀr(λ_k)/n`,
//! SSR discards feature `j` at `λ_{k+1}` if `|z_j| < 2λ_{k+1} − λ_k`
//! (rule (3)); the elastic-net form scales the threshold by α (rule (14)).
//!
//! SSR is *not* safe — it assumes the unit-slope bound (5) — so solutions
//! screened by SSR must be validated by post-convergence KKT checking
//! ([`crate::solver::kkt`]).

use crate::solver::Penalty;

/// The SSR threshold at `λ_next` given the previous grid point `λ_prev`.
///
/// Lasso: `2λ_{k+1} − λ_k`; elastic net: `α(2λ_{k+1} − λ_k)`.
#[inline]
pub fn threshold(penalty: Penalty, lam_next: f64, lam_prev: f64) -> f64 {
    penalty.alpha() * (2.0 * lam_next - lam_prev)
}

/// Apply SSR over the features flagged in `candidates`: returns the strong
/// set (features *kept* for optimization). `z[j]` must hold
/// `x_jᵀ r(λ_prev)/n` for every candidate `j`.
pub fn strong_set(
    penalty: Penalty,
    lam_next: f64,
    lam_prev: f64,
    z: &[f64],
    candidates: &[bool],
) -> Vec<usize> {
    let t = threshold(penalty, lam_next, lam_prev);
    candidates
        .iter()
        .enumerate()
        .filter(|&(j, &c)| c && z[j].abs() >= t)
        .map(|(j, _)| j)
        .collect()
}

/// Group-lasso SSR (rule (20)): keep group `g` iff
/// `‖X_gᵀr/n‖ ≥ √W_g · α(2λ_{k+1} − λ_k)`. `znorm[g]` must hold
/// `‖X_gᵀr/n‖`; the α scaling covers the group elastic net (α = 1 for the
/// group lasso), mirroring the column rule (14).
pub fn group_strong_set(
    penalty: Penalty,
    lam_next: f64,
    lam_prev: f64,
    znorm: &[f64],
    sizes: &[usize],
    candidates: &[bool],
) -> Vec<usize> {
    let t = threshold(penalty, lam_next, lam_prev);
    candidates
        .iter()
        .enumerate()
        .filter(|&(g, &c)| c && znorm[g] >= (sizes[g] as f64).sqrt() * t)
        .map(|(g, _)| g)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_forms() {
        assert!((threshold(Penalty::Lasso, 0.4, 0.5) - 0.3).abs() < 1e-15);
        let en = Penalty::ElasticNet { alpha: 0.5 };
        assert!((threshold(en, 0.4, 0.5) - 0.15).abs() < 1e-15);
    }

    #[test]
    fn strong_set_filters_small_correlations() {
        let z = vec![0.50, 0.10, -0.45, 0.29, -0.31];
        let cand = vec![true; 5];
        // λ_prev = 0.5, λ_next = 0.4 → t = 0.3
        let h = strong_set(Penalty::Lasso, 0.4, 0.5, &z, &cand);
        assert_eq!(h, vec![0, 2, 4]);
    }

    #[test]
    fn strong_set_respects_candidates() {
        let z = vec![1.0, 1.0, 1.0];
        let cand = vec![true, false, true];
        let h = strong_set(Penalty::Lasso, 0.4, 0.5, &z, &cand);
        assert_eq!(h, vec![0, 2]);
    }

    #[test]
    fn strong_set_empty_threshold_negative() {
        // When 2λ_next − λ_prev < 0 every candidate survives.
        let z = vec![0.0, 0.001];
        let h = strong_set(Penalty::Lasso, 0.1, 0.5, &z, &[true, true]);
        assert_eq!(h.len(), 2);
    }

    #[test]
    fn group_strong_set_scales_by_sqrt_w() {
        let znorm = vec![0.5, 0.5];
        let sizes = vec![1, 4]; // thresholds 0.3·1, 0.3·2
        let h = group_strong_set(Penalty::Lasso, 0.4, 0.5, &znorm, &sizes, &[true, true]);
        assert_eq!(h, vec![0]);
    }

    #[test]
    fn group_strong_set_scales_threshold_by_alpha() {
        let znorm = vec![0.2, 0.2];
        let sizes = vec![1, 4]; // lasso thresholds 0.3, 0.6 — both excluded
        let en = Penalty::ElasticNet { alpha: 0.5 };
        // enet thresholds 0.15, 0.3 — group 0 enters
        let h = group_strong_set(en, 0.4, 0.5, &znorm, &sizes, &[true, true]);
        assert_eq!(h, vec![0]);
        let h_lasso =
            group_strong_set(Penalty::Lasso, 0.4, 0.5, &znorm, &sizes, &[true, true]);
        assert!(h_lasso.is_empty());
    }
}
