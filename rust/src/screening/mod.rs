//! Feature screening rules for lasso-type problems.
//!
//! Two families:
//!
//! * **Safe rules** ([`SafeRule`]) are guaranteed never to discard an active
//!   feature. Implemented: [`bedpp::Bedpp`] (Thm 2.1 / Thm 4.1),
//!   [`sedpp::Sedpp`] (Thm 2.2), [`dome::DomeTest`] (Xiang & Ramadge 2012),
//!   [`rehybrid::BedppThenFrozenSedpp`] (the §6 future-work rule), and the
//!   *dynamic* gap-safe sphere rules [`gapsafe::GapSafe`] /
//!   [`gapsafe::GroupGapSafe`] (Fercoq, Gramfort & Salmon 2015), which
//!   tighten as the solver converges and are the only safe rules available
//!   to the logistic family.
//! * **The sequential strong rule** ([`ssr`]) is a heuristic that requires
//!   post-convergence KKT checking.
//!
//! A *hybrid safe-strong rule* (Definition 3.1) composes one of each; the
//! composition itself ([`hybrid::hssr_discard_set`]) is exercised by
//! Algorithm 1 in [`crate::solver::driver`]. Static rules fire once per λ
//! and are switched off by the `Flag` shutoff; dynamic rules
//! ([`SafeRule::dynamic`]) additionally re-fire mid-optimization through
//! [`crate::solver::driver::Problem::rescreen`]. See
//! `docs/ARCHITECTURE.md` for the full rule ↔ equation map.

pub mod bedpp;
pub mod dome;
pub mod gapsafe;
pub mod group;
pub mod hybrid;
pub mod rehybrid;
pub mod sedpp;
pub mod ssr;

use crate::linalg::{blocked, ops, DenseMatrix};
use crate::solver::Penalty;

/// Solver strategy — the "Method" column of the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RuleKind {
    /// Basic pathwise coordinate descent, no screening ("Basic PCD").
    BasicPcd,
    /// Active-set cycling (Lee et al. 2007) — "AC".
    ActiveCycling,
    /// Sequential strong rule alone — "SSR".
    Ssr,
    /// Sequential EDPP safe rule alone — "SEDPP".
    Sedpp,
    /// Hybrid SSR + basic EDPP — "SSR-BEDPP" (the paper's headline rule).
    SsrBedpp,
    /// Hybrid SSR + Dome test — "SSR-Dome".
    SsrDome,
    /// §6 extension: SSR + BEDPP re-hybridized with a frozen SEDPP once
    /// BEDPP goes dead — "SSR-BEDPP-SEDPP".
    SsrBedppSedpp,
    /// Hybrid SSR + dynamic gap-safe sphere rule — "SSR-GapSafe". The only
    /// HSSR instance available to every problem family (including the
    /// logistic path, where the quadratic-loss safe rules do not apply).
    SsrGapSafe,
}

impl RuleKind {
    /// Paper-style display name.
    pub fn label(&self) -> &'static str {
        match self {
            RuleKind::BasicPcd => "Basic PCD",
            RuleKind::ActiveCycling => "AC",
            RuleKind::Ssr => "SSR",
            RuleKind::Sedpp => "SEDPP",
            RuleKind::SsrBedpp => "SSR-BEDPP",
            RuleKind::SsrDome => "SSR-Dome",
            RuleKind::SsrBedppSedpp => "SSR-BEDPP-SEDPP",
            RuleKind::SsrGapSafe => "SSR-GapSafe",
        }
    }

    /// All methods compared in the paper's lasso experiments (Tables 2).
    pub fn paper_lasso_methods() -> [RuleKind; 6] {
        [
            RuleKind::BasicPcd,
            RuleKind::ActiveCycling,
            RuleKind::Ssr,
            RuleKind::Sedpp,
            RuleKind::SsrDome,
            RuleKind::SsrBedpp,
        ]
    }

    /// Whether this strategy uses a safe rule that needs `Xᵀx*` precompute.
    /// (SEDPP needs it too: its k = 0 case reduces to BEDPP.)
    pub fn needs_star(&self) -> bool {
        matches!(
            self,
            RuleKind::Sedpp | RuleKind::SsrBedpp | RuleKind::SsrDome | RuleKind::SsrBedppSedpp
        )
    }

    /// Whether this strategy uses SSR (and hence KKT checking).
    pub fn uses_ssr(&self) -> bool {
        matches!(
            self,
            RuleKind::Ssr
                | RuleKind::SsrBedpp
                | RuleKind::SsrDome
                | RuleKind::SsrBedppSedpp
                | RuleKind::SsrGapSafe
        )
    }
}

/// Quantities shared by every safe rule, computed once per fit (`O(np)`).
#[derive(Clone, Debug)]
pub struct SafeContext {
    /// Observations.
    pub n: usize,
    /// Features.
    pub p: usize,
    /// Centered response.
    pub y: Vec<f64>,
    /// `x_jᵀ y` for every feature (un-normalized).
    pub xty: Vec<f64>,
    /// `x_jᵀ x_*` for every feature; empty if not requested.
    pub xtx_star: Vec<f64>,
    /// `‖y‖²`.
    pub y_sq: f64,
    /// `λ_max = max_j |x_jᵀy|/(αn)`.
    pub lambda_max: f64,
    /// Index of `x_* = argmax_j |x_jᵀy|`.
    pub star: usize,
    /// `sign(x_*ᵀ y)`.
    pub sign_star: f64,
    /// Penalty (affects the elastic-net variants of every rule).
    pub penalty: Penalty,
}

impl SafeContext {
    /// Build the context. `need_star` controls whether the extra `O(np)`
    /// scan for `Xᵀx_*` is performed (only BEDPP/Dome need it).
    pub fn build(x: &DenseMatrix, y: &[f64], penalty: Penalty, need_star: bool) -> SafeContext {
        let n = x.nrows();
        let p = x.ncols();
        let mut xty = vec![0.0; p];
        // xty = n * scan(x, y) since scan divides by n.
        blocked::scan_all(x, y, &mut xty);
        for v in xty.iter_mut() {
            *v *= n as f64;
        }
        let (star, max_abs) = ops::abs_argmax(&xty);
        let alpha = penalty.alpha();
        let lambda_max = max_abs / (alpha * n as f64);
        let sign_star = if xty[star] >= 0.0 { 1.0 } else { -1.0 };
        let xtx_star = if need_star {
            let mut v = vec![0.0; p];
            blocked::scan_all(x, x.col(star), &mut v);
            for w in v.iter_mut() {
                *w *= n as f64;
            }
            v
        } else {
            Vec::new()
        };
        SafeContext {
            n,
            p,
            y: y.to_vec(),
            xty,
            xtx_star,
            y_sq: ops::nrm2_sq(y),
            lambda_max,
            star,
            sign_star,
            penalty,
        }
    }
}

/// Information about the previously solved λ point (or, for dynamic rules,
/// the *current iterate*), consumed by sequential and gap-safe rules.
pub struct PrevSolution<'a> {
    /// λ of the previous solution (`λ_k`); equals `λ_max` before any solve.
    pub lambda: f64,
    /// Residual `r(λ_k) = y − Xβ̂(λ_k)` (for the logistic family: the score
    /// residual `y − p̂`).
    pub r: &'a [f64],
    /// Coefficients the residual was computed at; `None` means `β = 0`.
    /// Sequential EDPP rules derive everything from `r`, but the gap-safe
    /// rules need `β` itself to form the primal/dual pair.
    pub beta: Option<&'a [f64]>,
}

/// A safe screening rule: guaranteed never to discard an active unit.
///
/// The trait is generic over its precompute context `C`, which also fixes
/// the *unit* of screening: lasso/elastic-net rules implement
/// `SafeRule<SafeContext>` (the default) and screen columns;
/// group-lasso rules implement `SafeRule<`[`group::GroupSafeContext`]`>`
/// and screen groups (one `survive` entry per group). The generic
/// [`crate::solver::driver`] consumes either through the same interface,
/// and [`SafeRule::plan`] predicates flow into the engines' fused screens
/// (`fused_screen` / `fused_group_screen`) for both unit kinds.
pub trait SafeRule<C = SafeContext>: Send {
    /// Rule name for reports.
    fn name(&self) -> &'static str;

    /// Screen at `lam_next`, writing `survive[u] = false` for units that
    /// are *safely* discarded. Entries are only ever cleared (callers reset
    /// the mask). Returns the number of units discarded by this call.
    fn screen(
        &mut self,
        x: &DenseMatrix,
        ctx: &C,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
    ) -> usize;

    /// True once the rule can no longer discard anything at smaller λ
    /// (drives the `Flag` shutoff in Algorithm 1).
    fn dead(&self) -> bool;

    /// Whether this rule is *dynamic*: its bound tightens with the current
    /// iterate (gap-safe rules), so Algorithm 1 must not apply the `Flag`
    /// shutoff on a zero-discard round and should re-fire the rule
    /// mid-optimization via
    /// [`crate::solver::driver::Problem::rescreen`]. Static rules (the
    /// default) are one-shot per λ and shut off permanently once powerless.
    fn dynamic(&self) -> bool {
        false
    }

    /// Plan screening at `lam_next` for the **fused** pass (Algorithm 1
    /// driven by `ScanEngine::fused_screen` or
    /// `ScanEngine::fused_group_screen`).
    ///
    /// Rules whose test is point-wise in per-fit precomputes (BEDPP, Dome,
    /// group BEDPP) return a `keep(u)` predicate that the fused kernel
    /// evaluates per unit — no separate mask traversal, no intermediate
    /// index vectors. Rules that need their own full scan or a per-λ state
    /// transition (SEDPP, the re-hybridized rule) use this default: run
    /// [`SafeRule::screen`] into the mask now (scan-then-filter), report
    /// its discard count through `masked_discards`, and return `None`.
    ///
    /// Contract: when `Some(keep)` is returned the mask is untouched and
    /// `*masked_discards` is 0; the caller treats a fused pass that
    /// discards nothing exactly like `screen` returning 0 (the `Flag`
    /// shutoff), so selections are identical between the fused and unfused
    /// drivers.
    fn plan<'s>(
        &'s mut self,
        x: &DenseMatrix,
        ctx: &'s C,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        masked_discards: &mut usize,
    ) -> Option<Box<dyn Fn(usize) -> bool + Sync + 's>> {
        *masked_discards = self.screen(x, ctx, prev, lam_next, survive);
        None
    }

    /// Engine-routed [`SafeRule::screen`]. Rules that traverse `X` *inside*
    /// the rule (the dynamic gap-safe family's full `z̃ = Xᵀr/n` scan)
    /// override this to dispatch that traversal through `engine` — so a
    /// chunked or out-of-core engine both serves and **counts** the reads —
    /// and add the columns read to `*scanned` (the caller folds them into
    /// `LambdaMetrics::cols_scanned`, keeping the path's accounting equal
    /// to the store's fetch counters). Static rules screen purely from
    /// per-fit precomputes; this default keeps them engine-free.
    #[allow(clippy::too_many_arguments)]
    fn screen_routed(
        &mut self,
        engine: &dyn crate::runtime::ScanEngine,
        x: &DenseMatrix,
        ctx: &C,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        scanned: &mut u64,
    ) -> crate::error::Result<usize> {
        let _ = (engine, &scanned);
        Ok(self.screen(x, ctx, prev, lam_next, survive))
    }

    /// Engine-routed [`SafeRule::plan`] — same contract as `plan`, with the
    /// in-rule traversal dispatched and accounted like
    /// [`SafeRule::screen_routed`].
    #[allow(clippy::too_many_arguments)]
    fn plan_routed<'s>(
        &'s mut self,
        engine: &dyn crate::runtime::ScanEngine,
        x: &DenseMatrix,
        ctx: &'s C,
        prev: &PrevSolution<'_>,
        lam_next: f64,
        survive: &mut [bool],
        masked_discards: &mut usize,
        scanned: &mut u64,
    ) -> crate::error::Result<Option<Box<dyn Fn(usize) -> bool + Sync + 's>>> {
        let _ = (engine, &scanned);
        Ok(self.plan(x, ctx, prev, lam_next, survive, masked_discards))
    }

    /// Select the arithmetic precision of the rule's screening scans.
    /// Rules with an f32 prefilter (the gap-safe family, SEDPP) override
    /// this; the default ignores it — static O(p) tests on f64
    /// precomputes (BEDPP, Dome) have no scan to downgrade, so f32 mode
    /// is a documented no-op for them.
    fn set_precision(&mut self, _precision: crate::runtime::Precision) {}

    /// The raw signed scan `z = Xᵀr/n` the rule computed during its last
    /// `screen_routed`/`plan_routed` call at the *current residual*, if it
    /// performed one in full f64. The fused-epoch driver republishes these
    /// into the path's `z` cache so the following KKT pass skips its own
    /// recomputation — one column traversal per epoch instead of two.
    /// Default: `None` (no full-scan rules, and any rule in f32 mode,
    /// must not feed the f64 cache).
    fn last_scan(&self) -> Option<&[f64]> {
        None
    }

    /// Serialize the rule's path-position state (dead flags, frozen-phase
    /// constants) for a crash-resume checkpoint. The default — an empty
    /// blob — is correct for stateless rules: the gap-safe family's only
    /// fields are per-call scratch recomputed at the next screen.
    fn save_state(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state written by [`SafeRule::save_state`]. The default
    /// accepts only the empty blob the default `save_state` produced — a
    /// stateful blob reaching a stateless rule means the checkpoint is
    /// from a different configuration.
    fn load_state(&mut self, state: &[u8]) -> crate::error::Result<()> {
        if state.is_empty() {
            Ok(())
        } else {
            Err(crate::error::HssrError::Corrupt(format!(
                "{}: unexpected safe-rule state in checkpoint",
                self.name()
            )))
        }
    }
}

/// Construct the safe rule (if any) used by a [`RuleKind`] strategy.
pub fn make_safe_rule(kind: RuleKind) -> Option<Box<dyn SafeRule>> {
    match kind {
        RuleKind::SsrBedpp => Some(Box::new(bedpp::Bedpp::new())),
        RuleKind::SsrDome => Some(Box::new(dome::DomeTest::new())),
        RuleKind::Sedpp => Some(Box::new(sedpp::Sedpp::new())),
        RuleKind::SsrBedppSedpp => Some(Box::new(rehybrid::BedppThenFrozenSedpp::new())),
        RuleKind::SsrGapSafe => Some(Box::new(gapsafe::GapSafe::quadratic())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;

    #[test]
    fn context_matches_naive() {
        let ds = DataSpec::synthetic(50, 20, 4).generate(1);
        let ctx = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, true);
        // λmax = max |x_jᵀ y| / n
        let mut lam = 0.0f64;
        for j in 0..20 {
            lam = lam.max(ops::dot(ds.x.col(j), &ds.y).abs() / 50.0);
        }
        assert!((ctx.lambda_max - lam).abs() < 1e-12);
        assert_eq!(ctx.xtx_star.len(), 20);
        // x_*ᵀ x_* = n under standardization
        assert!((ctx.xtx_star[ctx.star] - 50.0).abs() < 1e-8);
        // sign consistency
        assert_eq!(ctx.sign_star, ctx.xty[ctx.star].signum());
    }

    #[test]
    fn enet_lambda_max_scales_with_alpha() {
        let ds = DataSpec::synthetic(40, 10, 2).generate(2);
        let c1 = SafeContext::build(&ds.x, &ds.y, Penalty::Lasso, false);
        let c2 = SafeContext::build(&ds.x, &ds.y, Penalty::ElasticNet { alpha: 0.5 }, false);
        assert!((c2.lambda_max - 2.0 * c1.lambda_max).abs() < 1e-12);
        assert!(c2.xtx_star.is_empty());
    }

    #[test]
    fn labels_and_method_list() {
        assert_eq!(RuleKind::SsrBedpp.label(), "SSR-BEDPP");
        assert_eq!(RuleKind::paper_lasso_methods().len(), 6);
        assert!(RuleKind::SsrBedpp.needs_star());
        assert!(!RuleKind::Ssr.needs_star());
        assert!(RuleKind::Ssr.uses_ssr());
        assert!(!RuleKind::Sedpp.uses_ssr());
        // The gap-safe hybrid needs no Xᵀx* precompute but does use SSR.
        assert_eq!(RuleKind::SsrGapSafe.label(), "SSR-GapSafe");
        assert!(!RuleKind::SsrGapSafe.needs_star());
        assert!(RuleKind::SsrGapSafe.uses_ssr());
        // Dynamic marker: gap-safe yes, the static rules no.
        assert!(make_safe_rule(RuleKind::SsrGapSafe).unwrap().dynamic());
        assert!(!make_safe_rule(RuleKind::SsrBedpp).unwrap().dynamic());
        assert!(!make_safe_rule(RuleKind::Sedpp).unwrap().dynamic());
    }
}
