//! A minimal property-testing harness (the offline registry has no
//! `proptest`, so we provide the 10% of it these tests need).
//!
//! [`check`] runs a property over `cases` seeded-random inputs produced by a
//! generator closure; on failure it retries the failing seed with a
//! "shrunken" scale factor sequence (generators receive a `scale ∈ (0, 1]`
//! they should use to reduce structure size), then panics with the smallest
//! reproducing seed + scale so the case can be replayed deterministically.

use crate::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (every case derives `seed + case_index`).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 32, seed: 0x5EED }
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `property(rng, scale)` over random cases. `scale` is 1.0 for the
/// main pass; when a case fails, the same seed is retried at scales
/// 0.5, 0.25, 0.125 to report the smallest still-failing configuration.
pub fn check<F>(cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Pcg64, f64) -> PropResult,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = property(&mut rng, 1.0) {
            // shrink-lite: retry at smaller scales with the same seed
            let mut smallest = (1.0f64, msg.clone());
            for &scale in &[0.5, 0.25, 0.125] {
                let mut rng2 = Pcg64::new(seed);
                if let Err(m2) = property(&mut rng2, scale) {
                    smallest = (scale, m2);
                }
            }
            panic!(
                "property failed (seed={seed}, scale={}): {}\nreplay: Pcg64::new({seed})",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(PropConfig { cases: 8, seed: 1 }, |rng, _scale| {
            let x = rng.uniform();
            prop_assert!((0.0..1.0).contains(&x), "uniform out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(PropConfig { cases: 4, seed: 2 }, |rng, _| {
            let x = rng.uniform();
            prop_assert!(x < 0.0, "always fails: {x}");
            Ok(())
        });
    }

    /// The fused single-pass screening/KKT driver must select **exactly**
    /// the same features as the unfused scan-then-filter driver — same
    /// sparse solutions, same safe/strong set sizes at every λ — for every
    /// [`RuleKind`] and for both penalty families (lasso and elastic net
    /// `alpha < 1`), over randomized problem shapes.
    #[test]
    fn fused_pass_selects_same_features_as_unfused() {
        use crate::data::DataSpec;
        use crate::screening::RuleKind;
        use crate::solver::path::{fit_lasso_path, PathConfig};
        use crate::solver::Penalty;
        check(PropConfig { cases: 6, seed: 0xF05E }, |rng, scale| {
            let n = 40 + (rng.below(60) as f64 * scale) as usize;
            let p = 60 + (rng.below(160) as f64 * scale) as usize;
            let s = 1 + rng.below(8) as usize;
            let ds = DataSpec::synthetic(n, p, s).generate(rng.next_u64());
            // Random ℓ1 mixing weight in [0.4, 0.9] for the enet sweep.
            let alpha = 0.4 + 0.5 * rng.uniform();
            for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
                for rule in [
                    RuleKind::BasicPcd,
                    RuleKind::ActiveCycling,
                    RuleKind::Ssr,
                    RuleKind::Sedpp,
                    RuleKind::SsrBedpp,
                    RuleKind::SsrDome,
                    RuleKind::SsrBedppSedpp,
                    RuleKind::SsrGapSafe,
                ] {
                    let cfg = PathConfig {
                        rule,
                        penalty,
                        n_lambda: 15,
                        tol: 1e-8,
                        fused: true,
                        ..PathConfig::default()
                    };
                    let fused = fit_lasso_path(&ds, &cfg).map_err(|e| e.to_string())?;
                    let unfused =
                        fit_lasso_path(&ds, &PathConfig { fused: false, ..cfg })
                            .map_err(|e| e.to_string())?;
                    prop_assert!(
                        fused.betas == unfused.betas,
                        "{rule:?}/{penalty:?}: solutions differ (n={n}, p={p}, s={s})"
                    );
                    for (k, (a, b)) in
                        fused.metrics.iter().zip(&unfused.metrics).enumerate()
                    {
                        prop_assert!(
                            a.safe_size == b.safe_size,
                            "{rule:?}/{penalty:?}: |S| differs at λ#{k} ({} vs {})",
                            a.safe_size,
                            b.safe_size
                        );
                        prop_assert!(
                            a.strong_size == b.strong_size,
                            "{rule:?}/{penalty:?}: |H| differs at λ#{k} ({} vs {})",
                            a.strong_size,
                            b.strong_size
                        );
                    }
                }
            }
            Ok(())
        });
    }

    /// Group family: the fused pipeline (fused group screen + fused group
    /// KKT) must select exactly the same groups as the unfused one, over
    /// randomized group structures — for the group lasso *and* the group
    /// elastic net (`alpha < 1`).
    #[test]
    fn fused_group_pass_selects_same_groups_as_unfused() {
        use crate::data::synth::generate_grouped;
        use crate::screening::RuleKind;
        use crate::solver::group_path::{fit_group_path, GroupPathConfig};
        use crate::solver::Penalty;
        check(PropConfig { cases: 4, seed: 0x6907 }, |rng, scale| {
            let n = 50 + (rng.below(50) as f64 * scale) as usize;
            let groups = 8 + (rng.below(16) as f64 * scale) as usize;
            let gsize = 2 + rng.below(4) as usize;
            let strue = (1 + rng.below(4) as usize).min(groups);
            let ds = generate_grouped(n, groups, gsize, strue, rng.next_u64());
            let alpha = 0.4 + 0.5 * rng.uniform();
            for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
                for rule in [
                    RuleKind::BasicPcd,
                    RuleKind::ActiveCycling,
                    RuleKind::Ssr,
                    RuleKind::Sedpp,
                    RuleKind::SsrBedpp,
                    RuleKind::SsrGapSafe,
                ] {
                    let cfg = GroupPathConfig {
                        rule,
                        penalty,
                        n_lambda: 12,
                        tol: 1e-8,
                        fused: true,
                        ..GroupPathConfig::default()
                    };
                    let fused = fit_group_path(&ds, &cfg).map_err(|e| e.to_string())?;
                    let unfused =
                        fit_group_path(&ds, &GroupPathConfig { fused: false, ..cfg })
                            .map_err(|e| e.to_string())?;
                    prop_assert!(
                        fused.betas == unfused.betas,
                        "{rule:?}/{penalty:?}: group solutions differ (n={n}, groups={groups}, gsize={gsize})"
                    );
                    for (k, (a, b)) in
                        fused.metrics.iter().zip(&unfused.metrics).enumerate()
                    {
                        prop_assert!(
                            a.safe_size == b.safe_size,
                            "{rule:?}/{penalty:?}: group |S| differs at λ#{k}"
                        );
                        prop_assert!(
                            a.strong_size == b.strong_size,
                            "{rule:?}/{penalty:?}: group |H| differs at λ#{k}"
                        );
                    }
                }
            }
            Ok(())
        });
    }

    /// Engine independence: driving the fused pipeline through the
    /// counting [`ChunkedScanEngine`] (which keeps the trait's
    /// scan-then-filter fused defaults) must select exactly what the
    /// native one-traversal kernels select — same sparse paths, same
    /// safe/strong sizes — across the column and group families and both
    /// penalties, with the engine's fetch counters matching the path's
    /// own scan accounting.
    #[test]
    fn chunked_engine_selects_same_as_native_across_families() {
        use crate::data::chunked::{ChunkedMatrix, ChunkedScanEngine};
        use crate::data::synth::generate_grouped;
        use crate::data::DataSpec;
        use crate::screening::RuleKind;
        use crate::solver::group_path::{
            fit_group_path_with_engine, GroupPathConfig,
        };
        use crate::solver::path::{fit_lasso_path_with_engine, PathConfig};
        use crate::solver::Penalty;
        check(PropConfig { cases: 3, seed: 0xC4A2 }, |rng, scale| {
            let alpha = 0.4 + 0.5 * rng.uniform();
            let native = crate::runtime::native::NativeEngine::new();
            // column family
            let n = 40 + (rng.below(40) as f64 * scale) as usize;
            let p = 60 + (rng.below(120) as f64 * scale) as usize;
            let ds = DataSpec::synthetic(n, p, 5).generate(rng.next_u64());
            let store = ChunkedMatrix::from_dense(&ds.x, 32);
            for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
                let cfg = PathConfig {
                    rule: RuleKind::SsrBedpp,
                    penalty,
                    n_lambda: 12,
                    tol: 1e-8,
                    fused: true,
                    ..PathConfig::default()
                };
                store.reset_counters();
                let engine = ChunkedScanEngine::new(&store);
                let chunked = fit_lasso_path_with_engine(&ds, &cfg, &engine)
                    .map_err(|e| e.to_string())?;
                let nat = fit_lasso_path_with_engine(&ds, &cfg, &native)
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    chunked.betas == nat.betas,
                    "{penalty:?}: chunked column path differs (n={n}, p={p})"
                );
                prop_assert!(
                    store.cols_fetched() == chunked.total_cols_scanned(),
                    "{penalty:?}: column fetch accounting drift ({} vs {})",
                    store.cols_fetched(),
                    chunked.total_cols_scanned()
                );
            }
            // group family
            let groups = 8 + (rng.below(12) as f64 * scale) as usize;
            let gds = generate_grouped(n, groups, 3, 2, rng.next_u64());
            let gstore = ChunkedMatrix::from_dense(&gds.x, 16);
            for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
                let cfg = GroupPathConfig {
                    rule: RuleKind::SsrBedpp,
                    penalty,
                    n_lambda: 12,
                    tol: 1e-8,
                    fused: true,
                    ..GroupPathConfig::default()
                };
                gstore.reset_counters();
                let engine = ChunkedScanEngine::new(&gstore);
                let chunked = fit_group_path_with_engine(&gds, &cfg, &engine)
                    .map_err(|e| e.to_string())?;
                let nat = fit_group_path_with_engine(&gds, &cfg, &native)
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    chunked.betas == nat.betas,
                    "{penalty:?}: chunked group path differs (n={n}, groups={groups})"
                );
                for (k, (a, b)) in
                    chunked.metrics.iter().zip(&nat.metrics).enumerate()
                {
                    prop_assert!(
                        a.safe_size == b.safe_size && a.strong_size == b.strong_size,
                        "{penalty:?}: group sizes differ at λ#{k} across engines"
                    );
                }
                prop_assert!(
                    gstore.cols_fetched() == chunked.total_cols_scanned(),
                    "{penalty:?}: group fetch accounting drift ({} vs {})",
                    gstore.cols_fetched(),
                    chunked.total_cols_scanned()
                );
            }
            Ok(())
        });
    }

    /// Mixed-precision screening: a fit whose safe-rule scans run through
    /// the f32 prefilter (`precision: F32`) must produce **bit-identical**
    /// coefficient paths and set sizes to the all-f64 fit — the f32 pass
    /// may only change the *order* of work (prefilter + exact confirm),
    /// never a decision. Covered for the f32-capable rules (SEDPP,
    /// gap-safe), a rule where f32 is a documented no-op (SSR-BEDPP), an
    /// engine with f32 support (native mirror) and one without (chunked →
    /// exact fallback), and the group family.
    #[test]
    fn f32_screening_is_bit_identical_to_f64() {
        use crate::data::chunked::{ChunkedMatrix, ChunkedScanEngine};
        use crate::data::synth::generate_grouped;
        use crate::data::DataSpec;
        use crate::runtime::Precision;
        use crate::screening::RuleKind;
        use crate::solver::group_path::{fit_group_path, GroupPathConfig};
        use crate::solver::path::{fit_lasso_path_with_engine, PathConfig};
        use crate::solver::Penalty;
        check(PropConfig { cases: 3, seed: 0xF320 }, |rng, scale| {
            let n = 50 + (rng.below(50) as f64 * scale) as usize;
            let p = 70 + (rng.below(130) as f64 * scale) as usize;
            let ds = DataSpec::synthetic(n, p, 5).generate(rng.next_u64());
            let alpha = 0.4 + 0.5 * rng.uniform();
            let native = crate::runtime::native::NativeEngine::new();
            let store = ChunkedMatrix::from_dense(&ds.x, 32);
            for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
                for rule in [RuleKind::Sedpp, RuleKind::SsrBedpp, RuleKind::SsrGapSafe] {
                    let cfg64 = PathConfig {
                        rule,
                        penalty,
                        n_lambda: 14,
                        tol: 1e-8,
                        precision: Precision::F64,
                        ..PathConfig::default()
                    };
                    let cfg32 =
                        PathConfig { precision: Precision::F32, ..cfg64.clone() };
                    let a = fit_lasso_path_with_engine(&ds, &cfg64, &native)
                        .map_err(|e| e.to_string())?;
                    let b = fit_lasso_path_with_engine(&ds, &cfg32, &native)
                        .map_err(|e| e.to_string())?;
                    prop_assert!(
                        a.betas == b.betas,
                        "{rule:?}/{penalty:?}: f32-screened fit differs (n={n}, p={p})"
                    );
                    for (k, (ma, mb)) in a.metrics.iter().zip(&b.metrics).enumerate() {
                        prop_assert!(
                            ma.safe_size == mb.safe_size
                                && ma.strong_size == mb.strong_size,
                            "{rule:?}/{penalty:?}: set sizes differ at λ#{k} under f32"
                        );
                    }
                    // An engine without f32 support must decline the
                    // prefilter and fall back to the exact path.
                    let engine = ChunkedScanEngine::new(&store);
                    let c = fit_lasso_path_with_engine(&ds, &cfg32, &engine)
                        .map_err(|e| e.to_string())?;
                    prop_assert!(
                        c.betas == a.betas,
                        "{rule:?}/{penalty:?}: f32 on a non-f32 engine diverged"
                    );
                }
            }
            // Group family: the group gap-safe norm prefilter.
            let gds = generate_grouped(n.min(70), 12, 3, 2, rng.next_u64());
            for rule in [RuleKind::SsrBedpp, RuleKind::SsrGapSafe] {
                let g64 = GroupPathConfig {
                    rule,
                    n_lambda: 12,
                    tol: 1e-8,
                    precision: Precision::F64,
                    ..GroupPathConfig::default()
                };
                let g32 = GroupPathConfig { precision: Precision::F32, ..g64.clone() };
                let a = fit_group_path(&gds, &g64).map_err(|e| e.to_string())?;
                let b = fit_group_path(&gds, &g32).map_err(|e| e.to_string())?;
                prop_assert!(
                    a.betas == b.betas,
                    "{rule:?}: f32-screened group fit differs"
                );
            }
            Ok(())
        });
    }

    /// Fused epoch: republishing the dynamic rule's re-screen scan into
    /// the lazy `z` cache (one column traversal per epoch) must leave the
    /// coefficient path and set sizes **bit-identical** to the two-pass
    /// flow — and must demonstrably cut scan traffic, since the KKT
    /// refresh stops re-fetching columns the re-screen just scanned.
    /// Verified on the native kernels and on a counting store-backed
    /// engine (which exercises the trait-default lazy fused KKT).
    #[test]
    fn fused_epoch_is_bit_identical_and_scans_less() {
        use crate::data::chunked::{ChunkedMatrix, ChunkedScanEngine};
        use crate::data::DataSpec;
        use crate::screening::RuleKind;
        use crate::solver::path::{fit_lasso_path_with_engine, PathConfig};
        check(PropConfig { cases: 4, seed: 0xEF0C }, |rng, scale| {
            let n = 50 + (rng.below(50) as f64 * scale) as usize;
            let p = 80 + (rng.below(120) as f64 * scale) as usize;
            let ds = DataSpec::synthetic(n, p, 5).generate(rng.next_u64());
            let native = crate::runtime::native::NativeEngine::new();
            let store = ChunkedMatrix::from_dense(&ds.x, 32);
            let on = PathConfig {
                rule: RuleKind::SsrGapSafe,
                n_lambda: 16,
                tol: 1e-8,
                fused_epoch: true,
                ..PathConfig::default()
            };
            let off = PathConfig { fused_epoch: false, ..on.clone() };
            let a = fit_lasso_path_with_engine(&ds, &on, &native)
                .map_err(|e| e.to_string())?;
            let b = fit_lasso_path_with_engine(&ds, &off, &native)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                a.betas == b.betas,
                "fused epoch changed the solution (n={n}, p={p})"
            );
            for (k, (ma, mb)) in a.metrics.iter().zip(&b.metrics).enumerate() {
                prop_assert!(
                    ma.safe_size == mb.safe_size && ma.strong_size == mb.strong_size,
                    "fused epoch changed set sizes at λ#{k}"
                );
            }
            prop_assert!(
                a.total_cols_scanned() < b.total_cols_scanned(),
                "fused epoch did not cut refresh traffic ({} vs {})",
                a.total_cols_scanned(),
                b.total_cols_scanned()
            );
            // Store-backed source: the trait-default fused KKT honors the
            // republished cache the same way, and the engine's own fetch
            // counter corroborates the metrics' drop.
            let ea = ChunkedScanEngine::new(&store);
            store.reset_counters();
            let sa = fit_lasso_path_with_engine(&ds, &on, &ea)
                .map_err(|e| e.to_string())?;
            let fetched_on = store.cols_fetched();
            let eb = ChunkedScanEngine::new(&store);
            store.reset_counters();
            let sb = fit_lasso_path_with_engine(&ds, &off, &eb)
                .map_err(|e| e.to_string())?;
            let fetched_off = store.cols_fetched();
            prop_assert!(
                sa.betas == a.betas && sb.betas == a.betas,
                "store-backed fused-epoch fit diverged (n={n}, p={p})"
            );
            prop_assert!(
                fetched_on < fetched_off,
                "store fetches did not drop under fused epoch ({fetched_on} vs {fetched_off})"
            );
            Ok(())
        });
    }

    /// The unified logistic driver: the fused pipeline must select exactly
    /// the same features as the unfused one — identical sparse paths,
    /// intercepts, and strong-set sizes — across strategies and penalties
    /// (including elastic net), over randomized problems.
    #[test]
    fn fused_logistic_selects_same_features_as_unfused() {
        use crate::screening::RuleKind;
        use crate::solver::logistic::{
            fit_logistic_path, synthetic_logistic, LogisticPathConfig,
        };
        use crate::solver::Penalty;
        check(PropConfig { cases: 4, seed: 0x1061 }, |rng, scale| {
            let n = 60 + (rng.below(60) as f64 * scale) as usize;
            let p = 30 + (rng.below(60) as f64 * scale) as usize;
            let s = 1 + rng.below(5) as usize;
            let (x, y, _) = synthetic_logistic(n, p, s, rng.next_u64());
            let alpha = 0.5 + 0.4 * rng.uniform();
            for penalty in [Penalty::Lasso, Penalty::ElasticNet { alpha }] {
                for rule in [
                    RuleKind::BasicPcd,
                    RuleKind::ActiveCycling,
                    RuleKind::Ssr,
                    RuleKind::SsrGapSafe,
                ] {
                    let cfg = LogisticPathConfig {
                        rule,
                        penalty,
                        n_lambda: 12,
                        tol: 1e-8,
                        fused: true,
                        ..LogisticPathConfig::default()
                    };
                    let fused =
                        fit_logistic_path(&x, &y, &cfg).map_err(|e| e.to_string())?;
                    let unfused = fit_logistic_path(
                        &x,
                        &y,
                        &LogisticPathConfig { fused: false, ..cfg },
                    )
                    .map_err(|e| e.to_string())?;
                    prop_assert!(
                        fused.betas == unfused.betas,
                        "{rule:?}/{penalty:?}: logistic solutions differ (n={n}, p={p})"
                    );
                    prop_assert!(
                        fused.intercepts == unfused.intercepts,
                        "{rule:?}/{penalty:?}: intercepts differ"
                    );
                    for (k, (a, b)) in
                        fused.metrics.iter().zip(&unfused.metrics).enumerate()
                    {
                        prop_assert!(
                            a.strong_size == b.strong_size,
                            "{rule:?}/{penalty:?}: |H| differs at λ#{k} ({} vs {})",
                            a.strong_size,
                            b.strong_size
                        );
                        prop_assert!(
                            a.violations == b.violations,
                            "{rule:?}/{penalty:?}: violations differ at λ#{k}"
                        );
                    }
                }
            }
            Ok(())
        });
    }
}
