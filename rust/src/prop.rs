//! A minimal property-testing harness (the offline registry has no
//! `proptest`, so we provide the 10% of it these tests need).
//!
//! [`check`] runs a property over `cases` seeded-random inputs produced by a
//! generator closure; on failure it retries the failing seed with a
//! "shrunken" scale factor sequence (generators receive a `scale ∈ (0, 1]`
//! they should use to reduce structure size), then panics with the smallest
//! reproducing seed + scale so the case can be replayed deterministically.

use crate::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (every case derives `seed + case_index`).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 32, seed: 0x5EED }
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Run `property(rng, scale)` over random cases. `scale` is 1.0 for the
/// main pass; when a case fails, the same seed is retried at scales
/// 0.5, 0.25, 0.125 to report the smallest still-failing configuration.
pub fn check<F>(cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Pcg64, f64) -> PropResult,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = property(&mut rng, 1.0) {
            // shrink-lite: retry at smaller scales with the same seed
            let mut smallest = (1.0f64, msg.clone());
            for &scale in &[0.5, 0.25, 0.125] {
                let mut rng2 = Pcg64::new(seed);
                if let Err(m2) = property(&mut rng2, scale) {
                    smallest = (scale, m2);
                }
            }
            panic!(
                "property failed (seed={seed}, scale={}): {}\nreplay: Pcg64::new({seed})",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(PropConfig { cases: 8, seed: 1 }, |rng, _scale| {
            let x = rng.uniform();
            prop_assert!((0.0..1.0).contains(&x), "uniform out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(PropConfig { cases: 4, seed: 2 }, |rng, _| {
            let x = rng.uniform();
            prop_assert!(x < 0.0, "always fails: {x}");
            Ok(())
        });
    }
}
