//! `hssr` — CLI for the hybrid safe-strong rule lasso solver.
//!
//! ```text
//! hssr fit   [--data synth|gene|mnist|gwas|nyt] [--n N] [--p P] [--rule METHOD]
//!            [--alpha A] [--nlambda K] [--lmin-ratio R] [--seed S]
//!            [--engine native|pjrt|ooc] [--cache-mb M] [--prefetch]
//!            [--checkpoint file.ckpt]   # crash-resumable λ-path
//!            [--precision f32|f64]      # mixed-precision screening scans
//! hssr group [--data synth|grvs|spline] [--groups G] [--gsize W] [--rule METHOD]
//!            [--alpha A]                              # group elastic net when A < 1
//! hssr power [--data gene] [--n N] [--p P]          # Figure-1 style curves
//! hssr cv    [--folds K] [--data ...]                # k-fold CV for λ
//! hssr logistic [--n N] [--p P] [--rule basic|ac|ssr|ssr-gapsafe]
//!               [--engine native|pjrt|ooc]           # sparse logistic path (§6)
//! hssr convert <in.csv|in.bin> <out.store> [--chunk-cols C]
//!                                # stream CSV/HSSRBIN to the out-of-core store
//! hssr serve [--clients N] [--max-concurrent M] [--data ...] [--cache-mb M]
//!                                # N concurrent λ-paths, one store, one cache
//! hssr bench-serve [--fits F] [--clients N]          # fits/sec vs concurrency
//! hssr trace <trace.json>        # summarize a --trace-out file per rule
//! hssr info                                          # build/runtime info
//! ```
//!
//! `--data csv --path file.csv` loads external data (response in column 1);
//! `--data store --path file.store` loads a converted column store, and with
//! `--engine ooc` serves every screening/KKT scan from that store through a
//! bounded chunk cache (`HSSR_CACHE_MB` or `--cache-mb`).
//!
//! `--checkpoint file` (fit/group/logistic) writes a crash-resumable
//! checkpoint after every completed λ and resumes from it when it exists.
//! `--precision f32` (fit/group; default `HSSR_PRECISION`) prefilters
//! safe-rule screening with error-bounded f32 scans, confirming boundary
//! decisions exactly in f64 — fits are bit-identical to `--precision f64`.
//! `--faults spec` (any command) arms the deterministic storage fault
//! injector — equivalent to setting `HSSR_FAULTS=spec` — for exercising
//! the retry/checksum machinery; see `docs/ARCHITECTURE.md`.
//! `--trace-out file.json` (any command) turns on per-λ phase tracing
//! (equivalent to `HSSR_TRACE=1`) and, on exit, writes a Chrome
//! trace-event file (`chrome://tracing` / Perfetto loadable) plus a
//! `file.json.metrics.jsonl` registry dump; `hssr trace file.json`
//! summarizes one into a per-rule screening-cost vs solve-time table.

use hssr::coordinator::config::{parse_rule, Config};
use hssr::coordinator::metrics::screening_power;
use hssr::coordinator::report::Table;
use hssr::data::{bspline, realistic, store, synth, DataSpec, Dataset, GroupedDataset};
use hssr::error::{HssrError, Result};
use hssr::runtime::{make_engine, ooc::OocEngine, EngineKind, ScanEngine};
use hssr::screening::RuleKind;
use hssr::solver::group_path::{fit_group_path, GroupPathConfig};
use hssr::solver::path::{fit_lasso_path_with_engine, PathConfig};
use hssr::solver::Penalty;

fn usage() -> ! {
    eprintln!(
        "usage: hssr <fit|group|power|cv|logistic|convert|serve|bench-serve|trace|info> \
         [--key value ...]\n\
         see README.md for the full flag reference"
    );
    std::process::exit(2);
}

/// Cache budget in bytes: `--cache-mb` beats `HSSR_CACHE_MB` beats the
/// default.
fn cache_budget_from(cfg: &Config) -> usize {
    match cfg.get("cache-mb") {
        Some(v) => store::parse_cache_mb(Some(v), store::DEFAULT_CACHE_MB) << 20,
        None => store::cache_budget_bytes(),
    }
}

/// Mount the out-of-core engine for a fit: reuse the store file when the
/// data came from one (`--data store --path …`), else spill the generated
/// dataset to a temp store.
fn ooc_engine_for(cfg: &Config, x: &hssr::linalg::DenseMatrix, y: &[f64]) -> Result<OocEngine> {
    let budget = cache_budget_from(cfg);
    if cfg.get_str("data", "synth") == "store" {
        let path = cfg
            .get("path")
            .ok_or_else(|| HssrError::Config("--data store requires --path".into()))?;
        return OocEngine::open(std::path::Path::new(path), budget);
    }
    eprintln!("spilling design to a temp store (budget {} MB)…", budget >> 20);
    OocEngine::spill(x, y, budget)
}

fn dataset_from_cfg(cfg: &Config) -> Result<Dataset> {
    let seed = cfg.get_parse("seed", 42u64)?;
    let kind = cfg.get_str("data", "synth");
    let spec = match kind.as_str() {
        "synth" => DataSpec::synthetic(
            cfg.get_parse("n", 1000usize)?,
            cfg.get_parse("p", 5000usize)?,
            cfg.get_parse("s", 20usize)?,
        ),
        "gene" => DataSpec::gene_like(
            cfg.get_parse("n", 536usize)?,
            cfg.get_parse("p", 17_322usize)?,
        ),
        "mnist" => DataSpec::mnist_like(
            cfg.get_parse("n", 784usize)?,
            cfg.get_parse("p", 60_000usize)?,
        ),
        "gwas" => DataSpec::gwas_like(
            cfg.get_parse("n", 313usize)?,
            cfg.get_parse("p", 66_050usize)?,
        ),
        "nyt" => DataSpec::nyt_like(
            cfg.get_parse("n", 5_000usize)?,
            cfg.get_parse("p", 55_000usize)?,
        ),
        "csv" => {
            let path = cfg
                .get("path")
                .ok_or_else(|| HssrError::Config("--data csv requires --path".into()))?;
            eprintln!("loading {path}…");
            return hssr::data::io::load_csv(std::path::Path::new(path));
        }
        "store" => {
            let path = cfg
                .get("path")
                .ok_or_else(|| HssrError::Config("--data store requires --path".into()))?;
            eprintln!("loading store {path}…");
            let st = store::ColumnStore::open(std::path::Path::new(path), 1 << 20)?;
            return st.to_dataset();
        }
        other => {
            return Err(HssrError::Config(format!("unknown --data '{other}'")));
        }
    };
    eprintln!("generating {} (seed {seed})…", spec.name());
    Ok(spec.generate(seed))
}

fn path_config_from(cfg: &Config) -> Result<PathConfig> {
    let rule_s = cfg.get_str("rule", "ssr-bedpp");
    let rule = parse_rule(&rule_s)
        .ok_or_else(|| HssrError::Config(format!("unknown --rule '{rule_s}'")))?;
    let alpha: f64 = cfg.get_parse("alpha", 1.0)?;
    let penalty =
        if alpha >= 1.0 { Penalty::Lasso } else { Penalty::ElasticNet { alpha } };
    Ok(PathConfig {
        rule,
        penalty,
        n_lambda: cfg.get_parse("nlambda", 100usize)?,
        lambda_min_ratio: cfg.get_parse("lmin-ratio", 0.1)?,
        tol: cfg.get_parse("tol", 1e-7)?,
        rescreen_every: cfg.get_parse("rescreen-every", 10usize)?,
        checkpoint: cfg.get("checkpoint").map(std::path::PathBuf::from),
        precision: precision_from(cfg)?,
        ..PathConfig::default()
    })
}

/// `--precision f32|f64` (defaults to `HSSR_PRECISION`, then f64). f32
/// routes supporting safe-rule scans through the mixed-precision
/// prefilter; results stay bit-identical to f64 (see docs/ARCHITECTURE.md).
fn precision_from(cfg: &Config) -> Result<hssr::runtime::Precision> {
    match cfg.get("precision") {
        None => Ok(hssr::runtime::Precision::from_env()),
        Some(s) => hssr::runtime::Precision::parse(s)
            .ok_or_else(|| HssrError::Config(format!("unknown --precision '{s}' (f32|f64)"))),
    }
}

/// Report a gracefully degraded path: the completed λ-prefix is valid and
/// returned; the failure is surfaced, not hidden.
fn warn_degraded(error: Option<&hssr::solver::driver::PathError>, kept: usize) {
    if let Some(e) = error {
        eprintln!("warning: {e}; keeping the {kept}-λ completed prefix");
    }
}

fn cmd_fit(cfg: &Config) -> Result<()> {
    let ds = dataset_from_cfg(cfg)?;
    let pcfg = path_config_from(cfg)?;
    let engine_kind = EngineKind::parse(&cfg.get_str("engine", "native"))
        .ok_or_else(|| HssrError::Config("engine must be native|pjrt|ooc".into()))?;
    let ooc = match engine_kind {
        EngineKind::Ooc => Some(ooc_engine_for(cfg, &ds.x, &ds.y)?),
        _ => None,
    };
    let boxed;
    let engine: &dyn ScanEngine = match &ooc {
        Some(e) => e,
        None => {
            boxed = make_engine(engine_kind, &cfg.get_str("artifacts", "artifacts"))?;
            boxed.as_ref()
        }
    };
    let fit = fit_lasso_path_with_engine(&ds, &pcfg, engine)?;
    warn_degraded(fit.error.as_ref(), fit.lambdas.len());
    println!(
        "fitted {} over {} λ values in {:.3}s  (rule {}, engine {})",
        ds.name,
        fit.lambdas.len(),
        fit.seconds,
        fit.rule.label(),
        engine.name(),
    );
    let mut t = Table::new(
        "path summary (every 10th λ)",
        &["k", "λ/λmax", "|S|", "|H|", "kkt", "viol", "nnz", "objective"],
    );
    for (k, m) in fit.metrics.iter().enumerate() {
        if k % 10 == 0 || k + 1 == fit.metrics.len() {
            t.push_row(vec![
                k.to_string(),
                format!("{:.3}", m.lambda / fit.lambda_max),
                m.safe_size.to_string(),
                m.strong_size.to_string(),
                m.kkt_checked.to_string(),
                m.violations.to_string(),
                m.nonzero.to_string(),
                format!("{:.5}", m.objective),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "totals: {} columns scanned, {} KKT checks, {} violations",
        fit.total_cols_scanned(),
        fit.total_kkt_checks(),
        fit.total_violations()
    );
    if let Some(e) = &ooc {
        let c = e.store().counters();
        println!(
            "ooc I/O: {} cols served, {} chunk loads, {:.1} MB read from disk, \
             {} cache hits, peak resident {:.1} MB (budget {:.0} MB, matrix {:.1} MB)",
            c.cols_fetched(),
            c.chunk_loads(),
            c.bytes_read() as f64 / 1e6,
            c.cache_hits(),
            c.peak_resident() as f64 / 1e6,
            e.store().budget_bytes() as f64 / 1e6,
            e.store().header().matrix_bytes() as f64 / 1e6,
        );
        println!(
            "ooc solver: {} cols pinned-served, {} demand stalls; prefetch {} \
             issued, {} hits, {} wasted{}",
            c.solver_cols(),
            c.stalls(),
            c.prefetch_issued(),
            c.prefetch_hits(),
            c.prefetch_wasted(),
            if e.prefetch_enabled() { "" } else { " (prefetch off)" },
        );
        println!(
            "ooc faults: {} read retries, {} checksum failures, {} short reads",
            c.retries(),
            c.checksum_failures(),
            c.short_reads(),
        );
    }
    Ok(())
}

/// `hssr convert <in.csv|in.bin> <out.store>` — stream external data to
/// the out-of-core column store. The input format is sniffed from the
/// `HSSRBIN1` magic; anything else is parsed as CSV with streaming
/// (Welford) standardization.
fn cmd_convert(cfg: &Config) -> Result<()> {
    let [input, output] = match cfg.positional.as_slice() {
        [a, b] => [a.clone(), b.clone()],
        _ => {
            return Err(HssrError::Config(
                "convert needs two positional args: <in.csv|in.bin> <out.store>".into(),
            ))
        }
    };
    let chunk_cols = cfg.get_parse("chunk-cols", 256usize)?;
    let inp = std::path::Path::new(&input);
    let outp = std::path::Path::new(&output);
    let mut magic = [0u8; 8];
    let is_bin = std::fs::File::open(inp).and_then(|mut f| {
        use std::io::Read;
        f.read_exact(&mut magic)
    });
    let summary = match is_bin {
        Ok(()) if &magic == b"HSSRBIN1" => {
            eprintln!("converting binary cache {input} → {output}…");
            store::convert_bin(inp, chunk_cols, outp)?
        }
        _ => {
            eprintln!("converting csv {input} → {output} (streaming standardization)…");
            store::convert_csv(inp, chunk_cols, outp)?
        }
    };
    let h = summary.header;
    println!(
        "wrote {output}: n={}, p={}, {} chunks × {} cols, {:.1} MB \
         ({}; fit with: hssr fit --data store --path {output} --engine ooc)",
        h.n,
        h.p,
        h.num_chunks(),
        h.chunk_cols,
        summary.file_bytes as f64 / 1e6,
        if h.standardized { "pre-standardized" } else { "raw + read-time standardization" },
    );
    Ok(())
}

fn grouped_from_cfg(cfg: &Config) -> Result<GroupedDataset> {
    let seed = cfg.get_parse("seed", 42u64)?;
    let kind = cfg.get_str("data", "synth");
    Ok(match kind.as_str() {
        "synth" => synth::generate_grouped(
            cfg.get_parse("n", 1000usize)?,
            cfg.get_parse("groups", 1000usize)?,
            cfg.get_parse("gsize", 10usize)?,
            cfg.get_parse("strue", 10usize)?,
            seed,
        ),
        "grvs" => realistic::grvs_like(
            cfg.get_parse("n", 697usize)?,
            cfg.get_parse("groups", 3205usize)?,
            cfg.get_parse("maxgene", 30usize)?,
            cfg.get_parse("strue", 10usize)?,
            seed,
        ),
        "spline" => {
            let base = DataSpec::gene_like(
                cfg.get_parse("n", 536usize)?,
                cfg.get_parse("p", 17_322usize)?,
            )
            .generate(seed);
            bspline::expand_dataset(&base, cfg.get_parse("basis", 5usize)?)
        }
        other => {
            return Err(HssrError::Config(format!("unknown group --data '{other}'")));
        }
    })
}

fn cmd_group(cfg: &Config) -> Result<()> {
    let ds = grouped_from_cfg(cfg)?;
    let rule_s = cfg.get_str("rule", "ssr-bedpp");
    let rule = parse_rule(&rule_s)
        .ok_or_else(|| HssrError::Config(format!("unknown --rule '{rule_s}'")))?;
    let alpha: f64 = cfg.get_parse("alpha", 1.0)?;
    let penalty =
        if alpha >= 1.0 { Penalty::Lasso } else { Penalty::ElasticNet { alpha } };
    let gcfg = GroupPathConfig {
        rule,
        penalty,
        n_lambda: cfg.get_parse("nlambda", 100usize)?,
        lambda_min_ratio: cfg.get_parse("lmin-ratio", 0.1)?,
        tol: cfg.get_parse("tol", 1e-7)?,
        rescreen_every: cfg.get_parse("rescreen-every", 10usize)?,
        checkpoint: cfg.get("checkpoint").map(std::path::PathBuf::from),
        precision: precision_from(cfg)?,
        ..GroupPathConfig::default()
    };
    let fit = fit_group_path(&ds, &gcfg)?;
    warn_degraded(fit.error.as_ref(), fit.lambdas.len());
    println!(
        "fitted {} ({} groups) over {} λ values in {:.3}s (rule {}, α={alpha})",
        ds.name,
        ds.num_groups(),
        fit.lambdas.len(),
        fit.seconds,
        fit.rule.label()
    );
    let last = fit.metrics.last().unwrap();
    println!(
        "at λmin: |S|={} groups, |H|={} groups, {} nonzero coefficients",
        last.safe_size, last.strong_size, last.nonzero
    );
    Ok(())
}

fn cmd_power(cfg: &Config) -> Result<()> {
    let ds = dataset_from_cfg(cfg)?;
    let pcfg = PathConfig {
        n_lambda: cfg.get_parse("nlambda", 100usize)?,
        ..PathConfig::default()
    };
    let curves = screening_power(&ds, &pcfg)?;
    let mut t = Table::new(
        &format!("Figure 1 — % features discarded ({})", ds.name),
        &["λ/λmax", "Dome", "BEDPP", "SEDPP", "SSR", "SSR-BEDPP", "SSR-GapSafe"],
    );
    let k = curves[0].lambda_frac.len();
    for i in (0..k).step_by((k / 20).max(1)) {
        let mut row = vec![format!("{:.2}", curves[0].lambda_frac[i])];
        for c in &curves {
            row.push(format!("{:.1}%", 100.0 * c.discarded_frac[i]));
        }
        t.push_row(row);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_cv(cfg: &Config) -> Result<()> {
    let ds = dataset_from_cfg(cfg)?;
    let pcfg = path_config_from(cfg)?;
    let folds = cfg.get_parse("folds", 5usize)?;
    let cv = hssr::coordinator::cv::cv_lasso(&ds, &pcfg, folds, cfg.get_parse("seed", 42u64)?)?;
    let mut t = Table::new(
        &format!("{}-fold CV on {}", folds, ds.name),
        &["λ/λmax", "cv mse", "se"],
    );
    let lmax = cv.lambdas[0];
    for i in (0..cv.lambdas.len()).step_by((cv.lambdas.len() / 20).max(1)) {
        t.push_row(vec![
            format!("{:.3}", cv.lambdas[i] / lmax),
            format!("{:.5}", cv.cv_mean[i]),
            format!("{:.5}", cv.cv_se[i]),
        ]);
    }
    println!("{}", t.render());
    println!(
        "λ_min = {:.5} (index {}), λ_1se = {:.5} (index {})",
        cv.lambda_min(),
        cv.idx_min,
        cv.lambda_1se(),
        cv.idx_1se
    );
    Ok(())
}

fn cmd_logistic(cfg: &Config) -> Result<()> {
    use hssr::solver::logistic::{
        fit_logistic_path_with_engine, synthetic_logistic, LogisticPathConfig,
    };
    let n = cfg.get_parse("n", 500usize)?;
    let p = cfg.get_parse("p", 2000usize)?;
    let s = cfg.get_parse("s", 10usize)?;
    let seed = cfg.get_parse("seed", 42u64)?;
    let rule_s = cfg.get_str("rule", "ssr");
    let rule = parse_rule(&rule_s)
        .ok_or_else(|| HssrError::Config(format!("unknown --rule '{rule_s}'")))?;
    let (x, y, truth) = synthetic_logistic(n, p, s, seed);
    let lcfg = LogisticPathConfig {
        rule,
        n_lambda: cfg.get_parse("nlambda", 100usize)?,
        rescreen_every: cfg.get_parse("rescreen-every", 1usize)?,
        checkpoint: cfg.get("checkpoint").map(std::path::PathBuf::from),
        ..Default::default()
    };
    let engine_kind = EngineKind::parse(&cfg.get_str("engine", "native"))
        .ok_or_else(|| HssrError::Config("engine must be native|pjrt|ooc".into()))?;
    let ooc = match engine_kind {
        EngineKind::Ooc => Some(OocEngine::spill(&x, &y, cache_budget_from(cfg))?),
        _ => None,
    };
    let boxed;
    let engine: &dyn ScanEngine = match &ooc {
        Some(e) => e,
        None => {
            boxed = make_engine(engine_kind, &cfg.get_str("artifacts", "artifacts"))?;
            boxed.as_ref()
        }
    };
    let fit = fit_logistic_path_with_engine(&x, &y, &lcfg, engine)?;
    warn_degraded(fit.error.as_ref(), fit.lambdas.len());
    println!(
        "logistic path (n={n}, p={p}) fitted in {:.3}s (rule {}, engine {})",
        fit.seconds,
        fit.rule.label(),
        engine.name(),
    );
    let sel: Vec<usize> = fit.betas.last().unwrap().iter().map(|&(j, _)| j).collect();
    let hits = truth.iter().filter(|j| sel.contains(j)).count();
    println!("selected {} features at λmin, recovering {hits}/{} true", sel.len(), truth.len());
    Ok(())
}

/// The request mix a serve run simulates: clients cycle through the
/// sequential strategies so the shared cache sees heterogeneous paths.
const SERVE_RULES: [RuleKind; 3] = [RuleKind::Ssr, RuleKind::SsrBedpp, RuleKind::SsrGapSafe];

/// Build the `--clients` concurrent requests for a serve run from the
/// base CLI config (per-fit checkpoints are disabled: one file cannot be
/// shared by concurrent fits).
fn serve_requests(base: &PathConfig, clients: usize) -> Vec<PathConfig> {
    if base.checkpoint.is_some() {
        eprintln!("note: --checkpoint is ignored in serve mode");
    }
    (0..clients)
        .map(|i| {
            let mut c = base.clone();
            c.rule = SERVE_RULES[i % SERVE_RULES.len()];
            c.checkpoint = None;
            c
        })
        .collect()
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    use hssr::coordinator::serve::FitService;
    let ds = dataset_from_cfg(cfg)?;
    let base = path_config_from(cfg)?;
    let clients = cfg.get_parse("clients", 8usize)?;
    let max_c =
        cfg.get_parse("max-concurrent", hssr::coordinator::jobs::default_threads())?;
    let engine = ooc_engine_for(cfg, &ds.x, &ds.y)?;
    let svc = FitService::new(engine.shared_store(), max_c);
    let cfgs = serve_requests(&base, clients);
    let t0 = std::time::Instant::now();
    let out = svc.run_batch(&cfgs)?;
    let secs = t0.elapsed().as_secs_f64();
    let mut t = Table::new(
        &format!("serve — {clients} clients on {} (admission {max_c})", ds.name),
        &["client", "rule", "fit id", "λs", "nnz@λmin", "warm", "secs", "λ/s"],
    );
    for (i, r) in out.iter().enumerate() {
        t.push_row(vec![
            i.to_string(),
            r.fit.rule.label().to_string(),
            r.fit_id.to_string(),
            r.fit.lambdas.len().to_string(),
            r.fit.betas.last().map(Vec::len).unwrap_or(0).to_string(),
            if r.warm_hit { "hit" } else { "cold" }.to_string(),
            format!("{:.3}", r.fit.seconds),
            format!("{:.1}", r.fit.lambdas.len() as f64 / r.fit.seconds.max(1e-9)),
        ]);
    }
    println!("{}", t.render());
    let c = svc.store().counters();
    let hits = c.cache_hits();
    println!(
        "served {} fits in {secs:.3}s ({:.2} fits/s, peak {} in flight)",
        out.len(),
        out.len() as f64 / secs.max(1e-9),
        svc.peak_in_flight(),
    );
    println!(
        "shared cache: {} chunk loads, {hits} hits, {} cross-fit hits \
         ({:.1}% of hits), peak resident {:.1} MB (budget {:.0} MB)",
        c.chunk_loads(),
        c.cross_fit_hits(),
        100.0 * c.cross_fit_hits() as f64 / hits.max(1) as f64,
        c.peak_resident() as f64 / 1e6,
        svc.store().budget_bytes() as f64 / 1e6,
    );
    println!("warm registry: {} entries", svc.registry_len());
    println!("{}", svc.stats_report().render());
    Ok(())
}

/// `hssr trace <trace.json>` — summarize a `--trace-out` Chrome trace
/// into the per-rule screening-cost vs solve-savings table.
fn cmd_trace(cfg: &Config) -> Result<()> {
    let path = match cfg.positional.as_slice() {
        [p] => p.clone(),
        _ => {
            return Err(HssrError::Config(
                "trace needs one positional arg: <trace.json>".into(),
            ))
        }
    };
    let text = std::fs::read_to_string(&path)?;
    let t = hssr::obs::summary::summarize_trace_text(&text)?;
    println!("{}", t.render());
    Ok(())
}

fn cmd_bench_serve(cfg: &Config) -> Result<()> {
    use hssr::coordinator::serve::FitService;
    let ds = dataset_from_cfg(cfg)?;
    let base = path_config_from(cfg)?;
    let fits = cfg.get_parse("fits", 16usize)?;
    let max_clients = cfg.get_parse("clients", 8usize)?;
    let engine = ooc_engine_for(cfg, &ds.x, &ds.y)?;
    let cfgs = serve_requests(&base, fits);
    let mut t = Table::new(
        &format!("serve throughput — {fits} fits on {}", ds.name),
        &["concurrency", "secs", "fits/s", "cache hits", "xfit hits", "peak res MB"],
    );
    let mut clients = 1usize;
    while clients <= max_clients.max(1) {
        engine.store().reset();
        let svc = FitService::new(engine.shared_store(), clients);
        let t0 = std::time::Instant::now();
        let out = svc.run_batch(&cfgs)?;
        let secs = t0.elapsed().as_secs_f64();
        let c = svc.store().counters();
        t.push_row(vec![
            clients.to_string(),
            format!("{secs:.3}"),
            format!("{:.2}", out.len() as f64 / secs.max(1e-9)),
            c.cache_hits().to_string(),
            c.cross_fit_hits().to_string(),
            format!("{:.2}", c.peak_resident() as f64 / 1e6),
        ]);
        clients *= 2;
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!(
        "hssr {} — hybrid safe-strong rules for lasso-type problems",
        env!("CARGO_PKG_VERSION")
    );
    println!("methods: {:?}", RuleKind::paper_lasso_methods().map(|r| r.label()));
    match make_engine(EngineKind::Pjrt, "artifacts") {
        Ok(e) => println!("pjrt engine: available ({})", e.name()),
        Err(e) => println!("pjrt engine: unavailable — {e}"),
    }
    println!(
        "threads: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    Ok(())
}

fn main() {
    let mut args = std::env::args().skip(1);
    let Some(cmd) = args.next() else { usage() };
    let mut cfg = Config::default();
    if let Err(e) = cfg.apply_args(args) {
        eprintln!("argument error: {e}");
        std::process::exit(2);
    }
    // `--faults spec` arms the deterministic storage fault injector for
    // this process — validated eagerly so a typo fails fast, then handed
    // to the reader layer through the same HSSR_FAULTS path the env var
    // uses.
    if let Some(spec) = cfg.get("faults") {
        if let Err(e) = store::FaultSpec::parse(spec) {
            eprintln!("argument error: bad --faults spec: {e}");
            std::process::exit(2);
        }
        std::env::set_var("HSSR_FAULTS", spec);
        eprintln!("fault injection armed: {spec}");
    }
    // `--prefetch` turns on the async λ-ahead chunk prefetcher for
    // `--engine ooc` fits — equivalent to HSSR_PREFETCH=1, which the
    // out-of-core engine reads when it mounts the store.
    if cfg.get_bool("prefetch", false) {
        std::env::set_var("HSSR_PREFETCH", "1");
    }
    // `--trace-out file.json` arms per-λ phase tracing for any command
    // (equivalent to HSSR_TRACE=1) and flushes a Chrome trace-event file
    // plus a registry metrics dump when the command finishes.
    let trace_out = cfg.get("trace-out").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        hssr::obs::trace::set_enabled(true);
    }
    let result = match cmd.as_str() {
        "fit" => cmd_fit(&cfg),
        "group" => cmd_group(&cfg),
        "power" => cmd_power(&cfg),
        "cv" => cmd_cv(&cfg),
        "logistic" => cmd_logistic(&cfg),
        "convert" => cmd_convert(&cfg),
        "serve" => cmd_serve(&cfg),
        "bench-serve" => cmd_bench_serve(&cfg),
        "trace" => cmd_trace(&cfg),
        "info" => cmd_info(),
        _ => usage(),
    };
    if let Some(path) = &trace_out {
        use hssr::obs::trace;
        let events = trace::drain();
        match trace::write_chrome_trace(path, &events) {
            Ok(()) => eprintln!(
                "trace: {} events written to {} ({} dropped)",
                events.len(),
                path.display(),
                trace::dropped(),
            ),
            Err(e) => eprintln!("trace: failed to write {}: {e}", path.display()),
        }
        let mut metrics = path.as_os_str().to_os_string();
        metrics.push(".metrics.jsonl");
        let metrics = std::path::PathBuf::from(metrics);
        match hssr::obs::registry::write_jsonl(&metrics) {
            Ok(()) => eprintln!("trace: metrics registry dumped to {}", metrics.display()),
            Err(e) => eprintln!("trace: failed to write {}: {e}", metrics.display()),
        }
    }
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
