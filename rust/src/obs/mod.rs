//! Observability: phase-span tracing, a process-global metrics registry,
//! and trace exporters/summaries — all zero-dependency.
//!
//! Three pieces:
//!
//! * [`trace`] — per-λ phase spans ([`trace::Span`]) emitted by the
//!   driver, the worker pool, the column-store reader and the serve
//!   admission queue. Off by default; `HSSR_TRACE=1` (or `--trace-out`)
//!   turns it on. The disabled path is one relaxed atomic load, which is
//!   what lets spans sit on hot paths (the `perf_probe` bench asserts a
//!   per-call bound).
//! * [`registry`] — named atomic counters/gauges/histograms. Recording is
//!   always-on (a few relaxed atomic ops); the registry map is only
//!   touched at registration and snapshot time. Serve-mode latency
//!   percentiles and queue depth live here.
//! * [`json`] + [`summary`] — a minimal JSON reader and the per-rule
//!   screening-cost-vs-solve-savings aggregation behind the `hssr trace`
//!   subcommand.
//!
//! Span taxonomy (name @ category):
//!
//! | span | cat | emitted by | key args |
//! |------|-----|------------|----------|
//! | `fit` | `fit` | `solver/driver.rs` walk | `rule`, `simd`, `units`, `n_lambda` |
//! | `setup` | `fit` | `solver/path.rs` construction | `engine`, I/O deltas |
//! | `screen` / `solve` / `rescreen` / `kkt` / `prefetch` / `finalize` | `lambda` | `run_one_lambda` | `LambdaMetrics` + `StoreCounters` deltas |
//! | `stall` / `prefetch_batch` | `store` | `ColumnStore` reader | `chunk`, `cols` |
//! | `pool_dispatch` | `pool` | `WorkerPool::run` | `chunks` |
//! | `queue_wait` / `serve_fit` | `serve` | `FitService` | `fit_id` |
//!
//! Per-λ spans carry counter *deltas* (not absolutes), so summing a fit's
//! spans reproduces its `LambdaMetrics` / `StoreCounters` totals exactly
//! — `tests/trace_obs.rs` enforces this.

pub mod json;
pub mod registry;
pub mod summary;
pub mod trace;
