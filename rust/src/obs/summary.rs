//! Trace summarization for the `hssr trace` CLI subcommand: fold a
//! Chrome trace-event file back into the paper's screening-cost vs
//! solve-savings accounting, per rule.
//!
//! The driver tags every per-λ phase span with its fit's sequence number
//! and every fit span with its rule label, so a trace containing many
//! concurrent fits (serve mode) still aggregates cleanly: spans join to
//! their fit via `fit_seq`, fits join to rules via the `rule` arg.

use std::collections::BTreeMap;
use std::collections::HashMap;

use super::json::Json;
use crate::coordinator::table::Table;
use crate::error::{HssrError, Result};

/// One span row lifted out of a Chrome trace document.
#[derive(Clone, Debug)]
pub struct TraceRow {
    /// Span name (`screen`, `solve`, …).
    pub name: String,
    /// Category (`fit`, `lambda`, `store`, `pool`, `serve`).
    pub cat: String,
    /// Duration in µs.
    pub dur_us: u64,
    /// Fit sequence number (0 when the span ran outside a fit scope).
    pub fit_seq: u64,
    /// The span's `args` object.
    pub args: Json,
}

impl TraceRow {
    fn arg_u64(&self, key: &str) -> u64 {
        self.args.get(key).and_then(Json::as_u64).unwrap_or(0)
    }
}

/// Lift the `traceEvents` array of a parsed Chrome trace into rows.
pub fn rows_from_chrome(doc: &Json) -> Result<Vec<TraceRow>> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| HssrError::Config("trace: no traceEvents array".into()))?;
    let mut rows = Vec::with_capacity(events.len());
    for ev in events {
        let name = ev.get("name").and_then(Json::as_str).unwrap_or("").to_string();
        let cat = ev.get("cat").and_then(Json::as_str).unwrap_or("").to_string();
        let dur_us = ev.get("dur").and_then(Json::as_u64).unwrap_or(0);
        let args = ev.get("args").cloned().unwrap_or(Json::Obj(Vec::new()));
        let fit_seq = args.get("fit_seq").and_then(Json::as_u64).unwrap_or(0);
        rows.push(TraceRow { name, cat, dur_us, fit_seq, args });
    }
    Ok(rows)
}

#[derive(Default)]
struct RuleAgg {
    fits: u64,
    lambdas: u64,
    setup_us: u64,
    screen_us: u64,
    solve_us: u64,
    kkt_us: u64,
    rescreen_us: u64,
    cols_scanned: u64,
    cd_cycles: u64,
    violations: u64,
}

fn ms(us: u64) -> String {
    format!("{:.2}", us as f64 / 1e3)
}

/// Build the per-rule screening-cost vs solve-savings table the paper's
/// figures are about: wall-clock per phase, scan traffic, and the share
/// of fit time spent deciding what *not* to solve.
pub fn rule_summary(rows: &[TraceRow]) -> Table {
    // fit_seq → rule label, from the fit spans.
    let mut rule_of: HashMap<u64, String> = HashMap::new();
    for r in rows {
        if r.name == "fit" && r.fit_seq != 0 {
            if let Some(rule) = r.args.get("rule").and_then(Json::as_str) {
                rule_of.insert(r.fit_seq, rule.to_string());
            }
        }
    }
    let mut agg: BTreeMap<String, RuleAgg> = BTreeMap::new();
    for r in rows {
        let rule = rule_of
            .get(&r.fit_seq)
            .cloned()
            .unwrap_or_else(|| "(untagged)".to_string());
        let a = agg.entry(rule).or_default();
        match (r.cat.as_str(), r.name.as_str()) {
            ("fit", "fit") => a.fits += 1,
            ("fit", "setup") => a.setup_us += r.dur_us,
            ("lambda", "screen") => {
                a.lambdas += 1;
                a.screen_us += r.dur_us;
                a.cols_scanned += r.arg_u64("cols_scanned");
                a.cd_cycles += r.arg_u64("cd_cycles");
                a.violations += r.arg_u64("violations");
            }
            ("lambda", name) => {
                match name {
                    "solve" => a.solve_us += r.dur_us,
                    "kkt" => a.kkt_us += r.dur_us,
                    "rescreen" => a.rescreen_us += r.dur_us,
                    _ => {}
                }
                a.cols_scanned += r.arg_u64("cols_scanned");
                a.cd_cycles += r.arg_u64("cd_cycles");
                a.violations += r.arg_u64("violations");
            }
            _ => {}
        }
    }
    let mut table = Table::new(
        "Screening cost vs solve savings (per rule)",
        &[
            "Rule",
            "fits",
            "λ",
            "setup ms",
            "screen ms",
            "KKT ms",
            "rescreen ms",
            "solve ms",
            "cols scanned",
            "CD cycles",
            "violations",
            "screen share",
        ],
    );
    for (rule, a) in &agg {
        let screen_cost = a.screen_us + a.kkt_us + a.rescreen_us;
        let accounted = screen_cost + a.solve_us;
        let share = if accounted == 0 {
            "—".to_string()
        } else {
            format!("{:.1}%", 100.0 * screen_cost as f64 / accounted as f64)
        };
        table.push_row(vec![
            rule.clone(),
            a.fits.to_string(),
            a.lambdas.to_string(),
            ms(a.setup_us),
            ms(a.screen_us),
            ms(a.kkt_us),
            ms(a.rescreen_us),
            ms(a.solve_us),
            a.cols_scanned.to_string(),
            a.cd_cycles.to_string(),
            a.violations.to_string(),
            share,
        ]);
    }
    table
}

/// Parse a Chrome trace file's text and summarize it (the `hssr trace`
/// entry point).
pub fn summarize_trace_text(text: &str) -> Result<Table> {
    let doc = super::json::parse(text)?;
    let rows = rows_from_chrome(&doc)?;
    Ok(rule_summary(&rows))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::obs::trace::{chrome_trace_json, ArgValue, Event};

    fn ev(
        name: &'static str,
        cat: &'static str,
        dur_us: u64,
        args: Vec<(&'static str, ArgValue)>,
    ) -> Event {
        Event { name, cat, ts_us: 0, dur_us, tid: 1, args }
    }

    #[test]
    fn summary_joins_spans_to_rules() {
        let events = vec![
            ev(
                "fit",
                "fit",
                100,
                vec![("fit_seq", ArgValue::U64(7)), ("rule", ArgValue::Str("SsrBedpp".into()))],
            ),
            ev(
                "screen",
                "lambda",
                30,
                vec![("fit_seq", ArgValue::U64(7)), ("cols_scanned", ArgValue::U64(50))],
            ),
            ev(
                "solve",
                "lambda",
                60,
                vec![("fit_seq", ArgValue::U64(7)), ("cd_cycles", ArgValue::U64(9))],
            ),
            ev(
                "kkt",
                "lambda",
                10,
                vec![("fit_seq", ArgValue::U64(7)), ("cols_scanned", ArgValue::U64(5))],
            ),
        ];
        let doc = super::super::json::parse(&chrome_trace_json(&events)).unwrap();
        let rows = rows_from_chrome(&doc).unwrap();
        assert_eq!(rows.len(), 4);
        let table = rule_summary(&rows);
        assert_eq!(table.rows.len(), 1);
        let row = &table.rows[0];
        assert_eq!(row[0], "SsrBedpp");
        assert_eq!(row[1], "1", "one fit");
        assert_eq!(row[2], "1", "one λ (screen span count)");
        assert_eq!(row[8], "55", "cols scanned sums across phases");
        assert_eq!(row[9], "9");
        // screen share = (30+10)/(30+10+60) = 40%.
        assert_eq!(row[11], "40.0%");
    }

    #[test]
    fn untagged_spans_get_their_own_bucket() {
        let events =
            vec![ev("screen", "lambda", 5, vec![("cols_scanned", ArgValue::U64(3))])];
        let doc = super::super::json::parse(&chrome_trace_json(&events)).unwrap();
        let table = rule_summary(&rows_from_chrome(&doc).unwrap());
        assert_eq!(table.rows[0][0], "(untagged)");
    }
}
