//! Process-global metrics registry: atomic counters, gauges and
//! log-bucketed histograms registered by name.
//!
//! Instruments are plain atomics — recording is a handful of relaxed
//! atomic ops whether or not tracing is enabled, so always-on telemetry
//! (the serve latency histogram, queue-depth gauge) costs nothing
//! measurable. The registry itself (a mutex-guarded name map) is touched
//! only at registration and snapshot time, never per-record: call sites
//! hold the `Arc` handle.
//!
//! [`snapshot_jsonl`] renders every registered instrument as one JSON
//! line (`--trace-out FILE` writes it next to the Chrome trace as
//! `FILE.metrics.jsonl`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::trace::{json_escape, json_f64};

// --------------------------------------------------------------- counter

/// Monotonic counter.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// Fresh unregistered counter.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

// ----------------------------------------------------------------- gauge

/// Up/down gauge with a high-water mark.
#[derive(Default)]
pub struct Gauge {
    v: AtomicI64,
    hi: AtomicI64,
}

impl Gauge {
    /// Fresh unregistered gauge.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Add `d` (negative to decrement); returns the new value.
    pub fn add(&self, d: i64) -> i64 {
        let now = self.v.fetch_add(d, Ordering::Relaxed) + d;
        self.hi.fetch_max(now, Ordering::Relaxed);
        now
    }

    /// Set the value outright.
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
        self.hi.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }

    /// High-water mark since creation.
    pub fn peak(&self) -> i64 {
        self.hi.load(Ordering::Relaxed)
    }
}

// ------------------------------------------------------------- histogram

/// Bucket count: values 0–7 exact, then 4 sub-buckets per power of two
/// (two significand bits) up to `u64::MAX` — ≤ 12.5 % relative error on
/// any reported quantile, 256 fixed slots, lock-free recording.
const NBUCKETS: usize = 256;

fn bucket_index(v: u64) -> usize {
    if v < 8 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // ≥ 3
    let sub = ((v >> (msb - 2)) & 3) as usize;
    8 + (msb - 3) * 4 + sub
}

fn bucket_lower(idx: usize) -> u64 {
    if idx < 8 {
        return idx as u64;
    }
    let msb = 3 + (idx - 8) / 4;
    let sub = ((idx - 8) % 4) as u64;
    (1u64 << msb) + (sub << (msb - 2))
}

/// Representative value reported for a bucket (its geometric middle).
fn bucket_rep(idx: usize) -> u64 {
    if idx < 8 {
        return idx as u64;
    }
    let msb = 3 + (idx - 8) / 4;
    bucket_lower(idx) + (1u64 << (msb - 2)) / 2
}

/// Lock-free log-bucketed histogram (latency in µs, sizes in bytes).
pub struct Histogram {
    counts: [AtomicU64; NBUCKETS],
    n: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram { counts: [ZERO; NBUCKETS], n: AtomicU64::new(0), sum: AtomicU64::new(0) }
    }
}

impl Histogram {
    /// Fresh unregistered histogram.
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one observation.
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    /// Sum of observations.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean observation (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Approximate `q`-quantile (`0.0 ≤ q ≤ 1.0`): the representative
    /// value of the bucket holding the `⌈q·n⌉`-th observation. Within
    /// 12.5 % of exact by bucket construction; 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (idx, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= target {
                return bucket_rep(idx);
            }
        }
        bucket_rep(NBUCKETS - 1)
    }
}

// -------------------------------------------------------------- registry

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

fn registry() -> &'static Mutex<BTreeMap<String, Metric>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<String, Metric>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn lock() -> std::sync::MutexGuard<'static, BTreeMap<String, Metric>> {
    registry().lock().unwrap_or_else(|p| p.into_inner())
}

/// Get-or-register the counter named `name`. A name already registered as
/// a different kind yields a fresh detached instance (recording still
/// works; it just won't appear in snapshots) — mis-typed lookups must not
/// panic in production paths.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut reg = lock();
    match reg.get(name) {
        Some(Metric::Counter(c)) => Arc::clone(c),
        Some(_) => Arc::new(Counter::new()),
        None => {
            let c = Arc::new(Counter::new());
            reg.insert(name.to_string(), Metric::Counter(Arc::clone(&c)));
            c
        }
    }
}

/// Get-or-register the gauge named `name` (same kind-mismatch rule as
/// [`counter`]).
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut reg = lock();
    match reg.get(name) {
        Some(Metric::Gauge(g)) => Arc::clone(g),
        Some(_) => Arc::new(Gauge::new()),
        None => {
            let g = Arc::new(Gauge::new());
            reg.insert(name.to_string(), Metric::Gauge(Arc::clone(&g)));
            g
        }
    }
}

/// Get-or-register the histogram named `name` (same kind-mismatch rule as
/// [`counter`]).
pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut reg = lock();
    match reg.get(name) {
        Some(Metric::Histogram(h)) => Arc::clone(h),
        Some(_) => Arc::new(Histogram::new()),
        None => {
            let h = Arc::new(Histogram::new());
            reg.insert(name.to_string(), Metric::Histogram(Arc::clone(&h)));
            h
        }
    }
}

/// Render every registered instrument as JSONL, one object per line,
/// sorted by name (the map is a `BTreeMap`, so the dump is deterministic).
pub fn snapshot_jsonl() -> String {
    let reg = lock();
    let mut out = String::new();
    for (name, m) in reg.iter() {
        match m {
            Metric::Counter(c) => out.push_str(&format!(
                "{{\"metric\":\"{}\",\"type\":\"counter\",\"value\":{}}}\n",
                json_escape(name),
                c.get()
            )),
            Metric::Gauge(g) => out.push_str(&format!(
                "{{\"metric\":\"{}\",\"type\":\"gauge\",\"value\":{},\"peak\":{}}}\n",
                json_escape(name),
                g.get(),
                g.peak()
            )),
            Metric::Histogram(h) => out.push_str(&format!(
                "{{\"metric\":\"{}\",\"type\":\"histogram\",\"count\":{},\"sum\":{},\
                 \"mean\":{},\"p50\":{},\"p95\":{},\"p99\":{}}}\n",
                json_escape(name),
                h.count(),
                h.sum(),
                json_f64(h.mean()),
                h.quantile(0.50),
                h.quantile(0.95),
                h.quantile(0.99)
            )),
        }
    }
    out
}

/// Write the registry snapshot to `path` as JSONL.
pub fn write_jsonl(path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path, snapshot_jsonl())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = counter("test.reg.counter");
        c.add(3);
        c.inc();
        assert_eq!(counter("test.reg.counter").get(), 4, "same name, same instrument");
        let g = gauge("test.reg.gauge");
        g.add(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        assert_eq!(g.peak(), 5);
    }

    #[test]
    fn kind_mismatch_detaches() {
        counter("test.reg.kind");
        let g = gauge("test.reg.kind");
        g.set(9);
        // The detached gauge records fine but the registered counter is
        // untouched.
        assert_eq!(counter("test.reg.kind").get(), 0);
    }

    #[test]
    fn bucket_index_is_monotone_and_bounded() {
        let mut prev = 0usize;
        for shift in 0..64 {
            let v = 1u64 << shift;
            let idx = bucket_index(v);
            assert!(idx >= prev);
            assert!(idx < NBUCKETS);
            assert!(bucket_lower(idx) <= v, "lower({idx}) ≤ {v}");
            prev = idx;
        }
        assert_eq!(bucket_index(u64::MAX), bucket_index(u64::MAX), "total");
    }

    #[test]
    fn quantiles_track_a_known_distribution() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.50) as f64;
        let p99 = h.quantile(0.99) as f64;
        assert!((p50 - 500.0).abs() / 500.0 < 0.15, "p50 ≈ 500, got {p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.15, "p99 ≈ 990, got {p99}");
        assert!(h.quantile(1.0) >= h.quantile(0.5));
    }

    #[test]
    fn jsonl_snapshot_mentions_registered_names() {
        counter("test.reg.jsonl.c").add(2);
        let h = histogram("test.reg.jsonl.h");
        h.record(10);
        let dump = snapshot_jsonl();
        assert!(dump.contains("\"metric\":\"test.reg.jsonl.c\""));
        assert!(dump.contains("\"type\":\"histogram\""));
        for line in dump.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'));
        }
    }
}
