//! Minimal JSON reader for the `hssr trace` subcommand — just enough to
//! re-read the Chrome trace-event files this crate writes (and any
//! well-formed JSON), with no dependencies.
//!
//! Recursive-descent over bytes with a depth cap; numbers are parsed as
//! `f64` (trace fields are µs counts well inside f64's exact-integer
//! range).

use crate::error::{HssrError, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (insertion-ordered key/value pairs; duplicate keys keep the
    /// first occurrence on lookup).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Non-negative integer value, if this is a whole number.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= u64::MAX as f64 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    i: usize,
}

fn err(msg: impl Into<String>) -> HssrError {
    HssrError::Config(format!("json: {}", msg.into()))
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.i) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.i).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.i += 1;
            Ok(())
        } else {
            Err(err(format!("expected '{}' at byte {}", b as char, self.i)))
        }
    }

    fn eat_lit(&mut self, lit: &str, val: Json) -> Result<Json> {
        if self.bytes[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(val)
        } else {
            Err(err(format!("bad literal at byte {}", self.i)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            return Err(err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(err(format!("unexpected byte at {}", self.i))),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value(depth + 1)?;
            members.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(err(format!("expected ',' or '}}' at byte {}", self.i))),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(err(format!("expected ',' or ']' at byte {}", self.i))),
            }
        }
    }

    fn hex4(&mut self) -> Result<u16> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| err("truncated \\u escape"))?;
            let d = match b {
                b'0'..=b'9' => b - b'0',
                b'a'..=b'f' => b - b'a' + 10,
                b'A'..=b'F' => b - b'A' + 10,
                _ => return Err(err(format!("bad hex digit at byte {}", self.i))),
            };
            v = (v << 4) | d as u16;
            self.i += 1;
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek().ok_or_else(|| err("unterminated string"))?;
            self.i += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| err("truncated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xd800..0xdc00).contains(&hi) {
                                // Surrogate pair: expect \uXXXX low half.
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let code = 0x10000
                                    + (((hi as u32 - 0xd800) << 10) | (lo as u32 - 0xdc00));
                                char::from_u32(code)
                            } else {
                                char::from_u32(hi as u32)
                            };
                            out.push(c.ok_or_else(|| err("invalid \\u escape"))?);
                        }
                        _ => return Err(err(format!("bad escape at byte {}", self.i))),
                    }
                }
                _ => {
                    // Copy the raw UTF-8 run up to the next quote/escape.
                    let start = self.i - 1;
                    while let Some(&nb) = self.bytes.get(self.i) {
                        if nb == b'"' || nb == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.i])
                            .map_err(|_| err("invalid utf-8 in string"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.i])
            .map_err(|_| err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| err(format!("bad number '{text}'")))
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), i: 0 };
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != p.bytes.len() {
        return Err(err(format!("trailing garbage at byte {}", p.i)));
    }
    Ok(v)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-2.5e1").unwrap(), Json::Num(-25.0));
        let v = parse(r#"{"a": [1, "x", {"b": false}], "c": 3}"#).unwrap();
        assert_eq!(v.get("c").and_then(Json::as_u64), Some(3));
        let arr = v.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[1].as_str(), Some("x"));
        assert_eq!(arr[2].get("b"), Some(&Json::Bool(false)));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = parse(r#""a\"b\\c\ndA😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn round_trips_own_chrome_output() {
        use crate::obs::trace::{chrome_trace_json, ArgValue, Event};
        let ev = Event {
            name: "kkt",
            cat: "lambda",
            ts_us: 42,
            dur_us: 7,
            tid: 1,
            args: vec![("cols_scanned", ArgValue::U64(12)), ("lambda", ArgValue::F64(0.5))],
        };
        let doc = parse(&chrome_trace_json(&[ev])).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("name").and_then(Json::as_str), Some("kkt"));
        assert_eq!(events[0].get("dur").and_then(Json::as_u64), Some(7));
        let args = events[0].get("args").unwrap();
        assert_eq!(args.get("cols_scanned").and_then(Json::as_u64), Some(12));
        assert_eq!(args.get("lambda").and_then(Json::as_f64), Some(0.5));
    }
}
