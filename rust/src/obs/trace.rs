//! Phase-span tracing: Chrome-trace-event emission with near-zero cost
//! when disabled.
//!
//! The tracer is process-global and off by default. It turns on when the
//! environment sets `HSSR_TRACE` (to anything but `0`/empty) or when a
//! caller flips it explicitly ([`set_enabled`] — the `--trace-out` CLI
//! flag and the trace tests do this). Every instrumentation site goes
//! through [`Span::begin`], whose disabled path is a single relaxed
//! atomic load and a `None` — cheap enough to sit on the worker-pool
//! dispatch and store chunk-miss paths without perturbing them (the
//! `perf_probe` bench asserts a per-call bound on exactly this path).
//!
//! When enabled, spans record wall-clock (µs since a process epoch),
//! a small thread id, an optional fit sequence number (see [`FitScope`])
//! and a list of typed args — counter *deltas* attached by the driver so
//! that summing a fit's span args reproduces its `LambdaMetrics` /
//! `StoreCounters` totals exactly (property-tested in
//! `tests/trace_obs.rs`). Completed spans land in a bounded global sink;
//! [`drain`] takes them and [`chrome_trace_json`] renders the
//! `about:tracing` / Perfetto "X" (complete-event) format.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------- enable

const OFF: u8 = 0;
const ON: u8 = 1;
const UNINIT: u8 = 2;

static ENABLED: AtomicU8 = AtomicU8::new(UNINIT);

/// Is tracing on? First call resolves `HSSR_TRACE`; later calls are one
/// relaxed load. This is the guard every hot-path site checks.
#[inline]
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        OFF => false,
        ON => true,
        _ => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> bool {
    let on = std::env::var("HSSR_TRACE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    ENABLED.store(if on { ON } else { OFF }, Ordering::Relaxed);
    on
}

/// Force tracing on or off, overriding `HSSR_TRACE` (used by `--trace-out`
/// and the trace tests).
pub fn set_enabled(on: bool) {
    ENABLED.store(if on { ON } else { OFF }, Ordering::Relaxed);
}

// ------------------------------------------------------------ time / ids

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);
thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
}

/// Small per-thread id for the trace `tid` field (assigned on first use,
/// stable for the thread's lifetime).
fn tid() -> u64 {
    TID.with(|c| {
        let mut t = c.get();
        if t == 0 {
            t = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            c.set(t);
        }
        t
    })
}

// ------------------------------------------------------------- fit scope

static NEXT_FIT_SEQ: AtomicU64 = AtomicU64::new(1);
thread_local! {
    static FIT_SEQ: Cell<u64> = const { Cell::new(0) };
}

/// RAII fit grouping: while a scope is alive on a thread, every span that
/// thread begins carries a `fit_seq` arg, so concurrent fits' spans can be
/// told apart in a shared trace (the serve pool, parallel tests). Nested
/// scopes reuse the outer sequence number — `fit_lasso_path*` opens one
/// around problem construction and [`crate::solver::driver::drive_warm`]
/// opens another inside it; both belong to the same fit.
pub struct FitScope {
    outer: u64,
}

impl FitScope {
    /// Enter a fit scope (allocating a fresh sequence number unless one is
    /// already active on this thread).
    pub fn enter() -> FitScope {
        let outer = FIT_SEQ.with(|c| c.get());
        if outer == 0 {
            FIT_SEQ.with(|c| c.set(NEXT_FIT_SEQ.fetch_add(1, Ordering::Relaxed)));
        }
        FitScope { outer }
    }

    /// The active fit sequence number on this thread (0 = none).
    pub fn current() -> u64 {
        FIT_SEQ.with(|c| c.get())
    }
}

impl Drop for FitScope {
    fn drop(&mut self) {
        if self.outer == 0 {
            FIT_SEQ.with(|c| c.set(0));
        }
    }
}

// ------------------------------------------------------------------ sink

/// A typed span argument (kept as data so exporters can render JSON
/// without stringly-typed round trips).
#[derive(Clone, Debug, PartialEq)]
pub enum ArgValue {
    /// Unsigned counter delta / id.
    U64(u64),
    /// Floating value (λ, objective).
    F64(f64),
    /// Label (rule, SIMD level, path).
    Str(String),
}

/// One completed span.
#[derive(Clone, Debug)]
pub struct Event {
    /// Span name (`screen`, `solve`, `kkt`, …).
    pub name: &'static str,
    /// Category (`fit`, `lambda`, `store`, `pool`, `serve`).
    pub cat: &'static str,
    /// Start, µs since the process epoch.
    pub ts_us: u64,
    /// Duration, µs.
    pub dur_us: u64,
    /// Small thread id.
    pub tid: u64,
    /// Typed args (counter deltas, labels).
    pub args: Vec<(&'static str, ArgValue)>,
}

impl Event {
    /// Fetch a `u64` arg by key.
    pub fn arg_u64(&self, key: &str) -> Option<u64> {
        self.args.iter().find_map(|(k, v)| match v {
            ArgValue::U64(u) if *k == key => Some(*u),
            _ => None,
        })
    }

    /// Fetch a string arg by key.
    pub fn arg_str(&self, key: &str) -> Option<&str> {
        self.args.iter().find_map(|(k, v)| match v {
            ArgValue::Str(s) if *k == key => Some(s.as_str()),
            _ => None,
        })
    }
}

/// Sink cap: a long tracing-enabled run (the CI trace leg runs the whole
/// suite under `HSSR_TRACE=1`) must not grow without bound. Beyond the
/// cap, events are counted as dropped instead of stored.
const MAX_EVENTS: usize = 1 << 20;

static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static DROPPED: AtomicU64 = AtomicU64::new(0);

fn push(ev: Event) {
    let mut sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
    if sink.len() >= MAX_EVENTS {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        return;
    }
    sink.push(ev);
}

/// Take all buffered events (exporters and tests; leaves the sink empty).
pub fn drain() -> Vec<Event> {
    std::mem::take(&mut *SINK.lock().unwrap_or_else(|p| p.into_inner()))
}

/// Events dropped at the sink cap since process start.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

// ------------------------------------------------------------------ span

struct SpanInner {
    name: &'static str,
    cat: &'static str,
    ts_us: u64,
    start: Instant,
    args: Vec<(&'static str, ArgValue)>,
}

/// RAII span: begun at a phase boundary, emits one complete event on drop.
/// Disabled tracing makes every method a no-op on a `None`.
pub struct Span {
    inner: Option<SpanInner>,
}

impl Span {
    /// Begin a span — the universal instrumentation entry point. The
    /// disabled path does one relaxed load and returns an inert guard.
    #[inline]
    pub fn begin(name: &'static str, cat: &'static str) -> Span {
        if !enabled() {
            return Span { inner: None };
        }
        Span::begin_live(name, cat)
    }

    #[cold]
    fn begin_live(name: &'static str, cat: &'static str) -> Span {
        let mut args = Vec::new();
        let seq = FitScope::current();
        if seq != 0 {
            args.push(("fit_seq", ArgValue::U64(seq)));
        }
        Span {
            inner: Some(SpanInner { name, cat, ts_us: now_us(), start: Instant::now(), args }),
        }
    }

    /// Whether this span is live (callers skip arg computation when not).
    #[inline]
    pub fn is_on(&self) -> bool {
        self.inner.is_some()
    }

    /// Attach an unsigned arg (counter delta).
    pub fn arg_u64(&mut self, key: &'static str, v: u64) {
        if let Some(inner) = &mut self.inner {
            inner.args.push((key, ArgValue::U64(v)));
        }
    }

    /// Attach a float arg.
    pub fn arg_f64(&mut self, key: &'static str, v: f64) {
        if let Some(inner) = &mut self.inner {
            inner.args.push((key, ArgValue::F64(v)));
        }
    }

    /// Attach a string arg.
    pub fn arg_str(&mut self, key: &'static str, v: impl Into<String>) {
        if let Some(inner) = &mut self.inner {
            inner.args.push((key, ArgValue::Str(v.into())));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            push(Event {
                name: inner.name,
                cat: inner.cat,
                ts_us: inner.ts_us,
                dur_us: inner.start.elapsed().as_micros() as u64,
                tid: tid(),
                args: inner.args,
            });
        }
    }
}

// ------------------------------------------------------------- exporters

/// Escape a string for embedding in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a finite f64 as JSON (non-finite values have no JSON literal and
/// become `null`).
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn write_args(out: &mut String, args: &[(&'static str, ArgValue)]) {
    out.push('{');
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('"');
        out.push_str(&json_escape(k));
        out.push_str("\":");
        match v {
            ArgValue::U64(u) => out.push_str(&u.to_string()),
            ArgValue::F64(f) => out.push_str(&json_f64(*f)),
            ArgValue::Str(s) => {
                out.push('"');
                out.push_str(&json_escape(s));
                out.push('"');
            }
        }
    }
    out.push('}');
}

/// Render events as Chrome trace-event JSON (`{"traceEvents": [...]}`,
/// "X" complete events) — loadable in `about:tracing` and Perfetto.
pub fn chrome_trace_json(events: &[Event]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    for (i, e) in events.iter().enumerate() {
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{},\"args\":",
            json_escape(e.name),
            json_escape(e.cat),
            e.ts_us,
            e.dur_us,
            e.tid
        ));
        write_args(&mut out, &e.args);
        out.push('}');
        if i + 1 < events.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]}\n");
    out
}

/// Write events to `path` in Chrome trace-event format.
pub fn write_chrome_trace(path: &std::path::Path, events: &[Event]) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json(events))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn escape_covers_controls_and_quotes() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn json_f64_handles_non_finite() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
    }

    #[test]
    fn disabled_span_is_inert() {
        set_enabled(false);
        let mut sp = Span::begin("x", "test");
        assert!(!sp.is_on());
        sp.arg_u64("k", 1);
        drop(sp);
        // No event was buffered by the inert span (the sink may hold
        // events from other tests; absence is checked via is_on above).
    }

    #[test]
    fn fit_scope_nests_and_clears() {
        let outer = FitScope::enter();
        let seq = FitScope::current();
        assert_ne!(seq, 0);
        {
            let _inner = FitScope::enter();
            assert_eq!(FitScope::current(), seq, "nested scope reuses the fit seq");
        }
        assert_eq!(FitScope::current(), seq);
        drop(outer);
        assert_eq!(FitScope::current(), 0);
    }

    #[test]
    fn chrome_json_shape() {
        let ev = Event {
            name: "screen",
            cat: "lambda",
            ts_us: 10,
            dur_us: 5,
            tid: 3,
            args: vec![("cols", ArgValue::U64(7)), ("rule", ArgValue::Str("Ssr".into()))],
        };
        let json = chrome_trace_json(&[ev]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"cols\":7"));
        assert!(json.contains("\"rule\":\"Ssr\""));
        assert!(json.trim_end().ends_with("]}"));
    }
}
