//! Benchmark harness — replicates the paper's measurement protocol
//! ("average computing time (standard error) over 20 replications") without
//! `criterion`, which is unavailable in the offline registry.
//!
//! Each measurement runs a setup closure (excluded from timing — dataset
//! generation) and a timed body, repeating over `reps` replications with
//! distinct seeds, and reports mean and standard error of the mean.

use std::time::Instant;

/// A mean ± SE measurement over replications.
#[derive(Clone, Copy, Debug, Default)]
pub struct Timing {
    /// Mean seconds per replication.
    pub mean: f64,
    /// Standard error of the mean.
    pub se: f64,
    /// Number of replications.
    pub reps: usize,
}

impl Timing {
    /// Summarize raw per-replication seconds.
    pub fn from_samples(samples: &[f64]) -> Timing {
        let n = samples.len();
        if n == 0 {
            return Timing::default();
        }
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Timing { mean, se: (var / n as f64).sqrt(), reps: n }
    }

    /// Format as the paper's tables do: `12.84 (0.06)`.
    pub fn paper_format(&self) -> String {
        format!("{:.2} ({:.2})", self.mean, self.se)
    }

    /// Speedup of `baseline` relative to `self` (e.g. Basic PCD / method).
    pub fn speedup_vs(&self, baseline: &Timing) -> f64 {
        if self.mean > 0.0 {
            baseline.mean / self.mean
        } else {
            f64::INFINITY
        }
    }
}

/// Run `reps` replications. `setup(rep)` produces the input (untimed);
/// `body(input)` is timed. The replication index doubles as the data seed
/// offset, matching the paper's fresh-data-per-replication protocol.
pub fn measure<I, S, B, O>(reps: usize, mut setup: S, mut body: B) -> Timing
where
    S: FnMut(usize) -> I,
    B: FnMut(I) -> O,
{
    let mut samples = Vec::with_capacity(reps);
    for rep in 0..reps {
        let input = setup(rep);
        let t = Instant::now();
        let out = body(input);
        samples.push(t.elapsed().as_secs_f64());
        std::hint::black_box(out);
    }
    Timing::from_samples(&samples)
}

/// Number of replications: the paper uses 20; the default here is reduced
/// for quick runs and restored by `HSSR_BENCH_FULL=1`.
pub fn default_reps() -> usize {
    if full_scale() {
        20
    } else {
        std::env::var("HSSR_BENCH_REPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(3)
    }
}

/// Whether paper-scale dimensions were requested.
pub fn full_scale() -> bool {
    std::env::var("HSSR_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_stats() {
        let t = Timing::from_samples(&[1.0, 2.0, 3.0]);
        assert!((t.mean - 2.0).abs() < 1e-12);
        assert!((t.se - (1.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(t.reps, 3);
    }

    #[test]
    fn paper_formatting() {
        let t = Timing { mean: 12.836, se: 0.0612, reps: 20 };
        assert_eq!(t.paper_format(), "12.84 (0.06)");
    }

    #[test]
    fn speedup_ratio() {
        let base = Timing { mean: 10.0, se: 0.0, reps: 1 };
        let fast = Timing { mean: 2.0, se: 0.0, reps: 1 };
        assert!((fast.speedup_vs(&base) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn measure_runs_setup_per_rep() {
        let mut seeds = Vec::new();
        let t = measure(
            4,
            |rep| {
                seeds.push(rep);
                rep
            },
            |x| x * 2,
        );
        assert_eq!(t.reps, 4);
        assert_eq!(seeds, vec![0, 1, 2, 3]);
    }

    #[test]
    fn empty_samples() {
        let t = Timing::from_samples(&[]);
        assert_eq!(t.reps, 0);
        assert_eq!(t.mean, 0.0);
    }
}
