//! # hssr — Hybrid Safe-Strong Rules for lasso-type problems
//!
//! A Rust + JAX + Pallas reproduction of Zeng, Yang & Breheny (2017),
//! *"Efficient Feature Screening for Lasso-Type Problems via Hybrid
//! Safe-Strong Rules"*.
//!
//! The library solves the lasso, elastic net, and group lasso over a grid of
//! decreasing regularization parameters with pathwise coordinate descent
//! (Algorithm 1 of the paper), accelerated by pluggable *feature screening
//! rules*:
//!
//! * [`screening::ssr`] — sequential strong rule (Tibshirani et al. 2012),
//! * [`screening::bedpp`] — basic EDPP safe rule (Wang et al. 2015, Thm 2.1),
//! * [`screening::sedpp`] — sequential EDPP safe rule (Thm 2.2),
//! * [`screening::dome`] — the Dome safe test (Xiang & Ramadge 2012),
//! * [`screening::hybrid`] — the paper's contribution: hybrid safe-strong
//!   rules **SSR-BEDPP** and **SSR-Dome** (Definition 3.1),
//! * [`screening::rehybrid`] — the §6 future-work extension that re-hybridizes
//!   with a frozen SEDPP rule once BEDPP goes dead,
//! * [`screening::gapsafe`] — **dynamic gap-safe sphere rules** (Fercoq,
//!   Gramfort & Salmon 2015) built on the duality machinery of
//!   [`solver::duality`]: they tighten as the solver converges, re-fire
//!   mid-optimization, and extend safe screening to every family —
//!   including the ℓ1-logistic path (**SSR-GapSafe**), which the static
//!   quadratic-loss rules cannot reach.
//!
//! The λ walk itself — the paper's Algorithm 1 — is written once in
//! [`solver::driver`] as a generic `Problem`/`PathDriver` core; the lasso,
//! group-lasso, and logistic families are `Problem` instances. See
//! `docs/ARCHITECTURE.md` for the complete code ↔ paper map (every
//! screening module, its equation/theorem, and a rule decision table).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** owns the path orchestration, screening state, KKT
//!   checking, warm starts, datasets, metrics, and the CLI.
//! * **L2/L1 (build-time Python)** author the screening-scan compute graph
//!   (`z = Xᵀr/n`) in JAX with a Pallas kernel hot-spot; `make artifacts`
//!   AOT-lowers them to HLO text under `artifacts/`.
//! * **[`runtime`]** loads those artifacts through the PJRT C API (`xla`
//!   crate) so the Rust hot path can execute the AOT-compiled scans; a
//!   native Rust engine with identical semantics is the default.
//!
//! ## Environment knobs
//!
//! * `HSSR_THREADS` — worker-pool size for the scan kernels (default:
//!   `available_parallelism()`, read once at pool creation).
//! * `HSSR_FUSED` — `0` flips every config's `fused` default to the
//!   unfused scan-then-filter drivers (CI runs the suite both ways).
//! * `HSSR_ENGINE` — `ooc` reroutes the default-engine `fit_*` shims
//!   through an out-of-core spill store ([`runtime::ooc`]), so every
//!   screening/KKT scan is served from disk (CI runs the suite this way
//!   under a tiny cache budget).
//! * `HSSR_CACHE_MB` — chunk-cache budget (megabytes) for the out-of-core
//!   column store ([`data::store`]; default 64).
//! * `HSSR_TRACE` — `1` turns on per-λ phase-span tracing ([`obs`]); the
//!   CLI's `--trace-out FILE` exports the spans as Chrome trace-event
//!   JSON plus a metrics JSONL dump. Off by default (one relaxed atomic
//!   load per instrumentation site).
//!
//! ## Quickstart
//!
//! ```no_run
//! use hssr::prelude::*;
//!
//! let ds = DataSpec::synthetic(1_000, 5_000, 20).generate(42);
//! let cfg = PathConfig { rule: RuleKind::SsrBedpp, ..PathConfig::default() };
//! let fit = fit_lasso_path(&ds, &cfg).unwrap();
//! println!("selected {} features at λ_min", fit.nonzero_at(fit.lambdas.len() - 1));
//! ```

pub mod bench_harness;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod linalg;
pub mod obs;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod screening;
pub mod serialize;
pub mod solver;

#[cfg(test)]
pub(crate) mod testutil;

pub use error::HssrError;

/// Convenience re-exports covering the common fitting workflow.
pub mod prelude {
    pub use crate::data::{DataSpec, Dataset, GroupedDataset};
    pub use crate::error::HssrError;
    pub use crate::screening::RuleKind;
    pub use crate::solver::driver::{drive, DriverConfig, DriverFit, PathDriver, Problem};
    pub use crate::solver::path::{fit_lasso_path, PathConfig, PathFit};
    pub use crate::solver::group_path::{fit_group_path, GroupPathConfig, GroupPathFit};
    pub use crate::solver::logistic::{fit_logistic_path, LogisticPathConfig, LogisticPathFit};
    pub use crate::solver::Penalty;
}
