//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the `hssr` library.
#[derive(Debug, Error)]
pub enum HssrError {
    /// Input dimensions are inconsistent (e.g. `X` rows vs `y` length).
    #[error("dimension mismatch: {0}")]
    Dimension(String),

    /// An invalid configuration value was supplied.
    #[error("invalid config: {0}")]
    Config(String),

    /// The inner optimizer failed to converge within `max_iter` iterations.
    #[error("solver did not converge at lambda index {lambda_index} (max_iter={max_iter}, last delta={last_delta:.3e})")]
    NoConvergence {
        /// Index into the λ grid where convergence failed.
        lambda_index: usize,
        /// The iteration cap that was exhausted.
        max_iter: usize,
        /// Magnitude of the last coefficient update.
        last_delta: f64,
    },

    /// An AOT artifact was missing or malformed.
    #[error("runtime artifact error: {0}")]
    Artifact(String),

    /// Error surfaced from the PJRT/XLA runtime.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// I/O error (dataset cache, artifact files, report output).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for HssrError {
    fn from(e: xla::Error) -> Self {
        HssrError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, HssrError>;
