//! Crate-wide error type and the storage-layer fault taxonomy.
//!
//! Hand-written `Display`/`Error` impls (the offline registry has no
//! `thiserror`; this is the 10 lines of it we need).
//!
//! The fault-tolerance layer (`data::store`, `solver::driver`) classifies
//! failures along two axes: **transience** ([`FaultClass`], driving the
//! store's retry-with-backoff policy) and **recoverability**
//! ([`HssrError::is_degradable`], driving the path driver's graceful
//! truncation of a λ-path instead of discarding the completed prefix).

use std::fmt;

/// Errors produced by the `hssr` library.
#[derive(Debug)]
pub enum HssrError {
    /// Input dimensions are inconsistent (e.g. `X` rows vs `y` length).
    Dimension(String),

    /// An invalid configuration value was supplied.
    Config(String),

    /// The inner optimizer failed to converge within `max_iter` iterations.
    NoConvergence {
        /// Index into the λ grid where convergence failed.
        lambda_index: usize,
        /// The iteration cap that was exhausted.
        max_iter: usize,
        /// Magnitude of the last coefficient update.
        last_delta: f64,
    },

    /// The optimizer produced a non-finite quantity (NaN/Inf residual,
    /// coefficient update, or objective) — divergence, not slowness.
    NonFinite {
        /// Index into the λ grid where the non-finite value appeared.
        lambda_index: usize,
        /// Which quantity went non-finite (e.g. "cd delta", "irls delta").
        context: String,
    },

    /// Stored data failed integrity verification (checksum mismatch,
    /// quarantined chunk, malformed checkpoint) and retries are exhausted.
    Corrupt(String),

    /// A cross-validation run failed: a fold fit errored (the fold index is
    /// attached) or λ selection found no finite fold-mean MSE. Not
    /// degradable — a CV estimate built on missing folds is not an estimate.
    Cv {
        /// Fold whose fit failed; `None` for selection-stage failures that
        /// are not attributable to one fold.
        fold: Option<usize>,
        /// The underlying failure, rendered (fold-fit error, λ context).
        message: String,
    },

    /// An AOT artifact was missing or malformed.
    Artifact(String),

    /// Error surfaced from the PJRT/XLA runtime.
    Xla(String),

    /// I/O error (dataset cache, artifact files, report output).
    Io(std::io::Error),
}

/// Transience classification for storage-layer I/O failures: transient
/// faults are worth a bounded retry; permanent ones are surfaced at once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultClass {
    /// Interrupted syscalls, timeouts, short reads — retry with backoff.
    Transient,
    /// Missing files, permission errors, bad descriptors — fail fast.
    Permanent,
}

/// Classify an I/O error for the store's retry policy. `Interrupted`,
/// `WouldBlock`, and `TimedOut` are classic transient kernel conditions;
/// `UnexpectedEof` covers short reads of a file that may still be growing
/// or a racing reader. Everything else is treated as permanent.
pub fn io_fault_class(e: &std::io::Error) -> FaultClass {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::Interrupted
        | ErrorKind::WouldBlock
        | ErrorKind::TimedOut
        | ErrorKind::UnexpectedEof => FaultClass::Transient,
        _ => FaultClass::Permanent,
    }
}

impl HssrError {
    /// Whether a λ-path hitting this error mid-grid can degrade gracefully
    /// — keep the completed λ-prefix and report the failure — rather than
    /// discard the whole fit. Divergence (`NoConvergence`, `NonFinite`) is
    /// a property of one λ; config/dimension/IO errors poison the run.
    pub fn is_degradable(&self) -> bool {
        matches!(
            self,
            HssrError::NoConvergence { .. } | HssrError::NonFinite { .. }
        )
    }
}

impl fmt::Display for HssrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HssrError::Dimension(s) => write!(f, "dimension mismatch: {s}"),
            HssrError::Config(s) => write!(f, "invalid config: {s}"),
            HssrError::NoConvergence { lambda_index, max_iter, last_delta } => write!(
                f,
                "solver did not converge at lambda index {lambda_index} \
                 (max_iter={max_iter}, last delta={last_delta:.3e})"
            ),
            HssrError::NonFinite { lambda_index, context } => write!(
                f,
                "solver diverged at lambda index {lambda_index}: \
                 non-finite {context}"
            ),
            HssrError::Corrupt(s) => write!(f, "data corruption: {s}"),
            HssrError::Cv { fold: Some(k), message } => {
                write!(f, "cross-validation failed at fold {k}: {message}")
            }
            HssrError::Cv { fold: None, message } => {
                write!(f, "cross-validation failed: {message}")
            }
            HssrError::Artifact(s) => write!(f, "runtime artifact error: {s}"),
            HssrError::Xla(s) => write!(f, "xla runtime error: {s}"),
            HssrError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for HssrError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            HssrError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for HssrError {
    fn from(e: std::io::Error) -> Self {
        HssrError::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for HssrError {
    fn from(e: xla::Error) -> Self {
        HssrError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, HssrError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = HssrError::Dimension("x vs y".into());
        assert_eq!(e.to_string(), "dimension mismatch: x vs y");
        let e = HssrError::NoConvergence { lambda_index: 3, max_iter: 10, last_delta: 0.5 };
        assert!(e.to_string().contains("lambda index 3"));
        let e = HssrError::Io(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        assert!(e.to_string().contains("boom"));
        let e = HssrError::NonFinite { lambda_index: 4, context: "cd delta".into() };
        assert!(e.to_string().contains("lambda index 4"));
        assert!(e.to_string().contains("cd delta"));
        let e = HssrError::Corrupt("chunk 3 checksum".into());
        assert!(e.to_string().contains("corruption"));
        let e = HssrError::Cv { fold: Some(2), message: "solver diverged".into() };
        assert_eq!(e.to_string(), "cross-validation failed at fold 2: solver diverged");
        let e = HssrError::Cv { fold: None, message: "all fold-mean MSEs non-finite".into() };
        assert!(e.to_string().starts_with("cross-validation failed: "));
    }

    #[test]
    fn fault_classification() {
        use std::io::{Error, ErrorKind};
        for k in [
            ErrorKind::Interrupted,
            ErrorKind::WouldBlock,
            ErrorKind::TimedOut,
            ErrorKind::UnexpectedEof,
        ] {
            assert_eq!(io_fault_class(&Error::new(k, "x")), FaultClass::Transient);
        }
        for k in [ErrorKind::NotFound, ErrorKind::PermissionDenied, ErrorKind::Other] {
            assert_eq!(io_fault_class(&Error::new(k, "x")), FaultClass::Permanent);
        }
    }

    #[test]
    fn degradable_errors_are_per_lambda() {
        assert!(HssrError::NoConvergence { lambda_index: 0, max_iter: 1, last_delta: 1.0 }
            .is_degradable());
        assert!(HssrError::NonFinite { lambda_index: 0, context: "r".into() }
            .is_degradable());
        assert!(!HssrError::Config("bad".into()).is_degradable());
        assert!(!HssrError::Corrupt("chunk".into()).is_degradable());
        assert!(!HssrError::Cv { fold: Some(0), message: "x".into() }.is_degradable());
        assert!(!HssrError::Io(std::io::Error::new(std::io::ErrorKind::Other, "x"))
            .is_degradable());
    }
}
