//! Crate-wide error type.
//!
//! Hand-written `Display`/`Error` impls (the offline registry has no
//! `thiserror`; this is the 10 lines of it we need).

use std::fmt;

/// Errors produced by the `hssr` library.
#[derive(Debug)]
pub enum HssrError {
    /// Input dimensions are inconsistent (e.g. `X` rows vs `y` length).
    Dimension(String),

    /// An invalid configuration value was supplied.
    Config(String),

    /// The inner optimizer failed to converge within `max_iter` iterations.
    NoConvergence {
        /// Index into the λ grid where convergence failed.
        lambda_index: usize,
        /// The iteration cap that was exhausted.
        max_iter: usize,
        /// Magnitude of the last coefficient update.
        last_delta: f64,
    },

    /// An AOT artifact was missing or malformed.
    Artifact(String),

    /// Error surfaced from the PJRT/XLA runtime.
    Xla(String),

    /// I/O error (dataset cache, artifact files, report output).
    Io(std::io::Error),
}

impl fmt::Display for HssrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HssrError::Dimension(s) => write!(f, "dimension mismatch: {s}"),
            HssrError::Config(s) => write!(f, "invalid config: {s}"),
            HssrError::NoConvergence { lambda_index, max_iter, last_delta } => write!(
                f,
                "solver did not converge at lambda index {lambda_index} \
                 (max_iter={max_iter}, last delta={last_delta:.3e})"
            ),
            HssrError::Artifact(s) => write!(f, "runtime artifact error: {s}"),
            HssrError::Xla(s) => write!(f, "xla runtime error: {s}"),
            HssrError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for HssrError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            HssrError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for HssrError {
    fn from(e: std::io::Error) -> Self {
        HssrError::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for HssrError {
    fn from(e: xla::Error) -> Self {
        HssrError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, HssrError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = HssrError::Dimension("x vs y".into());
        assert_eq!(e.to_string(), "dimension mismatch: x vs y");
        let e = HssrError::NoConvergence { lambda_index: 3, max_iter: 10, last_delta: 0.5 };
        assert!(e.to_string().contains("lambda index 3"));
        let e = HssrError::Io(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        assert!(e.to_string().contains("boom"));
    }
}
