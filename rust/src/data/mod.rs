//! Dataset substrate: standardized design matrices, synthetic generators,
//! and real-data-like workload simulators.
//!
//! The paper evaluates on four real lasso data sets (GENE, MNIST, GWAS, NYT)
//! and two group-lasso data sets (GRVS, GENE-SPLINE) that are not shipped
//! with this repository; [`DataSpec`] provides generators that reproduce the
//! statistical regime of each (dimensions, correlation structure, signal
//! sparsity, marginal distributions). See DESIGN.md §2 for the substitution
//! rationale.
//!
//! All generators are deterministic given a `u64` seed.

pub mod bspline;
pub mod chunked;
pub mod io;
pub mod realistic;
pub mod standardize;
pub mod store;
pub mod synth;

use crate::linalg::DenseMatrix;

/// A standardized regression dataset: `y` centered, columns of `x` centered
/// and scaled to unit variance (paper condition (2)).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Standardized `n × p` design matrix.
    pub x: DenseMatrix,
    /// Centered response, length `n`.
    pub y: Vec<f64>,
    /// Column means of the raw design (for back-transforming intercepts).
    pub centers: Vec<f64>,
    /// Column scales (`sqrt(Σ(x−x̄)²/n)`) of the raw design; 0 marks a
    /// constant column that was zeroed out.
    pub scales: Vec<f64>,
    /// Human-readable workload name (used in bench reports).
    pub name: String,
    /// Indices of the true (generating) features, when known.
    pub truth: Option<Vec<usize>>,
}

impl Dataset {
    /// Number of observations.
    pub fn n(&self) -> usize {
        self.x.nrows()
    }

    /// Number of features.
    pub fn p(&self) -> usize {
        self.x.ncols()
    }
}

/// Contiguous feature-group layout for the group lasso.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GroupLayout {
    /// Start column of each group.
    pub starts: Vec<usize>,
    /// Number of columns in each group (`W_g`).
    pub sizes: Vec<usize>,
}

impl GroupLayout {
    /// Build a layout from group sizes.
    pub fn from_sizes(sizes: Vec<usize>) -> Self {
        let mut starts = Vec::with_capacity(sizes.len());
        let mut acc = 0;
        for &s in &sizes {
            starts.push(acc);
            acc += s;
        }
        GroupLayout { starts, sizes }
    }

    /// Number of groups `G`.
    pub fn num_groups(&self) -> usize {
        self.sizes.len()
    }

    /// Total number of columns.
    pub fn total_cols(&self) -> usize {
        self.starts.last().map(|s| s + self.sizes[self.sizes.len() - 1]).unwrap_or(0)
    }

    /// Column range of group `g`.
    pub fn range(&self, g: usize) -> std::ops::Range<usize> {
        self.starts[g]..self.starts[g] + self.sizes[g]
    }
}

/// A group-lasso dataset with the additional group-level orthonormalization
/// of paper condition (19): `X_gᵀ X_g / n = I` for every group.
#[derive(Clone, Debug)]
pub struct GroupedDataset {
    /// Orthonormalized `n × p` design.
    pub x: DenseMatrix,
    /// Centered response.
    pub y: Vec<f64>,
    /// Group layout over the columns of `x` (post-orthonormalization; rank
    /// deficient groups shrink).
    pub layout: GroupLayout,
    /// Per-group back-transform `T_g` such that `β_raw = T_g · β_ortho`
    /// (stored column-major, `raw_size × ortho_size`).
    pub back_transforms: Vec<Vec<f64>>,
    /// Raw (pre-orthonormalization) group sizes.
    pub raw_sizes: Vec<usize>,
    /// Workload name.
    pub name: String,
    /// Indices of true nonzero groups, when known.
    pub truth: Option<Vec<usize>>,
}

impl GroupedDataset {
    /// Number of observations.
    pub fn n(&self) -> usize {
        self.x.nrows()
    }

    /// Number of (post-orthonormalization) columns.
    pub fn p(&self) -> usize {
        self.x.ncols()
    }

    /// Number of groups.
    pub fn num_groups(&self) -> usize {
        self.layout.num_groups()
    }
}

/// Declarative description of a workload; `generate(seed)` realizes it.
///
/// Dimensions follow the paper's defaults; every field can be overridden to
/// scale workloads down for quick benchmarks.
#[derive(Clone, Debug, PartialEq)]
pub enum DataSpec {
    /// Wang-et-al synthetic model: i.i.d. N(0,1) design, `s` true features
    /// with Unif[−1,1] coefficients, `y = Xβ + 0.1ε`.
    Synthetic { n: usize, p: usize, s: usize },
    /// Gene-expression-like: block-AR(1) correlated Gaussian columns.
    GeneLike { n: usize, p: usize, block: usize, rho: f64, s: usize },
    /// MNIST-like: spatially smoothed, globally correlated "image" columns;
    /// the response is a held-out column.
    MnistLike { n: usize, p: usize, window: usize, global_mix: f64 },
    /// GWAS-like: {0,1,2} allele dosages with LD windows.
    GwasLike { n: usize, p: usize, ld_window: usize, s: usize },
    /// Bag-of-words-like: log1p of Zipf-Poisson counts; response is a
    /// held-out word column.
    NytLike { n: usize, p: usize, zipf_s: f64 },
}

impl DataSpec {
    /// The standard synthetic model used by Figure 2.
    pub fn synthetic(n: usize, p: usize, s: usize) -> Self {
        DataSpec::Synthetic { n, p, s }
    }

    /// GENE-like defaults (paper: n=536, p=17,322).
    pub fn gene_like(n: usize, p: usize) -> Self {
        DataSpec::GeneLike { n, p, block: 100, rho: 0.8, s: 20 }
    }

    /// MNIST-like defaults (paper: n=784, p=60,000).
    pub fn mnist_like(n: usize, p: usize) -> Self {
        DataSpec::MnistLike { n, p, window: 8, global_mix: 0.35 }
    }

    /// GWAS-like defaults (paper: n=313, p=660,496; default scaled ×10 down).
    pub fn gwas_like(n: usize, p: usize) -> Self {
        DataSpec::GwasLike { n, p, ld_window: 20, s: 20 }
    }

    /// NYT-like defaults (paper: n=5,000, p=55,000).
    pub fn nyt_like(n: usize, p: usize) -> Self {
        DataSpec::NytLike { n, p, zipf_s: 1.3 }
    }

    /// Workload name used in reports.
    pub fn name(&self) -> String {
        match self {
            DataSpec::Synthetic { n, p, s } => format!("synth(n={n},p={p},s={s})"),
            DataSpec::GeneLike { n, p, .. } => format!("gene-like(n={n},p={p})"),
            DataSpec::MnistLike { n, p, .. } => format!("mnist-like(n={n},p={p})"),
            DataSpec::GwasLike { n, p, .. } => format!("gwas-like(n={n},p={p})"),
            DataSpec::NytLike { n, p, .. } => format!("nyt-like(n={n},p={p})"),
        }
    }

    /// Realize the workload deterministically from `seed`.
    pub fn generate(&self, seed: u64) -> Dataset {
        match *self {
            DataSpec::Synthetic { n, p, s } => synth::generate(n, p, s, seed),
            DataSpec::GeneLike { n, p, block, rho, s } => {
                realistic::gene_like(n, p, block, rho, s, seed)
            }
            DataSpec::MnistLike { n, p, window, global_mix } => {
                realistic::mnist_like(n, p, window, global_mix, seed)
            }
            DataSpec::GwasLike { n, p, ld_window, s } => {
                realistic::gwas_like(n, p, ld_window, s, seed)
            }
            DataSpec::NytLike { n, p, zipf_s } => realistic::nyt_like(n, p, zipf_s, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_from_sizes() {
        let l = GroupLayout::from_sizes(vec![3, 2, 4]);
        assert_eq!(l.starts, vec![0, 3, 5]);
        assert_eq!(l.total_cols(), 9);
        assert_eq!(l.range(1), 3..5);
        assert_eq!(l.num_groups(), 3);
    }

    #[test]
    fn empty_layout() {
        let l = GroupLayout::from_sizes(vec![]);
        assert_eq!(l.total_cols(), 0);
    }

    #[test]
    fn spec_names() {
        assert!(DataSpec::synthetic(10, 20, 3).name().contains("synth"));
        assert!(DataSpec::gene_like(5, 6).name().contains("gene"));
    }

    #[test]
    fn generate_is_deterministic() {
        let spec = DataSpec::synthetic(30, 40, 5);
        let a = spec.generate(99);
        let b = spec.generate(99);
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.y, b.y);
    }
}
