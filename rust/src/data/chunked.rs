//! In-RAM *model* of the out-of-core column substrate, with scan
//! accounting.
//!
//! §3.2.3 of the paper argues HSSR's *memory* advantage: SSR and SEDPP must
//! fully scan the feature matrix at every λ, while HSSR scans only the safe
//! set — decisive when the matrix lives on disk (biglasso's memory-mapped
//! big.matrix). This module models that substrate cheaply: a
//! [`ChunkedMatrix`] stores columns in fixed-size chunks and *counts every
//! column fetched* (through the shared
//! [`crate::data::store::StoreCounters`]), so benches can report
//! bytes-scanned per rule without touching disk. The **real** disk-backed
//! substrate — seek/read chunks, LRU cache, measured byte traffic — is
//! [`crate::data::store::ColumnStore`] behind
//! [`crate::runtime::ooc::OocEngine`].

use crate::data::store::StoreCounters;
use crate::error::Result;
use crate::linalg::{ops, DenseMatrix};
use crate::runtime::ScanEngine;

/// A column-chunked matrix that counts column accesses.
pub struct ChunkedMatrix {
    n: usize,
    p: usize,
    chunk_cols: usize,
    chunks: Vec<Vec<f64>>,
    counters: StoreCounters,
}

impl ChunkedMatrix {
    /// Split a dense matrix into chunks of `chunk_cols` columns.
    pub fn from_dense(x: &DenseMatrix, chunk_cols: usize) -> Self {
        let n = x.nrows();
        let p = x.ncols();
        let cc = chunk_cols.max(1);
        let mut chunks = Vec::with_capacity(p.div_ceil(cc));
        let mut j = 0;
        while j < p {
            let w = cc.min(p - j);
            chunks.push(x.col_block(j, w).to_vec());
            j += w;
        }
        ChunkedMatrix { n, p, chunk_cols: cc, chunks, counters: StoreCounters::default() }
    }

    /// Rows.
    pub fn nrows(&self) -> usize {
        self.n
    }

    /// Columns.
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// Column view with access accounting.
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.p);
        self.counters.add_col();
        let c = j / self.chunk_cols;
        let off = (j - c * self.chunk_cols) * self.n;
        if off == 0 {
            // A fetch landing on a chunk's first column models the chunk
            // load a disk-backed store would pay.
            self.counters.add_load((self.chunks[c].len() * 8) as u64);
        }
        &self.chunks[c][off..off + self.n]
    }

    /// Scan `out[k] = x_{idx[k]}ᵀ v / n` with accounting (the out-of-core
    /// analogue of [`crate::linalg::blocked::scan_subset`]).
    pub fn scan_subset(&self, v: &[f64], idx: &[usize], out: &mut [f64]) {
        assert_eq!(out.len(), idx.len());
        let inv_n = 1.0 / self.n as f64;
        for (k, &j) in idx.iter().enumerate() {
            out[k] = ops::dot(self.col(j), v) * inv_n;
        }
    }

    /// Total columns fetched since construction (or last reset).
    pub fn cols_fetched(&self) -> u64 {
        self.counters.cols_fetched()
    }

    /// Chunk faults (fetches landing on a chunk's first column — the
    /// would-be chunk loads of a disk-backed store).
    pub fn chunk_faults(&self) -> u64 {
        self.counters.chunk_loads()
    }

    /// Bytes fetched, assuming each column fetch reads its f64 data.
    pub fn bytes_fetched(&self) -> u64 {
        self.cols_fetched() * (self.n as u64) * 8
    }

    /// The shared counter block (modeled traffic).
    pub fn counters(&self) -> &StoreCounters {
        &self.counters
    }

    /// Reset the access counters.
    pub fn reset_counters(&self) {
        self.counters.reset();
    }
}

/// A [`ScanEngine`] that executes every screening/KKT scan against a
/// [`ChunkedMatrix`] column store, counting each column fetch — the
/// out-of-core accounting engine behind the §3.2.3 bytes-scanned-per-rule
/// report ([`crate::coordinator::metrics::scan_traffic`]).
///
/// The engine keeps the trait's scan-then-filter fused defaults, so every
/// fused pass decomposes into counted [`ChunkedMatrix::scan_subset`] calls
/// while selecting exactly what the native one-pass kernels select.
pub struct ChunkedScanEngine<'a> {
    store: &'a ChunkedMatrix,
}

impl<'a> ChunkedScanEngine<'a> {
    /// Wrap a chunked store. The store must hold the same matrix the
    /// solver passes in (the engine reads columns from the store so the
    /// fetches are accounted).
    pub fn new(store: &'a ChunkedMatrix) -> Self {
        ChunkedScanEngine { store }
    }
}

impl ScanEngine for ChunkedScanEngine<'_> {
    fn name(&self) -> &'static str {
        "chunked"
    }

    fn scan_subset(
        &self,
        x: &DenseMatrix,
        v: &[f64],
        idx: &[usize],
        out: &mut [f64],
    ) -> Result<()> {
        // Columns come from the counted store; `x` only cross-checks shape.
        debug_assert_eq!(x.nrows(), self.store.nrows(), "store/design row mismatch");
        debug_assert_eq!(x.ncols(), self.store.ncols(), "store/design col mismatch");
        let _ = x;
        self.store.scan_subset(v, idx, out);
        Ok(())
    }

    fn scan_all(&self, x: &DenseMatrix, v: &[f64], out: &mut [f64]) -> Result<()> {
        let idx: Vec<usize> = (0..self.store.ncols()).collect();
        self.scan_subset(x, v, &idx, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn values_match_dense() {
        let mut rng = Pcg64::new(1);
        let x = DenseMatrix::from_fn(13, 9, |_, _| rng.normal());
        let c = ChunkedMatrix::from_dense(&x, 4);
        for j in 0..9 {
            assert_eq!(c.col(j), x.col(j));
        }
    }

    #[test]
    fn counters_track_accesses() {
        let x = DenseMatrix::zeros(5, 10);
        let c = ChunkedMatrix::from_dense(&x, 3);
        assert_eq!(c.cols_fetched(), 0);
        let _ = c.col(0);
        let _ = c.col(7);
        assert_eq!(c.cols_fetched(), 2);
        assert_eq!(c.bytes_fetched(), 2 * 5 * 8);
        c.reset_counters();
        assert_eq!(c.cols_fetched(), 0);
    }

    /// Driving the unified path through the chunked engine must not change
    /// selections, and every column the path accounts as scanned must be a
    /// counted store fetch (the §3.2.3 accounting model).
    #[test]
    fn chunked_engine_counts_path_traffic() {
        use crate::data::DataSpec;
        use crate::screening::RuleKind;
        use crate::solver::path::{fit_lasso_path, fit_lasso_path_with_engine, PathConfig};
        let ds = DataSpec::gene_like(60, 120).generate(11);
        let store = ChunkedMatrix::from_dense(&ds.x, 32);
        let engine = ChunkedScanEngine::new(&store);
        let cfg =
            PathConfig { rule: RuleKind::SsrBedpp, n_lambda: 15, ..PathConfig::default() };
        let fit = fit_lasso_path_with_engine(&ds, &cfg, &engine).unwrap();
        let native = fit_lasso_path(&ds, &cfg).unwrap();
        assert_eq!(fit.betas, native.betas, "chunked engine changed selections");
        assert_eq!(store.cols_fetched(), fit.total_cols_scanned());
        assert!(store.chunk_faults() > 0);
        assert!(store.chunk_faults() <= store.cols_fetched());
    }

    #[test]
    fn scan_subset_matches_blocked() {
        let mut rng = Pcg64::new(2);
        let x = DenseMatrix::from_fn(20, 15, |_, _| rng.normal());
        let v = rng.normal_vec(20);
        let c = ChunkedMatrix::from_dense(&x, 4);
        let idx = vec![1usize, 3, 14];
        let mut got = vec![0.0; 3];
        c.scan_subset(&v, &idx, &mut got);
        let full = crate::linalg::blocked::scan_all_vec(&x, &v);
        for (k, &j) in idx.iter().enumerate() {
            assert!((got[k] - full[j]).abs() < 1e-12);
        }
        assert_eq!(c.cols_fetched(), 3);
    }
}
