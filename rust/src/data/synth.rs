//! The synthetic model of Wang et al. (2015) used by the paper's Figure 2
//! and Figure 4: `y = Xβ + 0.1ε` with i.i.d. N(0,1) design and noise.

use super::standardize::standardize_in_place;
use super::{Dataset, GroupLayout, GroupedDataset};
use crate::linalg::DenseMatrix;
use crate::rng::Pcg64;

/// Generate the standard lasso synthetic workload: `s` randomly placed true
/// features with Unif[−1,1] coefficients (paper §5.1.1).
pub fn generate(n: usize, p: usize, s: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let mut x = DenseMatrix::from_fn(n, p, |_, _| rng.normal());
    let truth = rng.sample_indices(p, s.min(p));
    let mut beta = vec![0.0; p];
    for &j in &truth {
        beta[j] = rng.uniform_in(-1.0, 1.0);
    }
    let mut y = x.matvec(&beta);
    for yi in y.iter_mut() {
        *yi += 0.1 * rng.normal();
    }
    let (centers, scales) = standardize_in_place(&mut x, &mut y);
    let mut truth_sorted = truth;
    truth_sorted.sort_unstable();
    Dataset {
        x,
        y,
        centers,
        scales,
        name: format!("synth(n={n},p={p},s={s})"),
        truth: Some(truth_sorted),
    }
}

/// Generate the group-lasso synthetic workload of paper §5.2.1: `g_total`
/// groups of `w` features each, `g_true` nonzero groups, coefficients
/// Unif[−1,1], `y = Xβ + 0.1ε`. Groups are orthonormalized to condition (19).
pub fn generate_grouped(
    n: usize,
    g_total: usize,
    w: usize,
    g_true: usize,
    seed: u64,
) -> GroupedDataset {
    let mut rng = Pcg64::new(seed);
    let p = g_total * w;
    let mut x = DenseMatrix::from_fn(n, p, |_, _| rng.normal());
    let true_groups = {
        let mut t = rng.sample_indices(g_total, g_true.min(g_total));
        t.sort_unstable();
        t
    };
    let mut beta = vec![0.0; p];
    for &g in &true_groups {
        for j in g * w..(g + 1) * w {
            beta[j] = rng.uniform_in(-1.0, 1.0);
        }
    }
    let mut y = x.matvec(&beta);
    for yi in y.iter_mut() {
        *yi += 0.1 * rng.normal();
    }
    let (_, _) = standardize_in_place(&mut x, &mut y);
    let layout = GroupLayout::from_sizes(vec![w; g_total]);
    let og = super::standardize::orthonormalize_groups(&x, &layout.starts, &layout.sizes);
    let new_layout = GroupLayout::from_sizes(og.sizes.clone());
    GroupedDataset {
        x: og.x,
        y,
        layout: new_layout,
        back_transforms: og.back_transforms,
        raw_sizes: vec![w; g_total],
        name: format!("group-synth(n={n},G={g_total},W={w})"),
        truth: Some(true_groups),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;

    #[test]
    fn dimensions_and_standardization() {
        let ds = generate(80, 40, 5, 7);
        assert_eq!(ds.n(), 80);
        assert_eq!(ds.p(), 40);
        assert!(ops::sum(&ds.y).abs() < 1e-8);
        for j in 0..ds.p() {
            assert!((ops::nrm2_sq(ds.x.col(j)) / 80.0 - 1.0).abs() < 1e-8);
        }
        assert_eq!(ds.truth.as_ref().unwrap().len(), 5);
    }

    #[test]
    fn truth_features_carry_signal() {
        let ds = generate(200, 50, 5, 11);
        // The largest |x_jᵀy| features should be enriched in the truth set.
        let mut cors: Vec<(usize, f64)> = (0..ds.p())
            .map(|j| (j, ops::dot(ds.x.col(j), &ds.y).abs()))
            .collect();
        cors.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let top5: Vec<usize> = cors[..5].iter().map(|c| c.0).collect();
        let truth = ds.truth.unwrap();
        let overlap = top5.iter().filter(|j| truth.contains(j)).count();
        assert!(overlap >= 2, "top correlations {top5:?} vs truth {truth:?}");
    }

    #[test]
    fn grouped_satisfies_condition_19() {
        let ds = generate_grouped(60, 6, 4, 2, 13);
        assert_eq!(ds.num_groups(), 6);
        let n = ds.n() as f64;
        for g in 0..ds.num_groups() {
            let r = ds.layout.range(g);
            for a in r.clone() {
                for b in r.clone() {
                    let d = ops::dot(ds.x.col(a), ds.x.col(b)) / n;
                    let want = if a == b { 1.0 } else { 0.0 };
                    assert!((d - want).abs() < 1e-8);
                }
            }
        }
    }

    #[test]
    fn grouped_deterministic() {
        let a = generate_grouped(30, 4, 3, 1, 5);
        let b = generate_grouped(30, 4, 3, 1, 5);
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.truth, b.truth);
    }
}
