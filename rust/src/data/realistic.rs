//! Real-data-like workload simulators.
//!
//! The paper benchmarks on GENE / MNIST / GWAS / NYT (lasso) and GRVS /
//! GENE-SPLINE (group lasso). Those data sets are not redistributable here;
//! each generator below reproduces the *statistical regime* that drives
//! screening-rule behaviour: dimensions, inter-column correlation,
//! marginal distributions, and signal sparsity. DESIGN.md §2 documents each
//! substitution.

use super::standardize::standardize_in_place;
use super::{Dataset, GroupLayout, GroupedDataset};
use crate::linalg::DenseMatrix;
use crate::rng::Pcg64;

/// GENE-like: gene-expression panel with co-expression blocks.
///
/// Columns follow a block-AR(1) process: within blocks of `block` features,
/// `x_j = ρ·x_{j−1} + √(1−ρ²)·ε_j`. The response is generated from `s`
/// random true features (Unif[−1,1] effects) plus noise at SNR ≈ 10, then
/// everything is standardized.
pub fn gene_like(n: usize, p: usize, block: usize, rho: f64, s: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let mut x = DenseMatrix::zeros(n, p);
    let carry = (1.0 - rho * rho).sqrt();
    let mut prev = vec![0.0; n];
    for j in 0..p {
        let fresh = j % block.max(1) == 0;
        let col = x.col_mut(j);
        if fresh {
            for (i, v) in col.iter_mut().enumerate() {
                *v = rng.normal();
                prev[i] = *v;
            }
        } else {
            for (i, v) in col.iter_mut().enumerate() {
                *v = rho * prev[i] + carry * rng.normal();
                prev[i] = *v;
            }
        }
    }
    let truth = {
        let mut t = rng.sample_indices(p, s.min(p));
        t.sort_unstable();
        t
    };
    let mut beta = vec![0.0; p];
    for &j in &truth {
        beta[j] = rng.uniform_in(-1.0, 1.0);
    }
    let mut y = x.matvec(&beta);
    let signal_sd = (crate::linalg::ops::nrm2_sq(&y) / n as f64).sqrt().max(1e-8);
    for yi in y.iter_mut() {
        *yi += 0.3 * signal_sd * rng.normal();
    }
    let (centers, scales) = standardize_in_place(&mut x, &mut y);
    Dataset { x, y, centers, scales, name: format!("gene-like(n={n},p={p})"), truth: Some(truth) }
}

/// MNIST-like: "image" columns with strong mutual correlation.
///
/// Each column is `global_mix·g + (1−global_mix)·smooth(ε_j)` where `g` is a
/// shared length-`n` component (global illumination) and `smooth` is a
/// circular moving average of width `window` (spatial smoothness of pixel
/// rows). The response is an extra held-out column of the same process —
/// mirroring the paper's protocol of regressing a test image on training
/// images.
pub fn mnist_like(n: usize, p: usize, window: usize, global_mix: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let g = rng.normal_vec(n);
    let make_col = |rng: &mut Pcg64| -> Vec<f64> {
        let raw = rng.normal_vec(n);
        let mut sm = vec![0.0; n];
        let w = window.max(1);
        let inv = 1.0 / w as f64;
        // circular moving average
        let mut acc: f64 = (0..w).map(|k| raw[k % n]).sum();
        for i in 0..n {
            sm[i] = acc * inv;
            acc += raw[(i + w) % n] - raw[i % n];
        }
        sm.iter().zip(&g).map(|(s, gi)| global_mix * gi + (1.0 - global_mix) * s).collect()
    };
    let cols: Vec<Vec<f64>> = (0..p).map(|_| make_col(&mut rng)).collect();
    let mut x = DenseMatrix::from_columns(&cols).expect("mnist_like: build");
    let mut y = make_col(&mut rng);
    let (centers, scales) = standardize_in_place(&mut x, &mut y);
    Dataset { x, y, centers, scales, name: format!("mnist-like(n={n},p={p})"), truth: None }
}

/// Inverse standard normal CDF (Acklam's rational approximation, |ε|<1.15e-9).
pub fn inv_norm_cdf(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -inv_norm_cdf(1.0 - p)
    }
}

/// GWAS-like: SNP dosage matrix {0,1,2} with linkage-disequilibrium windows.
///
/// Two latent AR(1) haplotype chains per individual run across SNPs; allele
/// `a = 1` iff the latent Gaussian falls below the MAF quantile (Gaussian
/// copula), giving dosages with realistic LD decay inside windows of
/// `ld_window` SNPs. MAFs are Unif[0.05, 0.5]. `s` causal SNPs at SNR ≈ 4.
pub fn gwas_like(n: usize, p: usize, ld_window: usize, s: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let rho: f64 = 0.9;
    let carry = (1.0 - rho * rho).sqrt();
    let mut x = DenseMatrix::zeros(n, p);
    let mut h1 = vec![0.0; n];
    let mut h2 = vec![0.0; n];
    for j in 0..p {
        let fresh = j % ld_window.max(1) == 0;
        let maf = rng.uniform_in(0.05, 0.5);
        let thresh = inv_norm_cdf(maf);
        let col = x.col_mut(j);
        for i in 0..n {
            if fresh {
                h1[i] = rng.normal();
                h2[i] = rng.normal();
            } else {
                h1[i] = rho * h1[i] + carry * rng.normal();
                h2[i] = rho * h2[i] + carry * rng.normal();
            }
            let d = (h1[i] < thresh) as u8 + (h2[i] < thresh) as u8;
            col[i] = d as f64;
        }
    }
    let truth = {
        let mut t = rng.sample_indices(p, s.min(p));
        t.sort_unstable();
        t
    };
    let mut beta = vec![0.0; p];
    for &j in &truth {
        beta[j] = rng.uniform_in(-0.5, 0.5);
    }
    let mut y = x.matvec(&beta);
    let signal_sd = (crate::linalg::ops::nrm2_sq(&y) / n as f64).sqrt().max(1e-8);
    for yi in y.iter_mut() {
        *yi += 0.5 * signal_sd * rng.normal();
    }
    let (centers, scales) = standardize_in_place(&mut x, &mut y);
    Dataset { x, y, centers, scales, name: format!("gwas-like(n={n},p={p})"), truth: Some(truth) }
}

/// NYT-like: log1p of Zipf-Poisson word counts; response is a held-out word.
///
/// Document lengths are log-normal; word `j` has base rate `f_j ∝ r_j^{−s}`
/// for a random Zipf rank `r_j`; a low-rank topic structure (8 topics)
/// correlates words that co-occur. Counts are Poisson, features are
/// `log(1+count)` — the paper's preprocessing of the UCI bag-of-words set.
pub fn nyt_like(n: usize, p: usize, zipf_s: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let n_topics = 8;
    // Document topic weights (softmax-ish positive mixture).
    let doc_topics: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            let mut w: Vec<f64> = (0..n_topics).map(|_| rng.uniform().powi(2)).collect();
            let s: f64 = w.iter().sum::<f64>().max(1e-9);
            w.iter_mut().for_each(|v| *v /= s);
            w
        })
        .collect();
    let doc_len: Vec<f64> =
        (0..n).map(|_| (rng.normal_ms(4.0, 0.6)).exp()).collect();
    let make_word = |rng: &mut Pcg64| -> Vec<f64> {
        let rank = rng.zipf(p.max(2) as u64, zipf_s) as f64;
        let base = rank.powf(-zipf_s) * 40.0;
        let topic_aff: Vec<f64> = (0..n_topics).map(|_| rng.uniform().powi(3)).collect();
        let aff_sum: f64 = topic_aff.iter().sum::<f64>().max(1e-9);
        (0..n)
            .map(|i| {
                let mix: f64 = doc_topics[i]
                    .iter()
                    .zip(&topic_aff)
                    .map(|(dw, ta)| dw * ta / aff_sum)
                    .sum();
                let lam = base * doc_len[i] * (0.2 + 2.0 * mix);
                (rng.poisson(lam) as f64).ln_1p()
            })
            .collect()
    };
    let cols: Vec<Vec<f64>> = (0..p).map(|_| make_word(&mut rng)).collect();
    let mut x = DenseMatrix::from_columns(&cols).expect("nyt_like: build");
    let mut y = make_word(&mut rng);
    let (centers, scales) = standardize_in_place(&mut x, &mut y);
    Dataset { x, y, centers, scales, name: format!("nyt-like(n={n},p={p})"), truth: None }
}

/// GRVS-like: rare-variant groups for the group lasso (paper §5.2.2a).
///
/// Variants are {0,1,2} dosages with rare MAFs (Unif[0.001, 0.02]); genes
/// are contiguous groups of 1–`max_gene` variants; the phenotype follows a
/// burden model over `g_true` causal genes. Groups are orthonormalized to
/// condition (19); monomorphic variants are dropped inside the
/// orthonormalization (rank reduction).
pub fn grvs_like(
    n: usize,
    g_total: usize,
    max_gene: usize,
    g_true: usize,
    seed: u64,
) -> GroupedDataset {
    let mut rng = Pcg64::new(seed);
    let sizes: Vec<usize> =
        (0..g_total).map(|_| 1 + rng.below(max_gene as u64) as usize).collect();
    let layout = GroupLayout::from_sizes(sizes.clone());
    let p = layout.total_cols();
    let mut x = DenseMatrix::zeros(n, p);
    for j in 0..p {
        let maf = rng.uniform_in(0.001, 0.02);
        let col = x.col_mut(j);
        for v in col.iter_mut() {
            *v = rng.binomial(2, maf) as f64;
        }
    }
    let truth = {
        let mut t = rng.sample_indices(g_total, g_true.min(g_total));
        t.sort_unstable();
        t
    };
    // Burden model: y = Σ_causal effect_g · (Σ_j∈g x_ij) + ε
    let mut y = vec![0.0; n];
    for &g in &truth {
        let eff = rng.uniform_in(0.5, 1.5) * if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        for j in layout.range(g) {
            crate::linalg::ops::axpy(eff, x.col(j), &mut y);
        }
    }
    let signal_sd = (crate::linalg::ops::nrm2_sq(&y) / n as f64).sqrt().max(0.3);
    for yi in y.iter_mut() {
        *yi += 0.7 * signal_sd * rng.normal();
    }
    let (_, scales) = standardize_in_place(&mut x, &mut y);
    // Drop monomorphic (zero-variance) columns before orthonormalization by
    // keeping them: they are all-zero post-standardization, so the group
    // Gram is singular there and rank reduction removes them.
    let _ = scales;
    let og = super::standardize::orthonormalize_groups(&x, &layout.starts, &layout.sizes);
    let new_layout = GroupLayout::from_sizes(og.sizes.clone());
    GroupedDataset {
        x: og.x,
        y,
        layout: new_layout,
        back_transforms: og.back_transforms,
        raw_sizes: sizes,
        name: format!("grvs-like(n={n},G={g_total})"),
        truth: Some(truth),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;

    #[test]
    fn gene_like_block_correlation() {
        let ds = gene_like(300, 60, 20, 0.8, 5, 1);
        // Adjacent columns in a block are strongly correlated (post-
        // standardization, correlation = dot/n).
        let c01 = ops::dot(ds.x.col(1), ds.x.col(2)) / 300.0;
        assert!(c01 > 0.5, "within-block corr = {c01}");
        // Columns across the block boundary (19,20) are near-independent.
        let c_cross = ops::dot(ds.x.col(19), ds.x.col(20)) / 300.0;
        assert!(c_cross.abs() < 0.35, "cross-block corr = {c_cross}");
    }

    #[test]
    fn mnist_like_is_globally_correlated() {
        let ds = mnist_like(200, 30, 8, 0.35, 2);
        let mut acc = 0.0;
        let mut cnt = 0;
        for a in 0..10 {
            for b in (a + 1)..10 {
                acc += ops::dot(ds.x.col(a), ds.x.col(b)) / 200.0;
                cnt += 1;
            }
        }
        let mean_corr = acc / cnt as f64;
        assert!(mean_corr > 0.15, "mean inter-column corr = {mean_corr}");
    }

    #[test]
    fn inv_norm_cdf_sane() {
        assert!(inv_norm_cdf(0.5).abs() < 1e-8);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((inv_norm_cdf(0.025) + 1.959964).abs() < 1e-4);
        assert!((inv_norm_cdf(1e-6) + 4.753424).abs() < 1e-3);
    }

    #[test]
    fn gwas_like_dosages_and_ld() {
        let n = 400;
        let raw_check = {
            // regenerate raw dosage behaviour via a fresh call and check the
            // standardized structure instead: adjacent SNPs correlated.
            gwas_like(n, 40, 20, 5, 3)
        };
        let c = ops::dot(raw_check.x.col(1), raw_check.x.col(2)) / n as f64;
        assert!(c > 0.25, "LD corr = {c}");
        let c_cross = ops::dot(raw_check.x.col(19), raw_check.x.col(20)) / n as f64;
        assert!(c_cross.abs() < 0.4, "cross-window corr = {c_cross}");
    }

    #[test]
    fn nyt_like_is_sparse_and_skewed() {
        let ds = nyt_like(150, 40, 1.3, 4);
        assert_eq!(ds.n(), 150);
        assert_eq!(ds.p(), 40);
        // Standardized columns remain unit-variance by construction.
        for j in 0..ds.p() {
            let v = ops::nrm2_sq(ds.x.col(j)) / 150.0;
            assert!(v < 1.0 + 1e-6, "col {j} variance {v}");
        }
    }

    #[test]
    fn grvs_like_group_structure() {
        let ds = grvs_like(250, 30, 8, 5, 5);
        assert_eq!(ds.raw_sizes.len(), 30);
        assert!(ds.num_groups() == 30);
        // condition (19) on a few groups
        let n = ds.n() as f64;
        for g in [0usize, 7, 29] {
            let r = ds.layout.range(g);
            for a in r.clone() {
                for b in r.clone() {
                    let d = ops::dot(ds.x.col(a), ds.x.col(b)) / n;
                    let want = if a == b { 1.0 } else { 0.0 };
                    assert!((d - want).abs() < 1e-6, "g={g} gram({a},{b})={d}");
                }
            }
        }
    }
}
