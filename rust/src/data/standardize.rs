//! Standardization to paper conditions (2) and (19).
//!
//! * [`standardize_in_place`] centers `y`, and centers + scales every column
//!   of `X` so that `Σᵢ xᵢⱼ = 0` and `Σᵢ xᵢⱼ²/n = 1` — condition (2). All
//!   screening-rule formulas in [`crate::screening`] assume this.
//! * [`orthonormalize_groups`] additionally enforces `X_gᵀX_g/n = I` per
//!   group — condition (19) — via an eigendecomposition of the small group
//!   Gram matrix (the approach used by `grpreg`). Rank-deficient groups are
//!   reduced to their numerical rank; the back-transform to raw coefficients
//!   is returned.

use crate::linalg::{ops, DenseMatrix};

/// Center a vector in place; returns the subtracted mean.
pub fn center(v: &mut [f64]) -> f64 {
    let m = ops::mean(v);
    for x in v.iter_mut() {
        *x -= m;
    }
    m
}

/// Center and scale every column of `x` to condition (2), and center `y`.
///
/// Returns `(centers, scales)`. Columns with zero variance are zeroed out
/// and get `scale = 0` (they can never enter the model, matching how
/// `biglasso` drops constant columns).
pub fn standardize_in_place(x: &mut DenseMatrix, y: &mut [f64]) -> (Vec<f64>, Vec<f64>) {
    let n = x.nrows();
    let p = x.ncols();
    assert_eq!(y.len(), n);
    center(y);
    let mut centers = vec![0.0; p];
    let mut scales = vec![0.0; p];
    for j in 0..p {
        let col = x.col_mut(j);
        let m = ops::mean(col);
        for v in col.iter_mut() {
            *v -= m;
        }
        let ss = ops::nrm2_sq(col) / n as f64;
        let sd = ss.sqrt();
        centers[j] = m;
        if sd > 1e-12 {
            let inv = 1.0 / sd;
            for v in col.iter_mut() {
                *v *= inv;
            }
            scales[j] = sd;
        } else {
            for v in col.iter_mut() {
                *v = 0.0;
            }
            scales[j] = 0.0;
        }
    }
    (centers, scales)
}

/// Jacobi eigendecomposition of a symmetric `w × w` matrix stored
/// column-major. Returns `(eigenvalues, eigenvectors)` with eigenvectors in
/// the columns of the returned matrix, `A = V diag(d) Vᵀ`.
///
/// Groups in the paper's workloads have `W_g ≤ 30`, so the classic cyclic
/// Jacobi method is both simple and plenty fast.
pub fn jacobi_eigen(a: &[f64], w: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), w * w);
    let mut m = a.to_vec();
    let mut v = vec![0.0; w * w];
    for i in 0..w {
        v[i * w + i] = 1.0;
    }
    let idx = |r: usize, c: usize| c * w + r;
    for _sweep in 0..100 {
        // Off-diagonal magnitude.
        let mut off = 0.0;
        for c in 0..w {
            for r in 0..c {
                off += m[idx(r, c)] * m[idx(r, c)];
            }
        }
        if off < 1e-24 {
            break;
        }
        for q in 0..w {
            for p_ in 0..q {
                let apq = m[idx(p_, q)];
                if apq.abs() < 1e-18 {
                    continue;
                }
                let app = m[idx(p_, p_)];
                let aqq = m[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation G(p,q,θ) on both sides: M ← GᵀMG, V ← VG.
                for k in 0..w {
                    let mkp = m[idx(k, p_)];
                    let mkq = m[idx(k, q)];
                    m[idx(k, p_)] = c * mkp - s * mkq;
                    m[idx(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..w {
                    let mpk = m[idx(p_, k)];
                    let mqk = m[idx(q, k)];
                    m[idx(p_, k)] = c * mpk - s * mqk;
                    m[idx(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..w {
                    let vkp = v[idx(k, p_)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p_)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let d: Vec<f64> = (0..w).map(|i| m[idx(i, i)]).collect();
    (d, v)
}

/// Result of group orthonormalization.
pub struct OrthoGroups {
    /// New design with `X_gᵀX_g/n = I` per (possibly shrunk) group.
    pub x: DenseMatrix,
    /// New group sizes (ranks).
    pub sizes: Vec<usize>,
    /// Back-transforms `T_g` (`raw_size × new_size`, column-major):
    /// `β_raw = T_g β_new`.
    pub back_transforms: Vec<Vec<f64>>,
}

/// Orthonormalize each contiguous group of columns to condition (19).
///
/// `X_g → X_g · V_g · diag(1/√d_g)` where `X_gᵀX_g/n = V diag(d) Vᵀ`.
/// Eigenvalues below `1e-10 · max(d)` are dropped (numerical rank).
pub fn orthonormalize_groups(
    x: &DenseMatrix,
    starts: &[usize],
    sizes: &[usize],
) -> OrthoGroups {
    let n = x.nrows();
    let mut new_cols: Vec<Vec<f64>> = Vec::new();
    let mut new_sizes = Vec::with_capacity(sizes.len());
    let mut backs = Vec::with_capacity(sizes.len());
    for (g, (&j0, &w)) in starts.iter().zip(sizes).enumerate() {
        let _ = g;
        // Gram matrix G = X_gᵀ X_g / n (w × w, column-major).
        let mut gram = vec![0.0; w * w];
        for a in 0..w {
            for b in a..w {
                let d = ops::dot(x.col(j0 + a), x.col(j0 + b)) / n as f64;
                gram[b * w + a] = d;
                gram[a * w + b] = d;
            }
        }
        let (d, v) = jacobi_eigen(&gram, w);
        let dmax = d.iter().cloned().fold(0.0f64, f64::max);
        let keep: Vec<usize> =
            (0..w).filter(|&k| d[k] > 1e-10 * dmax.max(1e-300)).collect();
        let rank = keep.len();
        // New columns: X_g · v_k / sqrt(d_k), and back-transform
        // T[:, k] = v_k / sqrt(d_k).
        let mut back = vec![0.0; w * rank];
        for (kk, &k) in keep.iter().enumerate() {
            let inv_sd = 1.0 / d[k].sqrt();
            let mut col = vec![0.0; n];
            for a in 0..w {
                let coef = v[k * w + a] * inv_sd;
                back[kk * w + a] = coef;
                if coef != 0.0 {
                    ops::axpy(coef, x.col(j0 + a), &mut col);
                }
            }
            new_cols.push(col);
        }
        new_sizes.push(rank);
        backs.push(back);
    }
    let x_new = DenseMatrix::from_columns(&new_cols).expect("ortho: column build");
    OrthoGroups { x: x_new, sizes: new_sizes, back_transforms: backs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn standardize_satisfies_condition_2() {
        let mut rng = Pcg64::new(1);
        let n = 50;
        let mut x = DenseMatrix::from_fn(n, 7, |_, j| rng.normal() * (j + 1) as f64 + 3.0);
        let mut y: Vec<f64> = (0..n).map(|_| rng.normal() + 5.0).collect();
        standardize_in_place(&mut x, &mut y);
        assert!(ops::sum(&y).abs() < 1e-9);
        for j in 0..7 {
            assert!(ops::sum(x.col(j)).abs() < 1e-9, "col {j} not centered");
            assert!((ops::nrm2_sq(x.col(j)) / n as f64 - 1.0).abs() < 1e-9, "col {j} not unit");
        }
    }

    #[test]
    fn constant_column_zeroed() {
        let mut x = DenseMatrix::from_fn(10, 2, |i, j| if j == 0 { 7.0 } else { i as f64 });
        let mut y = vec![1.0; 10];
        let (_, scales) = standardize_in_place(&mut x, &mut y);
        assert_eq!(scales[0], 0.0);
        assert!(x.col(0).iter().all(|&v| v == 0.0));
        assert!(scales[1] > 0.0);
    }

    #[test]
    fn jacobi_recovers_diagonal() {
        let a = vec![3.0, 0.0, 0.0, 5.0]; // diag(3,5)
        let (mut d, _) = jacobi_eigen(&a, 2);
        d.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((d[0] - 3.0).abs() < 1e-12 && (d[1] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_reconstructs_matrix() {
        let mut rng = Pcg64::new(2);
        let w = 6;
        // random symmetric PSD: A = BᵀB
        let b: Vec<f64> = (0..w * w).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; w * w];
        for i in 0..w {
            for j in 0..w {
                let mut s = 0.0;
                for k in 0..w {
                    s += b[i * w + k] * b[j * w + k];
                }
                a[j * w + i] = s;
            }
        }
        let (d, v) = jacobi_eigen(&a, w);
        // Check A·v_k = d_k·v_k for each k.
        for k in 0..w {
            for i in 0..w {
                let mut av = 0.0;
                for j in 0..w {
                    av += a[j * w + i] * v[k * w + j];
                }
                assert!((av - d[k] * v[k * w + i]).abs() < 1e-8, "eigenpair {k} broken");
            }
        }
    }

    #[test]
    fn groups_become_orthonormal() {
        let mut rng = Pcg64::new(3);
        let n = 60;
        let mut x = DenseMatrix::from_fn(n, 9, |_, _| rng.normal());
        let mut y = rng.normal_vec(n);
        standardize_in_place(&mut x, &mut y);
        let starts = vec![0, 4, 7];
        let sizes = vec![4, 3, 2];
        let og = orthonormalize_groups(&x, &starts, &sizes);
        assert_eq!(og.sizes, sizes); // full rank here
        let mut j0 = 0;
        for &w in &og.sizes {
            for a in 0..w {
                for b in 0..w {
                    let d = ops::dot(og.x.col(j0 + a), og.x.col(j0 + b)) / n as f64;
                    let want = if a == b { 1.0 } else { 0.0 };
                    assert!((d - want).abs() < 1e-8, "gram({a},{b}) = {d}");
                }
            }
            j0 += w;
        }
    }

    #[test]
    fn rank_deficient_group_shrinks() {
        let mut rng = Pcg64::new(4);
        let n = 40;
        let base = rng.normal_vec(n);
        // group of 3 where col2 = col0 + col1 (rank 2)
        let c0 = base.clone();
        let c1 = rng.normal_vec(n);
        let c2: Vec<f64> = c0.iter().zip(&c1).map(|(a, b)| a + b).collect();
        let x = DenseMatrix::from_columns(&[c0, c1, c2]).unwrap();
        let og = orthonormalize_groups(&x, &[0], &[3]);
        assert_eq!(og.sizes, vec![2]);
        assert_eq!(og.back_transforms[0].len(), 3 * 2);
    }

    #[test]
    fn back_transform_reproduces_fitted_values() {
        // X_new β_new must equal X_raw (T β_new).
        let mut rng = Pcg64::new(5);
        let n = 30;
        let x = DenseMatrix::from_fn(n, 5, |_, _| rng.normal());
        let og = orthonormalize_groups(&x, &[0], &[5]);
        let beta_new: Vec<f64> = (0..og.sizes[0]).map(|_| rng.normal()).collect();
        let fit_new = og.x.matvec(&beta_new);
        // β_raw = T β_new
        let t = &og.back_transforms[0];
        let mut beta_raw = vec![0.0; 5];
        for k in 0..og.sizes[0] {
            for a in 0..5 {
                beta_raw[a] += t[k * 5 + a] * beta_new[k];
            }
        }
        let fit_raw = x.matvec(&beta_raw);
        for i in 0..n {
            assert!((fit_new[i] - fit_raw[i]).abs() < 1e-8);
        }
    }
}
