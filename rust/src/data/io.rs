//! Dataset I/O: CSV ingestion for user data and a fast binary cache — the
//! adoption path for fitting external data through the CLI
//! (`hssr fit --data csv --path data.csv`).
//!
//! * CSV: numeric matrix, optional header row (auto-detected), response in
//!   the first column, features in the rest. Standardization to paper
//!   condition (2) happens on load. [`CsvRows`] is the shared streaming
//!   row parser — [`load_csv`] buffers it into a [`Dataset`], while the
//!   column store's `hssr convert` path
//!   ([`crate::data::store::writer::convert_csv`]) streams it straight to
//!   disk with Welford standardization, never holding the matrix.
//! * Binary cache: little-endian `HSSRBIN1` + dims + raw f64s; ~20× faster
//!   to reload than CSV for big matrices. Either format can be converted
//!   to the **real out-of-core column store** ([`crate::data::store`],
//!   `hssr convert in.csv out.store`): fitting with `--engine ooc` then
//!   serves every screening/KKT scan — the §3.2.3 memory-traffic
//!   bottleneck — from disk through a bounded LRU chunk cache
//!   (`HSSR_CACHE_MB`), with real I/O measured by
//!   `examples/out_of_core.rs`. (The inner CD solver still reads a
//!   resident design; bounding it the same way is a ROADMAP open item.)

use std::io::{BufRead, BufReader, BufWriter, Lines, Read, Write};
use std::path::Path;

use super::standardize::standardize_in_place;
use super::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::DenseMatrix;

const MAGIC: &[u8; 8] = b"HSSRBIN1";

/// Streaming CSV row parser: yields one `Vec<f64>` per data row, skipping
/// blank lines, `#` comments, and an auto-detected header row, and
/// enforcing a constant width. Shared by [`load_csv`] (which buffers the
/// rows) and the out-of-core converter
/// ([`crate::data::store::writer::convert_csv`], which never does).
pub struct CsvRows {
    lines: std::iter::Enumerate<Lines<BufReader<std::fs::File>>>,
    width: Option<usize>,
    any_data: bool,
}

impl CsvRows {
    /// Open a CSV file for streaming row iteration.
    pub fn open(path: &Path) -> Result<CsvRows> {
        let f = std::fs::File::open(path)?;
        Ok(CsvRows {
            lines: BufReader::new(f).lines().enumerate(),
            width: None,
            any_data: false,
        })
    }
}

impl Iterator for CsvRows {
    type Item = Result<Vec<f64>>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            let (lineno, line) = self.lines.next()?;
            let line = match line {
                Ok(l) => l,
                Err(e) => return Some(Err(e.into())),
            };
            let trimmed = line.trim();
            if trimmed.is_empty() || trimmed.starts_with('#') {
                continue;
            }
            let parsed: std::result::Result<Vec<f64>, _> =
                trimmed.split(',').map(|c| c.trim().parse::<f64>()).collect();
            match parsed {
                Ok(vals) => {
                    if let Some(w) = self.width {
                        if vals.len() != w {
                            return Some(Err(HssrError::Dimension(format!(
                                "csv line {}: {} columns, expected {w}",
                                lineno + 1,
                                vals.len()
                            ))));
                        }
                    } else {
                        self.width = Some(vals.len());
                    }
                    self.any_data = true;
                    return Some(Ok(vals));
                }
                Err(_) if !self.any_data => continue, // header row
                Err(e) => {
                    return Some(Err(HssrError::Config(format!(
                        "csv line {}: {e}",
                        lineno + 1
                    ))))
                }
            }
        }
    }
}

/// Parse a CSV file: `y, x1, x2, …` per row; `#` comments and an optional
/// header row are skipped. Returns a standardized [`Dataset`].
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut width: Option<usize> = None;
    for row in CsvRows::open(path)? {
        let vals = row?;
        width = Some(vals.len());
        rows.push(vals);
    }
    let w = width.ok_or_else(|| HssrError::Config("csv: no data rows".into()))?;
    if w < 2 {
        return Err(HssrError::Config("csv needs ≥ 2 columns (y + features)".into()));
    }
    let n = rows.len();
    let p = w - 1;
    let mut y = Vec::with_capacity(n);
    let mut x = DenseMatrix::zeros(n, p);
    for (i, row) in rows.iter().enumerate() {
        if let Some(j) = row.iter().position(|v| !v.is_finite()) {
            return Err(HssrError::Config(format!(
                "csv data row {}: non-finite value ({}) in column {j} — \
                 clean the data before fitting",
                i + 1,
                row[j]
            )));
        }
        y.push(row[0]);
        for j in 0..p {
            x.set(i, j, row[j + 1]);
        }
    }
    let (centers, scales) = standardize_in_place(&mut x, &mut y);
    if let Some(j) = scales.iter().position(|&s| s == 0.0) {
        return Err(HssrError::Config(format!(
            "csv feature column {j} has zero variance — a constant column \
             carries no signal and breaks standardization; drop it before \
             fitting"
        )));
    }
    Ok(Dataset {
        x,
        y,
        centers,
        scales,
        name: path.file_name().and_then(|s| s.to_str()).unwrap_or("csv").to_string(),
        truth: None,
    })
}

/// Write a dataset (standardized form) to the binary cache format.
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(ds.n() as u64).to_le_bytes())?;
    w.write_all(&(ds.p() as u64).to_le_bytes())?;
    for v in &ds.y {
        w.write_all(&v.to_le_bytes())?;
    }
    for v in ds.x.as_slice() {
        w.write_all(&v.to_le_bytes())?;
    }
    for v in ds.centers.iter().chain(&ds.scales) {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load a dataset from the binary cache.
pub fn load_bin(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(HssrError::Config(format!(
            "{}: not an HSSR binary cache",
            path.display()
        )));
    }
    let mut u = [0u8; 8];
    r.read_exact(&mut u)?;
    let n = u64::from_le_bytes(u) as usize;
    r.read_exact(&mut u)?;
    let p = u64::from_le_bytes(u) as usize;
    let mut read_f64s = |count: usize| -> Result<Vec<f64>> {
        let mut buf = vec![0u8; count * 8];
        r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| {
                let mut b = [0u8; 8];
                b.copy_from_slice(c);
                f64::from_le_bytes(b)
            })
            .collect())
    };
    let y = read_f64s(n)?;
    let data = read_f64s(n * p)?;
    let centers = read_f64s(p)?;
    let scales = read_f64s(p)?;
    for (what, vals) in
        [("response", &y), ("matrix", &data), ("centers", &centers), ("scales", &scales)]
    {
        if let Some(i) = vals.iter().position(|v| !v.is_finite()) {
            return Err(HssrError::Config(format!(
                "{}: non-finite {what} value at index {i} — the cache is \
                 corrupt or was written from unclean data",
                path.display()
            )));
        }
    }
    if let Some(j) = scales.iter().position(|&s| s == 0.0) {
        return Err(HssrError::Config(format!(
            "{}: feature column {j} has zero variance — drop constant \
             columns before caching",
            path.display()
        )));
    }
    Ok(Dataset {
        x: DenseMatrix::from_col_major(n, p, data)?,
        y,
        centers,
        scales,
        name: path.file_name().and_then(|s| s.to_str()).unwrap_or("bin").to_string(),
        truth: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hssr_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip_with_header_and_comments() {
        let path = tmp("t1.csv");
        std::fs::write(
            &path,
            "y,x1,x2\n# comment\n1.0, 2.0, 3.0\n-1.0, 0.5, 1.5\n2.0, -1.0, 0.0\n4.0, 1.0, 2.0\n",
        )
        .unwrap();
        let ds = load_csv(&path).unwrap();
        assert_eq!(ds.n(), 4);
        assert_eq!(ds.p(), 2);
        // standardized
        assert!(crate::linalg::ops::sum(&ds.y).abs() < 1e-9);
        for j in 0..2 {
            assert!((crate::linalg::ops::nrm2_sq(ds.x.col(j)) / 4.0 - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn csv_errors_are_descriptive() {
        let path = tmp("t2.csv");
        std::fs::write(&path, "1.0,2.0\n1.0\n").unwrap();
        let err = load_csv(&path).unwrap_err();
        assert!(err.to_string().contains("columns"));
        let path3 = tmp("t3.csv");
        std::fs::write(&path3, "justone\n1.0\n").unwrap();
        assert!(load_csv(&path3).is_err());
    }

    #[test]
    fn bin_roundtrip_exact() {
        let ds = DataSpec::synthetic(25, 10, 3).generate(1);
        let path = tmp("t4.bin");
        save_bin(&ds, &path).unwrap();
        let back = load_bin(&path).unwrap();
        assert_eq!(back.n(), 25);
        assert_eq!(back.p(), 10);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.x.as_slice(), ds.x.as_slice());
        assert_eq!(back.centers, ds.centers);
        assert_eq!(back.scales, ds.scales);
    }

    #[test]
    fn bin_rejects_garbage() {
        let path = tmp("t5.bin");
        std::fs::write(&path, b"NOTHSSR!xxxx").unwrap();
        assert!(load_bin(&path).is_err());
    }

    /// NaN/Inf and zero-variance columns are typed load-time errors —
    /// bad data must never flow silently into a fit.
    #[test]
    fn csv_rejects_nonfinite_and_constant_columns() {
        let path = tmp("t7.csv");
        std::fs::write(&path, "1.0,2.0,3.0\n-1.0,inf,1.0\n0.5,0.25,2.0\n").unwrap();
        let err = load_csv(&path).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "got {err}");
        let path = tmp("t8.csv");
        std::fs::write(&path, "1.0,2.0,7.5\n-1.0,3.0,7.5\n0.5,0.25,7.5\n").unwrap();
        let err = load_csv(&path).unwrap_err();
        assert!(err.to_string().contains("zero variance"), "got {err}");
    }

    #[test]
    fn bin_rejects_nonfinite_payload() {
        let ds = DataSpec::synthetic(10, 4, 2).generate(9);
        let path = tmp("t9.bin");
        save_bin(&ds, &path).unwrap();
        // poison one matrix value with NaN (y is 10 f64s after the
        // 24-byte preamble; matrix follows)
        let mut bytes = std::fs::read(&path).unwrap();
        let off = 24 + 10 * 8 + 5 * 8;
        bytes[off..off + 8].copy_from_slice(&f64::NAN.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = load_bin(&path).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "got {err}");
    }

    #[test]
    fn csv_then_fit_works() {
        // the actual user workflow
        let path = tmp("t6.csv");
        let mut body = String::from("y,a,b,c\n");
        let mut rng = crate::rng::Pcg64::new(5);
        for _ in 0..40 {
            let a = rng.normal();
            let b = rng.normal();
            let c = rng.normal();
            let y = 2.0 * a - b + 0.1 * rng.normal();
            body.push_str(&format!("{y},{a},{b},{c}\n"));
        }
        std::fs::write(&path, body).unwrap();
        let ds = load_csv(&path).unwrap();
        let fit = crate::solver::path::fit_lasso_path(
            &ds,
            &crate::solver::path::PathConfig::default(),
        )
        .unwrap();
        let sel: Vec<usize> =
            fit.betas.last().unwrap().iter().map(|&(j, _)| j).collect();
        assert!(sel.contains(&0) && sel.contains(&1), "selected {sel:?}");
    }
}
