//! Cubic B-spline basis expansion — the GENE-SPLINE workload (paper §5.2.2b)
//! applies a 5-term basis expansion to each raw feature and treats the five
//! expansions as a group.

use super::standardize::{orthonormalize_groups, standardize_in_place};
use super::{Dataset, GroupLayout, GroupedDataset};
use crate::linalg::DenseMatrix;

/// Evaluate the Cox–de Boor recursion for B-spline basis `i` of degree `k`
/// over knot vector `t` at point `x`.
fn bspline_basis(i: usize, k: usize, t: &[f64], x: f64) -> f64 {
    if k == 0 {
        // half-open intervals, closed at the right end of the last interval
        let last = i + 1 == t.len() - 1 || t[i + 1] >= t[t.len() - 1];
        if (t[i] <= x && x < t[i + 1]) || (last && (x - t[i + 1]).abs() < 1e-12) {
            1.0
        } else {
            0.0
        }
    } else {
        let mut v = 0.0;
        let d1 = t[i + k] - t[i];
        if d1 > 1e-12 {
            v += (x - t[i]) / d1 * bspline_basis(i, k - 1, t, x);
        }
        let d2 = t[i + k + 1] - t[i + 1];
        if d2 > 1e-12 {
            v += (t[i + k + 1] - x) / d2 * bspline_basis(i + 1, k - 1, t, x);
        }
        v
    }
}

/// Expand one column into `n_basis` cubic B-spline bases with knots at the
/// empirical quantiles (boundary knots at min/max), as `splines::bs` does.
pub fn expand_column(col: &[f64], n_basis: usize) -> Vec<Vec<f64>> {
    assert!(n_basis >= 4, "cubic B-splines need >= 4 basis functions");
    let degree = 3usize;
    let n_inner = n_basis - degree; // interior-knot count + 1 spans
    let mut sorted = col.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let lo = sorted[0];
    let hi = sorted[sorted.len() - 1];
    // knot vector: degree+1 copies of lo, interior quantile knots, degree+1 of hi
    let mut knots = vec![lo; degree + 1];
    for q in 1..n_inner {
        let frac = q as f64 / n_inner as f64;
        let idx = ((sorted.len() - 1) as f64 * frac).round() as usize;
        knots.push(sorted[idx]);
    }
    knots.extend(std::iter::repeat(hi).take(degree + 1));
    (0..n_basis)
        .map(|b| col.iter().map(|&x| bspline_basis(b, degree, &knots, x)).collect())
        .collect()
}

/// Build the GENE-SPLINE grouped dataset: a `n_basis`-term B-spline
/// expansion of every column of `base`, one group per raw feature, then
/// standardization (2) + group orthonormalization (19).
pub fn expand_dataset(base: &Dataset, n_basis: usize) -> GroupedDataset {
    let p_raw = base.p();
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(p_raw * n_basis);
    for j in 0..p_raw {
        cols.extend(expand_column(base.x.col(j), n_basis));
    }
    let mut x = DenseMatrix::from_columns(&cols).expect("expand_dataset: build");
    let mut y = base.y.clone();
    let (_, _) = standardize_in_place(&mut x, &mut y);
    let layout = GroupLayout::from_sizes(vec![n_basis; p_raw]);
    let og = orthonormalize_groups(&x, &layout.starts, &layout.sizes);
    let new_layout = GroupLayout::from_sizes(og.sizes.clone());
    GroupedDataset {
        x: og.x,
        y,
        layout: new_layout,
        back_transforms: og.back_transforms,
        raw_sizes: vec![n_basis; p_raw],
        name: format!("{}-spline{}", base.name, n_basis),
        truth: base.truth.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::rng::Pcg64;

    #[test]
    fn partition_of_unity() {
        let mut rng = Pcg64::new(1);
        let col: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let bases = expand_column(&col, 5);
        assert_eq!(bases.len(), 5);
        for i in 0..100 {
            let s: f64 = bases.iter().map(|b| b[i]).sum();
            assert!((s - 1.0).abs() < 1e-9, "sum of bases at i={i} is {s}");
        }
    }

    #[test]
    fn bases_nonnegative_and_local() {
        let mut rng = Pcg64::new(2);
        let col: Vec<f64> = (0..80).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let bases = expand_column(&col, 6);
        for b in &bases {
            assert!(b.iter().all(|&v| v >= -1e-12 && v <= 1.0 + 1e-12));
        }
    }

    #[test]
    fn expanded_dataset_shape_and_ortho() {
        let base = DataSpec::gene_like(120, 8).generate(3);
        let g = expand_dataset(&base, 5);
        assert_eq!(g.num_groups(), 8);
        assert!(g.p() <= 40);
        let n = g.n() as f64;
        for grp in 0..g.num_groups() {
            let r = g.layout.range(grp);
            for a in r.clone() {
                for b in r.clone() {
                    let d = crate::linalg::ops::dot(g.x.col(a), g.x.col(b)) / n;
                    let want = if a == b { 1.0 } else { 0.0 };
                    assert!((d - want).abs() < 1e-7);
                }
            }
        }
    }
}
