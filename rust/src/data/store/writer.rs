//! Streaming writers for the `HSSRSTOR1` column store.
//!
//! Three producers, all with bounded memory:
//!
//! * [`convert_csv`] — the adoption path for external data
//!   (`hssr convert data.csv data.store`). CSV arrives row-major while the
//!   store is column-major, so the converter makes one cheap row-count
//!   pass and then a single parse pass that **streams standardization**:
//!   per-column Welford mean/variance accumulate while row blocks are
//!   scattered to their final column offsets with positioned writes. The
//!   chunk data stays *raw*; the center/scale stats land in the tail and
//!   the reader applies `(x − center)/scale` at chunk load, so the full
//!   `n×p` matrix is never resident during conversion (memory is one
//!   row block plus the Welford state and `y`).
//! * [`convert_bin`] — `HSSRBIN1` caches are already standardized and
//!   column-major; the converter is a straight re-framed stream copy.
//! * [`write_matrix`] / [`write_dataset`] — spill an in-memory
//!   (standardized) design to a store, column-major sequential. This is
//!   what `--engine ooc` uses to mount a generated dataset, and what the
//!   equivalence tests use to get bit-exact values on disk.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use super::format::{Header, HEADER_LEN};
use super::pwrite;
use crate::data::io::CsvRows;
use crate::data::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::DenseMatrix;

/// What a writer produced: the decoded header plus the file size.
#[derive(Clone, Copy, Debug)]
pub struct StoreSummary {
    /// The header written.
    pub header: Header,
    /// Total bytes in the store file.
    pub file_bytes: u64,
}

fn write_f64s<W: Write>(w: &mut W, vals: &[f64]) -> Result<()> {
    // 8 KiB staging buffer keeps the syscall count low without holding
    // more than a sliver of the data.
    let mut buf = Vec::with_capacity(8192);
    for chunk in vals.chunks(1024) {
        buf.clear();
        for v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Write a column-major matrix (plus response and per-column stats) as a
/// store. `standardized` declares whether `x` is already in paper
/// condition (2) — if `true` the reader serves the values verbatim and
/// `centers`/`scales` are carried as dataset metadata; if `false` the
/// reader applies `(x − center)/scale` per column at chunk load.
pub fn write_matrix(
    x: &DenseMatrix,
    y: &[f64],
    centers: &[f64],
    scales: &[f64],
    standardized: bool,
    chunk_cols: usize,
    path: &Path,
) -> Result<StoreSummary> {
    let (n, p) = (x.nrows(), x.ncols());
    if y.len() != n || centers.len() != p || scales.len() != p {
        return Err(HssrError::Dimension(format!(
            "store write: y/centers/scales lengths ({}, {}, {}) do not match n={n}, p={p}",
            y.len(),
            centers.len(),
            scales.len()
        )));
    }
    let header = Header { n, p, chunk_cols: chunk_cols.clamp(1, p.max(1)), standardized };
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(&header.encode())?;
    // The backing slice is already column-major — the chunk layout is a
    // pure re-framing of the same byte order.
    write_f64s(&mut w, x.as_slice())?;
    write_f64s(&mut w, y)?;
    write_f64s(&mut w, centers)?;
    write_f64s(&mut w, scales)?;
    w.flush()?;
    Ok(StoreSummary { header, file_bytes: header.file_len() })
}

/// Spill a standardized [`Dataset`] to a store (identity read transform;
/// the dataset's centers/scales ride along as metadata).
pub fn write_dataset(ds: &Dataset, chunk_cols: usize, path: &Path) -> Result<StoreSummary> {
    write_matrix(&ds.x, &ds.y, &ds.centers, &ds.scales, true, chunk_cols, path)
}

/// Convert an `HSSRBIN1` binary cache (already standardized, column-major)
/// to a store by streaming: the matrix payload is copied in fixed-size
/// buffers, never fully resident.
pub fn convert_bin(src: &Path, chunk_cols: usize, out: &Path) -> Result<StoreSummary> {
    let mut r = std::io::BufReader::new(File::open(src)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != b"HSSRBIN1" {
        return Err(HssrError::Config(format!(
            "{}: not an HSSR binary cache",
            src.display()
        )));
    }
    let mut u = [0u8; 8];
    r.read_exact(&mut u)?;
    let n = u64::from_le_bytes(u) as usize;
    r.read_exact(&mut u)?;
    let p = u64::from_le_bytes(u) as usize;
    if n == 0 || p == 0 {
        return Err(HssrError::Config("binary cache is empty".into()));
    }
    // HSSRBIN layout: y, x, centers, scales. Store layout: x, y, centers,
    // scales — so hold y (length n) and stream everything else.
    let mut ybytes = vec![0u8; n * 8];
    r.read_exact(&mut ybytes)?;
    let header = Header { n, p, chunk_cols: chunk_cols.clamp(1, p), standardized: true };
    let mut w = BufWriter::new(File::create(out)?);
    w.write_all(&header.encode())?;
    let mut remaining = (n * p * 8) as u64;
    let mut buf = vec![0u8; 1 << 20];
    while remaining > 0 {
        let take = (buf.len() as u64).min(remaining) as usize;
        r.read_exact(&mut buf[..take])?;
        w.write_all(&buf[..take])?;
        remaining -= take as u64;
    }
    w.write_all(&ybytes)?;
    let mut stats = (2 * p * 8) as u64;
    while stats > 0 {
        let take = (buf.len() as u64).min(stats) as usize;
        r.read_exact(&mut buf[..take])?;
        w.write_all(&buf[..take])?;
        stats -= take as u64;
    }
    w.flush()?;
    Ok(StoreSummary { header, file_bytes: header.file_len() })
}

/// Per-column Welford accumulator (numerically stable streaming
/// mean/variance — the "streaming standardization" state).
#[derive(Clone, Copy, Default)]
struct Welford {
    count: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    fn push(&mut self, x: f64) {
        self.count += 1;
        let d = x - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Population scale `√(Σ(x−x̄)²/n)`; 0 marks a constant column (the
    /// same `1e-12` threshold as
    /// [`crate::data::standardize::standardize_in_place`]).
    fn scale(&self) -> f64 {
        let sd = (self.m2 / self.count.max(1) as f64).sqrt();
        if sd > 1e-12 {
            sd
        } else {
            0.0
        }
    }
}

/// Convert a CSV file (`y, x1, …, xp` per row, `#` comments and an
/// optional header skipped — the same dialect as
/// [`crate::data::io::load_csv`]) to a store, with streaming
/// standardization. Returns the summary of the written store.
pub fn convert_csv(src: &Path, chunk_cols: usize, out: &Path) -> Result<StoreSummary> {
    // Pass 1: count data rows (and learn the width) without buffering.
    let mut n = 0usize;
    let mut width = 0usize;
    for row in CsvRows::open(src)? {
        let row = row?;
        width = row.len();
        n += 1;
    }
    if n == 0 {
        return Err(HssrError::Config("csv: no data rows".into()));
    }
    if width < 2 {
        return Err(HssrError::Config("csv needs ≥ 2 columns (y + features)".into()));
    }
    let p = width - 1;
    let header = Header { n, p, chunk_cols: chunk_cols.clamp(1, p), standardized: false };

    // Pass 2: stream rows, scattering row blocks to their final
    // column-major offsets while the Welford state accumulates.
    let file = File::create(out)?;
    pwrite(&file, &header.encode(), 0)?;
    let block_rows = ((4 << 20) / (p * 8)).clamp(1, n);
    let mut block: Vec<Vec<f64>> = vec![Vec::with_capacity(block_rows); p];
    let mut stats = vec![Welford::default(); p];
    let mut y = Vec::with_capacity(n);
    let mut rows_done = 0usize;
    let mut colbytes = Vec::with_capacity(block_rows * 8);
    let mut flush = |block: &mut Vec<Vec<f64>>, rows_done: usize| -> Result<()> {
        for (j, col) in block.iter_mut().enumerate() {
            if col.is_empty() {
                continue;
            }
            colbytes.clear();
            for v in col.iter() {
                colbytes.extend_from_slice(&v.to_le_bytes());
            }
            let off = HEADER_LEN + ((j * n + rows_done) * 8) as u64;
            pwrite(&file, &colbytes, off)?;
            col.clear();
        }
        Ok(())
    };
    for row in CsvRows::open(src)? {
        let row = row?;
        if row.len() != width {
            return Err(HssrError::Dimension(format!(
                "csv changed width mid-stream ({} vs {width})",
                row.len()
            )));
        }
        if y.len() == n {
            return Err(HssrError::Dimension(
                "csv grew between passes (more rows than counted)".into(),
            ));
        }
        y.push(row[0]);
        for j in 0..p {
            let v = row[j + 1];
            stats[j].push(v);
            block[j].push(v);
        }
        if block[0].len() == block_rows {
            flush(&mut block, rows_done)?;
            rows_done += block_rows;
        }
    }
    let tail_rows = block[0].len();
    flush(&mut block, rows_done)?;
    rows_done += tail_rows;
    if rows_done != n {
        return Err(HssrError::Dimension(format!(
            "csv shrank between passes ({rows_done} rows vs {n} counted)"
        )));
    }

    // Tail: centered y, then the streaming centers/scales.
    let ybar = y.iter().sum::<f64>() / n as f64;
    for v in y.iter_mut() {
        *v -= ybar;
    }
    let centers: Vec<f64> = stats.iter().map(|s| s.mean).collect();
    let scales: Vec<f64> = stats.iter().map(|s| s.scale()).collect();
    let mut tail = Vec::with_capacity((n + 2 * p) * 8);
    for v in y.iter().chain(&centers).chain(&scales) {
        tail.extend_from_slice(&v.to_le_bytes());
    }
    pwrite(&file, &tail, header.tail_offset())?;
    file.sync_all().ok();
    Ok(StoreSummary { header, file_bytes: header.file_len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hssr_store_writer_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn welford_matches_two_pass() {
        let xs = [3.0, -1.5, 2.25, 0.5, 9.0, -4.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean - mean).abs() < 1e-12);
        assert!((w.scale() - var.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn welford_constant_column_zero_scale() {
        let mut w = Welford::default();
        for _ in 0..10 {
            w.push(7.0);
        }
        assert_eq!(w.scale(), 0.0);
    }

    #[test]
    fn write_matrix_rejects_bad_dims() {
        let x = DenseMatrix::zeros(4, 3);
        let err = write_matrix(
            &x,
            &[0.0; 3], // wrong length
            &[0.0; 3],
            &[1.0; 3],
            true,
            2,
            &tmp("bad.store"),
        );
        assert!(err.is_err());
    }

    #[test]
    fn convert_bin_roundtrips_header() {
        use crate::data::DataSpec;
        let ds = DataSpec::synthetic(12, 7, 2).generate(3);
        let bin = tmp("cb.bin");
        crate::data::io::save_bin(&ds, &bin).unwrap();
        let out = tmp("cb.store");
        let s = convert_bin(&bin, 3, &out).unwrap();
        assert_eq!((s.header.n, s.header.p, s.header.chunk_cols), (12, 7, 3));
        assert!(s.header.standardized);
        assert_eq!(std::fs::metadata(&out).unwrap().len(), s.file_bytes);
    }
}
