//! Streaming writers for the `HSSRSTOR` column store (v2: every chunk and
//! the tail are CRC32-checksummed; see [`super::format`]).
//!
//! Three producers, all with bounded memory:
//!
//! * [`convert_csv`] — the adoption path for external data
//!   (`hssr convert data.csv data.store`). CSV arrives row-major while the
//!   store is column-major, so the converter makes one cheap row-count
//!   pass and then a single parse pass that **streams standardization**:
//!   per-column Welford mean/variance accumulate while row blocks are
//!   scattered to their final column offsets with positioned writes. The
//!   chunk data stays *raw*; the center/scale stats land in the tail and
//!   the reader applies `(x − center)/scale` at chunk load, so the full
//!   `n×p` matrix is never resident during conversion (memory is one
//!   row block plus the Welford state and `y`).
//! * [`convert_bin`] — `HSSRBIN1` caches are already standardized and
//!   column-major; the converter is a straight re-framed stream copy.
//! * [`write_matrix`] / [`write_dataset`] — spill an in-memory
//!   (standardized) design to a store, column-major sequential. This is
//!   what `--engine ooc` uses to mount a generated dataset, and what the
//!   equivalence tests use to get bit-exact values on disk.
//!
//! All three validate their inputs — non-finite values (and, for the
//! conversion paths, zero-variance feature columns) are typed errors at
//! the write boundary, never data that surfaces later as a diverging fit —
//! and finish with a checksum pass ([`append_checksums`]) that reads the
//! written payload back and appends one CRC32 per chunk plus one for the
//! tail.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use super::format::{Header, HEADER_LEN};
use super::{pread, pwrite};
use crate::data::io::CsvRows;
use crate::data::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::DenseMatrix;
use crate::serialize::crc32;

/// What a writer produced: the decoded header plus the file size.
#[derive(Clone, Copy, Debug)]
pub struct StoreSummary {
    /// The header written.
    pub header: Header,
    /// Total bytes in the store file.
    pub file_bytes: u64,
}

/// Whether `HSSR_STORE_F32=1` asks the writers to append an f32 shadow
/// section to every store they produce.
fn f32_shadow_requested() -> bool {
    matches!(
        std::env::var("HSSR_STORE_F32").as_deref(),
        Ok("1") | Ok("true") | Ok("on")
    )
}

/// Append the f32 shadow section to an existing store: the standardized
/// matrix re-cast to f32 in the same chunk framing, one CRC32 per shadow
/// chunk, and — **last**, so a crash mid-append leaves a valid
/// shadow-less file — the header flag at byte 10. Idempotent: a store
/// that already carries a shadow is returned unchanged. Returns the
/// updated header.
///
/// Each shadow value is `standardized_value as f32`, where the
/// standardized f64 is computed exactly as the reader's chunk decode does
/// (`(x − center)·(1/scale)`, zero for constant columns) — so a shadow
/// scan is bit-identical to casting the served f64 columns, which is what
/// lets the mixed-precision screening path swap freely between shadowed
/// and shadow-less stores.
pub fn append_f32_shadow(path: &Path) -> Result<Header> {
    let file = File::options().read(true).write(true).open(path)?;
    let mut head = [0u8; HEADER_LEN as usize];
    pread(&file, &mut head, 0)?;
    let header = Header::decode(&head)?;
    if header.f32_shadow {
        return Ok(header);
    }
    let (n, p) = (header.n, header.p);
    // Per-column stats live in the tail: needed to standardize raw chunks.
    let mut stats = vec![0u8; 2 * p * 8];
    pread(&file, &mut stats, header.tail_offset() + (n * 8) as u64)?;
    let decode = |b: &[u8]| -> Vec<f64> {
        b.chunks_exact(8)
            .map(|c| {
                let mut v = [0u8; 8];
                v.copy_from_slice(c);
                f64::from_le_bytes(v)
            })
            .collect()
    };
    let centers = decode(&stats[..p * 8]);
    let scales = decode(&stats[p * 8..]);
    let shadowed = Header { f32_shadow: true, ..header };
    let mut crcs = Vec::with_capacity(4 * shadowed.num_chunks());
    let mut raw = Vec::new();
    let mut cast = Vec::new();
    for c in 0..shadowed.num_chunks() {
        raw.resize(shadowed.chunk_bytes(c), 0u8);
        pread(&file, &mut raw, shadowed.chunk_offset(c))?;
        cast.clear();
        let j0 = c * shadowed.chunk_cols;
        for (local, col) in raw.chunks_exact(n * 8).enumerate() {
            let j = j0 + local;
            let scale = scales[j];
            let center = centers[j];
            let inv = 1.0 / scale;
            for v in decode(col) {
                let std = if shadowed.standardized {
                    v
                } else if scale == 0.0 {
                    0.0
                } else {
                    (v - center) * inv
                };
                cast.extend_from_slice(&(std as f32).to_le_bytes());
            }
        }
        crcs.extend_from_slice(&crc32(&cast).to_le_bytes());
        pwrite(&file, &cast, shadowed.shadow_chunk_offset(c))?;
    }
    pwrite(&file, &crcs, shadowed.shadow_crc_offset())?;
    file.sync_all()?;
    // Publish the shadow only after every byte of it is durable.
    pwrite(&file, &[1u8], 10)?;
    file.sync_all().ok();
    Ok(shadowed)
}

/// Run the `HSSR_STORE_F32` writer hook: append the shadow when
/// requested, returning the (possibly updated) summary.
fn finish_store(header: Header, path: &Path) -> Result<StoreSummary> {
    let header = if f32_shadow_requested() { append_f32_shadow(path)? } else { header };
    Ok(StoreSummary { header, file_bytes: header.file_len() })
}

/// Read the written payload back and append the v2 checksum section: one
/// CRC32 per chunk in order, then one CRC32 of the whole tail. The file
/// handle must be readable and writable.
fn append_checksums(file: &File, header: &Header) -> Result<()> {
    debug_assert!(header.checksums);
    let mut sect = Vec::with_capacity(header.checksum_bytes() as usize);
    let mut buf = Vec::new();
    for c in 0..header.num_chunks() {
        buf.resize(header.chunk_bytes(c), 0u8);
        pread(file, &mut buf, header.chunk_offset(c))?;
        sect.extend_from_slice(&crc32(&buf).to_le_bytes());
    }
    let mut tail = vec![0u8; header.tail_bytes()];
    pread(file, &mut tail, header.tail_offset())?;
    sect.extend_from_slice(&crc32(&tail).to_le_bytes());
    pwrite(file, &sect, header.checksum_offset())?;
    Ok(())
}

/// Reject non-finite values in a little-endian f64 byte run. `base` is the
/// global value index of `bytes[0]`, so the error names the real position.
fn check_finite_bytes(bytes: &[u8], base: usize, what: &str) -> Result<()> {
    for (i, c) in bytes.chunks_exact(8).enumerate() {
        let mut b = [0u8; 8];
        b.copy_from_slice(c);
        let v = f64::from_le_bytes(b);
        if !v.is_finite() {
            return Err(HssrError::Config(format!(
                "{what}: non-finite value ({v}) at index {}",
                base + i
            )));
        }
    }
    Ok(())
}

fn write_f64s<W: Write>(w: &mut W, vals: &[f64]) -> Result<()> {
    // 8 KiB staging buffer keeps the syscall count low without holding
    // more than a sliver of the data.
    let mut buf = Vec::with_capacity(8192);
    for chunk in vals.chunks(1024) {
        buf.clear();
        for v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Write a column-major matrix (plus response and per-column stats) as a
/// store. `standardized` declares whether `x` is already in paper
/// condition (2) — if `true` the reader serves the values verbatim and
/// `centers`/`scales` are carried as dataset metadata; if `false` the
/// reader applies `(x − center)/scale` per column at chunk load.
pub fn write_matrix(
    x: &DenseMatrix,
    y: &[f64],
    centers: &[f64],
    scales: &[f64],
    standardized: bool,
    chunk_cols: usize,
    path: &Path,
) -> Result<StoreSummary> {
    let (n, p) = (x.nrows(), x.ncols());
    if y.len() != n || centers.len() != p || scales.len() != p {
        return Err(HssrError::Dimension(format!(
            "store write: y/centers/scales lengths ({}, {}, {}) do not match n={n}, p={p}",
            y.len(),
            centers.len(),
            scales.len()
        )));
    }
    if let Some(pos) = x.as_slice().iter().position(|v| !v.is_finite()) {
        return Err(HssrError::Config(format!(
            "store write: non-finite value in design matrix \
             (column {}, row {})",
            pos / n.max(1),
            pos % n.max(1)
        )));
    }
    if let Some(i) = y.iter().position(|v| !v.is_finite()) {
        return Err(HssrError::Config(format!(
            "store write: non-finite response value at row {i}"
        )));
    }
    let header = Header {
        n,
        p,
        chunk_cols: chunk_cols.clamp(1, p.max(1)),
        standardized,
        checksums: true,
        f32_shadow: false,
    };
    let file = File::options().read(true).write(true).create(true).truncate(true).open(path)?;
    let mut w = BufWriter::new(&file);
    w.write_all(&header.encode())?;
    // The backing slice is already column-major — the chunk layout is a
    // pure re-framing of the same byte order.
    write_f64s(&mut w, x.as_slice())?;
    write_f64s(&mut w, y)?;
    write_f64s(&mut w, centers)?;
    write_f64s(&mut w, scales)?;
    w.flush()?;
    drop(w);
    append_checksums(&file, &header)?;
    finish_store(header, path)
}

/// Spill a standardized [`Dataset`] to a store (identity read transform;
/// the dataset's centers/scales ride along as metadata).
pub fn write_dataset(ds: &Dataset, chunk_cols: usize, path: &Path) -> Result<StoreSummary> {
    write_matrix(&ds.x, &ds.y, &ds.centers, &ds.scales, true, chunk_cols, path)
}

/// Dimensions and tail metadata for a [`write_columns`] streaming spill.
pub struct ColumnSpill<'a> {
    /// Rows per column.
    pub n: usize,
    /// Number of columns the generator will be asked for.
    pub p: usize,
    /// Response vector for the tail (length `n`).
    pub y: &'a [f64],
    /// Per-column centers metadata (length `p`).
    pub centers: &'a [f64],
    /// Per-column scales metadata (length `p`).
    pub scales: &'a [f64],
    /// Whether the generated values are already standardized (served
    /// verbatim) — see [`write_matrix`].
    pub standardized: bool,
    /// Chunk width in columns (clamped to `1..=p`).
    pub chunk_cols: usize,
}

/// Write a store from a **column generator**: `col(j, buf)` fills `buf`
/// with column `j`'s `n` values, called once per column in ascending
/// order. Peak memory is one column plus one chunk (the checksum pass) —
/// never `n×p` — which is what lets CV spill a standardized fold view of
/// an out-of-core design without materializing the fold.
pub fn write_columns(
    spec: &ColumnSpill<'_>,
    mut col: impl FnMut(usize, &mut Vec<f64>) -> Result<()>,
    path: &Path,
) -> Result<StoreSummary> {
    let (n, p) = (spec.n, spec.p);
    if n == 0 || p == 0 {
        return Err(HssrError::Config("store write: empty design".into()));
    }
    if spec.y.len() != n || spec.centers.len() != p || spec.scales.len() != p {
        return Err(HssrError::Dimension(format!(
            "store write: y/centers/scales lengths ({}, {}, {}) do not match n={n}, p={p}",
            spec.y.len(),
            spec.centers.len(),
            spec.scales.len()
        )));
    }
    if let Some(i) = spec.y.iter().position(|v| !v.is_finite()) {
        return Err(HssrError::Config(format!(
            "store write: non-finite response value at row {i}"
        )));
    }
    let header = Header {
        n,
        p,
        chunk_cols: spec.chunk_cols.clamp(1, p),
        standardized: spec.standardized,
        checksums: true,
        f32_shadow: false,
    };
    let file = File::options().read(true).write(true).create(true).truncate(true).open(path)?;
    let mut w = BufWriter::new(&file);
    w.write_all(&header.encode())?;
    let mut buf: Vec<f64> = Vec::with_capacity(n);
    for j in 0..p {
        buf.clear();
        col(j, &mut buf)?;
        if buf.len() != n {
            return Err(HssrError::Dimension(format!(
                "store write: column generator produced {} rows for column {j}, expected {n}",
                buf.len()
            )));
        }
        if let Some(i) = buf.iter().position(|v| !v.is_finite()) {
            return Err(HssrError::Config(format!(
                "store write: non-finite value in generated column {j}, row {i}"
            )));
        }
        write_f64s(&mut w, &buf)?;
    }
    write_f64s(&mut w, spec.y)?;
    write_f64s(&mut w, spec.centers)?;
    write_f64s(&mut w, spec.scales)?;
    w.flush()?;
    drop(w);
    append_checksums(&file, &header)?;
    finish_store(header, path)
}

/// Convert an `HSSRBIN1` binary cache (already standardized, column-major)
/// to a store by streaming: the matrix payload is copied in fixed-size
/// buffers, never fully resident.
pub fn convert_bin(src: &Path, chunk_cols: usize, out: &Path) -> Result<StoreSummary> {
    let mut r = std::io::BufReader::new(File::open(src)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != b"HSSRBIN1" {
        return Err(HssrError::Config(format!(
            "{}: not an HSSR binary cache",
            src.display()
        )));
    }
    let mut u = [0u8; 8];
    r.read_exact(&mut u)?;
    let n = u64::from_le_bytes(u) as usize;
    r.read_exact(&mut u)?;
    let p = u64::from_le_bytes(u) as usize;
    if n == 0 || p == 0 {
        return Err(HssrError::Config("binary cache is empty".into()));
    }
    // HSSRBIN layout: y, x, centers, scales. Store layout: x, y, centers,
    // scales — so hold y (length n) and stream everything else.
    let mut ybytes = vec![0u8; n * 8];
    r.read_exact(&mut ybytes)?;
    check_finite_bytes(&ybytes, 0, "binary cache response")?;
    let header = Header {
        n,
        p,
        chunk_cols: chunk_cols.clamp(1, p),
        standardized: true,
        checksums: true,
        f32_shadow: false,
    };
    let file = File::options().read(true).write(true).create(true).truncate(true).open(out)?;
    let mut w = BufWriter::new(&file);
    w.write_all(&header.encode())?;
    let mut remaining = (n * p * 8) as u64;
    let mut done = 0usize;
    let mut buf = vec![0u8; 1 << 20];
    while remaining > 0 {
        let take = (buf.len() as u64).min(remaining) as usize;
        r.read_exact(&mut buf[..take])?;
        check_finite_bytes(&buf[..take], done, "binary cache matrix")?;
        w.write_all(&buf[..take])?;
        remaining -= take as u64;
        done += take / 8;
    }
    w.write_all(&ybytes)?;
    // Stats tail is small (2p values): buffer it so the scales half can be
    // validated — a zero scale marks a constant (zero-variance) column.
    let mut stats = vec![0u8; 2 * p * 8];
    r.read_exact(&mut stats)?;
    check_finite_bytes(&stats, 0, "binary cache column stats")?;
    for (j, c) in stats[p * 8..].chunks_exact(8).enumerate() {
        let mut b = [0u8; 8];
        b.copy_from_slice(c);
        if f64::from_le_bytes(b) == 0.0 {
            return Err(HssrError::Config(format!(
                "{}: feature column {j} has zero variance — drop constant \
                 columns before converting",
                src.display()
            )));
        }
    }
    w.write_all(&stats)?;
    w.flush()?;
    drop(w);
    append_checksums(&file, &header)?;
    finish_store(header, out)
}

/// Per-column Welford accumulator (numerically stable streaming
/// mean/variance — the "streaming standardization" state).
#[derive(Clone, Copy, Default)]
struct Welford {
    count: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    fn push(&mut self, x: f64) {
        self.count += 1;
        let d = x - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Population scale `√(Σ(x−x̄)²/n)`; 0 marks a constant column (the
    /// same `1e-12` threshold as
    /// [`crate::data::standardize::standardize_in_place`]).
    fn scale(&self) -> f64 {
        let sd = (self.m2 / self.count.max(1) as f64).sqrt();
        if sd > 1e-12 {
            sd
        } else {
            0.0
        }
    }
}

/// Convert a CSV file (`y, x1, …, xp` per row, `#` comments and an
/// optional header skipped — the same dialect as
/// [`crate::data::io::load_csv`]) to a store, with streaming
/// standardization. Returns the summary of the written store.
pub fn convert_csv(src: &Path, chunk_cols: usize, out: &Path) -> Result<StoreSummary> {
    // Pass 1: count data rows (and learn the width) without buffering.
    let mut n = 0usize;
    let mut width = 0usize;
    for row in CsvRows::open(src)? {
        let row = row?;
        width = row.len();
        n += 1;
    }
    if n == 0 {
        return Err(HssrError::Config("csv: no data rows".into()));
    }
    if width < 2 {
        return Err(HssrError::Config("csv needs ≥ 2 columns (y + features)".into()));
    }
    let p = width - 1;
    let header = Header {
        n,
        p,
        chunk_cols: chunk_cols.clamp(1, p),
        standardized: false,
        checksums: true,
        f32_shadow: false,
    };

    // Pass 2: stream rows, scattering row blocks to their final
    // column-major offsets while the Welford state accumulates.
    let file = File::options().read(true).write(true).create(true).truncate(true).open(out)?;
    pwrite(&file, &header.encode(), 0)?;
    let block_rows = ((4 << 20) / (p * 8)).clamp(1, n);
    let mut block: Vec<Vec<f64>> = vec![Vec::with_capacity(block_rows); p];
    let mut stats = vec![Welford::default(); p];
    let mut y = Vec::with_capacity(n);
    let mut rows_done = 0usize;
    let mut colbytes = Vec::with_capacity(block_rows * 8);
    let mut flush = |block: &mut Vec<Vec<f64>>, rows_done: usize| -> Result<()> {
        for (j, col) in block.iter_mut().enumerate() {
            if col.is_empty() {
                continue;
            }
            colbytes.clear();
            for v in col.iter() {
                colbytes.extend_from_slice(&v.to_le_bytes());
            }
            let off = HEADER_LEN + ((j * n + rows_done) * 8) as u64;
            pwrite(&file, &colbytes, off)?;
            col.clear();
        }
        Ok(())
    };
    for row in CsvRows::open(src)? {
        let row = row?;
        if row.len() != width {
            return Err(HssrError::Dimension(format!(
                "csv changed width mid-stream ({} vs {width})",
                row.len()
            )));
        }
        if y.len() == n {
            return Err(HssrError::Dimension(
                "csv grew between passes (more rows than counted)".into(),
            ));
        }
        if let Some(j) = row.iter().position(|v| !v.is_finite()) {
            let _ = std::fs::remove_file(out);
            return Err(HssrError::Config(format!(
                "csv row {}: non-finite value ({}) in column {j} — clean the \
                 data before converting",
                y.len() + 1,
                row[j]
            )));
        }
        y.push(row[0]);
        for j in 0..p {
            let v = row[j + 1];
            stats[j].push(v);
            block[j].push(v);
        }
        if block[0].len() == block_rows {
            flush(&mut block, rows_done)?;
            rows_done += block_rows;
        }
    }
    let tail_rows = block[0].len();
    flush(&mut block, rows_done)?;
    rows_done += tail_rows;
    if rows_done != n {
        return Err(HssrError::Dimension(format!(
            "csv shrank between passes ({rows_done} rows vs {n} counted)"
        )));
    }

    // Tail: centered y, then the streaming centers/scales.
    let ybar = y.iter().sum::<f64>() / n as f64;
    for v in y.iter_mut() {
        *v -= ybar;
    }
    let centers: Vec<f64> = stats.iter().map(|s| s.mean).collect();
    let scales: Vec<f64> = stats.iter().map(|s| s.scale()).collect();
    if let Some(j) = scales.iter().position(|&s| s == 0.0) {
        let _ = std::fs::remove_file(out);
        return Err(HssrError::Config(format!(
            "csv feature column {j} has zero variance — a constant column \
             carries no signal and breaks standardization; drop it before \
             converting"
        )));
    }
    let mut tail = Vec::with_capacity((n + 2 * p) * 8);
    for v in y.iter().chain(&centers).chain(&scales) {
        tail.extend_from_slice(&v.to_le_bytes());
    }
    pwrite(&file, &tail, header.tail_offset())?;
    append_checksums(&file, &header)?;
    file.sync_all().ok();
    finish_store(header, out)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hssr_store_writer_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn welford_matches_two_pass() {
        let xs = [3.0, -1.5, 2.25, 0.5, 9.0, -4.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean - mean).abs() < 1e-12);
        assert!((w.scale() - var.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn welford_constant_column_zero_scale() {
        let mut w = Welford::default();
        for _ in 0..10 {
            w.push(7.0);
        }
        assert_eq!(w.scale(), 0.0);
    }

    #[test]
    fn write_matrix_rejects_bad_dims() {
        let x = DenseMatrix::zeros(4, 3);
        let err = write_matrix(
            &x,
            &[0.0; 3], // wrong length
            &[0.0; 3],
            &[1.0; 3],
            true,
            2,
            &tmp("bad.store"),
        );
        assert!(err.is_err());
    }

    #[test]
    fn convert_bin_roundtrips_header() {
        use crate::data::DataSpec;
        let ds = DataSpec::synthetic(12, 7, 2).generate(3);
        let bin = tmp("cb.bin");
        crate::data::io::save_bin(&ds, &bin).unwrap();
        let out = tmp("cb.store");
        let s = convert_bin(&bin, 3, &out).unwrap();
        assert_eq!((s.header.n, s.header.p, s.header.chunk_cols), (12, 7, 3));
        assert!(s.header.standardized);
        assert!(s.header.checksums, "writers must produce v2 stores");
        assert_eq!(std::fs::metadata(&out).unwrap().len(), s.file_bytes);
    }

    /// The appended checksum section holds the real CRC32 of each chunk
    /// payload and of the tail, byte for byte.
    #[test]
    fn checksum_section_matches_payload() {
        use crate::data::DataSpec;
        let ds = DataSpec::synthetic(9, 10, 2).generate(11);
        let path = tmp("crc.store");
        let s = write_dataset(&ds, 4, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let h = s.header;
        assert_eq!(bytes.len() as u64, h.file_len());
        let mut off = h.checksum_offset() as usize;
        for c in 0..h.num_chunks() {
            let start = h.chunk_offset(c) as usize;
            let want = crc32(&bytes[start..start + h.chunk_bytes(c)]);
            let got = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            assert_eq!(got, want, "chunk {c} CRC mismatch");
            off += 4;
        }
        let tail_start = h.tail_offset() as usize;
        let want = crc32(&bytes[tail_start..tail_start + h.tail_bytes()]);
        let got = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
        assert_eq!(got, want, "tail CRC mismatch");
    }

    /// `append_f32_shadow` writes exactly `value as f32` per entry in the
    /// chunk framing, CRCs each shadow chunk, flips the flag byte, and is
    /// idempotent.
    #[test]
    fn f32_shadow_holds_cast_values() {
        use crate::data::DataSpec;
        let ds = DataSpec::synthetic(9, 10, 2).generate(17);
        let path = tmp("shadow.store");
        let s = write_dataset(&ds, 4, &path).unwrap();
        assert!(!s.header.f32_shadow);
        let h = append_f32_shadow(&path).unwrap();
        assert!(h.f32_shadow);
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len() as u64, h.file_len());
        assert_eq!(bytes[10], 1, "flag byte not patched");
        // Shadow values are the standardized design cast to f32, column
        // by column in the same chunk framing.
        for j in 0..10usize {
            let c = j / 4;
            let local = j - c * 4;
            let off = h.shadow_chunk_offset(c) as usize + local * 9 * 4;
            for (i, &want) in ds.x.col(j).iter().enumerate() {
                let got = f32::from_le_bytes(
                    bytes[off + i * 4..off + i * 4 + 4].try_into().unwrap(),
                );
                assert_eq!(got, want as f32, "shadow value drifted at ({i}, {j})");
            }
        }
        // Shadow CRCs cover the shadow payloads.
        let mut crc_off = h.shadow_crc_offset() as usize;
        for c in 0..h.num_chunks() {
            let start = h.shadow_chunk_offset(c) as usize;
            let want = crc32(&bytes[start..start + h.shadow_chunk_bytes(c)]);
            let got = u32::from_le_bytes(bytes[crc_off..crc_off + 4].try_into().unwrap());
            assert_eq!(got, want, "shadow chunk {c} CRC mismatch");
            crc_off += 4;
        }
        // Idempotent: a second append changes nothing.
        let again = append_f32_shadow(&path).unwrap();
        assert_eq!(again, h);
        assert_eq!(std::fs::read(&path).unwrap(), bytes);
    }

    /// A `write_columns` spill of the same data is byte-identical to the
    /// `write_matrix` spill — the streamed layout is the same format.
    #[test]
    fn write_columns_matches_write_matrix_bytes() {
        use crate::data::DataSpec;
        let ds = DataSpec::synthetic(11, 9, 2).generate(13);
        let a = tmp("wc_a.store");
        write_dataset(&ds, 4, &a).unwrap();
        let b = tmp("wc_b.store");
        let spec = ColumnSpill {
            n: 11,
            p: 9,
            y: &ds.y,
            centers: &ds.centers,
            scales: &ds.scales,
            standardized: true,
            chunk_cols: 4,
        };
        write_columns(
            &spec,
            |j, buf| {
                buf.extend_from_slice(ds.x.col(j));
                Ok(())
            },
            &b,
        )
        .unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
    }

    /// Generator misbehavior — wrong column length, non-finite values —
    /// surfaces typed, and generator errors pass through.
    #[test]
    fn write_columns_rejects_bad_generators() {
        let spec = ColumnSpill {
            n: 4,
            p: 2,
            y: &[0.0; 4],
            centers: &[0.0; 2],
            scales: &[1.0; 2],
            standardized: true,
            chunk_cols: 2,
        };
        let err = write_columns(
            &spec,
            |_, buf| {
                buf.extend_from_slice(&[1.0; 3]); // short column
                Ok(())
            },
            &tmp("wc_short.store"),
        )
        .unwrap_err();
        assert!(err.to_string().contains("expected 4"), "got {err}");
        let err = write_columns(
            &spec,
            |_, buf| {
                buf.extend_from_slice(&[1.0, f64::NAN, 0.0, 0.0]);
                Ok(())
            },
            &tmp("wc_nan.store"),
        )
        .unwrap_err();
        assert!(err.to_string().contains("non-finite"), "got {err}");
        let err = write_columns(
            &spec,
            |_, _| Err(HssrError::Config("generator failed".into())),
            &tmp("wc_gen.store"),
        )
        .unwrap_err();
        assert!(err.to_string().contains("generator failed"), "got {err}");
    }

    #[test]
    fn non_finite_values_rejected() {
        let mut data = vec![0.5; 12];
        data[7] = f64::NAN;
        let x = DenseMatrix::from_col_major(4, 3, data).unwrap();
        let err = write_matrix(&x, &[0.0; 4], &[0.0; 3], &[1.0; 3], true, 2, &tmp("nan.store"))
            .unwrap_err();
        assert!(err.to_string().contains("non-finite"), "got {err}");
        let x = DenseMatrix::from_col_major(4, 3, vec![0.5; 12]).unwrap();
        let err = write_matrix(
            &x,
            &[0.0, f64::INFINITY, 0.0, 0.0],
            &[0.0; 3],
            &[1.0; 3],
            true,
            2,
            &tmp("inf.store"),
        )
        .unwrap_err();
        assert!(err.to_string().contains("non-finite"), "got {err}");
    }

    #[test]
    fn convert_csv_rejects_bad_columns() {
        // constant feature column → zero variance → typed rejection
        let csv = tmp("zv.csv");
        std::fs::write(&csv, "1.0,2.0,7.5\n-1.0,3.5,7.5\n0.5,1.25,7.5\n").unwrap();
        let err = convert_csv(&csv, 2, &tmp("zv.store")).unwrap_err();
        assert!(err.to_string().contains("zero variance"), "got {err}");
        assert!(!tmp("zv.store").exists(), "rejected store must not linger");
        // non-finite value → typed rejection naming the spot
        let csv = tmp("nf.csv");
        std::fs::write(&csv, "1.0,2.0,3.0\n-1.0,nan,1.0\n").unwrap();
        let err = convert_csv(&csv, 2, &tmp("nf.store")).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "got {err}");
    }
}
