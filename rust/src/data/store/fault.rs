//! Deterministic fault injection between [`ColumnStore`] and the
//! filesystem.
//!
//! The store's retry/checksum machinery is only trustworthy if it can be
//! *proven* to mask faults without changing results. [`FaultInjector`]
//! sits in the chunk-read path and, driven by a seeded hash of
//! `(file offset, attempt)`, injects the three storage failure modes the
//! retry policy must absorb:
//!
//! * **transient read errors** — the read fails with `Interrupted`,
//! * **short reads** — the read fails with `UnexpectedEof`,
//! * **bit flips** — one bit of the returned buffer is corrupted (only
//!   exercised on checksummed stores, where CRC verification converts the
//!   flip into a retried checksum failure instead of silent corruption).
//!
//! Decisions are pure functions of `(seed, offset, attempt)`, so a given
//! spec replays identically, and **no fault is ever injected at attempt
//! [`FaultInjector::MAX_FAULT_ATTEMPTS`] or later** — within the store's
//! retry budget every read deterministically succeeds, which is what lets
//! the property tests assert bit-identical fits under injection
//! (`tests/fault_tolerance.rs`).
//!
//! Activation: `HSSR_FAULTS="seed=42,transient=0.1,short=0.05,flip=0.02"`
//! in the environment (picked up by every [`ColumnStore::open`], which is
//! how CI runs the whole suite under injected faults), or the CLI's
//! `--faults <spec>` flag, or [`ColumnStore::set_faults`] from tests.
//!
//! [`ColumnStore`]: super::reader::ColumnStore
//! [`ColumnStore::open`]: super::reader::ColumnStore::open
//! [`ColumnStore::set_faults`]: super::reader::ColumnStore::set_faults

use crate::error::{HssrError, Result};
use crate::rng::splitmix64;

/// Parsed fault-injection parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Seed for the deterministic fault stream.
    pub seed: u64,
    /// Probability of a transient (`Interrupted`) read error per attempt.
    pub transient: f64,
    /// Probability of a short read (`UnexpectedEof`) per attempt.
    pub short: f64,
    /// Probability of a single bit flip in the returned buffer.
    pub flip: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec { seed: 0, transient: 0.0, short: 0.0, flip: 0.0 }
    }
}

impl FaultSpec {
    /// Parse a `key=value` comma list, e.g.
    /// `"seed=42,transient=0.1,short=0.05,flip=0.02"`. Unknown keys and
    /// out-of-range rates are typed errors — a mistyped spec must not
    /// silently disable injection.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                HssrError::Config(format!("fault spec '{part}': expected key=value"))
            })?;
            let rate = |v: &str| -> Result<f64> {
                let r: f64 = v.parse().map_err(|_| {
                    HssrError::Config(format!("fault spec: bad rate '{v}'"))
                })?;
                if !(0.0..=1.0).contains(&r) {
                    return Err(HssrError::Config(format!(
                        "fault spec: rate {r} outside [0, 1]"
                    )));
                }
                Ok(r)
            };
            match key.trim() {
                "seed" => {
                    spec.seed = val.trim().parse().map_err(|_| {
                        HssrError::Config(format!("fault spec: bad seed '{val}'"))
                    })?;
                }
                "transient" => spec.transient = rate(val.trim())?,
                "short" => spec.short = rate(val.trim())?,
                "flip" => spec.flip = rate(val.trim())?,
                other => {
                    return Err(HssrError::Config(format!(
                        "fault spec: unknown key '{other}' \
                         (expected seed/transient/short/flip)"
                    )))
                }
            }
        }
        Ok(spec)
    }

    /// Whether any fault mode has a non-zero rate.
    pub fn is_active(&self) -> bool {
        self.transient > 0.0 || self.short > 0.0 || self.flip > 0.0
    }
}

/// The outcome of one injection decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Fail the read with `io::ErrorKind::Interrupted`.
    Transient,
    /// Fail the read with `io::ErrorKind::UnexpectedEof`.
    ShortRead,
    /// Flip the given bit of the read buffer (byte index, bit index).
    BitFlip(usize, u8),
    /// Let the read through untouched.
    None,
}

/// Deterministic fault source keyed by `(seed, offset, attempt)`.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    spec: FaultSpec,
}

impl FaultInjector {
    /// Attempts `>= MAX_FAULT_ATTEMPTS` are never faulted, guaranteeing
    /// deterministic success within any retry budget above it.
    pub const MAX_FAULT_ATTEMPTS: u32 = 3;

    /// Build an injector from a parsed spec.
    pub fn new(spec: FaultSpec) -> FaultInjector {
        FaultInjector { spec }
    }

    /// Build from the `HSSR_FAULTS` environment variable: `Ok(None)` when
    /// unset or inactive, a typed error when set but malformed.
    pub fn from_env() -> Result<Option<FaultInjector>> {
        match std::env::var("HSSR_FAULTS") {
            Ok(s) if !s.trim().is_empty() => {
                let spec = FaultSpec::parse(&s)?;
                Ok(spec.is_active().then(|| FaultInjector::new(spec)))
            }
            _ => Ok(None),
        }
    }

    /// The spec this injector replays.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Decide the fault for a read of `len` bytes at file `offset`, on
    /// retry `attempt` (0-based). `flip_ok` gates bit flips to reads whose
    /// consumer verifies a checksum — flipping an unverified read would
    /// silently corrupt data, the exact failure the layer exists to stop.
    pub fn decide(&self, offset: u64, attempt: u32, len: usize, flip_ok: bool) -> Fault {
        if attempt >= Self::MAX_FAULT_ATTEMPTS || len == 0 {
            return Fault::None;
        }
        let base = splitmix64(
            self.spec.seed ^ splitmix64(offset) ^ splitmix64(0x9E37_79B9 + attempt as u64),
        );
        let unit = |h: u64| (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let d1 = splitmix64(base);
        if unit(d1) < self.spec.transient {
            return Fault::Transient;
        }
        let d2 = splitmix64(d1);
        if unit(d2) < self.spec.short {
            return Fault::ShortRead;
        }
        let d3 = splitmix64(d2);
        if flip_ok && unit(d3) < self.spec.flip {
            let d4 = splitmix64(d3);
            let byte = (d4 % len as u64) as usize;
            let bit = (splitmix64(d4) % 8) as u8;
            return Fault::BitFlip(byte, bit);
        }
        Fault::None
    }

    /// Apply the decision to a completed read: error faults become
    /// `io::Error`s (as if the filesystem had failed), bit flips mutate
    /// the buffer in place.
    pub fn inject(
        &self,
        offset: u64,
        attempt: u32,
        buf: &mut [u8],
        flip_ok: bool,
    ) -> std::io::Result<()> {
        match self.decide(offset, attempt, buf.len(), flip_ok) {
            Fault::Transient => Err(std::io::Error::new(
                std::io::ErrorKind::Interrupted,
                format!("injected transient fault at offset {offset}, attempt {attempt}"),
            )),
            Fault::ShortRead => Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("injected short read at offset {offset}, attempt {attempt}"),
            )),
            Fault::BitFlip(byte, bit) => {
                buf[byte] ^= 1 << bit;
                Ok(())
            }
            Fault::None => Ok(()),
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        let s = FaultSpec::parse("seed=42, transient=0.1, short=0.05, flip=0.02").unwrap();
        assert_eq!(
            s,
            FaultSpec { seed: 42, transient: 0.1, short: 0.05, flip: 0.02 }
        );
        assert!(s.is_active());
        assert!(!FaultSpec::parse("seed=7").unwrap().is_active());
        assert!(FaultSpec::parse("transient=1.5").is_err());
        assert!(FaultSpec::parse("bogus=1").is_err());
        assert!(FaultSpec::parse("transient").is_err());
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
    }

    /// Decisions are pure: the same (seed, offset, attempt) always yields
    /// the same fault, and different seeds yield different streams.
    #[test]
    fn decisions_are_deterministic() {
        let spec = FaultSpec { seed: 9, transient: 0.3, short: 0.2, flip: 0.2 };
        let inj = FaultInjector::new(spec);
        for offset in [0u64, 40, 4096, 1 << 30] {
            for attempt in 0..3 {
                let a = inj.decide(offset, attempt, 512, true);
                let b = inj.decide(offset, attempt, 512, true);
                assert_eq!(a, b);
            }
        }
        let other = FaultInjector::new(FaultSpec { seed: 10, ..spec });
        let differs = (0..200u64)
            .any(|o| inj.decide(o * 64, 0, 512, true) != other.decide(o * 64, 0, 512, true));
        assert!(differs, "seeds 9 and 10 produced identical fault streams");
    }

    /// The retry-budget guarantee: attempts at or past the cutoff are
    /// never faulted, even at rate 1.0.
    #[test]
    fn attempts_past_cutoff_always_succeed() {
        let inj = FaultInjector::new(FaultSpec {
            seed: 1,
            transient: 1.0,
            short: 1.0,
            flip: 1.0,
        });
        for offset in (0..100u64).map(|i| i * 123) {
            assert_eq!(inj.decide(offset, 0, 64, true), Fault::Transient);
            assert_eq!(
                inj.decide(offset, FaultInjector::MAX_FAULT_ATTEMPTS, 64, true),
                Fault::None
            );
            assert_eq!(inj.decide(offset, 7, 64, true), Fault::None);
        }
    }

    /// At realistic rates every fault mode actually fires somewhere.
    #[test]
    fn all_modes_reachable() {
        let inj = FaultInjector::new(FaultSpec {
            seed: 3,
            transient: 0.2,
            short: 0.2,
            flip: 0.2,
        });
        let mut seen = (false, false, false);
        for offset in (0..500u64).map(|i| i * 57) {
            match inj.decide(offset, 0, 256, true) {
                Fault::Transient => seen.0 = true,
                Fault::ShortRead => seen.1 = true,
                Fault::BitFlip(b, bit) => {
                    assert!(b < 256 && bit < 8);
                    seen.2 = true;
                }
                Fault::None => {}
            }
        }
        assert!(seen.0 && seen.1 && seen.2, "modes seen: {seen:?}");
    }

    /// Bit flips are suppressed on reads with no checksum backstop.
    #[test]
    fn flips_gated_on_verification() {
        let inj =
            FaultInjector::new(FaultSpec { seed: 5, transient: 0.0, short: 0.0, flip: 1.0 });
        assert!(matches!(inj.decide(0, 0, 64, true), Fault::BitFlip(..)));
        assert_eq!(inj.decide(0, 0, 64, false), Fault::None);
    }

    #[test]
    fn inject_mutates_buffer_on_flip() {
        let inj =
            FaultInjector::new(FaultSpec { seed: 5, transient: 0.0, short: 0.0, flip: 1.0 });
        let clean = vec![0u8; 64];
        let mut buf = clean.clone();
        inj.inject(0, 0, &mut buf, true).unwrap();
        let flipped: usize = clean
            .iter()
            .zip(&buf)
            .map(|(a, b)| (a ^ b).count_ones() as usize)
            .sum();
        assert_eq!(flipped, 1, "exactly one bit must flip");
    }
}
