//! The `HSSRSTOR1` on-disk layout: header encode/decode and offset math.
//!
//! ```text
//! offset 0   magic  b"HSSRSTOR1"                      (9 bytes)
//! offset 9   standardized flag: 1 ⇒ the chunk data is already in paper
//!            condition (2) and the per-column stats are informational;
//!            0 ⇒ the chunk data is raw and the reader applies
//!            (x − center)/scale per column on load   (1 byte)
//! offset 10  reserved (zero)                          (6 bytes)
//! offset 16  n  (rows)        u64 LE
//! offset 24  p  (columns)     u64 LE
//! offset 32  chunk_cols       u64 LE
//! offset 40  chunk data: the n×p matrix, column-major, grouped into
//!            ⌈p/chunk_cols⌉ fixed-size chunks (every chunk holds
//!            chunk_cols columns except a possibly-short tail), so
//!            chunk c starts at 40 + c·chunk_cols·n·8 and column j
//!            starts at 40 + j·n·8
//! …          y        (n × f64 LE, centered)
//! …          centers  (p × f64 LE)
//! …          scales   (p × f64 LE; 0 marks a constant column)
//! ```
//!
//! All offsets are computable from `(n, p, chunk_cols)` alone, which is
//! what lets the reader serve any column slice with one `seek`/`read`.

use crate::error::{HssrError, Result};

/// Store magic: format name + version in one token.
pub const MAGIC: &[u8; 9] = b"HSSRSTOR1";

/// Fixed header length in bytes (magic + flag + reserved + three u64s).
pub const HEADER_LEN: u64 = 40;

/// Decoded fixed header of a store file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Header {
    /// Rows (observations).
    pub n: usize,
    /// Columns (features).
    pub p: usize,
    /// Columns per chunk (the fetch granularity).
    pub chunk_cols: usize,
    /// Whether the chunk data is pre-standardized (see module docs).
    pub standardized: bool,
}

impl Header {
    /// Number of chunks covering the `p` columns.
    pub fn num_chunks(&self) -> usize {
        self.p.div_ceil(self.chunk_cols.max(1))
    }

    /// Column width of chunk `c` (the tail chunk may be short).
    pub fn chunk_width(&self, c: usize) -> usize {
        debug_assert!(c < self.num_chunks());
        self.chunk_cols.min(self.p - c * self.chunk_cols)
    }

    /// Payload bytes of chunk `c`.
    pub fn chunk_bytes(&self, c: usize) -> usize {
        self.chunk_width(c) * self.n * 8
    }

    /// Byte offset of chunk `c`'s payload.
    pub fn chunk_offset(&self, c: usize) -> u64 {
        HEADER_LEN + (c * self.chunk_cols * self.n * 8) as u64
    }

    /// Byte offset of the tail (`y`, then `centers`, then `scales`).
    pub fn tail_offset(&self) -> u64 {
        HEADER_LEN + (self.n * self.p * 8) as u64
    }

    /// Total file size implied by the header.
    pub fn file_len(&self) -> u64 {
        self.tail_offset() + ((self.n + 2 * self.p) * 8) as u64
    }

    /// [`Header::file_len`] with overflow-checked arithmetic — `None`
    /// means the header's dimensions cannot describe a real file (a
    /// corrupt or crafted header whose size math would wrap), so readers
    /// can reject it instead of attempting an absurd allocation.
    pub fn checked_file_len(&self) -> Option<u64> {
        let n = self.n as u64;
        let p = self.p as u64;
        let matrix = n.checked_mul(p)?.checked_mul(8)?;
        let tail = n.checked_add(p.checked_mul(2)?)?.checked_mul(8)?;
        HEADER_LEN.checked_add(matrix)?.checked_add(tail)
    }

    /// Matrix footprint in bytes (`n·p·8`) — what "larger than the cache
    /// budget" is measured against.
    pub fn matrix_bytes(&self) -> u64 {
        (self.n * self.p * 8) as u64
    }

    /// Encode the fixed header.
    pub fn encode(&self) -> [u8; HEADER_LEN as usize] {
        let mut buf = [0u8; HEADER_LEN as usize];
        buf[..9].copy_from_slice(MAGIC);
        buf[9] = self.standardized as u8;
        buf[16..24].copy_from_slice(&(self.n as u64).to_le_bytes());
        buf[24..32].copy_from_slice(&(self.p as u64).to_le_bytes());
        buf[32..40].copy_from_slice(&(self.chunk_cols as u64).to_le_bytes());
        buf
    }

    /// Decode and validate a fixed header.
    pub fn decode(buf: &[u8; HEADER_LEN as usize]) -> Result<Header> {
        if &buf[..9] != MAGIC {
            return Err(HssrError::Config(
                "not an HSSRSTOR1 column store (bad magic)".into(),
            ));
        }
        let u = |off: usize| {
            u64::from_le_bytes(buf[off..off + 8].try_into().unwrap()) as usize
        };
        let h = Header {
            n: u(16),
            p: u(24),
            chunk_cols: u(32),
            standardized: buf[9] != 0,
        };
        if h.n == 0 || h.p == 0 || h.chunk_cols == 0 {
            return Err(HssrError::Config(format!(
                "store header is degenerate (n={}, p={}, chunk_cols={})",
                h.n, h.p, h.chunk_cols
            )));
        }
        Ok(h)
    }
}

/// Pick a chunk width for a store of `n`-row columns targeting roughly
/// `target_bytes` per chunk (at least one column, at most all `p`).
pub fn chunk_cols_for(n: usize, p: usize, target_bytes: usize) -> usize {
    (target_bytes / (n.max(1) * 8)).clamp(1, p.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = Header { n: 17, p: 103, chunk_cols: 16, standardized: true };
        let back = Header::decode(&h.encode()).unwrap();
        assert_eq!(h, back);
        assert_eq!(back.num_chunks(), 7);
        assert_eq!(back.chunk_width(6), 103 - 6 * 16);
        assert_eq!(back.chunk_offset(0), HEADER_LEN);
        assert_eq!(back.chunk_offset(2), HEADER_LEN + (2 * 16 * 17 * 8) as u64);
        assert_eq!(back.tail_offset(), HEADER_LEN + (17 * 103 * 8) as u64);
        assert_eq!(
            back.file_len(),
            back.tail_offset() + ((17 + 2 * 103) * 8) as u64
        );
    }

    #[test]
    fn bad_headers_rejected() {
        let h = Header { n: 3, p: 4, chunk_cols: 2, standardized: false };
        let mut buf = h.encode();
        buf[0] = b'X';
        assert!(Header::decode(&buf).is_err());
        let degenerate = Header { n: 0, p: 4, chunk_cols: 2, standardized: false };
        assert!(Header::decode(&degenerate.encode()).is_err());
    }

    #[test]
    fn checked_len_rejects_wrapping_headers() {
        let ok = Header { n: 17, p: 103, chunk_cols: 16, standardized: false };
        assert_eq!(ok.checked_file_len(), Some(ok.file_len()));
        let huge =
            Header { n: 1 << 61, p: 4, chunk_cols: 1, standardized: false };
        assert_eq!(huge.checked_file_len(), None);
    }

    #[test]
    fn chunk_sizing() {
        assert_eq!(chunk_cols_for(100, 1000, 256 * 1024), 327);
        assert_eq!(chunk_cols_for(1_000_000, 10, 1024), 1);
        assert_eq!(chunk_cols_for(10, 5, 1 << 20), 5);
    }
}
