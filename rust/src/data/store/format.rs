//! The `HSSRSTOR` on-disk layout: header encode/decode and offset math.
//!
//! ```text
//! offset 0   magic  b"HSSRSTOR1" | b"HSSRSTOR2"       (9 bytes)
//! offset 9   standardized flag: 1 ⇒ the chunk data is already in paper
//!            condition (2) and the per-column stats are informational;
//!            0 ⇒ the chunk data is raw and the reader applies
//!            (x − center)/scale per column on load   (1 byte)
//! offset 10  f32-shadow flag: 1 ⇒ an f32 shadow section follows the
//!            checksum section (see below)             (1 byte)
//! offset 11  reserved (zero)                          (5 bytes)
//! offset 16  n  (rows)        u64 LE
//! offset 24  p  (columns)     u64 LE
//! offset 32  chunk_cols       u64 LE
//! offset 40  chunk data: the n×p matrix, column-major, grouped into
//!            ⌈p/chunk_cols⌉ fixed-size chunks (every chunk holds
//!            chunk_cols columns except a possibly-short tail), so
//!            chunk c starts at 40 + c·chunk_cols·n·8 and column j
//!            starts at 40 + j·n·8
//! …          y        (n × f64 LE, centered)
//! …          centers  (p × f64 LE)
//! …          scales   (p × f64 LE; 0 marks a constant column)
//! …          [v2 only] checksum section: one CRC32 (u32 LE) per chunk
//!            in order, then one CRC32 of the whole tail
//!            (y ‖ centers ‖ scales) — (num_chunks + 1) × 4 bytes
//! …          [f32 shadow, when byte 10 = 1] the **standardized** matrix
//!            re-cast to f32 LE in the same chunk framing
//!            (chunk c holds chunk_width(c)·n f32 values), followed by
//!            one CRC32 (u32 LE) per shadow chunk — n·p·4 + num_chunks·4
//!            bytes total. The shadow holds exactly
//!            `standardized_value as f32` per entry, so a shadow scan is
//!            bit-identical to casting the served f64 columns.
//! ```
//!
//! Version 2 (`HSSRSTOR2`) appends the checksum section and is what the
//! writers now produce; version-1 files remain fully readable (the reader
//! simply has no integrity data to verify against). The optional f32
//! shadow section (`HSSR_STORE_F32=1`, or
//! [`super::writer::append_f32_shadow`] post hoc) feeds mixed-precision
//! *screening* scans — it is advisory data the flag byte gates, so every
//! pre-shadow reader keeps working and a crash mid-append (flag still 0)
//! leaves a valid shadow-less store. All offsets are computable from
//! `(n, p, chunk_cols)` alone, which is what lets the reader serve any
//! column slice with one `seek`/`read`.

use crate::error::{HssrError, Result};

/// Version-1 store magic (no checksum section).
pub const MAGIC: &[u8; 9] = b"HSSRSTOR1";

/// Version-2 store magic: layout of v1 plus the trailing CRC32 section.
pub const MAGIC2: &[u8; 9] = b"HSSRSTOR2";

/// Fixed header length in bytes (magic + flag + reserved + three u64s).
pub const HEADER_LEN: u64 = 40;

/// Decoded fixed header of a store file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Header {
    /// Rows (observations).
    pub n: usize,
    /// Columns (features).
    pub p: usize,
    /// Columns per chunk (the fetch granularity).
    pub chunk_cols: usize,
    /// Whether the chunk data is pre-standardized (see module docs).
    pub standardized: bool,
    /// Whether the file carries the v2 trailing checksum section.
    pub checksums: bool,
    /// Whether the file carries the trailing f32 shadow section (the
    /// standardized matrix re-cast to f32, plus per-shadow-chunk CRC32s).
    pub f32_shadow: bool,
}

impl Header {
    /// Number of chunks covering the `p` columns.
    pub fn num_chunks(&self) -> usize {
        self.p.div_ceil(self.chunk_cols.max(1))
    }

    /// Column width of chunk `c` (the tail chunk may be short).
    pub fn chunk_width(&self, c: usize) -> usize {
        debug_assert!(c < self.num_chunks());
        self.chunk_cols.min(self.p - c * self.chunk_cols)
    }

    /// Payload bytes of chunk `c`.
    pub fn chunk_bytes(&self, c: usize) -> usize {
        self.chunk_width(c) * self.n * 8
    }

    /// Byte offset of chunk `c`'s payload.
    pub fn chunk_offset(&self, c: usize) -> u64 {
        HEADER_LEN + (c * self.chunk_cols * self.n * 8) as u64
    }

    /// Byte offset of the tail (`y`, then `centers`, then `scales`).
    pub fn tail_offset(&self) -> u64 {
        HEADER_LEN + (self.n * self.p * 8) as u64
    }

    /// Tail section size in bytes (`y` + `centers` + `scales`).
    pub fn tail_bytes(&self) -> usize {
        (self.n + 2 * self.p) * 8
    }

    /// Byte offset of the v2 checksum section (= the v1 end of file).
    pub fn checksum_offset(&self) -> u64 {
        self.tail_offset() + self.tail_bytes() as u64
    }

    /// Size of the v2 checksum section: one CRC32 per chunk + one for the
    /// tail. Zero for v1 files.
    pub fn checksum_bytes(&self) -> u64 {
        if self.checksums { 4 * (self.num_chunks() as u64 + 1) } else { 0 }
    }

    /// Byte offset of the f32 shadow section (right after the checksum
    /// section; meaningful only when [`Header::f32_shadow`] is set).
    pub fn shadow_offset(&self) -> u64 {
        self.checksum_offset() + self.checksum_bytes()
    }

    /// Byte offset of shadow chunk `c`'s f32 payload.
    pub fn shadow_chunk_offset(&self, c: usize) -> u64 {
        self.shadow_offset() + (c * self.chunk_cols * self.n * 4) as u64
    }

    /// Payload bytes of shadow chunk `c` (f32 values).
    pub fn shadow_chunk_bytes(&self, c: usize) -> usize {
        self.chunk_width(c) * self.n * 4
    }

    /// Byte offset of the shadow CRC section (one CRC32 per shadow
    /// chunk, after all shadow payloads).
    pub fn shadow_crc_offset(&self) -> u64 {
        self.shadow_offset() + (self.n * self.p * 4) as u64
    }

    /// Size of the whole f32 shadow section (payloads + CRCs); zero when
    /// the store carries no shadow.
    pub fn shadow_bytes(&self) -> u64 {
        if self.f32_shadow {
            (self.n * self.p * 4 + 4 * self.num_chunks()) as u64
        } else {
            0
        }
    }

    /// Total file size implied by the header.
    pub fn file_len(&self) -> u64 {
        self.checksum_offset() + self.checksum_bytes() + self.shadow_bytes()
    }

    /// [`Header::file_len`] with overflow-checked arithmetic — `None`
    /// means the header's dimensions cannot describe a real file (a
    /// corrupt or crafted header whose size math would wrap), so readers
    /// can reject it instead of attempting an absurd allocation.
    pub fn checked_file_len(&self) -> Option<u64> {
        let n = self.n as u64;
        let p = self.p as u64;
        let matrix = n.checked_mul(p)?.checked_mul(8)?;
        let tail = n.checked_add(p.checked_mul(2)?)?.checked_mul(8)?;
        let base = HEADER_LEN.checked_add(matrix)?.checked_add(tail)?;
        let chunks = p.div_ceil(self.chunk_cols.max(1) as u64);
        let with_crcs = if self.checksums {
            base.checked_add(chunks.checked_add(1)?.checked_mul(4)?)?
        } else {
            base
        };
        if !self.f32_shadow {
            return Some(with_crcs);
        }
        let shadow = n.checked_mul(p)?.checked_mul(4)?.checked_add(chunks.checked_mul(4)?)?;
        with_crcs.checked_add(shadow)
    }

    /// Matrix footprint in bytes (`n·p·8`) — what "larger than the cache
    /// budget" is measured against.
    pub fn matrix_bytes(&self) -> u64 {
        (self.n * self.p * 8) as u64
    }

    /// Encode the fixed header (the magic carries the version).
    pub fn encode(&self) -> [u8; HEADER_LEN as usize] {
        let mut buf = [0u8; HEADER_LEN as usize];
        buf[..9].copy_from_slice(if self.checksums { MAGIC2 } else { MAGIC });
        buf[9] = self.standardized as u8;
        buf[10] = self.f32_shadow as u8;
        buf[16..24].copy_from_slice(&(self.n as u64).to_le_bytes());
        buf[24..32].copy_from_slice(&(self.p as u64).to_le_bytes());
        buf[32..40].copy_from_slice(&(self.chunk_cols as u64).to_le_bytes());
        buf
    }

    /// Decode and validate a fixed header (either version).
    pub fn decode(buf: &[u8; HEADER_LEN as usize]) -> Result<Header> {
        let checksums = match &buf[..9] {
            m if m == MAGIC => false,
            m if m == MAGIC2 => true,
            _ => {
                return Err(HssrError::Config(
                    "not an HSSRSTOR column store (bad magic)".into(),
                ))
            }
        };
        let u = |off: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&buf[off..off + 8]);
            u64::from_le_bytes(b) as usize
        };
        let h = Header {
            n: u(16),
            p: u(24),
            chunk_cols: u(32),
            standardized: buf[9] != 0,
            checksums,
            f32_shadow: buf[10] != 0,
        };
        if h.n == 0 || h.p == 0 || h.chunk_cols == 0 {
            return Err(HssrError::Config(format!(
                "store header is degenerate (n={}, p={}, chunk_cols={})",
                h.n, h.p, h.chunk_cols
            )));
        }
        Ok(h)
    }
}

/// Pick a chunk width for a store of `n`-row columns targeting roughly
/// `target_bytes` per chunk (at least one column, at most all `p`).
pub fn chunk_cols_for(n: usize, p: usize, target_bytes: usize) -> usize {
    (target_bytes / (n.max(1) * 8)).clamp(1, p.max(1))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = Header {
            n: 17,
            p: 103,
            chunk_cols: 16,
            standardized: true,
            checksums: true,
            f32_shadow: false,
        };
        let back = Header::decode(&h.encode()).unwrap();
        assert_eq!(h, back);
        assert_eq!(back.num_chunks(), 7);
        assert_eq!(back.chunk_width(6), 103 - 6 * 16);
        assert_eq!(back.chunk_offset(0), HEADER_LEN);
        assert_eq!(back.chunk_offset(2), HEADER_LEN + (2 * 16 * 17 * 8) as u64);
        assert_eq!(back.tail_offset(), HEADER_LEN + (17 * 103 * 8) as u64);
        assert_eq!(
            back.file_len(),
            back.tail_offset() + ((17 + 2 * 103) * 8) as u64 + 4 * 8
        );
    }

    /// Version-1 headers decode with `checksums: false` and keep the old
    /// file-length math — existing stores stay readable byte for byte.
    #[test]
    fn v1_header_still_readable() {
        let h = Header {
            n: 17,
            p: 103,
            chunk_cols: 16,
            standardized: true,
            checksums: false,
            f32_shadow: false,
        };
        let enc = h.encode();
        assert_eq!(&enc[..9], MAGIC);
        let back = Header::decode(&enc).unwrap();
        assert_eq!(back, h);
        assert_eq!(back.checksum_bytes(), 0);
        assert_eq!(back.file_len(), back.tail_offset() + ((17 + 2 * 103) * 8) as u64);
        assert_eq!(back.checksum_offset(), back.file_len());
    }

    #[test]
    fn bad_headers_rejected() {
        let h = Header {
            n: 3,
            p: 4,
            chunk_cols: 2,
            standardized: false,
            checksums: true,
            f32_shadow: false,
        };
        let mut buf = h.encode();
        buf[0] = b'X';
        assert!(Header::decode(&buf).is_err());
        let degenerate = Header {
            n: 0,
            p: 4,
            chunk_cols: 2,
            standardized: false,
            checksums: true,
            f32_shadow: false,
        };
        assert!(Header::decode(&degenerate.encode()).is_err());
    }

    #[test]
    fn checked_len_rejects_wrapping_headers() {
        for checksums in [false, true] {
            for f32_shadow in [false, true] {
                let ok = Header {
                    n: 17,
                    p: 103,
                    chunk_cols: 16,
                    standardized: false,
                    checksums,
                    f32_shadow,
                };
                assert_eq!(ok.checked_file_len(), Some(ok.file_len()));
                let huge = Header {
                    n: 1 << 61,
                    p: 4,
                    chunk_cols: 1,
                    standardized: false,
                    checksums,
                    f32_shadow,
                };
                assert_eq!(huge.checked_file_len(), None);
            }
        }
    }

    /// Shadow offset math: payloads in the same chunk framing (4 bytes
    /// per value), then one CRC per shadow chunk; the flag round-trips
    /// through byte 10 and extends the implied file length.
    #[test]
    fn shadow_section_math() {
        let h = Header {
            n: 17,
            p: 103,
            chunk_cols: 16,
            standardized: true,
            checksums: true,
            f32_shadow: true,
        };
        let back = Header::decode(&h.encode()).unwrap();
        assert_eq!(back, h);
        assert!(back.f32_shadow);
        let base = h.checksum_offset() + h.checksum_bytes();
        assert_eq!(h.shadow_offset(), base);
        assert_eq!(h.shadow_chunk_offset(0), base);
        assert_eq!(h.shadow_chunk_offset(2), base + (2 * 16 * 17 * 4) as u64);
        assert_eq!(h.shadow_chunk_bytes(6), (103 - 6 * 16) * 17 * 4);
        assert_eq!(h.shadow_crc_offset(), base + (17 * 103 * 4) as u64);
        assert_eq!(h.shadow_bytes(), (17 * 103 * 4 + 7 * 4) as u64);
        assert_eq!(h.file_len(), base + h.shadow_bytes());
        let plain = Header { f32_shadow: false, ..h };
        assert_eq!(plain.shadow_bytes(), 0);
        assert_eq!(plain.file_len(), base);
    }

    #[test]
    fn chunk_sizing() {
        assert_eq!(chunk_cols_for(100, 1000, 256 * 1024), 327);
        assert_eq!(chunk_cols_for(1_000_000, 10, 1024), 1);
        assert_eq!(chunk_cols_for(10, 5, 1 << 20), 5);
    }
}
