//! The `HSSRSTOR` reader: seek/read column service through a bounded LRU
//! chunk cache with pool-dispatched prefetch, counting real I/O.
//!
//! [`ColumnStore`] is the disk-backed analogue of
//! [`crate::data::chunked::ChunkedMatrix`]: the same column-serving
//! surface, but every chunk miss is an actual positioned read, the cache
//! is bounded by a byte budget (`HSSR_CACHE_MB`), and the counters report
//! measured traffic — columns served, chunk loads, **bytes read from
//! disk**, cache hits, and peak resident bytes. Scans are bit-identical to
//! the dense path: a served column slice holds exactly the values the
//! in-memory design would, and the per-column reduction is the same
//! `ops::dot(col, v)/n` every engine uses.
//!
//! ## Fault tolerance
//!
//! Every chunk read flows through [`ColumnStore::read_chunk_verified`]:
//!
//! 1. positioned read (optionally perturbed by an attached
//!    [`FaultInjector`] — transient errors, short reads, bit flips);
//! 2. CRC32 verification against the v2 checksum section (v1 stores have
//!    no checksums and skip this step);
//! 3. on a transient I/O failure or checksum mismatch: bounded
//!    retry-with-backoff ([`ColumnStore::MAX_READ_ATTEMPTS`] attempts,
//!    microsecond-scale exponential sleep), counting each retry;
//! 4. on exhaustion: the chunk is **quarantined** (subsequent reads fail
//!    fast without touching the disk) and a typed
//!    [`HssrError::Corrupt`] surfaces — corrupt data is never decoded
//!    into coefficients.
//!
//! Counters only record a *successful* load (`chunk_loads`/`bytes_read`),
//! so cache-accounting invariants hold bit-for-bit whether or not faults
//! were injected along the way; the absorbed faults are visible separately
//! as `retries`, `checksum_failures`, and `short_reads`.
//!
//! ## Pinned chunk views and the λ-ahead prefetcher
//!
//! Two additions let the inner optimizers (CD/GD/IRLS) run *on* the store
//! instead of on resident columns:
//!
//! * [`PinnedColumns`] — a cursor over store columns that **pins** the
//!   chunk under it (exempt from LRU eviction, still counted against the
//!   byte budget) and releases the pin on advance/drop. Because every
//!   inner loop walks ascending working sets, one pinned chunk at a time
//!   suffices even under a one-chunk budget. Columns served this way are
//!   counted as `solver_cols`, *not* `cols_fetched`, so the scan
//!   accounting invariant is untouched.
//! * [`Prefetcher`] — a background thread that loads the chunks of the
//!   next λ's SSR-predicted working set while the current inner solve
//!   runs. Prefetch inserts are tagged and budget-respecting (they never
//!   evict pinned chunks and never push `resident` past the budget), and
//!   a prefetch read failure is simply dropped — the demand path retries
//!   from scratch, so an injected fault on the prefetch thread can never
//!   poison a fit. Counters: `prefetch_issued` / `prefetch_hits` /
//!   `prefetch_wasted`, with blocking demand loads counted as `stalls`.

use std::cell::Cell;
use std::fs::File;
use std::path::Path;
use std::sync::{mpsc, Arc, Mutex, MutexGuard, PoisonError};

use super::cache::ChunkCache;
use super::fault::FaultInjector;
use super::format::{Header, HEADER_LEN};
use super::{pread, StoreCounters};
use crate::data::Dataset;
use crate::error::{io_fault_class, FaultClass, HssrError, Result};
use crate::linalg::{ops, pool, simd, DenseMatrix};
use crate::serialize::crc32;

thread_local! {
    /// The fit id tagged onto this thread (`0` = untagged). Serve-mode
    /// concurrent fits each run under a distinct tag so the shared chunk
    /// cache can attribute loads and classify hits as same- or cross-fit.
    static FIT_ID: Cell<u64> = const { Cell::new(0) };
}

/// RAII fit tag: while alive, cache traffic issued from this thread is
/// attributed to fit `id`. Dropping restores the previous tag, so nested
/// scopes (a serve worker running a fold fit inside a service fit) unwind
/// correctly. Pool fan-outs inside [`ColumnStore`] re-tag their worker
/// closures with the dispatching thread's fit, so attribution survives the
/// work-stealing pool; the async [`Prefetcher`] thread stays untagged —
/// speculative loads belong to no fit.
pub struct FitTag {
    prev: u64,
}

impl FitTag {
    /// Tag the current thread with fit `id` until the guard drops.
    pub fn set(id: u64) -> FitTag {
        FitTag { prev: FIT_ID.with(|c| c.replace(id)) }
    }
}

impl Drop for FitTag {
    fn drop(&mut self) {
        FIT_ID.with(|c| c.set(self.prev));
    }
}

/// The fit id tagged onto the current thread (`0` when untagged).
pub fn current_fit() -> u64 {
    FIT_ID.with(|c| c.get())
}

/// Decode a little-endian f64 byte run (length must be a multiple of 8).
fn le_f64s(bytes: &[u8]) -> Vec<f64> {
    bytes
        .chunks_exact(8)
        .map(|c| {
            let mut b = [0u8; 8];
            b.copy_from_slice(c);
            f64::from_le_bytes(b)
        })
        .collect()
}

/// Decode a little-endian f32 byte run (length must be a multiple of 4).
fn le_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| {
            let mut b = [0u8; 4];
            b.copy_from_slice(c);
            f32::from_le_bytes(b)
        })
        .collect()
}

/// A disk-backed column store with a bounded chunk cache.
pub struct ColumnStore {
    file: File,
    header: Header,
    y: Vec<f64>,
    centers: Vec<f64>,
    scales: Vec<f64>,
    name: String,
    cache: Mutex<ChunkCache>,
    counters: StoreCounters,
    /// Per-chunk CRC32s from the v2 checksum section (empty for v1).
    chunk_crcs: Vec<u32>,
    /// Per-shadow-chunk CRC32s from the f32 shadow section (empty when
    /// the store carries no shadow).
    shadow_crcs: Vec<u32>,
    /// Chunks whose reads exhausted the retry budget — fail fast.
    quarantined: Mutex<std::collections::HashSet<usize>>,
    /// Optional deterministic fault source (env/CLI/tests).
    faults: Option<FaultInjector>,
    /// Read-only file mapping serving chunk reads instead of `pread` when
    /// the `mmap` chunk service is selected at runtime (`HSSR_MMAP`).
    #[cfg(all(feature = "mmap", unix))]
    map: Option<mm::Mmap>,
}

/// `mmap`-backed chunk service (cargo feature `mmap`, unix only): the
/// whole store file is mapped read-only at open, and chunk reads copy out
/// of the mapping instead of issuing positioned reads. Runtime-selected
/// via `HSSR_MMAP=1` so a single bench binary can A/B the two services.
#[cfg(all(feature = "mmap", unix))]
mod mm {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    const PROT_READ: i32 = 1;
    const MAP_SHARED: i32 = 1;

    extern "C" {
        fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    /// A read-only mapping of the whole store file.
    pub struct Mmap {
        ptr: *mut core::ffi::c_void,
        len: usize,
    }

    // The mapping is immutable shared memory owned by this struct; the
    // raw pointer is just a base address, safe to read from any thread.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        /// Map `len` bytes of `file` read-only. `None` on failure — the
        /// caller silently falls back to positioned reads.
        pub fn map(file: &File, len: usize) -> Option<Mmap> {
            if len == 0 {
                return None;
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_SHARED, file.as_raw_fd(), 0)
            };
            if ptr as isize == -1 {
                return None;
            }
            Some(Mmap { ptr, len })
        }

        /// The mapped bytes.
        pub fn bytes(&self) -> &[u8] {
            // Safety: `ptr` maps exactly `len` readable bytes until Drop.
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            // Safety: `ptr`/`len` came from a successful `mmap`.
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

/// Whether `HSSR_MMAP` selects the mapped chunk service at runtime.
#[cfg(all(feature = "mmap", unix))]
fn mmap_requested() -> bool {
    matches!(
        std::env::var("HSSR_MMAP").as_deref(),
        Ok("1") | Ok("true") | Ok("on")
    )
}

impl ColumnStore {
    /// Read attempts per chunk before quarantining (the fault injector
    /// guarantees clean reads from attempt
    /// [`FaultInjector::MAX_FAULT_ATTEMPTS`] on, so injected faults always
    /// resolve within this budget).
    pub const MAX_READ_ATTEMPTS: u32 = 5;

    /// Open a store, validating the header and loading the (small) tail:
    /// `y` and the per-column stats — verified against the tail CRC for
    /// v2 stores. `budget_bytes` bounds the chunk cache; a budget smaller
    /// than one chunk still admits the chunk being scanned (the cache
    /// never wedges). If `HSSR_FAULTS` is set, the parsed
    /// [`FaultInjector`] is attached to every subsequent chunk read.
    pub fn open(path: &Path, budget_bytes: usize) -> Result<ColumnStore> {
        let file = File::open(path)?;
        let mut head = [0u8; HEADER_LEN as usize];
        pread(&file, &mut head, 0)?;
        let header = Header::decode(&head)?;
        // Overflow-checked size math: a corrupt header whose dimensions
        // wrap must be rejected here, not surface as a huge allocation.
        let expect = header.checked_file_len().ok_or_else(|| {
            HssrError::Config(format!(
                "{}: store header dimensions overflow (n={}, p={})",
                path.display(),
                header.n,
                header.p
            ))
        })?;
        let actual = file.metadata()?.len();
        // Shorter than the header implies = truncation, always fatal.
        // Longer is tolerated: a crash mid-`append_f32_shadow` leaves
        // extra bytes after the (still unflagged) end of the store.
        if actual < expect {
            return Err(HssrError::Config(format!(
                "{}: store truncated ({actual} bytes, header implies {expect})",
                path.display()
            )));
        }
        let mut tail = vec![0u8; header.tail_bytes()];
        pread(&file, &mut tail, header.tail_offset())?;
        let mut chunk_crcs = Vec::new();
        if header.checksums {
            let mut sect = vec![0u8; header.checksum_bytes() as usize];
            pread(&file, &mut sect, header.checksum_offset())?;
            chunk_crcs = sect
                .chunks_exact(4)
                .map(|c| {
                    let mut b = [0u8; 4];
                    b.copy_from_slice(c);
                    u32::from_le_bytes(b)
                })
                .collect();
            let tail_crc = chunk_crcs.pop().ok_or_else(|| {
                HssrError::Corrupt(format!("{}: empty checksum section", path.display()))
            })?;
            let got = crc32(&tail);
            if got != tail_crc {
                return Err(HssrError::Corrupt(format!(
                    "{}: tail checksum mismatch \
                     (stored {tail_crc:#010x}, computed {got:#010x})",
                    path.display()
                )));
            }
        }
        let mut shadow_crcs = Vec::new();
        if header.f32_shadow {
            let mut sect = vec![0u8; 4 * header.num_chunks()];
            pread(&file, &mut sect, header.shadow_crc_offset())?;
            shadow_crcs = sect
                .chunks_exact(4)
                .map(|c| {
                    let mut b = [0u8; 4];
                    b.copy_from_slice(c);
                    u32::from_le_bytes(b)
                })
                .collect();
        }
        let (n, p) = (header.n, header.p);
        #[cfg(all(feature = "mmap", unix))]
        let map = if mmap_requested() {
            mm::Mmap::map(&file, actual as usize)
        } else {
            None
        };
        Ok(ColumnStore {
            file,
            header,
            y: le_f64s(&tail[..n * 8]),
            centers: le_f64s(&tail[n * 8..(n + p) * 8]),
            scales: le_f64s(&tail[(n + p) * 8..(n + 2 * p) * 8]),
            name: path
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or("store")
                .to_string(),
            cache: Mutex::new(ChunkCache::new(budget_bytes.max(1))),
            counters: StoreCounters::default(),
            chunk_crcs,
            shadow_crcs,
            quarantined: Mutex::new(std::collections::HashSet::new()),
            faults: FaultInjector::from_env()?,
            #[cfg(all(feature = "mmap", unix))]
            map,
        })
    }

    /// Attach (or clear) a fault injector — test hook mirroring the
    /// `HSSR_FAULTS` environment path.
    pub fn set_faults(&mut self, faults: Option<FaultInjector>) {
        self.faults = faults;
    }

    /// Rows.
    pub fn nrows(&self) -> usize {
        self.header.n
    }

    /// Columns.
    pub fn ncols(&self) -> usize {
        self.header.p
    }

    /// The decoded header.
    pub fn header(&self) -> &Header {
        &self.header
    }

    /// Centered response stored in the tail.
    pub fn y(&self) -> &[f64] {
        &self.y
    }

    /// Per-column centers (raw-data means for a converted store; dataset
    /// metadata for a spilled one).
    pub fn centers(&self) -> &[f64] {
        &self.centers
    }

    /// Per-column scales (0 marks a constant column).
    pub fn scales(&self) -> &[f64] {
        &self.scales
    }

    /// File name, used as the workload label.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The real-I/O counters.
    pub fn counters(&self) -> &StoreCounters {
        &self.counters
    }

    /// Lock the chunk cache, recovering from poisoning: the cache holds
    /// plain data (no invariants straddle a panic point), so a worker
    /// that panicked mid-insert must not wedge every other fit sharing
    /// the store.
    fn cache_lock(&self) -> MutexGuard<'_, ChunkCache> {
        self.cache.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Lock the quarantine set, recovering from poisoning (same
    /// reasoning as [`ColumnStore::cache_lock`]).
    fn quarantine_lock(&self) -> MutexGuard<'_, std::collections::HashSet<usize>> {
        self.quarantined.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The cache byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.cache_lock().budget()
    }

    /// Zero the counters and drop every cached chunk (per-rule bench
    /// isolation). Quarantine is *not* cleared — a corrupt chunk stays
    /// corrupt.
    pub fn reset(&self) {
        self.counters.reset();
        self.cache_lock().clear();
    }

    /// One positioned chunk-payload read — through the file mapping when
    /// the `mmap` chunk service is active (feature `mmap` + `HSSR_MMAP`),
    /// else a plain `pread`. Copying out of the map into the caller's
    /// buffer keeps the CRC/fault/retry logic byte-for-byte identical
    /// across both services.
    fn raw_read(&self, buf: &mut [u8], offset: u64) -> Result<()> {
        #[cfg(all(feature = "mmap", unix))]
        if let Some(map) = &self.map {
            let bytes = map.bytes();
            let start = offset as usize;
            match start.checked_add(buf.len()).filter(|&end| end <= bytes.len()) {
                Some(end) => {
                    buf.copy_from_slice(&bytes[start..end]);
                    return Ok(());
                }
                None => {
                    return Err(HssrError::Io(std::io::Error::from(
                        std::io::ErrorKind::UnexpectedEof,
                    )))
                }
            }
        }
        pread(&self.file, buf, offset)
    }

    /// Read chunk `c`'s raw payload with fault injection, checksum
    /// verification, bounded retry, and quarantine — the single gate
    /// between this store and the filesystem. Does not count a load.
    fn read_chunk_verified(&self, c: usize) -> Result<Vec<u8>> {
        self.read_chunk_verified_opts(c, true)
    }

    /// [`ColumnStore::read_chunk_verified`] with quarantining optional:
    /// the async prefetcher reads with `quarantine_on_exhaust = false`, so
    /// a fault burst on the prefetch thread can only leave a chunk *cold*
    /// — the demand path retries it from scratch with its own full retry
    /// budget, instead of fast-failing on a prefetch-poisoned entry.
    fn read_chunk_verified_opts(&self, c: usize, quarantine_on_exhaust: bool) -> Result<Vec<u8>> {
        self.read_verified(
            self.header.chunk_offset(c),
            self.header.chunk_bytes(c),
            self.chunk_crcs.get(c).copied(),
            c,
            &format!("chunk {c}"),
            quarantine_on_exhaust,
        )
    }

    /// Read and verify the f32 shadow payload of chunk `c` through the
    /// same fault/retry/quarantine gate as the f64 chunks. Shadow chunks
    /// quarantine under their own keys (`num_chunks + c`), so a corrupt
    /// shadow never blocks the exact f64 path for the same columns.
    fn read_shadow_chunk(&self, c: usize) -> Result<Vec<u8>> {
        debug_assert!(self.header.f32_shadow);
        self.read_verified(
            self.header.shadow_chunk_offset(c),
            self.header.shadow_chunk_bytes(c),
            self.shadow_crcs.get(c).copied(),
            self.header.num_chunks() + c,
            &format!("f32 shadow chunk {c}"),
            true,
        )
    }

    /// The generalized verified-read gate behind both the f64 chunks and
    /// the f32 shadow chunks: positioned read (optionally fault-injected),
    /// CRC32 verification when `want_crc` is present, bounded
    /// retry-with-backoff, and quarantine under `qkey` on exhaustion.
    fn read_verified(
        &self,
        offset: u64,
        len: usize,
        want_crc: Option<u32>,
        qkey: usize,
        what: &str,
        quarantine_on_exhaust: bool,
    ) -> Result<Vec<u8>> {
        if self.quarantine_lock().contains(&qkey) {
            return Err(HssrError::Corrupt(format!(
                "{}: {what} is quarantined after repeated read failures",
                self.name
            )));
        }
        let mut raw = vec![0u8; len];
        let mut attempt = 0u32;
        loop {
            let read = self.raw_read(&mut raw, offset).and_then(|()| {
                if let Some(inj) = &self.faults {
                    // Bit flips are only injected when a checksum can
                    // catch them (v2) — see `FaultInjector::decide`.
                    inj.inject(offset, attempt, &mut raw, want_crc.is_some())
                        .map_err(HssrError::Io)?;
                }
                Ok(())
            });
            let failure = match read {
                Ok(()) => {
                    match want_crc {
                        Some(want) => {
                            let got = crc32(&raw);
                            if got == want {
                                return Ok(raw);
                            }
                            self.counters.add_checksum_failure();
                            format!(
                                "checksum mismatch \
                                 (stored {want:#010x}, computed {got:#010x})"
                            )
                        }
                        // v1 store: nothing to verify against.
                        None => return Ok(raw),
                    }
                }
                Err(HssrError::Io(e)) => {
                    if e.kind() == std::io::ErrorKind::UnexpectedEof {
                        self.counters.add_short_read();
                    }
                    if io_fault_class(&e) == FaultClass::Permanent {
                        // Not worth retrying (missing file, bad fd, …).
                        return Err(HssrError::Io(e));
                    }
                    format!("transient read error: {e}")
                }
                Err(other) => return Err(other),
            };
            attempt += 1;
            if attempt >= Self::MAX_READ_ATTEMPTS {
                let note = if quarantine_on_exhaust {
                    self.quarantine_lock().insert(qkey);
                    "; chunk quarantined"
                } else {
                    ""
                };
                return Err(HssrError::Corrupt(format!(
                    "{}: {what} failed after {attempt} attempts — {failure}{note}",
                    self.name
                )));
            }
            self.counters.add_retry();
            // Tiny exponential backoff: long enough to let a transient
            // condition clear, short enough to be invisible in fits.
            std::thread::sleep(std::time::Duration::from_micros(50u64 << attempt.min(4)));
        }
    }

    /// Read chunk `c` from disk (verified) and decode it to standardized
    /// column values. Counts the load. Does not touch the cache.
    fn load_chunk(&self, c: usize) -> Result<Vec<f64>> {
        let raw = self.read_chunk_verified(c)?;
        self.counters.add_load(raw.len() as u64);
        Ok(self.decode_chunk(c, &raw))
    }

    /// Decode a chunk payload, applying the per-column affine transform
    /// when the store holds raw data.
    fn decode_chunk(&self, c: usize, raw: &[u8]) -> Vec<f64> {
        let n = self.header.n;
        let width = self.header.chunk_width(c);
        let j0 = c * self.header.chunk_cols;
        let mut out = Vec::with_capacity(width * n);
        for (local, col) in raw.chunks_exact(n * 8).enumerate() {
            let j = j0 + local;
            let scale = self.scales[j];
            if self.header.standardized {
                out.extend(le_f64s(col));
            } else if scale == 0.0 {
                // Constant column: standardization zeroes it out.
                out.resize(out.len() + n, 0.0);
            } else {
                let center = self.centers[j];
                let inv = 1.0 / scale;
                out.extend(le_f64s(col).into_iter().map(|v| (v - center) * inv));
            }
        }
        out
    }

    /// Drain the cache's accumulated prefetch hit/waste tallies into the
    /// atomic counters (called wherever the cache was just touched).
    fn drain_prefetch_stats(&self, cache: &mut ChunkCache) {
        let (hits, wasted) = cache.take_prefetch_stats();
        self.counters.add_prefetch_stats(hits, wasted);
    }

    /// Count a cross-fit hit when a *tagged* fit's demand access found a
    /// chunk loaded by a *different* tagged fit — the sharing the serve
    /// mode's one-cache design exists to create. Untagged traffic (plain
    /// CLI fits, the prefetcher) never counts on either side.
    fn note_cross_fit(&self, owner: u64) {
        let fit = current_fit();
        if fit != 0 && owner != 0 && owner != fit {
            self.counters.add_cross_fit_hit();
        }
    }

    /// Fetch chunk `c` through the cache (hit: LRU touch; miss: disk load
    /// + insert with LRU eviction under the byte budget). A miss is a
    /// *stall*: compute blocked on a synchronous disk read.
    fn chunk(&self, c: usize) -> Result<Arc<Vec<f64>>> {
        {
            let mut cache = self.cache_lock();
            let owner = cache.owner_of(c);
            if let Some(buf) = cache.get(c) {
                self.drain_prefetch_stats(&mut cache);
                drop(cache);
                self.counters.add_hit();
                self.note_cross_fit(owner.unwrap_or(0));
                return Ok(buf);
            }
        }
        self.counters.add_stall();
        let buf = {
            // A stall is compute blocked on a synchronous disk read — the
            // span the prefetcher exists to shrink.
            let mut span = crate::obs::trace::Span::begin("stall", "store");
            span.arg_u64("chunk", c as u64);
            Arc::new(self.load_chunk(c)?)
        };
        let mut cache = self.cache_lock();
        cache.insert(c, Arc::clone(&buf), current_fit());
        self.counters.note_resident(cache.resident() as u64);
        self.drain_prefetch_stats(&mut cache);
        Ok(buf)
    }

    /// Fetch chunk `c` and **pin** it: the entry is exempt from LRU
    /// eviction (its bytes still count against the budget) until the
    /// matching [`ColumnStore::unpin_chunk`]. Like [`ColumnStore::chunk`],
    /// a miss is a stall.
    fn pin_chunk(&self, c: usize) -> Result<Arc<Vec<f64>>> {
        {
            let mut cache = self.cache_lock();
            let owner = cache.owner_of(c);
            if let Some(buf) = cache.get(c) {
                cache.pin(c);
                self.drain_prefetch_stats(&mut cache);
                drop(cache);
                self.counters.add_hit();
                self.note_cross_fit(owner.unwrap_or(0));
                return Ok(buf);
            }
        }
        self.counters.add_stall();
        let buf = {
            // A stall is compute blocked on a synchronous disk read — the
            // span the prefetcher exists to shrink.
            let mut span = crate::obs::trace::Span::begin("stall", "store");
            span.arg_u64("chunk", c as u64);
            Arc::new(self.load_chunk(c)?)
        };
        let mut cache = self.cache_lock();
        cache.insert(c, Arc::clone(&buf), current_fit());
        cache.pin(c);
        self.counters.note_resident(cache.resident() as u64);
        self.drain_prefetch_stats(&mut cache);
        Ok(buf)
    }

    /// Release one pin on chunk `c`.
    fn unpin_chunk(&self, c: usize) {
        self.cache_lock().unpin(c);
    }

    /// A pinned single-chunk cursor over store columns, for the inner
    /// optimizers — see [`PinnedColumns`].
    pub fn pin_cols(&self) -> PinnedColumns<'_> {
        PinnedColumns { store: self, current: None }
    }

    /// Serve column `j` to `f`, counting the fetch. The slice holds the
    /// standardized values of the column.
    pub fn with_col<R>(&self, j: usize, f: impl FnOnce(&[f64]) -> R) -> Result<R> {
        debug_assert!(j < self.header.p);
        self.counters.add_col();
        let c = j / self.header.chunk_cols;
        let buf = self.chunk(c)?;
        let off = (j - c * self.header.chunk_cols) * self.header.n;
        Ok(f(&buf[off..off + self.header.n]))
    }

    /// Pool-dispatched prefetch: load the (distinct) chunks covering
    /// `cols` that are not yet cached, in parallel on the persistent
    /// worker pool, up to the cache capacity — the read-ahead the scan
    /// engine issues for the upcoming safe set before its dot loop.
    pub fn prefetch(&self, cols: &[usize]) -> Result<()> {
        let mut wanted: Vec<usize> = Vec::new();
        {
            let cache = self.cache_lock();
            let capacity = (cache.budget() / self.header.chunk_bytes(0).max(1)).max(1);
            for &j in cols {
                let c = j / self.header.chunk_cols;
                if wanted.len() >= capacity {
                    break;
                }
                if !cache.contains(c) && !wanted.contains(&c) {
                    wanted.push(c);
                }
            }
        }
        if wanted.is_empty() {
            return Ok(());
        }
        let fit = current_fit();
        let loaded: Vec<Result<Vec<f64>>> = pool::global().map(wanted.len(), |k| {
            // The scan blocks on these reads — they are demand stalls,
            // unlike the async λ-ahead loads in `prefetch_tagged`.
            self.counters.add_stall();
            let mut span = crate::obs::trace::Span::begin("stall", "store");
            span.arg_u64("chunk", wanted[k] as u64);
            self.load_chunk(wanted[k])
        });
        let mut cache = self.cache_lock();
        for (c, buf) in wanted.into_iter().zip(loaded) {
            cache.insert(c, Arc::new(buf?), fit);
        }
        self.counters.note_resident(cache.resident() as u64);
        self.drain_prefetch_stats(&mut cache);
        Ok(())
    }

    /// Asynchronous-path prefetch, called from the [`Prefetcher`] thread:
    /// load the uncached chunks covering `cols` and insert them *tagged*
    /// via the budget-refusing [`ChunkCache::insert_prefetched`]. Reads do
    /// not quarantine on retry exhaustion, and every error is swallowed —
    /// a failed prefetch just leaves the chunk cold for the demand path.
    pub(crate) fn prefetch_tagged(&self, cols: &[usize]) {
        let mut batch_span = crate::obs::trace::Span::begin("prefetch_batch", "store");
        batch_span.arg_u64("cols", cols.len() as u64);
        let mut wanted: Vec<usize> = Vec::new();
        {
            let cache = self.cache_lock();
            let chunk_bytes = self.header.chunk_bytes(0).max(1);
            // Only what fits beside the pinned bytes is worth fetching.
            let free = cache.budget().saturating_sub(cache.pinned_bytes());
            let capacity = free / chunk_bytes;
            for &j in cols {
                let c = j / self.header.chunk_cols;
                if wanted.len() >= capacity {
                    break;
                }
                if !cache.contains(c) && !wanted.contains(&c) {
                    wanted.push(c);
                }
            }
        }
        for c in wanted {
            let Ok(raw) = self.read_chunk_verified_opts(c, false) else {
                continue;
            };
            self.counters.add_load(raw.len() as u64);
            let buf = Arc::new(self.decode_chunk(c, &raw));
            let mut cache = self.cache_lock();
            if cache.insert_prefetched(c, buf, current_fit()) {
                self.counters.add_prefetch_issued();
            } else {
                // Loaded but not admitted (everything else pinned): pure
                // waste, visible as such.
                self.counters.add_prefetch_stats(0, 1);
            }
            self.counters.note_resident(cache.resident() as u64);
            self.drain_prefetch_stats(&mut cache);
        }
    }

    /// Scan `out[k] = x_{idx[k]}ᵀ v / n` against the store: prefetch the
    /// covering chunks, then the same per-column reduction every engine
    /// uses (bit-identical to the dense path — per-column dots are
    /// independent, so dispatching them on the pool changes wall-clock,
    /// not bits). Small scans stay serial, mirroring the native kernels'
    /// [`crate::linalg::blocked::PAR_THRESHOLD`].
    pub fn scan_subset(&self, v: &[f64], idx: &[usize], out: &mut [f64]) -> Result<()> {
        assert_eq!(out.len(), idx.len());
        assert_eq!(v.len(), self.header.n);
        self.prefetch(idx)?;
        let inv_n = 1.0 / self.header.n as f64;
        if self.header.n * idx.len() < crate::linalg::blocked::PAR_THRESHOLD {
            for (k, &j) in idx.iter().enumerate() {
                out[k] = self.with_col(j, |col| ops::dot(col, v))? * inv_n;
            }
            return Ok(());
        }
        // Pool workers have their own thread-locals: re-tag each closure
        // with the dispatching fit so cache attribution survives fan-out.
        let fit = current_fit();
        let dots: Vec<Result<f64>> = pool::global().map(idx.len(), |k| {
            let _tag = FitTag::set(fit);
            self.with_col(idx[k], |col| ops::dot(col, v)).map(|d| d * inv_n)
        });
        for (o, d) in out.iter_mut().zip(dots) {
            *o = d?;
        }
        Ok(())
    }

    /// Whether the mounted file carries the f32 shadow section.
    pub fn has_f32_shadow(&self) -> bool {
        self.header.f32_shadow
    }

    /// Mixed-precision full scan: `out[j] = x̃_jᵀ ṽ / n` computed in f32,
    /// where `x̃`/`ṽ` are the standardized columns and `v` cast to f32.
    /// With a shadow section the f32 columns stream straight off disk
    /// (half the bytes of the exact scan, one verified read per shadow
    /// chunk, no caching — screening scans touch each column once); a
    /// shadow-less store serves the f64 columns through the chunk cache
    /// and casts, which produces **identical f32 bits** (the shadow holds
    /// exactly `value as f32`), so callers never see which path ran.
    /// Every approximate value must still be widened by
    /// [`crate::linalg::simd::f32_scan_error_bound`] before any screening
    /// decision — see [`crate::runtime::ScanEngine::scan_all_f32`].
    pub fn scan_all_f32(&self, v: &[f64], out: &mut [f64]) -> Result<()> {
        let (n, p) = (self.header.n, self.header.p);
        assert_eq!(v.len(), n);
        assert_eq!(out.len(), p);
        let inv_n = 1.0 / n as f64;
        let v32: Vec<f32> = v.iter().map(|&e| e as f32).collect();
        if !self.header.f32_shadow {
            let mut col32 = vec![0.0f32; n];
            for (j, o) in out.iter_mut().enumerate() {
                *o = self.with_col(j, |col| {
                    for (d, &s) in col32.iter_mut().zip(col) {
                        *d = s as f32;
                    }
                    simd::dot_f32(&col32, &v32)
                })? as f64
                    * inv_n;
            }
            return Ok(());
        }
        for c in 0..self.header.num_chunks() {
            let raw = self.read_shadow_chunk(c)?;
            self.counters.add_load(raw.len() as u64);
            let cols = le_f32s(&raw);
            let j0 = c * self.header.chunk_cols;
            for local in 0..self.header.chunk_width(c) {
                self.counters.add_col();
                let col = &cols[local * n..(local + 1) * n];
                out[j0 + local] = simd::dot_f32(col, &v32) as f64 * inv_n;
            }
        }
        Ok(())
    }

    /// Materialize the full standardized dataset (dense). Reads every
    /// chunk once, directly — bypassing the cache and the load counters,
    /// since this is a load, not scan traffic — but still through the
    /// verified read path: corruption is detected here too.
    pub fn to_dataset(&self) -> Result<Dataset> {
        let (n, p) = (self.header.n, self.header.p);
        let mut data = Vec::with_capacity(n * p);
        for c in 0..self.header.num_chunks() {
            let raw = self.read_chunk_verified(c)?;
            data.extend(self.decode_chunk(c, &raw));
        }
        Ok(Dataset {
            x: DenseMatrix::from_col_major(n, p, data)?,
            y: self.y.clone(),
            centers: self.centers.clone(),
            scales: self.scales.clone(),
            name: self.name.clone(),
            truth: None,
        })
    }
}

/// A pinned single-chunk cursor serving store columns to an inner solver.
///
/// The chunk under the cursor is pinned (exempt from LRU eviction, bytes
/// still budgeted); moving to a column in a different chunk swaps the pin
/// — release old, pin new — so at most **one** chunk is ever pinned per
/// cursor, which is what lets a full fit run under a one-chunk cache
/// budget. Backward moves (e.g. group descent's second pass over a group
/// straddling a chunk boundary) are just another swap.
///
/// Columns served here count as `solver_cols`, not `cols_fetched`, so the
/// scan-accounting invariant (`cols_fetched == cols_scanned`) is
/// unaffected by solver traffic. Dropping the cursor releases its pin.
pub struct PinnedColumns<'s> {
    store: &'s ColumnStore,
    current: Option<(usize, Arc<Vec<f64>>)>,
}

impl PinnedColumns<'_> {
    /// Rows served per column.
    pub fn nrows(&self) -> usize {
        self.store.header.n
    }

    /// Serve standardized column `j`, pinning its chunk (swapping the
    /// previous pin if `j` lives elsewhere). Counts a `solver_col`.
    pub fn col(&mut self, j: usize) -> Result<&[f64]> {
        let h = &self.store.header;
        debug_assert!(j < h.p);
        let c = j / h.chunk_cols;
        if self.current.as_ref().map(|(cur, _)| *cur) != Some(c) {
            if let Some((old, _)) = self.current.take() {
                self.store.unpin_chunk(old);
            }
            let buf = self.store.pin_chunk(c)?;
            self.current = Some((c, buf));
        }
        self.store.counters.add_solver_col();
        let buf = self
            .current
            .as_ref()
            .map(|(_, b)| b)
            .ok_or_else(|| HssrError::Config("pinned cursor lost its chunk".into()))?;
        let off = (j - c * h.chunk_cols) * h.n;
        Ok(&buf[off..off + h.n])
    }
}

impl Drop for PinnedColumns<'_> {
    fn drop(&mut self) {
        if let Some((c, _)) = self.current.take() {
            self.store.unpin_chunk(c);
        }
    }
}

/// The async λ-ahead prefetch service: a dedicated thread that loads the
/// chunks of the *next* λ's SSR-predicted working set while the current
/// inner solve runs on the main/pool threads.
///
/// Requests coalesce — only the newest matters, since a stale working-set
/// prediction is worthless once the driver has moved on. All I/O errors
/// are swallowed on this thread (see [`ColumnStore::prefetch_tagged`]):
/// prefetch can make a fit faster, never wrong. Dropping the service
/// closes the channel and joins the thread.
pub struct Prefetcher {
    tx: Option<mpsc::Sender<Vec<usize>>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn the prefetch thread over a shared store handle.
    pub fn spawn(store: Arc<ColumnStore>) -> Prefetcher {
        let (tx, rx) = mpsc::channel::<Vec<usize>>();
        let handle = std::thread::Builder::new()
            .name("hssr-prefetch".into())
            .spawn(move || {
                while let Ok(mut job) = rx.recv() {
                    // Coalesce to the newest request.
                    while let Ok(next) = rx.try_recv() {
                        job = next;
                    }
                    store.prefetch_tagged(&job);
                }
            })
            .ok();
        Prefetcher { tx: Some(tx), handle }
    }

    /// Queue a column set for background prefetch (non-blocking; a send
    /// to a dead thread is silently dropped).
    pub fn request(&self, cols: &[usize]) {
        if cols.is_empty() {
            return;
        }
        if let Some(tx) = &self.tx {
            let _ = tx.send(cols.to_vec());
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::store::fault::FaultSpec;
    use crate::data::store::writer::write_dataset;
    use crate::data::store::MAGIC;
    use crate::data::DataSpec;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hssr_store_reader_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn dense_store_dense_is_exact() {
        let ds = DataSpec::gene_like(23, 41).generate(7);
        let path = tmp("exact.store");
        write_dataset(&ds, 8, &path).unwrap();
        let store = ColumnStore::open(&path, 1 << 20).unwrap();
        assert_eq!((store.nrows(), store.ncols()), (23, 41));
        let back = store.to_dataset().unwrap();
        assert_eq!(back.x.as_slice(), ds.x.as_slice(), "matrix bytes drifted");
        assert_eq!(back.y, ds.y);
        assert_eq!(back.centers, ds.centers);
        assert_eq!(back.scales, ds.scales);
        // column service matches too, and is counted
        for j in [0usize, 7, 40] {
            let col = store.with_col(j, |c| c.to_vec()).unwrap();
            assert_eq!(col.as_slice(), ds.x.col(j));
        }
        assert_eq!(store.counters().cols_fetched(), 3);
    }

    #[test]
    fn tiny_budget_forces_eviction_but_stays_correct() {
        let ds = DataSpec::synthetic(16, 30, 3).generate(1);
        let path = tmp("tiny.store");
        write_dataset(&ds, 4, &path).unwrap();
        // Budget of exactly one 4-column chunk (4·16·8 bytes).
        let store = ColumnStore::open(&path, 4 * 16 * 8).unwrap();
        let v: Vec<f64> = (0..16).map(|i| (i as f64).sin()).collect();
        let idx: Vec<usize> = (0..30).collect();
        let mut got = vec![0.0; 30];
        store.scan_subset(&v, &idx, &mut got).unwrap();
        let want = crate::linalg::blocked::scan_all_vec(&ds.x, &v);
        assert_eq!(got, want, "scans under eviction must stay bit-identical");
        // every chunk had to be loaded, and the cache never outgrew one chunk
        assert!(store.counters().chunk_loads() >= 8);
        assert!(store.counters().peak_resident() <= (4 * 16 * 8) as u64);
        // a second pass re-faults (the working set exceeds the budget)
        store.scan_subset(&v, &idx, &mut got).unwrap();
        assert!(store.counters().chunk_loads() >= 16);
    }

    #[test]
    fn warm_cache_serves_hits_without_reloads() {
        let ds = DataSpec::synthetic(10, 12, 2).generate(2);
        let path = tmp("warm.store");
        write_dataset(&ds, 4, &path).unwrap();
        let store = ColumnStore::open(&path, 1 << 20).unwrap();
        let v = vec![1.0; 10];
        let mut out = vec![0.0; 12];
        store.scan_subset(&v, &(0..12).collect::<Vec<_>>(), &mut out).unwrap();
        let loads = store.counters().chunk_loads();
        assert_eq!(loads, 3);
        store.scan_subset(&v, &(0..12).collect::<Vec<_>>(), &mut out).unwrap();
        assert_eq!(store.counters().chunk_loads(), loads, "warm pass reloaded");
        assert!(store.counters().cache_hits() >= 12);
        store.reset();
        assert_eq!(store.counters().chunk_loads(), 0);
    }

    #[test]
    fn truncated_store_rejected() {
        let ds = DataSpec::synthetic(8, 5, 2).generate(3);
        let path = tmp("trunc.store");
        write_dataset(&ds, 2, &path).unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 8).unwrap();
        drop(f);
        assert!(ColumnStore::open(&path, 1 << 20).is_err());
    }

    /// A v1 (`HSSRSTOR1`) file — no checksum section — still opens and
    /// serves bit-identical data. Built by stripping a v2 file's checksum
    /// section and rewriting the magic.
    #[test]
    fn v1_store_still_readable() {
        let ds = DataSpec::synthetic(12, 9, 2).generate(4);
        let path = tmp("v1compat.store");
        write_dataset(&ds, 4, &path).unwrap();
        let v2 = ColumnStore::open(&path, 1 << 20).unwrap();
        assert!(v2.header().checksums, "writers must produce v2");
        let v1_len = v2.header().checksum_offset();
        drop(v2);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(v1_len as usize);
        bytes[..9].copy_from_slice(MAGIC);
        let v1_path = tmp("v1compat_v1.store");
        std::fs::write(&v1_path, bytes).unwrap();
        let store = ColumnStore::open(&v1_path, 1 << 20).unwrap();
        assert!(!store.header().checksums);
        let back = store.to_dataset().unwrap();
        assert_eq!(back.x.as_slice(), ds.x.as_slice(), "v1 data drifted");
        assert_eq!(back.y, ds.y);
    }

    /// The f32 shadow scan returns bit-identical values whether the f32
    /// columns stream from the shadow section or are cast from the served
    /// f64 columns of a shadow-less store.
    #[test]
    fn shadow_scan_matches_cast_scan_bitwise() {
        let ds = DataSpec::gene_like(23, 41).generate(19);
        let a = tmp("sh_a.store");
        let b = tmp("sh_b.store");
        write_dataset(&ds, 8, &a).unwrap();
        write_dataset(&ds, 8, &b).unwrap();
        crate::data::store::append_f32_shadow(&b).unwrap();
        let plain = ColumnStore::open(&a, 1 << 20).unwrap();
        let shadowed = ColumnStore::open(&b, 1 << 20).unwrap();
        assert!(!plain.has_f32_shadow());
        assert!(shadowed.has_f32_shadow());
        let mut rng = crate::rng::Pcg64::new(21);
        let v = rng.normal_vec(23);
        let mut from_cast = vec![0.0; 41];
        let mut from_shadow = vec![0.0; 41];
        plain.scan_all_f32(&v, &mut from_cast).unwrap();
        shadowed.scan_all_f32(&v, &mut from_shadow).unwrap();
        assert_eq!(from_cast, from_shadow, "shadow path changed f32 scan bits");
        // Sanity: the f32 scan approximates the exact one within the
        // published error bound.
        let exact = crate::linalg::blocked::scan_all_vec(&ds.x, &v);
        let r_norm = ops::dot(&v, &v).sqrt();
        let eps = simd::f32_scan_error_bound(23, r_norm);
        for j in 0..41 {
            assert!(
                (from_shadow[j] - exact[j]).abs() <= eps,
                "column {j}: |{} - {}| > {eps}",
                from_shadow[j],
                exact[j]
            );
        }
        // Shadow reads are real I/O: loads and columns are counted.
        assert!(shadowed.counters().chunk_loads() >= 6);
        assert_eq!(shadowed.counters().cols_fetched(), 41);
    }

    /// A corrupt shadow chunk quarantines under its own key: the f32 scan
    /// fails typed while the exact f64 path for the same columns keeps
    /// serving clean data.
    #[test]
    fn corrupt_shadow_does_not_block_f64_path() {
        let ds = DataSpec::synthetic(10, 8, 2).generate(23);
        let path = tmp("shflip.store");
        write_dataset(&ds, 4, &path).unwrap();
        let h = crate::data::store::append_f32_shadow(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[h.shadow_chunk_offset(1) as usize + 9] ^= 0x20;
        std::fs::write(&path, bytes).unwrap();
        let store = ColumnStore::open(&path, 1 << 20).unwrap();
        let v = vec![1.0; 10];
        let mut out = vec![0.0; 8];
        let err = store.scan_all_f32(&v, &mut out).unwrap_err();
        assert!(matches!(err, HssrError::Corrupt(_)), "got {err}");
        assert!(err.to_string().contains("f32 shadow chunk 1"), "got {err}");
        // The f64 chunks are untouched and not quarantined.
        let back = store.to_dataset().unwrap();
        assert_eq!(back.x.as_slice(), ds.x.as_slice());
    }

    /// A flipped payload byte is detected by the chunk CRC and surfaced
    /// as a typed `Corrupt` error after the retry budget — never decoded
    /// into coefficients — and the chunk is quarantined.
    #[test]
    fn flipped_byte_detected_and_quarantined() {
        let ds = DataSpec::synthetic(10, 8, 2).generate(5);
        let path = tmp("flip.store");
        write_dataset(&ds, 4, &path).unwrap();
        {
            // Flip one bit in the middle of chunk 1's payload.
            let store = ColumnStore::open(&path, 1 << 20).unwrap();
            let off = store.header().chunk_offset(1) + 17;
            drop(store);
            let mut bytes = std::fs::read(&path).unwrap();
            bytes[off as usize] ^= 0x10;
            std::fs::write(&path, bytes).unwrap();
        }
        let store = ColumnStore::open(&path, 1 << 20).unwrap();
        // Chunk 0 is clean and serves fine.
        let col0 = store.with_col(0, |c| c.to_vec()).unwrap();
        assert_eq!(col0.as_slice(), ds.x.col(0));
        // Chunk 1 fails typed, with the failure visible in the counters.
        let err = store.with_col(5, |c| c.to_vec()).unwrap_err();
        assert!(matches!(err, HssrError::Corrupt(_)), "got {err}");
        assert!(store.counters().checksum_failures() >= 1);
        assert!(store.counters().retries() >= 1);
        // Quarantined: the second access fails fast with the same type.
        let before = store.counters().checksum_failures();
        let err = store.with_col(5, |c| c.to_vec()).unwrap_err();
        assert!(matches!(err, HssrError::Corrupt(_)));
        assert!(err.to_string().contains("quarantined"));
        assert_eq!(store.counters().checksum_failures(), before, "no new disk reads");
        // to_dataset refuses the corrupt store too.
        assert!(matches!(store.to_dataset(), Err(HssrError::Corrupt(_))));
    }

    /// A flipped byte in the tail (y/centers/scales) is caught at open.
    #[test]
    fn flipped_tail_byte_rejected_at_open() {
        let ds = DataSpec::synthetic(10, 8, 2).generate(6);
        let path = tmp("fliptail.store");
        write_dataset(&ds, 4, &path).unwrap();
        let tail_off = {
            let store = ColumnStore::open(&path, 1 << 20).unwrap();
            store.header().tail_offset()
        };
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[tail_off as usize + 3] ^= 0x01;
        std::fs::write(&path, bytes).unwrap();
        assert!(matches!(
            ColumnStore::open(&path, 1 << 20),
            Err(HssrError::Corrupt(_))
        ));
    }

    /// Under injected transient faults, short reads, and bit flips, every
    /// scan still returns exactly the clean values — the retry policy
    /// absorbs the faults and the counters prove they happened.
    #[test]
    fn injected_faults_are_absorbed_bit_identically() {
        let ds = DataSpec::synthetic(16, 30, 3).generate(7);
        let path = tmp("inject.store");
        write_dataset(&ds, 4, &path).unwrap();
        let mut store = ColumnStore::open(&path, 4 * 16 * 8).unwrap();
        store.set_faults(Some(FaultInjector::new(FaultSpec {
            seed: 42,
            transient: 0.3,
            short: 0.2,
            flip: 0.2,
        })));
        let v: Vec<f64> = (0..16).map(|i| (i as f64).cos()).collect();
        let idx: Vec<usize> = (0..30).collect();
        let mut got = vec![0.0; 30];
        // Tiny budget → constant eviction → many faulted reads.
        for _ in 0..3 {
            store.scan_subset(&v, &idx, &mut got).unwrap();
            let want = crate::linalg::blocked::scan_all_vec(&ds.x, &v);
            assert_eq!(got, want, "faulted scan drifted from clean values");
        }
        assert!(store.counters().retries() > 0, "faults were never injected");
        let back = store.to_dataset().unwrap();
        assert_eq!(back.x.as_slice(), ds.x.as_slice());
    }

    /// The pinned cursor serves bit-identical columns under a one-chunk
    /// budget, counts them as solver traffic (not scan traffic), and
    /// never lets the cache outgrow the budget.
    #[test]
    fn pinned_cursor_serves_exact_columns_within_budget() {
        let ds = DataSpec::synthetic(16, 30, 3).generate(9);
        let path = tmp("pin.store");
        write_dataset(&ds, 4, &path).unwrap();
        let budget = 4 * 16 * 8; // exactly one chunk
        let store = ColumnStore::open(&path, budget).unwrap();
        {
            let mut cur = store.pin_cols();
            // Ascending walk, then a backward move (GD's second pass).
            for j in (0..30).chain([2usize, 17]) {
                let col = cur.col(j).unwrap().to_vec();
                assert_eq!(col.as_slice(), ds.x.col(j), "column {j} drifted");
            }
        }
        assert_eq!(store.counters().cols_fetched(), 0, "solver traffic leaked into scans");
        assert_eq!(store.counters().solver_cols(), 32);
        assert!(store.counters().stalls() >= 8);
        assert!(store.counters().peak_resident() <= budget as u64);
        // The cursor dropped: nothing left pinned, inserts evict freely.
        assert_eq!(store.cache_lock().pinned_bytes(), 0);
    }

    /// Tagged prefetch fills the cache without quarantining on failure,
    /// and demand use of prefetched chunks shows up as hits.
    #[test]
    fn tagged_prefetch_feeds_demand_hits() {
        let ds = DataSpec::synthetic(12, 16, 2).generate(10);
        let path = tmp("tagpf.store");
        write_dataset(&ds, 4, &path).unwrap();
        let store = ColumnStore::open(&path, 1 << 20).unwrap();
        store.prefetch_tagged(&(0..16).collect::<Vec<_>>());
        assert_eq!(store.counters().prefetch_issued(), 4);
        let v = vec![1.0; 12];
        let mut out = vec![0.0; 16];
        store.scan_subset(&v, &(0..16).collect::<Vec<_>>(), &mut out).unwrap();
        assert_eq!(store.counters().prefetch_hits(), 4);
        assert_eq!(store.counters().stalls(), 0, "prefetched scan still stalled");
    }

    /// Cross-fit hits count exactly when a tagged fit's demand access
    /// lands on a chunk a *different* tagged fit loaded — never for
    /// same-fit or untagged traffic — and tags unwind on drop.
    #[test]
    fn cross_fit_hits_counted_between_tagged_fits() {
        let ds = DataSpec::synthetic(10, 8, 2).generate(12);
        let path = tmp("xfit.store");
        write_dataset(&ds, 4, &path).unwrap();
        let store = ColumnStore::open(&path, 1 << 20).unwrap();
        {
            let _tag = FitTag::set(1);
            assert_eq!(current_fit(), 1);
            {
                let _inner = FitTag::set(5);
                assert_eq!(current_fit(), 5);
            }
            assert_eq!(current_fit(), 1, "nested tag did not unwind");
            // Fit 1 loads chunk 0, then hits it again: same-fit traffic.
            store.with_col(0, |c| c.len()).unwrap();
            store.with_col(1, |c| c.len()).unwrap();
        }
        assert_eq!(current_fit(), 0);
        assert_eq!(store.counters().cross_fit_hits(), 0);
        {
            // Fit 2 hits the chunk fit 1 loaded: one cross-fit hit.
            let _tag = FitTag::set(2);
            store.with_col(2, |c| c.len()).unwrap();
        }
        assert_eq!(store.counters().cross_fit_hits(), 1);
        // Untagged demand traffic on the same chunk never counts.
        store.with_col(3, |c| c.len()).unwrap();
        assert_eq!(store.counters().cross_fit_hits(), 1);
        assert!(store.counters().cache_hits() >= 3);
    }

    /// The background prefetcher loads chunks while the requester does
    /// other work; requests on a dropped store thread are harmless.
    #[test]
    fn background_prefetcher_loads_chunks() {
        let ds = DataSpec::synthetic(12, 16, 2).generate(11);
        let path = tmp("bgpf.store");
        write_dataset(&ds, 4, &path).unwrap();
        let store = Arc::new(ColumnStore::open(&path, 1 << 20).unwrap());
        let pf = Prefetcher::spawn(Arc::clone(&store));
        pf.request(&(0..16).collect::<Vec<_>>());
        drop(pf); // joins the thread → all requested work done
        assert_eq!(store.counters().prefetch_issued(), 4);
        let col = store.with_col(5, |c| c.to_vec()).unwrap();
        assert_eq!(col.as_slice(), ds.x.col(5));
        assert_eq!(store.counters().stalls(), 0);
    }
}
