//! The `HSSRSTOR1` reader: seek/read column service through a bounded LRU
//! chunk cache with pool-dispatched prefetch, counting real I/O.
//!
//! [`ColumnStore`] is the disk-backed analogue of
//! [`crate::data::chunked::ChunkedMatrix`]: the same column-serving
//! surface, but every chunk miss is an actual positioned read, the cache
//! is bounded by a byte budget (`HSSR_CACHE_MB`), and the counters report
//! measured traffic — columns served, chunk loads, **bytes read from
//! disk**, cache hits, and peak resident bytes. Scans are bit-identical to
//! the dense path: a served column slice holds exactly the values the
//! in-memory design would, and the per-column reduction is the same
//! `ops::dot(col, v)/n` every engine uses.

use std::fs::File;
use std::path::Path;
use std::sync::{Arc, Mutex};

use super::cache::ChunkCache;
use super::format::{Header, HEADER_LEN};
use super::{pread, StoreCounters};
use crate::data::Dataset;
use crate::error::{HssrError, Result};
use crate::linalg::{ops, pool, DenseMatrix};

/// A disk-backed column store with a bounded chunk cache.
pub struct ColumnStore {
    file: File,
    header: Header,
    y: Vec<f64>,
    centers: Vec<f64>,
    scales: Vec<f64>,
    name: String,
    cache: Mutex<ChunkCache>,
    counters: StoreCounters,
}

impl ColumnStore {
    /// Open a store, validating the header and loading the (small) tail:
    /// `y` and the per-column stats. `budget_bytes` bounds the chunk
    /// cache; a budget smaller than one chunk still admits the chunk
    /// being scanned (the cache never wedges).
    pub fn open(path: &Path, budget_bytes: usize) -> Result<ColumnStore> {
        let file = File::open(path)?;
        let mut head = [0u8; HEADER_LEN as usize];
        pread(&file, &mut head, 0)?;
        let header = Header::decode(&head)?;
        // Overflow-checked size math: a corrupt header whose dimensions
        // wrap must be rejected here, not surface as a huge allocation.
        let expect = header.checked_file_len().ok_or_else(|| {
            HssrError::Config(format!(
                "{}: store header dimensions overflow (n={}, p={})",
                path.display(),
                header.n,
                header.p
            ))
        })?;
        let actual = file.metadata()?.len();
        if actual != expect {
            return Err(HssrError::Config(format!(
                "{}: store truncated ({actual} bytes, header implies {expect})",
                path.display()
            )));
        }
        let mut tail = vec![0u8; (header.n + 2 * header.p) * 8];
        pread(&file, &mut tail, header.tail_offset())?;
        let f64s = |range: std::ops::Range<usize>| -> Vec<f64> {
            tail[range.start * 8..range.end * 8]
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        let (n, p) = (header.n, header.p);
        Ok(ColumnStore {
            file,
            header,
            y: f64s(0..n),
            centers: f64s(n..n + p),
            scales: f64s(n + p..n + 2 * p),
            name: path
                .file_name()
                .and_then(|s| s.to_str())
                .unwrap_or("store")
                .to_string(),
            cache: Mutex::new(ChunkCache::new(budget_bytes.max(1))),
            counters: StoreCounters::default(),
        })
    }

    /// Rows.
    pub fn nrows(&self) -> usize {
        self.header.n
    }

    /// Columns.
    pub fn ncols(&self) -> usize {
        self.header.p
    }

    /// The decoded header.
    pub fn header(&self) -> &Header {
        &self.header
    }

    /// Centered response stored in the tail.
    pub fn y(&self) -> &[f64] {
        &self.y
    }

    /// Per-column centers (raw-data means for a converted store; dataset
    /// metadata for a spilled one).
    pub fn centers(&self) -> &[f64] {
        &self.centers
    }

    /// Per-column scales (0 marks a constant column).
    pub fn scales(&self) -> &[f64] {
        &self.scales
    }

    /// File name, used as the workload label.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The real-I/O counters.
    pub fn counters(&self) -> &StoreCounters {
        &self.counters
    }

    /// The cache byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.cache.lock().unwrap().budget()
    }

    /// Zero the counters and drop every cached chunk (per-rule bench
    /// isolation).
    pub fn reset(&self) {
        self.counters.reset();
        self.cache.lock().unwrap().clear();
    }

    /// Read chunk `c` from disk and decode it to standardized column
    /// values. Counts the load. Does not touch the cache.
    fn load_chunk(&self, c: usize) -> Result<Vec<f64>> {
        let bytes = self.header.chunk_bytes(c);
        let mut raw = vec![0u8; bytes];
        pread(&self.file, &mut raw, self.header.chunk_offset(c))?;
        self.counters.add_load(bytes as u64);
        Ok(self.decode_chunk(c, &raw))
    }

    /// Decode a chunk payload, applying the per-column affine transform
    /// when the store holds raw data.
    fn decode_chunk(&self, c: usize, raw: &[u8]) -> Vec<f64> {
        let n = self.header.n;
        let width = self.header.chunk_width(c);
        let j0 = c * self.header.chunk_cols;
        let mut out = Vec::with_capacity(width * n);
        for (local, col) in raw.chunks_exact(n * 8).enumerate() {
            let j = j0 + local;
            let scale = self.scales[j];
            if self.header.standardized {
                out.extend(col.chunks_exact(8).map(|b| f64::from_le_bytes(b.try_into().unwrap())));
            } else if scale == 0.0 {
                // Constant column: standardization zeroes it out.
                out.resize(out.len() + n, 0.0);
            } else {
                let center = self.centers[j];
                let inv = 1.0 / scale;
                out.extend(col.chunks_exact(8).map(|b| {
                    (f64::from_le_bytes(b.try_into().unwrap()) - center) * inv
                }));
            }
        }
        out
    }

    /// Fetch chunk `c` through the cache (hit: LRU touch; miss: disk load
    /// + insert with LRU eviction under the byte budget).
    fn chunk(&self, c: usize) -> Result<Arc<Vec<f64>>> {
        if let Some(buf) = self.cache.lock().unwrap().get(c) {
            self.counters.add_hit();
            return Ok(buf);
        }
        let buf = Arc::new(self.load_chunk(c)?);
        let mut cache = self.cache.lock().unwrap();
        cache.insert(c, Arc::clone(&buf));
        self.counters.note_resident(cache.resident() as u64);
        Ok(buf)
    }

    /// Serve column `j` to `f`, counting the fetch. The slice holds the
    /// standardized values of the column.
    pub fn with_col<R>(&self, j: usize, f: impl FnOnce(&[f64]) -> R) -> Result<R> {
        debug_assert!(j < self.header.p);
        self.counters.add_col();
        let c = j / self.header.chunk_cols;
        let buf = self.chunk(c)?;
        let off = (j - c * self.header.chunk_cols) * self.header.n;
        Ok(f(&buf[off..off + self.header.n]))
    }

    /// Pool-dispatched prefetch: load the (distinct) chunks covering
    /// `cols` that are not yet cached, in parallel on the persistent
    /// worker pool, up to the cache capacity — the read-ahead the scan
    /// engine issues for the upcoming safe set before its dot loop.
    pub fn prefetch(&self, cols: &[usize]) -> Result<()> {
        let mut wanted: Vec<usize> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            let capacity = (cache.budget() / self.header.chunk_bytes(0).max(1)).max(1);
            for &j in cols {
                let c = j / self.header.chunk_cols;
                if wanted.len() >= capacity {
                    break;
                }
                if !cache.contains(c) && !wanted.contains(&c) {
                    wanted.push(c);
                }
            }
        }
        if wanted.is_empty() {
            return Ok(());
        }
        let loaded: Vec<Result<Vec<f64>>> =
            pool::global().map(wanted.len(), |k| self.load_chunk(wanted[k]));
        let mut cache = self.cache.lock().unwrap();
        for (c, buf) in wanted.into_iter().zip(loaded) {
            cache.insert(c, Arc::new(buf?));
        }
        self.counters.note_resident(cache.resident() as u64);
        Ok(())
    }

    /// Scan `out[k] = x_{idx[k]}ᵀ v / n` against the store: prefetch the
    /// covering chunks, then the same per-column reduction every engine
    /// uses (bit-identical to the dense path — per-column dots are
    /// independent, so dispatching them on the pool changes wall-clock,
    /// not bits). Small scans stay serial, mirroring the native kernels'
    /// [`crate::linalg::blocked::PAR_THRESHOLD`].
    pub fn scan_subset(&self, v: &[f64], idx: &[usize], out: &mut [f64]) -> Result<()> {
        assert_eq!(out.len(), idx.len());
        assert_eq!(v.len(), self.header.n);
        self.prefetch(idx)?;
        let inv_n = 1.0 / self.header.n as f64;
        if self.header.n * idx.len() < crate::linalg::blocked::PAR_THRESHOLD {
            for (k, &j) in idx.iter().enumerate() {
                out[k] = self.with_col(j, |col| ops::dot(col, v))? * inv_n;
            }
            return Ok(());
        }
        let dots: Vec<Result<f64>> = pool::global().map(idx.len(), |k| {
            self.with_col(idx[k], |col| ops::dot(col, v)).map(|d| d * inv_n)
        });
        for (o, d) in out.iter_mut().zip(dots) {
            *o = d?;
        }
        Ok(())
    }

    /// Materialize the full standardized dataset (dense). Reads every
    /// chunk once, directly — bypassing the cache and the counters, since
    /// this is a load, not scan traffic.
    pub fn to_dataset(&self) -> Result<Dataset> {
        let (n, p) = (self.header.n, self.header.p);
        let mut data = Vec::with_capacity(n * p);
        for c in 0..self.header.num_chunks() {
            let bytes = self.header.chunk_bytes(c);
            let mut raw = vec![0u8; bytes];
            pread(&self.file, &mut raw, self.header.chunk_offset(c))?;
            data.extend(self.decode_chunk(c, &raw));
        }
        Ok(Dataset {
            x: DenseMatrix::from_col_major(n, p, data)?,
            y: self.y.clone(),
            centers: self.centers.clone(),
            scales: self.scales.clone(),
            name: self.name.clone(),
            truth: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::writer::write_dataset;
    use crate::data::DataSpec;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hssr_store_reader_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn dense_store_dense_is_exact() {
        let ds = DataSpec::gene_like(23, 41).generate(7);
        let path = tmp("exact.store");
        write_dataset(&ds, 8, &path).unwrap();
        let store = ColumnStore::open(&path, 1 << 20).unwrap();
        assert_eq!((store.nrows(), store.ncols()), (23, 41));
        let back = store.to_dataset().unwrap();
        assert_eq!(back.x.as_slice(), ds.x.as_slice(), "matrix bytes drifted");
        assert_eq!(back.y, ds.y);
        assert_eq!(back.centers, ds.centers);
        assert_eq!(back.scales, ds.scales);
        // column service matches too, and is counted
        for j in [0usize, 7, 40] {
            let col = store.with_col(j, |c| c.to_vec()).unwrap();
            assert_eq!(col.as_slice(), ds.x.col(j));
        }
        assert_eq!(store.counters().cols_fetched(), 3);
    }

    #[test]
    fn tiny_budget_forces_eviction_but_stays_correct() {
        let ds = DataSpec::synthetic(16, 30, 3).generate(1);
        let path = tmp("tiny.store");
        write_dataset(&ds, 4, &path).unwrap();
        // Budget of exactly one 4-column chunk (4·16·8 bytes).
        let store = ColumnStore::open(&path, 4 * 16 * 8).unwrap();
        let v: Vec<f64> = (0..16).map(|i| (i as f64).sin()).collect();
        let idx: Vec<usize> = (0..30).collect();
        let mut got = vec![0.0; 30];
        store.scan_subset(&v, &idx, &mut got).unwrap();
        let want = crate::linalg::blocked::scan_all_vec(&ds.x, &v);
        assert_eq!(got, want, "scans under eviction must stay bit-identical");
        // every chunk had to be loaded, and the cache never outgrew one chunk
        assert!(store.counters().chunk_loads() >= 8);
        assert!(store.counters().peak_resident() <= (4 * 16 * 8) as u64);
        // a second pass re-faults (the working set exceeds the budget)
        store.scan_subset(&v, &idx, &mut got).unwrap();
        assert!(store.counters().chunk_loads() >= 16);
    }

    #[test]
    fn warm_cache_serves_hits_without_reloads() {
        let ds = DataSpec::synthetic(10, 12, 2).generate(2);
        let path = tmp("warm.store");
        write_dataset(&ds, 4, &path).unwrap();
        let store = ColumnStore::open(&path, 1 << 20).unwrap();
        let v = vec![1.0; 10];
        let mut out = vec![0.0; 12];
        store.scan_subset(&v, &(0..12).collect::<Vec<_>>(), &mut out).unwrap();
        let loads = store.counters().chunk_loads();
        assert_eq!(loads, 3);
        store.scan_subset(&v, &(0..12).collect::<Vec<_>>(), &mut out).unwrap();
        assert_eq!(store.counters().chunk_loads(), loads, "warm pass reloaded");
        assert!(store.counters().cache_hits() >= 12);
        store.reset();
        assert_eq!(store.counters().chunk_loads(), 0);
    }

    #[test]
    fn truncated_store_rejected() {
        let ds = DataSpec::synthetic(8, 5, 2).generate(3);
        let path = tmp("trunc.store");
        write_dataset(&ds, 2, &path).unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 8).unwrap();
        drop(f);
        assert!(ColumnStore::open(&path, 1 << 20).is_err());
    }
}
