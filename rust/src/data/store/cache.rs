//! Bounded LRU chunk cache for the column-store reader.
//!
//! The budget is in **bytes** (`HSSR_CACHE_MB` at the CLI); eviction is
//! least-recently-used via a monotone touch stamp. Buffers are handed out
//! as `Arc<Vec<f64>>` so an in-flight scan keeps its chunk alive even if a
//! concurrent insert evicts it — resident accounting tracks what the cache
//! *holds*, which is what the budget bounds.
//!
//! ## Pins
//!
//! A store-backed inner solver walks its working set through a pinned
//! chunk view ([`crate::data::store::reader::PinnedColumns`]): the chunk
//! under the cursor is **pinned**, which exempts it from LRU eviction —
//! mid-burst churn can never evict the chunk a coordinate update is
//! reading — while its bytes stay counted against `resident`, so the
//! byte-budget guarantee covers pinned data too. Pins are released when
//! the cursor advances (and unconditionally on drop, i.e. per solve).
//!
//! ## Prefetch tagging
//!
//! Chunks inserted by the async λ-ahead prefetcher are tagged; the first
//! demand access of a tagged chunk counts a *prefetch hit*, and evicting a
//! tagged chunk that was never used counts a *prefetch waste*. The stats
//! accumulate here (under the cache lock) and are drained into the store's
//! atomic [`crate::data::store::StoreCounters`] by the reader.

use std::collections::HashMap;
use std::sync::Arc;

struct Entry {
    buf: Arc<Vec<f64>>,
    stamp: u64,
    /// Pin count: > 0 exempts the entry from LRU eviction.
    pins: u32,
    /// Inserted by the prefetcher and not yet used by a demand access.
    prefetched: bool,
    /// Fit id that loaded this chunk (`0` = untagged: single-fit CLI runs
    /// and the async prefetcher). A demand hit from a *different* non-zero
    /// fit id is a cross-fit hit — the serve-mode sharing the cache exists
    /// to produce (counted by the reader via [`ChunkCache::owner_of`]).
    owner: u64,
}

/// A byte-budgeted LRU map from chunk index to decoded column data.
pub struct ChunkCache {
    budget: usize,
    map: HashMap<usize, Entry>,
    clock: u64,
    resident: usize,
    /// Demand accesses that found a prefetched chunk (drained via
    /// [`ChunkCache::take_prefetch_stats`]).
    prefetch_hits: u64,
    /// Prefetched chunks evicted without ever being used.
    prefetch_wasted: u64,
}

impl ChunkCache {
    /// Create a cache bounded by `budget` bytes (a single chunk larger
    /// than the budget is still admitted — the cache never refuses the
    /// chunk a scan is about to read).
    pub fn new(budget: usize) -> Self {
        ChunkCache {
            budget,
            map: HashMap::new(),
            clock: 0,
            resident: 0,
            prefetch_hits: 0,
            prefetch_wasted: 0,
        }
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Bytes currently held.
    pub fn resident(&self) -> usize {
        self.resident
    }

    /// Bytes held by pinned entries (always ≤ `resident`).
    pub fn pinned_bytes(&self) -> usize {
        self.map
            .values()
            .filter(|e| e.pins > 0)
            .map(|e| e.buf.len() * 8)
            .sum()
    }

    /// Whether chunk `c` is cached (no LRU touch).
    pub fn contains(&self, c: usize) -> bool {
        self.map.contains_key(&c)
    }

    /// Fit id that loaded chunk `c` (no LRU touch); `None` when absent.
    /// Read *before* the demand [`ChunkCache::get`]/[`ChunkCache::pin`] to
    /// classify the hit as same-fit or cross-fit.
    pub fn owner_of(&self, c: usize) -> Option<u64> {
        self.map.get(&c).map(|e| e.owner)
    }

    /// Fetch chunk `c`, marking it most-recently-used. A first demand hit
    /// on a prefetched chunk clears its tag and counts a prefetch hit.
    pub fn get(&mut self, c: usize) -> Option<Arc<Vec<f64>>> {
        self.clock += 1;
        let clock = self.clock;
        let hits = &mut self.prefetch_hits;
        self.map.get_mut(&c).map(|e| {
            e.stamp = clock;
            if e.prefetched {
                e.prefetched = false;
                *hits += 1;
            }
            Arc::clone(&e.buf)
        })
    }

    /// Pin chunk `c` (must already be cached): exempt it from eviction
    /// until the matching [`ChunkCache::unpin`]. Counts as a use for the
    /// prefetch-hit accounting. Returns whether the entry was present.
    pub fn pin(&mut self, c: usize) -> bool {
        match self.map.get_mut(&c) {
            Some(e) => {
                e.pins += 1;
                if e.prefetched {
                    e.prefetched = false;
                    self.prefetch_hits += 1;
                }
                true
            }
            None => false,
        }
    }

    /// Release one pin on chunk `c` (no-op when absent or unpinned).
    pub fn unpin(&mut self, c: usize) {
        if let Some(e) = self.map.get_mut(&c) {
            e.pins = e.pins.saturating_sub(1);
        }
    }

    /// Pick the LRU eviction victim: the smallest-stamp entry that is not
    /// pinned and not `keep`.
    fn lru_victim(&self, keep: usize) -> Option<usize> {
        self.map
            .iter()
            .filter(|(&k, e)| e.pins == 0 && k != keep)
            .min_by_key(|(_, e)| e.stamp)
            .map(|(&k, _)| k)
    }

    /// Remove `victim`, maintaining resident/waste accounting.
    fn evict(&mut self, victim: usize) {
        if let Some(e) = self.map.remove(&victim) {
            self.resident -= e.buf.len() * 8;
            if e.prefetched {
                self.prefetch_wasted += 1;
            }
        }
    }

    /// Insert chunk `c` loaded by fit `owner` (`0` = untagged), evicting
    /// least-recently-used *unpinned* chunks until the budget holds (or
    /// nothing evictable remains). Returns the number of chunks evicted.
    pub fn insert(&mut self, c: usize, buf: Arc<Vec<f64>>, owner: u64) -> usize {
        let bytes = buf.len() * 8;
        let mut evicted = 0;
        while self.resident + bytes > self.budget {
            // No unpinned LRU victim — stop evicting rather than panic
            // (the oversized chunk is still admitted; see `new`).
            let Some(oldest) = self.lru_victim(c) else {
                break;
            };
            self.evict(oldest);
            evicted += 1;
        }
        self.clock += 1;
        if let Some(old) = self.map.insert(
            c,
            Entry { buf, stamp: self.clock, pins: 0, prefetched: false, owner },
        ) {
            self.resident -= old.buf.len() * 8;
        }
        self.resident += bytes;
        evicted
    }

    /// Prefetch-path insert: admit chunk `c` tagged as prefetched **only
    /// if it fits** — unpinned LRU entries are evicted to make room, but
    /// if the budget still cannot hold it (e.g. everything else is
    /// pinned), the buffer is discarded and `false` returned, so the
    /// async prefetcher can never push `resident` past the budget. An
    /// already-cached chunk is left untouched (`true`).
    pub fn insert_prefetched(&mut self, c: usize, buf: Arc<Vec<f64>>, owner: u64) -> bool {
        if self.map.contains_key(&c) {
            return true;
        }
        let bytes = buf.len() * 8;
        while self.resident + bytes > self.budget {
            let Some(oldest) = self.lru_victim(c) else {
                return false;
            };
            self.evict(oldest);
        }
        self.clock += 1;
        self.map
            .insert(c, Entry { buf, stamp: self.clock, pins: 0, prefetched: true, owner });
        self.resident += bytes;
        true
    }

    /// Drain the accumulated `(prefetch hits, prefetch wastes)`.
    pub fn take_prefetch_stats(&mut self) -> (u64, u64) {
        let out = (self.prefetch_hits, self.prefetch_wasted);
        self.prefetch_hits = 0;
        self.prefetch_wasted = 0;
        out
    }

    /// Drop every cached chunk (used between per-rule bench runs).
    pub fn clear(&mut self) {
        self.map.clear();
        self.resident = 0;
        self.prefetch_hits = 0;
        self.prefetch_wasted = 0;
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn chunk(len: usize, fill: f64) -> Arc<Vec<f64>> {
        Arc::new(vec![fill; len])
    }

    #[test]
    fn lru_evicts_oldest_under_budget() {
        // budget = 2 chunks of 4 f64 (32 bytes each)
        let mut c = ChunkCache::new(64);
        c.insert(0, chunk(4, 0.0), 0);
        c.insert(1, chunk(4, 1.0), 0);
        assert_eq!(c.resident(), 64);
        // touch 0 so 1 becomes LRU
        assert!(c.get(0).is_some());
        let evicted = c.insert(2, chunk(4, 2.0), 0);
        assert_eq!(evicted, 1);
        assert!(c.contains(0) && c.contains(2) && !c.contains(1));
        assert_eq!(c.resident(), 64);
    }

    #[test]
    fn oversized_chunk_still_admitted() {
        let mut c = ChunkCache::new(16);
        c.insert(0, chunk(100, 0.0), 0); // 800 bytes ≫ budget
        assert!(c.contains(0));
        assert_eq!(c.resident(), 800);
        // next insert evicts it
        c.insert(1, chunk(1, 0.0), 0);
        assert!(!c.contains(0) && c.contains(1));
        assert_eq!(c.resident(), 8);
    }

    #[test]
    fn reinsert_replaces_without_leaking_resident() {
        let mut c = ChunkCache::new(1024);
        c.insert(3, chunk(8, 0.0), 0);
        c.insert(3, chunk(8, 1.0), 0);
        assert_eq!(c.resident(), 64);
        assert_eq!(c.get(3).unwrap()[0], 1.0);
        c.clear();
        assert_eq!(c.resident(), 0);
        assert!(c.get(3).is_none());
    }

    #[test]
    fn pinned_chunks_survive_eviction_pressure() {
        // budget = 1 chunk of 4 f64
        let mut c = ChunkCache::new(32);
        c.insert(0, chunk(4, 0.0), 0);
        assert!(c.pin(0));
        assert_eq!(c.pinned_bytes(), 32);
        // A plain insert cannot evict the pinned chunk: it is admitted
        // over budget (the demand path must be served)…
        c.insert(1, chunk(4, 1.0), 0);
        assert!(c.contains(0), "pinned chunk was evicted");
        assert_eq!(c.resident(), 64);
        // …and once unpinned, the old chunk is evictable again.
        c.unpin(0);
        assert_eq!(c.pinned_bytes(), 0);
        c.insert(2, chunk(4, 2.0), 0);
        assert!(!c.contains(0) && c.contains(2));
        assert!(c.resident() <= 64);
    }

    #[test]
    fn prefetched_insert_respects_budget_and_pins() {
        let mut c = ChunkCache::new(32);
        c.insert(0, chunk(4, 0.0), 0);
        c.pin(0);
        // Everything resident is pinned: the prefetcher must refuse.
        assert!(!c.insert_prefetched(1, chunk(4, 1.0), 0));
        assert_eq!(c.resident(), 32);
        c.unpin(0);
        // Now it fits by evicting chunk 0.
        assert!(c.insert_prefetched(1, chunk(4, 1.0), 0));
        assert!(c.contains(1) && !c.contains(0));
        assert_eq!(c.resident(), 32);
    }

    #[test]
    fn prefetch_hit_and_waste_accounting() {
        let mut c = ChunkCache::new(64);
        assert!(c.insert_prefetched(0, chunk(4, 0.0), 0));
        assert!(c.insert_prefetched(1, chunk(4, 1.0), 0));
        // Demand-use chunk 0: one hit, counted once.
        assert!(c.get(0).is_some());
        assert!(c.get(0).is_some());
        // Evict chunk 1 without ever using it: one waste.
        c.insert(2, chunk(4, 2.0), 0);
        c.insert(3, chunk(4, 3.0), 0);
        let (hits, wasted) = c.take_prefetch_stats();
        assert_eq!((hits, wasted), (1, 1));
        // Drained.
        assert_eq!(c.take_prefetch_stats(), (0, 0));
    }

    /// Owner tags stick to the loading fit: reinsert replaces the owner,
    /// demand hits do not, and eviction removes the record entirely.
    #[test]
    fn owner_tag_tracks_loading_fit() {
        let mut c = ChunkCache::new(64);
        c.insert(0, chunk(4, 0.0), 7);
        assert_eq!(c.owner_of(0), Some(7));
        assert_eq!(c.owner_of(1), None);
        // A demand hit from another fit leaves the loader's tag in place.
        assert!(c.get(0).is_some());
        assert_eq!(c.owner_of(0), Some(7));
        // Reinsert (reload after eviction elsewhere) re-tags.
        c.insert(0, chunk(4, 0.5), 9);
        assert_eq!(c.owner_of(0), Some(9));
        assert!(c.insert_prefetched(1, chunk(4, 1.0), 0));
        assert_eq!(c.owner_of(1), Some(0));
    }
}
