//! Bounded LRU chunk cache for the column-store reader.
//!
//! The budget is in **bytes** (`HSSR_CACHE_MB` at the CLI); eviction is
//! least-recently-used via a monotone touch stamp. Buffers are handed out
//! as `Arc<Vec<f64>>` so an in-flight scan keeps its chunk alive even if a
//! concurrent insert evicts it — resident accounting tracks what the cache
//! *holds*, which is what the budget bounds.

use std::collections::HashMap;
use std::sync::Arc;

struct Entry {
    buf: Arc<Vec<f64>>,
    stamp: u64,
}

/// A byte-budgeted LRU map from chunk index to decoded column data.
pub struct ChunkCache {
    budget: usize,
    map: HashMap<usize, Entry>,
    clock: u64,
    resident: usize,
}

impl ChunkCache {
    /// Create a cache bounded by `budget` bytes (a single chunk larger
    /// than the budget is still admitted — the cache never refuses the
    /// chunk a scan is about to read).
    pub fn new(budget: usize) -> Self {
        ChunkCache { budget, map: HashMap::new(), clock: 0, resident: 0 }
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Bytes currently held.
    pub fn resident(&self) -> usize {
        self.resident
    }

    /// Whether chunk `c` is cached (no LRU touch).
    pub fn contains(&self, c: usize) -> bool {
        self.map.contains_key(&c)
    }

    /// Fetch chunk `c`, marking it most-recently-used.
    pub fn get(&mut self, c: usize) -> Option<Arc<Vec<f64>>> {
        self.clock += 1;
        let clock = self.clock;
        self.map.get_mut(&c).map(|e| {
            e.stamp = clock;
            Arc::clone(&e.buf)
        })
    }

    /// Insert chunk `c`, evicting least-recently-used chunks until the
    /// budget holds (or the cache is empty). Returns the number of chunks
    /// evicted.
    pub fn insert(&mut self, c: usize, buf: Arc<Vec<f64>>) -> usize {
        let bytes = buf.len() * 8;
        let mut evicted = 0;
        while self.resident + bytes > self.budget {
            // An empty map has no LRU victim — stop evicting rather than
            // panic (the oversized chunk is still admitted; see `new`).
            let Some(oldest) = self.map.iter().min_by_key(|(_, e)| e.stamp).map(|(&k, _)| k)
            else {
                break;
            };
            if oldest == c {
                break; // replacing in place; handled below
            }
            if let Some(e) = self.map.remove(&oldest) {
                self.resident -= e.buf.len() * 8;
                evicted += 1;
            }
        }
        self.clock += 1;
        if let Some(old) = self.map.insert(c, Entry { buf, stamp: self.clock }) {
            self.resident -= old.buf.len() * 8;
        }
        self.resident += bytes;
        evicted
    }

    /// Drop every cached chunk (used between per-rule bench runs).
    pub fn clear(&mut self) {
        self.map.clear();
        self.resident = 0;
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn chunk(len: usize, fill: f64) -> Arc<Vec<f64>> {
        Arc::new(vec![fill; len])
    }

    #[test]
    fn lru_evicts_oldest_under_budget() {
        // budget = 2 chunks of 4 f64 (32 bytes each)
        let mut c = ChunkCache::new(64);
        c.insert(0, chunk(4, 0.0));
        c.insert(1, chunk(4, 1.0));
        assert_eq!(c.resident(), 64);
        // touch 0 so 1 becomes LRU
        assert!(c.get(0).is_some());
        let evicted = c.insert(2, chunk(4, 2.0));
        assert_eq!(evicted, 1);
        assert!(c.contains(0) && c.contains(2) && !c.contains(1));
        assert_eq!(c.resident(), 64);
    }

    #[test]
    fn oversized_chunk_still_admitted() {
        let mut c = ChunkCache::new(16);
        c.insert(0, chunk(100, 0.0)); // 800 bytes ≫ budget
        assert!(c.contains(0));
        assert_eq!(c.resident(), 800);
        // next insert evicts it
        c.insert(1, chunk(1, 0.0));
        assert!(!c.contains(0) && c.contains(1));
        assert_eq!(c.resident(), 8);
    }

    #[test]
    fn reinsert_replaces_without_leaking_resident() {
        let mut c = ChunkCache::new(1024);
        c.insert(3, chunk(8, 0.0));
        c.insert(3, chunk(8, 1.0));
        assert_eq!(c.resident(), 64);
        assert_eq!(c.get(3).unwrap()[0], 1.0);
        c.clear();
        assert_eq!(c.resident(), 0);
        assert!(c.get(3).is_none());
    }
}
