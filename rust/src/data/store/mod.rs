//! **The real out-of-core substrate**: a chunked on-disk column store
//! (`HSSRSTOR1`) with a streaming writer and a cache-bounded reader.
//!
//! §3.2.3 of the paper argues that HSSR's decisive advantage is *memory*
//! traffic — SSR/SEDPP must scan the full feature matrix at every λ while
//! HSSR touches only the safe set — and biglasso (Zeng & Breheny 2017)
//! shows this wins in practice precisely when the matrix lives on disk.
//! [`crate::data::chunked::ChunkedMatrix`] *models* that substrate in RAM;
//! this module **is** it:
//!
//! * [`format`] — the `HSSRSTOR1` file layout: fixed header, column-major
//!   fixed-size chunks, and a tail holding `y` plus per-column
//!   center/scale stats, all seek-addressable from `(n, p, chunk_cols)`.
//! * [`writer`] — streaming converters. CSV is converted with **streaming
//!   standardization**: Welford per-column mean/variance folded into the
//!   chunk writes, so the full `n×p` matrix is never resident (memory is
//!   bounded by a small row-block buffer). `HSSRBIN` and in-memory
//!   datasets stream column-major directly.
//! * [`reader`] — [`ColumnStore`], which serves column slices via
//!   seek/read through a bounded LRU [`cache::ChunkCache`] with
//!   pool-dispatched parallel prefetch, counting **real I/O**
//!   ([`StoreCounters`]: columns served, disk chunk loads, bytes read,
//!   cache hits, peak resident bytes).
//!
//! [`crate::runtime::ooc::OocEngine`] mounts a [`ColumnStore`] behind the
//! [`crate::runtime::ScanEngine`] trait, so every family's screening/KKT
//! scans run out-of-core with zero driver changes. The cache budget comes
//! from `HSSR_CACHE_MB` ([`cache_budget_bytes`]).
//!
//! **Fault tolerance** (see `docs/ARCHITECTURE.md` § Fault tolerance): the
//! v2 format checksums every chunk and the tail; the reader verifies on
//! load, retries transient failures with bounded backoff, quarantines
//! chunks whose retries exhaust, and counts it all here
//! ([`StoreCounters::retries`] / `checksum_failures` / `short_reads`).
//! [`fault`] provides the deterministic injector that proves the policy
//! masks faults without changing a single bit of any fit.

// The storage layer must never panic on bad data — a flipped bit or a
// poisoned lock has a typed-error path. Test modules opt back out.
#![deny(clippy::unwrap_used)]

pub mod cache;
pub mod fault;
pub mod format;
pub mod reader;
pub mod writer;

pub use fault::{FaultInjector, FaultSpec};
pub use format::{chunk_cols_for, Header, HEADER_LEN, MAGIC, MAGIC2};
pub use reader::{current_fit, ColumnStore, FitTag, PinnedColumns, Prefetcher};
pub use writer::{
    append_f32_shadow, convert_bin, convert_csv, write_columns, write_dataset, write_matrix,
    ColumnSpill, StoreSummary,
};

use std::fs::File;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::Result;

/// Default chunk payload target (bytes) when the caller does not pick a
/// chunk width: big enough to amortize a seek, small enough that a few
/// chunks fit in a tiny test cache.
pub const DEFAULT_CHUNK_BYTES: usize = 256 * 1024;

/// Default cache budget when `HSSR_CACHE_MB` is unset.
pub const DEFAULT_CACHE_MB: usize = 64;

/// Parse an `HSSR_CACHE_MB`-style override: a positive integer number of
/// megabytes; anything else falls back to `default_mb`.
pub fn parse_cache_mb(value: Option<&str>, default_mb: usize) -> usize {
    match value.map(|s| s.trim().parse::<usize>()) {
        Some(Ok(mb)) if mb > 0 => mb,
        _ => default_mb,
    }
}

/// The store cache budget in **bytes**: `HSSR_CACHE_MB` megabytes if set
/// to a positive integer, else [`DEFAULT_CACHE_MB`].
pub fn cache_budget_bytes() -> usize {
    let var = std::env::var("HSSR_CACHE_MB").ok();
    parse_cache_mb(var.as_deref(), DEFAULT_CACHE_MB) * (1 << 20)
}

/// Real-I/O counters shared by the out-of-core stores. The in-RAM
/// [`crate::data::chunked::ChunkedMatrix`] reuses the same struct so the
/// modeled and measured substrates report through one vocabulary.
#[derive(Debug, Default)]
pub struct StoreCounters {
    cols_fetched: AtomicU64,
    chunk_loads: AtomicU64,
    bytes_read: AtomicU64,
    cache_hits: AtomicU64,
    peak_resident: AtomicU64,
    retries: AtomicU64,
    checksum_failures: AtomicU64,
    short_reads: AtomicU64,
    solver_cols: AtomicU64,
    stalls: AtomicU64,
    prefetch_issued: AtomicU64,
    prefetch_hits: AtomicU64,
    prefetch_wasted: AtomicU64,
    cross_fit_hits: AtomicU64,
}

impl StoreCounters {
    /// Count one column served to a scan.
    pub fn add_col(&self) {
        self.cols_fetched.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one chunk load of `bytes` payload (a disk read for the real
    /// store; a modeled fault for the in-RAM chunked matrix).
    pub fn add_load(&self, bytes: u64) {
        self.chunk_loads.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Count one cache hit.
    pub fn add_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the cache-resident byte count after an insert (keeps the
    /// running peak).
    pub fn note_resident(&self, bytes: u64) {
        self.peak_resident.fetch_max(bytes, Ordering::Relaxed);
    }

    /// Count one retried read attempt (transient fault or checksum
    /// mismatch absorbed by the retry policy).
    pub fn add_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one chunk/tail read whose CRC32 did not match.
    pub fn add_checksum_failure(&self) {
        self.checksum_failures.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one short read (`UnexpectedEof` before the buffer filled).
    pub fn add_short_read(&self) {
        self.short_reads.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one column served to an inner solver through a pinned chunk
    /// view. Kept separate from [`StoreCounters::add_col`] so the
    /// scan-accounting invariant (`cols_fetched == cols_scanned`) is
    /// unaffected by store-backed optimizer traffic.
    pub fn add_solver_col(&self) {
        self.solver_cols.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one stall: a demand chunk access that missed the cache and
    /// had to block on a disk read (the cycles prefetch exists to hide).
    pub fn add_stall(&self) {
        self.stalls.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one chunk loaded by the async λ-ahead prefetcher.
    pub fn add_prefetch_issued(&self) {
        self.prefetch_issued.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold in drained cache stats: demand accesses that found a
    /// prefetched chunk, and prefetched chunks evicted unused.
    pub fn add_prefetch_stats(&self, hits: u64, wasted: u64) {
        if hits > 0 {
            self.prefetch_hits.fetch_add(hits, Ordering::Relaxed);
        }
        if wasted > 0 {
            self.prefetch_wasted.fetch_add(wasted, Ordering::Relaxed);
        }
    }

    /// Count one cross-fit cache hit: a demand access from one tagged fit
    /// (see [`reader::FitTag`]) that found a chunk loaded by a *different*
    /// tagged fit. This is the sharing the serve-mode shared cache exists
    /// to create — CV folds and concurrent clients over one design hitting
    /// each other's chunks.
    pub fn add_cross_fit_hit(&self) {
        self.cross_fit_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Columns served since construction (or last reset).
    pub fn cols_fetched(&self) -> u64 {
        self.cols_fetched.load(Ordering::Relaxed)
    }

    /// Chunk loads (disk reads / modeled faults).
    pub fn chunk_loads(&self) -> u64 {
        self.chunk_loads.load(Ordering::Relaxed)
    }

    /// Payload bytes read from disk.
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Cache hits.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Peak cache-resident bytes observed.
    pub fn peak_resident(&self) -> u64 {
        self.peak_resident.load(Ordering::Relaxed)
    }

    /// Read attempts that were retried (transient faults + CRC retries).
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Checksum verification failures observed (each one retried or, when
    /// the budget exhausts, quarantined).
    pub fn checksum_failures(&self) -> u64 {
        self.checksum_failures.load(Ordering::Relaxed)
    }

    /// Short reads observed.
    pub fn short_reads(&self) -> u64 {
        self.short_reads.load(Ordering::Relaxed)
    }

    /// Columns served to inner solvers through pinned chunk views.
    pub fn solver_cols(&self) -> u64 {
        self.solver_cols.load(Ordering::Relaxed)
    }

    /// Demand chunk accesses that blocked on a disk read.
    pub fn stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Chunks loaded asynchronously by the λ-ahead prefetcher.
    pub fn prefetch_issued(&self) -> u64 {
        self.prefetch_issued.load(Ordering::Relaxed)
    }

    /// Demand accesses served by a previously prefetched chunk.
    pub fn prefetch_hits(&self) -> u64 {
        self.prefetch_hits.load(Ordering::Relaxed)
    }

    /// Prefetched chunks evicted without ever being used.
    pub fn prefetch_wasted(&self) -> u64 {
        self.prefetch_wasted.load(Ordering::Relaxed)
    }

    /// Demand hits on chunks loaded by a different concurrent fit.
    pub fn cross_fit_hits(&self) -> u64 {
        self.cross_fit_hits.load(Ordering::Relaxed)
    }

    /// Atomically-read copy of every counter (each field is a relaxed
    /// load; the set is not a consistent cut under concurrent writers,
    /// which is fine for monotonic counters). This — not [`reset`] — is
    /// how per-window traffic is measured while other fits may be
    /// running: take a snapshot before, a snapshot after, and
    /// [`StoreSnapshot::delta_since`] the two.
    ///
    /// [`reset`]: StoreCounters::reset
    pub fn snapshot(&self) -> StoreSnapshot {
        StoreSnapshot {
            cols_fetched: self.cols_fetched(),
            chunk_loads: self.chunk_loads(),
            bytes_read: self.bytes_read(),
            cache_hits: self.cache_hits(),
            peak_resident: self.peak_resident(),
            retries: self.retries(),
            checksum_failures: self.checksum_failures(),
            short_reads: self.short_reads(),
            solver_cols: self.solver_cols(),
            stalls: self.stalls(),
            prefetch_issued: self.prefetch_issued(),
            prefetch_hits: self.prefetch_hits(),
            prefetch_wasted: self.prefetch_wasted(),
            cross_fit_hits: self.cross_fit_hits(),
        }
    }

    /// Zero every counter.
    ///
    /// **Quiescent-only.** Reset is safe only when no fit is touching the
    /// store: a reset while another fit runs silently steals that fit's
    /// traffic from every report (and breaks the `cols_fetched ==
    /// cols_scanned` accounting invariant). The in-tree callers respect
    /// this — the rule-by-rule traffic sweeps (`ooc_fit_traffic`) and
    /// `bench-serve` reset *between* fits/rounds, never during — and
    /// serve mode never resets at all: [`crate::coordinator::serve`]
    /// measures per-window traffic with [`StoreCounters::snapshot`]
    /// deltas and attributes shared-cache sharing via
    /// [`reader::FitTag`]-based `cross_fit_hits` instead.
    pub fn reset(&self) {
        self.cols_fetched.store(0, Ordering::Relaxed);
        self.chunk_loads.store(0, Ordering::Relaxed);
        self.bytes_read.store(0, Ordering::Relaxed);
        self.cache_hits.store(0, Ordering::Relaxed);
        self.peak_resident.store(0, Ordering::Relaxed);
        self.retries.store(0, Ordering::Relaxed);
        self.checksum_failures.store(0, Ordering::Relaxed);
        self.short_reads.store(0, Ordering::Relaxed);
        self.solver_cols.store(0, Ordering::Relaxed);
        self.stalls.store(0, Ordering::Relaxed);
        self.prefetch_issued.store(0, Ordering::Relaxed);
        self.prefetch_hits.store(0, Ordering::Relaxed);
        self.prefetch_wasted.store(0, Ordering::Relaxed);
        self.cross_fit_hits.store(0, Ordering::Relaxed);
    }
}

/// A point-in-time copy of [`StoreCounters`] — plain integers, so
/// snapshots can be differenced to measure the traffic of a window
/// (one fit, one λ phase) without ever resetting the live counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreSnapshot {
    /// Columns served to scans.
    pub cols_fetched: u64,
    /// Chunk loads (disk reads).
    pub chunk_loads: u64,
    /// Payload bytes read.
    pub bytes_read: u64,
    /// Cache hits.
    pub cache_hits: u64,
    /// Peak cache-resident bytes (a high-water mark — `delta_since`
    /// carries the later value, not a difference).
    pub peak_resident: u64,
    /// Retried read attempts.
    pub retries: u64,
    /// Checksum failures.
    pub checksum_failures: u64,
    /// Short reads.
    pub short_reads: u64,
    /// Columns served to inner solvers via pinned chunks.
    pub solver_cols: u64,
    /// Demand accesses that blocked on disk.
    pub stalls: u64,
    /// Chunks loaded by the async prefetcher.
    pub prefetch_issued: u64,
    /// Demand accesses served by a prefetched chunk.
    pub prefetch_hits: u64,
    /// Prefetched chunks evicted unused.
    pub prefetch_wasted: u64,
    /// Demand hits on chunks loaded by a different fit.
    pub cross_fit_hits: u64,
}

impl StoreSnapshot {
    /// Counter movement from `earlier` to `self` (saturating, so a reset
    /// between snapshots degrades to zeros instead of wrapping).
    /// `peak_resident` is a high-water mark, not a counter: the delta
    /// carries `self`'s value.
    pub fn delta_since(&self, earlier: &StoreSnapshot) -> StoreSnapshot {
        StoreSnapshot {
            cols_fetched: self.cols_fetched.saturating_sub(earlier.cols_fetched),
            chunk_loads: self.chunk_loads.saturating_sub(earlier.chunk_loads),
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            cache_hits: self.cache_hits.saturating_sub(earlier.cache_hits),
            peak_resident: self.peak_resident,
            retries: self.retries.saturating_sub(earlier.retries),
            checksum_failures: self
                .checksum_failures
                .saturating_sub(earlier.checksum_failures),
            short_reads: self.short_reads.saturating_sub(earlier.short_reads),
            solver_cols: self.solver_cols.saturating_sub(earlier.solver_cols),
            stalls: self.stalls.saturating_sub(earlier.stalls),
            prefetch_issued: self.prefetch_issued.saturating_sub(earlier.prefetch_issued),
            prefetch_hits: self.prefetch_hits.saturating_sub(earlier.prefetch_hits),
            prefetch_wasted: self.prefetch_wasted.saturating_sub(earlier.prefetch_wasted),
            cross_fit_hits: self.cross_fit_hits.saturating_sub(earlier.cross_fit_hits),
        }
    }
}

/// Positioned read (no shared cursor — safe from pool workers).
pub(crate) fn pread(file: &File, buf: &mut [u8], offset: u64) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.read_exact_at(buf, offset)?;
    }
    #[cfg(windows)]
    {
        use std::os::windows::fs::FileExt;
        let mut done = 0usize;
        while done < buf.len() {
            let k = file.seek_read(&mut buf[done..], offset + done as u64)?;
            if k == 0 {
                return Err(std::io::Error::from(std::io::ErrorKind::UnexpectedEof).into());
            }
            done += k;
        }
    }
    Ok(())
}

/// Positioned write (no shared cursor; extends the file as needed).
pub(crate) fn pwrite(file: &File, buf: &[u8], offset: u64) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.write_all_at(buf, offset)?;
    }
    #[cfg(windows)]
    {
        use std::os::windows::fs::FileExt;
        let mut done = 0usize;
        while done < buf.len() {
            done += file.seek_write(&buf[done..], offset + done as u64)?;
        }
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn cache_mb_parsing() {
        assert_eq!(parse_cache_mb(Some("8"), 64), 8);
        assert_eq!(parse_cache_mb(Some(" 2 "), 64), 2);
        assert_eq!(parse_cache_mb(Some("0"), 64), 64);
        assert_eq!(parse_cache_mb(Some("huge"), 64), 64);
        assert_eq!(parse_cache_mb(None, 64), 64);
    }

    #[test]
    fn counters_track_and_reset() {
        let c = StoreCounters::default();
        c.add_col();
        c.add_col();
        c.add_load(100);
        c.add_hit();
        c.note_resident(64);
        c.note_resident(32);
        c.add_retry();
        c.add_retry();
        c.add_checksum_failure();
        c.add_short_read();
        c.add_solver_col();
        c.add_stall();
        c.add_prefetch_issued();
        c.add_prefetch_stats(2, 1);
        c.add_cross_fit_hit();
        assert_eq!(c.cols_fetched(), 2);
        assert_eq!(c.chunk_loads(), 1);
        assert_eq!(c.bytes_read(), 100);
        assert_eq!(c.cache_hits(), 1);
        assert_eq!(c.peak_resident(), 64);
        assert_eq!(c.retries(), 2);
        assert_eq!(c.checksum_failures(), 1);
        assert_eq!(c.short_reads(), 1);
        assert_eq!(c.solver_cols(), 1);
        assert_eq!(c.stalls(), 1);
        assert_eq!(c.prefetch_issued(), 1);
        assert_eq!((c.prefetch_hits(), c.prefetch_wasted()), (2, 1));
        assert_eq!(c.cross_fit_hits(), 1);
        c.reset();
        assert_eq!(c.cols_fetched() + c.chunk_loads() + c.bytes_read(), 0);
        assert_eq!(c.retries() + c.checksum_failures() + c.short_reads(), 0);
        assert_eq!(c.solver_cols() + c.stalls() + c.prefetch_issued(), 0);
        assert_eq!(c.prefetch_hits() + c.prefetch_wasted() + c.cross_fit_hits(), 0);
    }

    #[test]
    fn snapshot_deltas_measure_windows_without_reset() {
        let c = StoreCounters::default();
        c.add_col();
        c.add_load(10);
        let before = c.snapshot();
        c.add_col();
        c.add_col();
        c.add_load(90);
        c.add_hit();
        c.note_resident(512);
        let d = c.snapshot().delta_since(&before);
        assert_eq!(d.cols_fetched, 2);
        assert_eq!(d.chunk_loads, 1);
        assert_eq!(d.bytes_read, 90);
        assert_eq!(d.cache_hits, 1);
        assert_eq!(d.peak_resident, 512, "high-water mark carries the later value");
        // The live counters were never reset: totals still include the
        // pre-window traffic.
        assert_eq!(c.cols_fetched(), 3);
        assert_eq!(c.bytes_read(), 100);
        // A reset between snapshots saturates to zero instead of wrapping.
        c.reset();
        let after_reset = c.snapshot().delta_since(&before);
        assert_eq!(after_reset.cols_fetched, 0);
        assert_eq!(after_reset.bytes_read, 0);
    }

    #[test]
    fn pread_pwrite_roundtrip() {
        let dir = std::env::temp_dir().join("hssr_store_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("prw.bin");
        let f = File::create(&path).unwrap();
        pwrite(&f, b"abcdef", 4).unwrap();
        pwrite(&f, b"XY", 0).unwrap();
        drop(f);
        let f = File::open(&path).unwrap();
        let mut buf = [0u8; 6];
        pread(&f, &mut buf, 4).unwrap();
        assert_eq!(&buf, b"abcdef");
        let mut head = [0u8; 2];
        pread(&f, &mut head, 0).unwrap();
        assert_eq!(&head, b"XY");
    }
}
