//! Persistent scan-worker pool.
//!
//! The screening scan `z = Xᵀr/n` is executed hundreds of times per path
//! fit (screening, SSR refresh, KKT checking at every λ). The original
//! kernels spawned fresh OS threads via `std::thread::scope` on *every*
//! scan and hard-capped workers at 8; at path granularity the spawn/join
//! overhead rivaled the scan itself. This module replaces that with a
//! process-wide pool of long-lived workers:
//!
//! * **Dispatch** is a generation-stamped job slot guarded by a
//!   `Mutex`/`Condvar` pair: publishing a job bumps the generation and
//!   wakes every worker; workers park on the condvar between jobs (no
//!   spinning, no per-job allocation beyond one `AtomicUsize`).
//! * **Work stealing**: a job is a count of *chunks* (column ranges).
//!   Workers — including the submitting thread — claim chunks from a
//!   shared atomic counter until the range is exhausted, so an uneven
//!   column mix (hot caches, NUMA, frequency scaling) self-balances.
//! * **Sizing**: `std::thread::available_parallelism()` workers by
//!   default — the old 8-thread cap is gone — overridable with the
//!   `HSSR_THREADS` environment variable (read once, at pool creation).
//! * **Reentrancy**: a job submitted from inside a pool worker (e.g. a
//!   [`crate::coordinator::jobs::parallel_map`] job whose fit body scans)
//!   runs inline on the calling thread instead of deadlocking on its own
//!   pool.
//!
//! The pool is created once per process ([`global`]) and reused across
//! every fit; `WorkerPool::with_threads` exists for tests and benchmarks
//! that need a differently-sized instance.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

thread_local! {
    /// True on threads owned by a [`WorkerPool`] (reentrancy guard).
    static IN_POOL_WORKER: Cell<bool> = Cell::new(false);
}

/// Shared-mutable raw pointer for disjoint per-chunk writes from pool
/// workers. Callers must guarantee no two chunks touch the same index.
pub(crate) struct RacyPtr<T>(pub *mut T);
unsafe impl<T> Send for RacyPtr<T> {}
unsafe impl<T> Sync for RacyPtr<T> {}

/// One published job: a lifetime-erased task plus its chunk counter. The
/// pointers are only dereferenced while [`WorkerPool::run`] — whose stack
/// owns both referents — is blocked waiting for the job to finish.
#[derive(Clone, Copy)]
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    next: *const AtomicUsize,
    chunks: usize,
}
unsafe impl Send for Job {}

struct State {
    /// Bumped once per published job; workers run when it advances.
    generation: u64,
    job: Option<Job>,
    /// Workers still executing the current generation.
    running: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    work_done: Condvar,
    /// First panic payload from a chunk (contained so the worker survives;
    /// the submitter re-raises it with the original message).
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Claim chunks from the job's counter until exhausted.
fn run_job(job: Job, shared: &Shared) {
    // SAFETY: see `Job` — the submitter keeps both referents alive until
    // every worker has finished this generation.
    let task = unsafe { &*job.task };
    let next = unsafe { &*job.next };
    loop {
        let c = next.fetch_add(1, Ordering::Relaxed);
        if c >= job.chunks {
            break;
        }
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| task(c))) {
            let mut slot = shared.panic_payload.lock().unwrap();
            slot.get_or_insert(payload);
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    IN_POOL_WORKER.with(|f| f.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            while !st.shutdown && st.generation == seen {
                st = shared.work_ready.wait(st).unwrap();
            }
            if st.shutdown {
                return;
            }
            seen = st.generation;
            st.job.expect("job present when generation advances")
        };
        run_job(job, &shared);
        let mut st = shared.state.lock().unwrap();
        st.running -= 1;
        if st.running == 0 {
            shared.work_done.notify_one();
        }
    }
}

/// A persistent pool of scan workers (see module docs).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes job submission across external threads.
    submit: Mutex<()>,
}

impl WorkerPool {
    /// Create a pool that executes jobs on `threads` threads total
    /// (`threads − 1` parked workers; the submitting thread is the last).
    pub fn with_threads(threads: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                running: 0,
                shutdown: false,
            }),
            work_ready: Condvar::new(),
            work_done: Condvar::new(),
            panic_payload: Mutex::new(None),
        });
        let workers = threads.max(1) - 1;
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            let h = std::thread::Builder::new()
                .name(format!("hssr-scan-{i}"))
                .spawn(move || worker_loop(sh))
                .expect("spawn scan worker");
            handles.push(h);
        }
        WorkerPool { shared, handles, submit: Mutex::new(()) }
    }

    /// Total threads that execute a job (workers + the submitter).
    pub fn threads(&self) -> usize {
        self.handles.len() + 1
    }

    /// Execute `task(c)` for every chunk `c in 0..chunks` across the pool,
    /// blocking until all chunks complete. Chunks are claimed dynamically
    /// (work stealing); the calling thread participates. Calls from inside
    /// a pool worker run inline (serial) — see module docs.
    pub fn run(&self, chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        let inline =
            self.handles.is_empty() || chunks == 1 || IN_POOL_WORKER.with(|f| f.get());
        if inline {
            for c in 0..chunks {
                task(c);
            }
            return;
        }
        // Trace only the cross-thread dispatch path: the inline path above
        // stays untouched, and a disabled tracer costs one relaxed load.
        let mut dispatch_span = crate::obs::trace::Span::begin("pool_dispatch", "pool");
        dispatch_span.arg_u64("chunks", chunks as u64);
        let _guard = self.submit.lock().unwrap();
        let next = AtomicUsize::new(0);
        let job = Job { task: task as *const _, next: &next as *const _, chunks };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(job);
            st.generation = st.generation.wrapping_add(1);
            st.running = self.handles.len();
        }
        self.shared.work_ready.notify_all();
        // The submitter participates in stealing; flag it as in-pool so a
        // nested submission from one of its own chunks runs inline instead
        // of re-locking `submit`.
        let was_in_pool = IN_POOL_WORKER.with(|f| f.replace(true));
        run_job(job, &self.shared);
        IN_POOL_WORKER.with(|f| f.set(was_in_pool));
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.running != 0 {
                st = self.shared.work_done.wait(st).unwrap();
            }
            st.job = None;
        }
        let payload = self.shared.panic_payload.lock().unwrap().take();
        if let Some(payload) = payload {
            resume_unwind(payload);
        }
    }

    /// Run `f(i)` for `i in 0..items` across the pool, returning results in
    /// index order (one chunk per item; work-stealing balances skew).
    pub fn map<T, F>(&self, items: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = (0..items).map(|_| None).collect();
        let slots = RacyPtr(out.as_mut_ptr());
        self.run(items, &|i| {
            // SAFETY: chunk i is claimed by exactly one thread, so slot i
            // has exactly one writer; `run` blocks until all writes land.
            unsafe { *slots.0.add(i) = Some(f(i)) };
        });
        out.into_iter().map(|v| v.expect("pool job completed")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Parse a thread-count override string (the `HSSR_THREADS` format):
/// a positive integer; anything else falls back to the hardware count.
pub fn parse_thread_override(value: Option<&str>, hardware: usize) -> usize {
    match value.map(|s| s.trim().parse::<usize>()) {
        Some(Ok(t)) if t > 0 => t,
        _ => hardware.max(1),
    }
}

/// Thread count the global pool is built with: `HSSR_THREADS` if set to a
/// positive integer, else `available_parallelism()`.
pub fn configured_threads() -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let var = std::env::var("HSSR_THREADS").ok();
    parse_thread_override(var.as_deref(), hw)
}

/// The process-wide scan pool, created on first use and reused by every
/// fit, bench, and the coordinator job runner.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::with_threads(configured_threads()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_chunk_exactly_once() {
        let pool = WorkerPool::with_threads(4);
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        pool.run(hits.len(), &|c| {
            hits[c].fetch_add(1, Ordering::Relaxed);
        });
        for (c, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {c}");
        }
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = WorkerPool::with_threads(3);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(16, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 50 * 16);
    }

    #[test]
    fn map_preserves_order() {
        let pool = WorkerPool::with_threads(4);
        let out = pool.map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::with_threads(1);
        assert_eq!(pool.threads(), 1);
        let out = pool.map(5, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn nested_submission_runs_inline_without_deadlock() {
        let pool = Arc::new(WorkerPool::with_threads(4));
        let p2 = Arc::clone(&pool);
        let total = AtomicUsize::new(0);
        pool.run(8, &|_| {
            // Re-entrant submission from a worker must not deadlock.
            p2.run(4, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn zero_chunks_is_a_noop() {
        let pool = WorkerPool::with_threads(2);
        pool.run(0, &|_| panic!("must not run"));
    }

    #[test]
    fn thread_override_parsing() {
        assert_eq!(parse_thread_override(Some("6"), 8), 6);
        assert_eq!(parse_thread_override(Some(" 12 "), 8), 12);
        assert_eq!(parse_thread_override(Some("0"), 8), 8);
        assert_eq!(parse_thread_override(Some("lots"), 8), 8);
        assert_eq!(parse_thread_override(None, 8), 8);
        assert_eq!(parse_thread_override(None, 0), 1);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn task_panic_propagates_to_submitter() {
        let pool = WorkerPool::with_threads(2);
        pool.run(8, &|c| {
            if c == 3 {
                panic!("boom");
            }
        });
    }
}
