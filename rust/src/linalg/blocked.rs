//! Cache-blocked, pool-parallel screening scans and fused screening/KKT
//! kernels.
//!
//! The dominant operation in every screening rule and KKT check is the scan
//! `z_j = x_jᵀ r / n` over a *set* of columns. For large `p` this is memory
//! bound; we block over columns and fan the blocks out across the
//! persistent [`super::pool`] workers (work-stealing chunk claim, no
//! per-scan thread spawns). Threading kicks in only above
//! [`PAR_THRESHOLD`] scanned entries so small problems never pay dispatch
//! overhead.
//!
//! Beyond the plain scans, this module provides the **fused passes** that
//! Algorithm 1 runs once per λ step:
//!
//! * [`fused_screen`] — a single traversal that, per column, applies the
//!   safe-rule predicate, lazily refreshes `z_j` (only when stale — the
//!   paper's line-4 semantics), and applies the SSR threshold, instead of
//!   three separate loops (safe screen → stale subset scan → strong-set
//!   filter) with intermediate index vectors.
//! * [`fused_kkt`] — a single post-convergence traversal that recomputes
//!   `z_j` at the final residual and tests the KKT condition for
//!   non-strong survivors, subsuming the separate KKT subset scan and the
//!   end-of-step strong-set refresh.
//! * [`group_norms`] / [`fused_group_screen`] / [`fused_group_kkt`] — the
//!   group-lasso analogues at group granularity; `fused_group_screen` is
//!   the single traversal that applies the per-group safe predicate,
//!   refreshes stale pooled norms, and applies the group-SSR filter.
//!
//! The `*_scoped` variants keep the original spawn-per-scan
//! `std::thread::scope` implementation for benchmarking the pool win
//! (`benches/micro_kernels.rs`, `benches/perf_probe.rs`).

use super::ops;
use super::pool;
use super::pool::RacyPtr;
use super::DenseMatrix;

/// Minimum number of matrix entries scanned before the pool is used.
pub const PAR_THRESHOLD: usize = 1 << 20;

/// Columns per work-stealing chunk for `total` columns on `threads`
/// threads: ~8 chunks per thread for balance, at least 4 columns per chunk
/// to amortize the claim.
fn cols_per_chunk(total: usize, threads: usize) -> usize {
    total.div_ceil(threads.max(1) * 8).max(4)
}

/// Dense scan: `out[j] = x_jᵀ v / n` for every column `j`, pool-parallel.
pub fn scan_all(x: &DenseMatrix, v: &[f64], out: &mut [f64]) {
    assert_eq!(v.len(), x.nrows());
    assert_eq!(out.len(), x.ncols());
    let n = x.nrows();
    let p = x.ncols();
    let inv_n = 1.0 / n as f64;
    if n * p < PAR_THRESHOLD {
        for (j, o) in out.iter_mut().enumerate() {
            *o = ops::dot(x.col(j), v) * inv_n;
        }
        return;
    }
    let pool = pool::global();
    let per = cols_per_chunk(p, pool.threads());
    let outp = RacyPtr(out.as_mut_ptr());
    pool.run(p.div_ceil(per), &|c| {
        let j0 = c * per;
        let j1 = (j0 + per).min(p);
        for j in j0..j1 {
            // SAFETY: chunk c owns out[j0..j1] exclusively.
            unsafe { *outp.0.add(j) = ops::dot(x.col(j), v) * inv_n };
        }
    });
}

/// Subset scan: `out[k] = x_{idx[k]}ᵀ v / n`, pool-parallel over `idx`.
pub fn scan_subset(x: &DenseMatrix, v: &[f64], idx: &[usize], out: &mut [f64]) {
    assert_eq!(v.len(), x.nrows());
    assert_eq!(out.len(), idx.len());
    let n = x.nrows();
    let inv_n = 1.0 / n as f64;
    if n * idx.len() < PAR_THRESHOLD {
        for (k, &j) in idx.iter().enumerate() {
            out[k] = ops::dot(x.col(j), v) * inv_n;
        }
        return;
    }
    let pool = pool::global();
    let per = cols_per_chunk(idx.len(), pool.threads());
    let outp = RacyPtr(out.as_mut_ptr());
    pool.run(idx.len().div_ceil(per), &|c| {
        let k0 = c * per;
        let k1 = (k0 + per).min(idx.len());
        for k in k0..k1 {
            // SAFETY: chunk c owns out[k0..k1] exclusively.
            unsafe { *outp.0.add(k) = ops::dot(x.col(idx[k]), v) * inv_n };
        }
    });
}

/// Scan returning a freshly allocated vector (convenience wrapper).
pub fn scan_all_vec(x: &DenseMatrix, v: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; x.ncols()];
    scan_all(x, v, &mut out);
    out
}

/// Pool-parallel f32 shadow scan: `out[j] = fl32(x32_jᵀ v32) / n` over
/// every column of the column-major `n × p` f32 `mirror` (division done
/// in f64). Feeds the mixed-precision screening prefilters only — every
/// consumer must widen its bounds by
/// [`super::simd::f32_scan_error_bound`].
pub fn scan_all_f32_mirror(mirror: &[f32], n: usize, p: usize, v32: &[f32], out: &mut [f64]) {
    assert_eq!(mirror.len(), n * p);
    assert_eq!(v32.len(), n);
    assert_eq!(out.len(), p);
    let inv_n = 1.0 / n as f64;
    if n * p < PAR_THRESHOLD {
        for (j, o) in out.iter_mut().enumerate() {
            *o = super::simd::dot_f32(&mirror[j * n..(j + 1) * n], v32) as f64 * inv_n;
        }
        return;
    }
    let pool = pool::global();
    let per = cols_per_chunk(p, pool.threads());
    let outp = RacyPtr(out.as_mut_ptr());
    pool.run(p.div_ceil(per), &|c| {
        let j0 = c * per;
        let j1 = (j0 + per).min(p);
        for j in j0..j1 {
            let d = super::simd::dot_f32(&mirror[j * n..(j + 1) * n], v32) as f64 * inv_n;
            // SAFETY: chunk c owns out[j0..j1] exclusively.
            unsafe { *outp.0.add(j) = d };
        }
    });
}

// ---------------------------------------------------------------------------
// Legacy spawn-per-scan kernels, kept for pooled-vs-scoped benchmarking.
// ---------------------------------------------------------------------------

/// Worker count for the scoped (spawn-per-scan) kernels — the original
/// policy, including its 8-thread cap.
fn scoped_workers(work: usize) -> usize {
    if work < PAR_THRESHOLD {
        return 1;
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    hw.min(8).max(1)
}

/// [`scan_all`] with the original `std::thread::scope` spawn-per-scan
/// strategy (benchmark baseline; numerically identical).
pub fn scan_all_scoped(x: &DenseMatrix, v: &[f64], out: &mut [f64]) {
    assert_eq!(v.len(), x.nrows());
    assert_eq!(out.len(), x.ncols());
    let n = x.nrows();
    let p = x.ncols();
    let inv_n = 1.0 / n as f64;
    let workers = scoped_workers(n * p);
    if workers == 1 {
        for (j, o) in out.iter_mut().enumerate() {
            *o = ops::dot(x.col(j), v) * inv_n;
        }
        return;
    }
    let cols_per = p.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, chunk) in out.chunks_mut(cols_per).enumerate() {
            let j0 = w * cols_per;
            s.spawn(move || {
                for (dj, o) in chunk.iter_mut().enumerate() {
                    *o = ops::dot(x.col(j0 + dj), v) * inv_n;
                }
            });
        }
    });
}

/// [`scan_subset`] with the original spawn-per-scan strategy (benchmark
/// baseline; numerically identical).
pub fn scan_subset_scoped(x: &DenseMatrix, v: &[f64], idx: &[usize], out: &mut [f64]) {
    assert_eq!(v.len(), x.nrows());
    assert_eq!(out.len(), idx.len());
    let n = x.nrows();
    let inv_n = 1.0 / n as f64;
    let workers = scoped_workers(n * idx.len());
    if workers == 1 {
        for (k, &j) in idx.iter().enumerate() {
            out[k] = ops::dot(x.col(j), v) * inv_n;
        }
        return;
    }
    let per = idx.len().div_ceil(workers);
    std::thread::scope(|s| {
        for (chunk_idx, chunk_out) in idx.chunks(per).zip(out.chunks_mut(per)) {
            s.spawn(move || {
                for (k, &j) in chunk_idx.iter().enumerate() {
                    chunk_out[k] = ops::dot(x.col(j), v) * inv_n;
                }
            });
        }
    });
}

// ---------------------------------------------------------------------------
// Fused passes.
// ---------------------------------------------------------------------------

/// Outcome of one [`fused_screen`] pass.
#[derive(Clone, Debug, Default)]
pub struct FusedScreenOut {
    /// Survivors of the safe rule (|S|).
    pub safe_size: usize,
    /// Features discarded by the point-wise predicate in this pass.
    pub discarded: usize,
    /// The strong set `H` (ascending; survivors passing the SSR threshold).
    pub strong: Vec<usize>,
    /// Columns whose `z_j` was (re)computed.
    pub cols_scanned: u64,
}

/// Outcome of one [`fused_kkt`] / [`fused_group_kkt`] pass.
#[derive(Clone, Debug, Default)]
pub struct FusedKktOut {
    /// KKT violators (ascending).
    pub violations: Vec<usize>,
    /// Candidates tested (survivors outside the strong set).
    pub checked: usize,
    /// Columns scanned (candidates + refreshed strong columns).
    pub cols_scanned: u64,
}

/// Per-chunk accumulator for the fused passes (merged in chunk order so
/// index lists come out ascending and deterministic).
#[derive(Default)]
struct ChunkAcc {
    safe: usize,
    discarded: usize,
    checked: usize,
    scanned: u64,
    picked: Vec<usize>,
}

/// Fused screening pass (safe predicate + lazy-`z` refresh + SSR filter)
/// in one column traversal. For each `j` with `survive[j]`:
///
/// 1. if `keep` is given and `keep(j)` is false, clear `survive[j]` (safe
///    discard) and skip the column — its `z_j` is never computed;
/// 2. else, if `z_valid[j]` is false, compute `z[j] = x_jᵀ r / n` (lazy-z);
/// 3. classify into the strong set iff `|z_j| ≥ ssr_threshold`.
///
/// Selection is bit-identical to the unfused screen → subset-scan → filter
/// sequence: the same `ops::dot` kernel computes each `z_j`, and the same
/// comparisons run in the same per-column order.
pub fn fused_screen(
    x: &DenseMatrix,
    r: &[f64],
    keep: Option<&(dyn Fn(usize) -> bool + Sync)>,
    ssr_threshold: f64,
    survive: &mut [bool],
    z: &mut [f64],
    z_valid: &mut [bool],
) -> FusedScreenOut {
    let n = x.nrows();
    let p = x.ncols();
    assert_eq!(survive.len(), p);
    assert_eq!(z.len(), p);
    assert_eq!(z_valid.len(), p);
    assert_eq!(r.len(), n);
    let inv_n = 1.0 / n as f64;
    // Upper bound on scan work: stale survivors (the predicate only shrinks
    // this) × n.
    let stale = survive.iter().zip(z_valid.iter()).filter(|&(&s, &v)| s && !v).count();
    let mut out = FusedScreenOut::default();
    if stale * n < PAR_THRESHOLD {
        for j in 0..p {
            if !survive[j] {
                continue;
            }
            if let Some(pred) = keep {
                if !pred(j) {
                    survive[j] = false;
                    out.discarded += 1;
                    continue;
                }
            }
            out.safe_size += 1;
            if !z_valid[j] {
                z[j] = ops::dot(x.col(j), r) * inv_n;
                z_valid[j] = true;
                out.cols_scanned += 1;
            }
            if z[j].abs() >= ssr_threshold {
                out.strong.push(j);
            }
        }
        return out;
    }
    let pool = pool::global();
    let per = cols_per_chunk(p, pool.threads());
    let chunks = p.div_ceil(per);
    let mut accs: Vec<ChunkAcc> = (0..chunks).map(|_| ChunkAcc::default()).collect();
    {
        let sp = RacyPtr(survive.as_mut_ptr());
        let zp = RacyPtr(z.as_mut_ptr());
        let vp = RacyPtr(z_valid.as_mut_ptr());
        let ap = RacyPtr(accs.as_mut_ptr());
        pool.run(chunks, &|c| {
            let j0 = c * per;
            let j1 = (j0 + per).min(p);
            // SAFETY: chunk c owns accs[c] and columns [j0, j1) of the
            // survive/z/z_valid slices exclusively.
            let acc = unsafe { &mut *ap.0.add(c) };
            for j in j0..j1 {
                let sj = unsafe { &mut *sp.0.add(j) };
                if !*sj {
                    continue;
                }
                if let Some(pred) = keep {
                    if !pred(j) {
                        *sj = false;
                        acc.discarded += 1;
                        continue;
                    }
                }
                acc.safe += 1;
                let vj = unsafe { &mut *vp.0.add(j) };
                let zj = unsafe { &mut *zp.0.add(j) };
                if !*vj {
                    *zj = ops::dot(x.col(j), r) * inv_n;
                    *vj = true;
                    acc.scanned += 1;
                }
                if zj.abs() >= ssr_threshold {
                    acc.picked.push(j);
                }
            }
        });
    }
    for mut acc in accs {
        out.safe_size += acc.safe;
        out.discarded += acc.discarded;
        out.cols_scanned += acc.scanned;
        out.strong.append(&mut acc.picked);
    }
    out
}

/// Fused post-convergence KKT pass in one column traversal. For each `j`
/// with `survive[j]`:
///
/// * strong columns (`in_strong[j]`) are rescanned iff `refresh_strong`
///   (so the next λ's SSR screening sees correlations at the final
///   residual — subsuming the unfused end-of-step strong refresh);
/// * non-strong survivors get `z_j` recomputed and `violates(z_j)` applied.
///
/// Columns whose `z_valid[j]` is already set are **not** rescanned: the
/// cached `z[j]` is used directly (and not counted in `cols_scanned`).
/// This is the fused-epoch contract — a dynamic rule's rescreen pass may
/// publish the correlations it just computed at the *same residual* into
/// `z`/`z_valid`, and this pass then reuses them instead of paying a
/// second column traversal. Callers that cannot guarantee freshness must
/// clear `z_valid` first (the solver does so whenever CD moved the
/// residual).
///
/// Violators come back ascending, matching the unfused
/// scan-subset-then-filter order exactly.
#[allow(clippy::too_many_arguments)]
pub fn fused_kkt(
    x: &DenseMatrix,
    r: &[f64],
    survive: &[bool],
    in_strong: &[bool],
    violates: &(dyn Fn(f64) -> bool + Sync),
    refresh_strong: bool,
    z: &mut [f64],
    z_valid: &mut [bool],
) -> FusedKktOut {
    let n = x.nrows();
    let p = x.ncols();
    assert_eq!(survive.len(), p);
    assert_eq!(in_strong.len(), p);
    assert_eq!(z.len(), p);
    assert_eq!(z_valid.len(), p);
    assert_eq!(r.len(), n);
    let inv_n = 1.0 / n as f64;
    let work = (0..p)
        .filter(|&j| survive[j] && !z_valid[j] && (!in_strong[j] || refresh_strong))
        .count();
    let mut out = FusedKktOut::default();
    if work * n < PAR_THRESHOLD {
        for j in 0..p {
            if !survive[j] {
                continue;
            }
            if in_strong[j] {
                if refresh_strong && !z_valid[j] {
                    z[j] = ops::dot(x.col(j), r) * inv_n;
                    z_valid[j] = true;
                    out.cols_scanned += 1;
                }
                continue;
            }
            if !z_valid[j] {
                z[j] = ops::dot(x.col(j), r) * inv_n;
                z_valid[j] = true;
                out.cols_scanned += 1;
            }
            out.checked += 1;
            if violates(z[j]) {
                out.violations.push(j);
            }
        }
        return out;
    }
    let pool = pool::global();
    let per = cols_per_chunk(p, pool.threads());
    let chunks = p.div_ceil(per);
    let mut accs: Vec<ChunkAcc> = (0..chunks).map(|_| ChunkAcc::default()).collect();
    {
        let zp = RacyPtr(z.as_mut_ptr());
        let vp = RacyPtr(z_valid.as_mut_ptr());
        let ap = RacyPtr(accs.as_mut_ptr());
        pool.run(chunks, &|c| {
            let j0 = c * per;
            let j1 = (j0 + per).min(p);
            // SAFETY: chunk c owns accs[c] and columns [j0, j1) of z and
            // z_valid exclusively; survive/in_strong are read-only.
            let acc = unsafe { &mut *ap.0.add(c) };
            for j in j0..j1 {
                if !survive[j] {
                    continue;
                }
                // SAFETY: chunk c owns z[j] and z_valid[j] exclusively.
                let vj = unsafe { *vp.0.add(j) };
                if in_strong[j] {
                    if refresh_strong && !vj {
                        unsafe {
                            *zp.0.add(j) = ops::dot(x.col(j), r) * inv_n;
                            *vp.0.add(j) = true;
                        }
                        acc.scanned += 1;
                    }
                    continue;
                }
                let zj = if vj {
                    // SAFETY: as above; the cached value is fresh.
                    unsafe { *zp.0.add(j) }
                } else {
                    let zj = ops::dot(x.col(j), r) * inv_n;
                    unsafe {
                        *zp.0.add(j) = zj;
                        *vp.0.add(j) = true;
                    }
                    acc.scanned += 1;
                    zj
                };
                acc.checked += 1;
                if violates(zj) {
                    acc.picked.push(j);
                }
            }
        });
    }
    for mut acc in accs {
        out.checked += acc.checked;
        out.cols_scanned += acc.scanned;
        out.violations.append(&mut acc.picked);
    }
    out
}

/// Pool-parallel group-norm refresh: for each `g` in `groups`, recompute
/// `znorm[g] = ‖X_gᵀ r‖ / n` and mark it valid. Returns columns scanned.
///
/// The per-group norm is computed exactly as the unfused path did (column
/// dots collected into a buffer, then [`ops::nrm2`]) so results are
/// bit-identical.
pub fn group_norms(
    x: &DenseMatrix,
    r: &[f64],
    starts: &[usize],
    sizes: &[usize],
    groups: &[usize],
    znorm: &mut [f64],
    znorm_valid: &mut [bool],
) -> u64 {
    let n = x.nrows();
    let inv_n = 1.0 / n as f64;
    let norm_of = |g: usize, buf: &mut Vec<f64>| -> f64 {
        buf.clear();
        for j in starts[g]..starts[g] + sizes[g] {
            buf.push(ops::dot(x.col(j), r) * inv_n);
        }
        ops::nrm2(buf)
    };
    let total_cols: usize = groups.iter().map(|&g| sizes[g]).sum();
    if total_cols * n < PAR_THRESHOLD {
        let mut buf = Vec::new();
        for &g in groups {
            znorm[g] = norm_of(g, &mut buf);
            znorm_valid[g] = true;
        }
        return total_cols as u64;
    }
    let pool = pool::global();
    let per = groups.len().div_ceil(pool.threads() * 8).max(1);
    let zp = RacyPtr(znorm.as_mut_ptr());
    let vp = RacyPtr(znorm_valid.as_mut_ptr());
    pool.run(groups.len().div_ceil(per), &|c| {
        let k0 = c * per;
        let k1 = (k0 + per).min(groups.len());
        let mut buf = Vec::new();
        for &g in &groups[k0..k1] {
            // SAFETY: `groups` holds distinct indices and chunk c owns
            // positions [k0, k1) exclusively.
            unsafe {
                *zp.0.add(g) = norm_of(g, &mut buf);
                *vp.0.add(g) = true;
            }
        }
    });
    total_cols as u64
}

/// Fused group-level screening pass — [`fused_screen`] at group
/// granularity, in one traversal over the groups. For each `g` with
/// `survive[g]`:
///
/// 1. if `keep` is given and `keep(g)` is false, clear `survive[g]` (safe
///    discard) and skip the group — its columns are never touched;
/// 2. else, if `znorm_valid[g]` is false, recompute
///    `znorm[g] = ‖X_gᵀr‖/n` (lazy norms, `W_g` column scans);
/// 3. classify into the strong set iff `znorm[g] ≥ √W_g · ssr_t`
///    (group SSR, rule (20); `ssr_t` already carries the elastic-net α).
///
/// Selection is bit-identical to the scan-then-filter default (predicate
/// sweep → [`group_norms`] over the stale survivors → strong filter): the
/// per-group norm is computed by the same buffer+`nrm2` kernel, and the
/// same comparisons run in the same per-group order.
#[allow(clippy::too_many_arguments)]
pub fn fused_group_screen(
    x: &DenseMatrix,
    r: &[f64],
    starts: &[usize],
    sizes: &[usize],
    keep: Option<&(dyn Fn(usize) -> bool + Sync)>,
    ssr_t: f64,
    survive: &mut [bool],
    znorm: &mut [f64],
    znorm_valid: &mut [bool],
) -> FusedScreenOut {
    let n = x.nrows();
    let g_count = starts.len();
    assert_eq!(sizes.len(), g_count);
    assert_eq!(survive.len(), g_count);
    assert_eq!(znorm.len(), g_count);
    assert_eq!(znorm_valid.len(), g_count);
    assert_eq!(r.len(), n);
    let inv_n = 1.0 / n as f64;
    let norm_of = |g: usize, buf: &mut Vec<f64>| -> f64 {
        buf.clear();
        for j in starts[g]..starts[g] + sizes[g] {
            buf.push(ops::dot(x.col(j), r) * inv_n);
        }
        ops::nrm2(buf)
    };
    // Upper bound on scan work: stale surviving groups (the predicate only
    // shrinks this) × n.
    let stale_cols: usize = (0..g_count)
        .filter(|&g| survive[g] && !znorm_valid[g])
        .map(|g| sizes[g])
        .sum();
    let mut out = FusedScreenOut::default();
    if stale_cols * n < PAR_THRESHOLD {
        let mut buf = Vec::new();
        for g in 0..g_count {
            if !survive[g] {
                continue;
            }
            if let Some(pred) = keep {
                if !pred(g) {
                    survive[g] = false;
                    out.discarded += 1;
                    continue;
                }
            }
            out.safe_size += 1;
            if !znorm_valid[g] {
                znorm[g] = norm_of(g, &mut buf);
                znorm_valid[g] = true;
                out.cols_scanned += sizes[g] as u64;
            }
            if znorm[g] >= (sizes[g] as f64).sqrt() * ssr_t {
                out.strong.push(g);
            }
        }
        return out;
    }
    let pool = pool::global();
    let per = g_count.div_ceil(pool.threads() * 8).max(1);
    let chunks = g_count.div_ceil(per);
    let mut accs: Vec<ChunkAcc> = (0..chunks).map(|_| ChunkAcc::default()).collect();
    {
        let sp = RacyPtr(survive.as_mut_ptr());
        let zp = RacyPtr(znorm.as_mut_ptr());
        let vp = RacyPtr(znorm_valid.as_mut_ptr());
        let ap = RacyPtr(accs.as_mut_ptr());
        pool.run(chunks, &|c| {
            let g0 = c * per;
            let g1 = (g0 + per).min(g_count);
            // SAFETY: chunk c owns accs[c] and groups [g0, g1) of the
            // survive/znorm/znorm_valid slices exclusively.
            let acc = unsafe { &mut *ap.0.add(c) };
            let mut buf = Vec::new();
            for g in g0..g1 {
                let sg = unsafe { &mut *sp.0.add(g) };
                if !*sg {
                    continue;
                }
                if let Some(pred) = keep {
                    if !pred(g) {
                        *sg = false;
                        acc.discarded += 1;
                        continue;
                    }
                }
                acc.safe += 1;
                let vg = unsafe { &mut *vp.0.add(g) };
                let zg = unsafe { &mut *zp.0.add(g) };
                if !*vg {
                    *zg = norm_of(g, &mut buf);
                    *vg = true;
                    acc.scanned += sizes[g] as u64;
                }
                if *zg >= (sizes[g] as f64).sqrt() * ssr_t {
                    acc.picked.push(g);
                }
            }
        });
    }
    for mut acc in accs {
        out.safe_size += acc.safe;
        out.discarded += acc.discarded;
        out.cols_scanned += acc.scanned;
        out.strong.append(&mut acc.picked);
    }
    out
}

/// Fused group KKT pass — [`fused_kkt`] at group granularity. Surviving
/// groups get their norm recomputed (strong groups only when
/// `refresh_strong`); non-strong survivors are tested with
/// `violates(g, znorm_g)`.
#[allow(clippy::too_many_arguments)]
pub fn fused_group_kkt(
    x: &DenseMatrix,
    r: &[f64],
    starts: &[usize],
    sizes: &[usize],
    survive: &[bool],
    in_strong: &[bool],
    violates: &(dyn Fn(usize, f64) -> bool + Sync),
    refresh_strong: bool,
    znorm: &mut [f64],
    znorm_valid: &mut [bool],
) -> FusedKktOut {
    let n = x.nrows();
    let g_count = starts.len();
    assert_eq!(sizes.len(), g_count);
    assert_eq!(survive.len(), g_count);
    assert_eq!(in_strong.len(), g_count);
    assert_eq!(znorm.len(), g_count);
    assert_eq!(znorm_valid.len(), g_count);
    let inv_n = 1.0 / n as f64;
    let norm_of = |g: usize, buf: &mut Vec<f64>| -> f64 {
        buf.clear();
        for j in starts[g]..starts[g] + sizes[g] {
            buf.push(ops::dot(x.col(j), r) * inv_n);
        }
        ops::nrm2(buf)
    };
    let work: usize = (0..g_count)
        .filter(|&g| survive[g] && (!in_strong[g] || refresh_strong))
        .map(|g| sizes[g])
        .sum();
    let mut out = FusedKktOut::default();
    if work * n < PAR_THRESHOLD {
        let mut buf = Vec::new();
        for g in 0..g_count {
            if !survive[g] {
                continue;
            }
            if in_strong[g] {
                if refresh_strong {
                    znorm[g] = norm_of(g, &mut buf);
                    znorm_valid[g] = true;
                    out.cols_scanned += sizes[g] as u64;
                }
                continue;
            }
            znorm[g] = norm_of(g, &mut buf);
            znorm_valid[g] = true;
            out.cols_scanned += sizes[g] as u64;
            out.checked += 1;
            if violates(g, znorm[g]) {
                out.violations.push(g);
            }
        }
        return out;
    }
    let pool = pool::global();
    let per = g_count.div_ceil(pool.threads() * 8).max(1);
    let chunks = g_count.div_ceil(per);
    let mut accs: Vec<ChunkAcc> = (0..chunks).map(|_| ChunkAcc::default()).collect();
    {
        let zp = RacyPtr(znorm.as_mut_ptr());
        let vp = RacyPtr(znorm_valid.as_mut_ptr());
        let ap = RacyPtr(accs.as_mut_ptr());
        pool.run(chunks, &|c| {
            let g0 = c * per;
            let g1 = (g0 + per).min(g_count);
            // SAFETY: chunk c owns accs[c] and groups [g0, g1) exclusively.
            let acc = unsafe { &mut *ap.0.add(c) };
            let mut buf = Vec::new();
            for g in g0..g1 {
                if !survive[g] {
                    continue;
                }
                if in_strong[g] {
                    if refresh_strong {
                        unsafe {
                            *zp.0.add(g) = norm_of(g, &mut buf);
                            *vp.0.add(g) = true;
                        }
                        acc.scanned += sizes[g] as u64;
                    }
                    continue;
                }
                let zn = norm_of(g, &mut buf);
                unsafe {
                    *zp.0.add(g) = zn;
                    *vp.0.add(g) = true;
                }
                acc.scanned += sizes[g] as u64;
                acc.checked += 1;
                if violates(g, zn) {
                    acc.picked.push(g);
                }
            }
        });
    }
    for mut acc in accs {
        out.checked += acc.checked;
        out.cols_scanned += acc.scanned;
        out.violations.append(&mut acc.picked);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_matrix(n: usize, p: usize, seed: u64) -> (DenseMatrix, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.normal());
        let v = rng.normal_vec(n);
        (x, v)
    }

    #[test]
    fn scan_all_matches_matvec_t() {
        let (x, v) = random_matrix(40, 17, 1);
        let mut out = vec![0.0; 17];
        scan_all(&x, &v, &mut out);
        let reference = x.matvec_t(&v);
        for j in 0..17 {
            assert!((out[j] - reference[j] / 40.0).abs() < 1e-12);
        }
    }

    #[test]
    fn scan_subset_matches_full() {
        let (x, v) = random_matrix(30, 23, 2);
        let idx = vec![0usize, 5, 22, 7];
        let mut out = vec![0.0; 4];
        scan_subset(&x, &v, &idx, &mut out);
        let full = scan_all_vec(&x, &v);
        for (k, &j) in idx.iter().enumerate() {
            assert_eq!(out[k], full[j]);
        }
    }

    /// Pooled-vs-serial equivalence, dense: force the pooled path by
    /// exceeding PAR_THRESHOLD and compare against per-column dots.
    #[test]
    fn pooled_scan_all_matches_serial() {
        let n = 600;
        let p = (PAR_THRESHOLD / n) + 50;
        let (x, v) = random_matrix(n, p, 3);
        let mut par = vec![0.0; p];
        scan_all(&x, &v, &mut par);
        let inv_n = 1.0 / n as f64;
        for j in (0..p).step_by(499) {
            let serial = ops::dot(x.col(j), &v) * inv_n;
            assert_eq!(par[j], serial, "column {j}");
        }
        // and bit-identical to the scoped legacy kernel
        let mut scoped = vec![0.0; p];
        scan_all_scoped(&x, &v, &mut scoped);
        assert_eq!(par, scoped);
    }

    /// Pooled-vs-serial equivalence, subset.
    #[test]
    fn pooled_scan_subset_matches_serial() {
        let n = 512;
        let count = (PAR_THRESHOLD / n) + 37;
        let (x, v) = random_matrix(n, count + 10, 4);
        let idx: Vec<usize> = (0..count).collect();
        let mut par = vec![0.0; count];
        scan_subset(&x, &v, &idx, &mut par);
        let mut scoped = vec![0.0; count];
        scan_subset_scoped(&x, &v, &idx, &mut scoped);
        assert_eq!(par, scoped);
        let inv_n = 1.0 / n as f64;
        for k in (0..count).step_by(401) {
            assert_eq!(par[k], ops::dot(x.col(idx[k]), &v) * inv_n);
        }
    }

    /// Small-case group norms against a naive reference.
    #[test]
    fn group_norms_match_naive() {
        let (x, v) = random_matrix(25, 12, 4);
        let starts = vec![0usize, 4, 9];
        let sizes = vec![4usize, 5, 3];
        let groups = vec![0usize, 1, 2];
        let mut out = vec![0.0; 3];
        let mut valid = vec![false; 3];
        group_norms(&x, &v, &starts, &sizes, &groups, &mut out, &mut valid);
        for g in 0..3 {
            let mut ss = 0.0;
            for j in starts[g]..starts[g] + sizes[g] {
                let d = ops::dot(x.col(j), &v) / 25.0;
                ss += d * d;
            }
            assert!((out[g] - ss.sqrt()).abs() < 1e-12);
        }
    }

    /// Pooled-vs-serial equivalence, group norms: force the pooled path and
    /// compare against the serial buffer+nrm2 reference.
    #[test]
    fn pooled_group_norms_match_serial() {
        let n = 400;
        let g_count = (PAR_THRESHOLD / (n * 4)) + 9;
        let sizes: Vec<usize> = (0..g_count).map(|g| 3 + g % 3).collect();
        let starts: Vec<usize> = sizes
            .iter()
            .scan(0usize, |acc, &s| {
                let st = *acc;
                *acc += s;
                Some(st)
            })
            .collect();
        let p: usize = sizes.iter().sum();
        let (x, v) = random_matrix(n, p, 5);
        let groups: Vec<usize> = (0..g_count).collect();
        let mut znorm = vec![0.0; g_count];
        let mut valid = vec![false; g_count];
        let cols = group_norms(&x, &v, &starts, &sizes, &groups, &mut znorm, &mut valid);
        assert_eq!(cols, p as u64);
        assert!(valid.iter().all(|&b| b));
        let inv_n = 1.0 / n as f64;
        for g in (0..g_count).step_by(97) {
            let buf: Vec<f64> = (starts[g]..starts[g] + sizes[g])
                .map(|j| ops::dot(x.col(j), &v) * inv_n)
                .collect();
            assert_eq!(znorm[g], ops::nrm2(&buf), "group {g}");
        }
    }

    /// The fused screen must agree exactly with the unfused
    /// screen → subset-scan → filter sequence, serial and pooled.
    #[test]
    fn fused_screen_matches_scan_then_filter() {
        // Second case is big enough (stale survivors × n > PAR_THRESHOLD)
        // to exercise the pooled kernel.
        for (n, p, seed) in [(50, 120, 7u64), (600, 2 * (PAR_THRESHOLD / 600) + 40, 8u64)] {
            let (x, r) = random_matrix(n, p, seed);
            let pred = |j: usize| j % 7 != 0; // arbitrary safe predicate
            let keep: &(dyn Fn(usize) -> bool + Sync) = &pred;
            let t = 0.02;
            // unfused reference
            let mut survive_ref = vec![true; p];
            let mut z_ref = vec![0.0; p];
            let mut valid_ref: Vec<bool> = (0..p).map(|j| j % 10 == 0).collect();
            let mut rng = Pcg64::new(seed + 1);
            for j in 0..p {
                if valid_ref[j] {
                    z_ref[j] = rng.normal() * 0.01;
                }
            }
            let mut z_fused = z_ref.clone();
            let mut valid_fused = valid_ref.clone();
            let mut survive_fused = vec![true; p];
            // reference: three passes
            let mut discarded_ref = 0;
            for j in 0..p {
                if !pred(j) {
                    survive_ref[j] = false;
                    discarded_ref += 1;
                }
            }
            let stale: Vec<usize> =
                (0..p).filter(|&j| survive_ref[j] && !valid_ref[j]).collect();
            let mut buf = vec![0.0; stale.len()];
            scan_subset(&x, &r, &stale, &mut buf);
            for (s, &j) in stale.iter().enumerate() {
                z_ref[j] = buf[s];
                valid_ref[j] = true;
            }
            let strong_ref: Vec<usize> =
                (0..p).filter(|&j| survive_ref[j] && z_ref[j].abs() >= t).collect();
            // fused: one pass
            let out = fused_screen(
                &x,
                &r,
                Some(keep),
                t,
                &mut survive_fused,
                &mut z_fused,
                &mut valid_fused,
            );
            assert_eq!(out.strong, strong_ref);
            assert_eq!(out.discarded, discarded_ref);
            assert_eq!(out.safe_size, p - discarded_ref);
            assert_eq!(out.cols_scanned, stale.len() as u64);
            assert_eq!(survive_fused, survive_ref);
            assert_eq!(z_fused, z_ref);
            assert_eq!(valid_fused, valid_ref);
        }
    }

    /// The fused KKT pass must agree exactly with the unfused subset scan +
    /// violation filter + strong refresh, serial and pooled.
    #[test]
    fn fused_kkt_matches_scan_then_filter() {
        for (n, p, seed) in [(40, 90, 11u64), (600, 2 * (PAR_THRESHOLD / 600) + 30, 12u64)] {
            let (x, r) = random_matrix(n, p, seed);
            let survive: Vec<bool> = (0..p).map(|j| j % 5 != 1).collect();
            let in_strong: Vec<bool> = (0..p).map(|j| j % 4 == 0).collect();
            let thresh = 0.05;
            let viol = |zj: f64| zj.abs() > thresh;
            let mut z_ref = vec![0.0; p];
            let mut valid_ref = vec![false; p];
            let mut z_fused = vec![0.0; p];
            let mut valid_fused = vec![false; p];
            // reference: candidate scan + filter, then strong refresh
            let check: Vec<usize> =
                (0..p).filter(|&j| survive[j] && !in_strong[j]).collect();
            let mut buf = vec![0.0; check.len()];
            scan_subset(&x, &r, &check, &mut buf);
            let mut viol_ref = Vec::new();
            for (s, &j) in check.iter().enumerate() {
                z_ref[j] = buf[s];
                valid_ref[j] = true;
                if viol(buf[s]) {
                    viol_ref.push(j);
                }
            }
            let strong_cols: Vec<usize> =
                (0..p).filter(|&j| survive[j] && in_strong[j]).collect();
            let mut sbuf = vec![0.0; strong_cols.len()];
            scan_subset(&x, &r, &strong_cols, &mut sbuf);
            for (s, &j) in strong_cols.iter().enumerate() {
                z_ref[j] = sbuf[s];
                valid_ref[j] = true;
            }
            // fused: one pass
            let out = fused_kkt(
                &x,
                &r,
                &survive,
                &in_strong,
                &viol,
                true,
                &mut z_fused,
                &mut valid_fused,
            );
            assert_eq!(out.violations, viol_ref);
            assert_eq!(out.checked, check.len());
            assert_eq!(out.cols_scanned, (check.len() + strong_cols.len()) as u64);
            assert_eq!(z_fused, z_ref);
            assert_eq!(valid_fused, valid_ref);
        }
    }

    /// The fused group screen must agree exactly with the unfused
    /// predicate → group-norm-refresh → strong-filter sequence, serial and
    /// pooled.
    #[test]
    fn fused_group_screen_matches_scan_then_filter() {
        // Second case forces the pooled kernel: stale-group columns × n
        // exceeds PAR_THRESHOLD (~2/3 of groups stale, mean width 3.5).
        for (n, g_count, seed) in
            [(30usize, 12usize, 17u64), (500, PAR_THRESHOLD / (500 * 2) + 59, 18u64)]
        {
            let sizes: Vec<usize> = (0..g_count).map(|g| 2 + g % 4).collect();
            let starts: Vec<usize> = sizes
                .iter()
                .scan(0usize, |acc, &s| {
                    let st = *acc;
                    *acc += s;
                    Some(st)
                })
                .collect();
            let p: usize = sizes.iter().sum();
            let (x, r) = random_matrix(n, p, seed);
            let pred = |g: usize| g % 5 != 1; // arbitrary safe predicate
            let keep: &(dyn Fn(usize) -> bool + Sync) = &pred;
            let t = 0.01;
            // shared stale/valid pattern with some pre-seeded norms
            let valid0: Vec<bool> = (0..g_count).map(|g| g % 3 == 0).collect();
            let mut rng = Pcg64::new(seed + 1);
            let mut znorm0 = vec![0.0; g_count];
            for g in 0..g_count {
                if valid0[g] {
                    znorm0[g] = rng.uniform() * 0.02;
                }
            }
            // reference: three passes
            let mut survive_ref = vec![true; g_count];
            let mut discarded_ref = 0;
            for g in 0..g_count {
                if !pred(g) {
                    survive_ref[g] = false;
                    discarded_ref += 1;
                }
            }
            let mut znorm_ref = znorm0.clone();
            let mut valid_ref = valid0.clone();
            let stale: Vec<usize> = (0..g_count)
                .filter(|&g| survive_ref[g] && !valid_ref[g])
                .collect();
            let stale_cols =
                group_norms(&x, &r, &starts, &sizes, &stale, &mut znorm_ref, &mut valid_ref);
            let strong_ref: Vec<usize> = (0..g_count)
                .filter(|&g| {
                    survive_ref[g] && znorm_ref[g] >= (sizes[g] as f64).sqrt() * t
                })
                .collect();
            // fused: one pass
            let mut survive_fused = vec![true; g_count];
            let mut znorm_fused = znorm0.clone();
            let mut valid_fused = valid0.clone();
            let out = fused_group_screen(
                &x,
                &r,
                &starts,
                &sizes,
                Some(keep),
                t,
                &mut survive_fused,
                &mut znorm_fused,
                &mut valid_fused,
            );
            assert_eq!(out.strong, strong_ref);
            assert_eq!(out.discarded, discarded_ref);
            assert_eq!(out.safe_size, g_count - discarded_ref);
            assert_eq!(out.cols_scanned, stale_cols);
            assert_eq!(survive_fused, survive_ref);
            assert_eq!(znorm_fused, znorm_ref);
            assert_eq!(valid_fused, valid_ref);
        }
    }

    /// Fused group KKT agrees with per-group scan + filter.
    #[test]
    fn fused_group_kkt_matches_reference() {
        let n = 30;
        let sizes = vec![3usize, 4, 2, 5, 3];
        let starts = vec![0usize, 3, 7, 9, 14];
        let p: usize = sizes.iter().sum();
        let (x, r) = random_matrix(n, p, 13);
        let survive = vec![true, true, false, true, true];
        let in_strong = vec![true, false, false, false, true];
        let thresh = 0.08;
        let viol = |_g: usize, zn: f64| zn > thresh;
        let mut znorm = vec![0.0; 5];
        let mut valid = vec![false; 5];
        let out = fused_group_kkt(
            &x, &r, &starts, &sizes, &survive, &in_strong, &viol, true, &mut znorm,
            &mut valid,
        );
        let inv_n = 1.0 / n as f64;
        let mut viol_ref = Vec::new();
        for g in 0..5 {
            if !survive[g] {
                assert!(!valid[g]);
                continue;
            }
            let buf: Vec<f64> = (starts[g]..starts[g] + sizes[g])
                .map(|j| ops::dot(x.col(j), &r) * inv_n)
                .collect();
            let zn = ops::nrm2(&buf);
            assert_eq!(znorm[g], zn, "group {g}");
            assert!(valid[g]);
            if !in_strong[g] && viol(g, zn) {
                viol_ref.push(g);
            }
        }
        assert_eq!(out.violations, viol_ref);
        assert_eq!(out.checked, 2);
    }
}
