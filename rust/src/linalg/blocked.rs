//! Cache-blocked, multi-threaded screening scans.
//!
//! The dominant operation in every screening rule and KKT check is the scan
//! `z_j = x_jᵀ r / n` over a *set* of columns. For large `p` this is memory
//! bound; we block over columns and fan out across `std::thread::scope`
//! workers. Threading kicks in only above [`PAR_THRESHOLD`] scanned entries
//! so small problems never pay spawn overhead.

use super::ops;
use super::DenseMatrix;

/// Minimum number of matrix entries scanned before threads are used.
pub const PAR_THRESHOLD: usize = 1 << 20;

/// Number of worker threads to use for a scan of `work` entries.
fn n_workers(work: usize) -> usize {
    if work < PAR_THRESHOLD {
        return 1;
    }
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    hw.min(8).max(1)
}

/// Dense scan: `out[j] = x_jᵀ v / n` for every column `j`, multi-threaded.
pub fn scan_all(x: &DenseMatrix, v: &[f64], out: &mut [f64]) {
    assert_eq!(v.len(), x.nrows());
    assert_eq!(out.len(), x.ncols());
    let n = x.nrows();
    let p = x.ncols();
    let inv_n = 1.0 / n as f64;
    let workers = n_workers(n * p);
    if workers == 1 {
        for (j, o) in out.iter_mut().enumerate() {
            *o = ops::dot(x.col(j), v) * inv_n;
        }
        return;
    }
    let cols_per = p.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, chunk) in out.chunks_mut(cols_per).enumerate() {
            let j0 = w * cols_per;
            s.spawn(move || {
                for (dj, o) in chunk.iter_mut().enumerate() {
                    *o = ops::dot(x.col(j0 + dj), v) * inv_n;
                }
            });
        }
    });
}

/// Subset scan: `out[k] = x_{idx[k]}ᵀ v / n`, multi-threaded over `idx`.
pub fn scan_subset(x: &DenseMatrix, v: &[f64], idx: &[usize], out: &mut [f64]) {
    assert_eq!(v.len(), x.nrows());
    assert_eq!(out.len(), idx.len());
    let n = x.nrows();
    let inv_n = 1.0 / n as f64;
    let workers = n_workers(n * idx.len());
    if workers == 1 {
        for (k, &j) in idx.iter().enumerate() {
            out[k] = ops::dot(x.col(j), v) * inv_n;
        }
        return;
    }
    let per = idx.len().div_ceil(workers);
    std::thread::scope(|s| {
        for (chunk_idx, chunk_out) in idx.chunks(per).zip(out.chunks_mut(per)) {
            s.spawn(move || {
                for (k, &j) in chunk_idx.iter().enumerate() {
                    chunk_out[k] = ops::dot(x.col(j), v) * inv_n;
                }
            });
        }
    });
}

/// Scan returning a freshly allocated vector (convenience wrapper).
pub fn scan_all_vec(x: &DenseMatrix, v: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; x.ncols()];
    scan_all(x, v, &mut out);
    out
}

/// Per-group scan for the group lasso: `out[g] = ‖X_gᵀ r‖ / n` where group
/// `g` spans columns `[starts[g], starts[g] + sizes[g])`.
pub fn group_scan_norms(
    x: &DenseMatrix,
    v: &[f64],
    starts: &[usize],
    sizes: &[usize],
    out: &mut [f64],
) {
    assert_eq!(starts.len(), sizes.len());
    assert_eq!(out.len(), starts.len());
    let n = x.nrows();
    let inv_n = 1.0 / n as f64;
    let total: usize = sizes.iter().sum::<usize>() * n;
    let workers = n_workers(total);
    let body = |g0: usize, chunk: &mut [f64]| {
        for (dg, o) in chunk.iter_mut().enumerate() {
            let g = g0 + dg;
            let mut ss = 0.0;
            for j in starts[g]..starts[g] + sizes[g] {
                let d = ops::dot(x.col(j), v) * inv_n;
                ss += d * d;
            }
            *o = ss.sqrt();
        }
    };
    if workers == 1 {
        body(0, out);
        return;
    }
    let per = out.len().div_ceil(workers);
    std::thread::scope(|s| {
        for (w, chunk) in out.chunks_mut(per).enumerate() {
            let g0 = w * per;
            s.spawn(move || body(g0, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_matrix(n: usize, p: usize, seed: u64) -> (DenseMatrix, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.normal());
        let v = rng.normal_vec(n);
        (x, v)
    }

    #[test]
    fn scan_all_matches_matvec_t() {
        let (x, v) = random_matrix(40, 17, 1);
        let mut out = vec![0.0; 17];
        scan_all(&x, &v, &mut out);
        let reference = x.matvec_t(&v);
        for j in 0..17 {
            assert!((out[j] - reference[j] / 40.0).abs() < 1e-12);
        }
    }

    #[test]
    fn scan_subset_matches_full() {
        let (x, v) = random_matrix(30, 23, 2);
        let idx = vec![0usize, 5, 22, 7];
        let mut out = vec![0.0; 4];
        scan_subset(&x, &v, &idx, &mut out);
        let full = scan_all_vec(&x, &v);
        for (k, &j) in idx.iter().enumerate() {
            assert_eq!(out[k], full[j]);
        }
    }

    #[test]
    fn threaded_path_consistent_with_serial() {
        // Force the threaded path by exceeding PAR_THRESHOLD.
        let n = 600;
        let p = (PAR_THRESHOLD / n) + 50;
        let (x, v) = random_matrix(n, p, 3);
        let mut par = vec![0.0; p];
        scan_all(&x, &v, &mut par);
        for j in (0..p).step_by(499) {
            let serial = crate::linalg::ops::dot(x.col(j), &v) / n as f64;
            assert!((par[j] - serial).abs() < 1e-12);
        }
    }

    #[test]
    fn group_scan_matches_naive() {
        let (x, v) = random_matrix(25, 12, 4);
        let starts = vec![0usize, 4, 9];
        let sizes = vec![4usize, 5, 3];
        let mut out = vec![0.0; 3];
        group_scan_norms(&x, &v, &starts, &sizes, &mut out);
        for g in 0..3 {
            let mut ss = 0.0;
            for j in starts[g]..starts[g] + sizes[g] {
                let d = crate::linalg::ops::dot(x.col(j), &v) / 25.0;
                ss += d * d;
            }
            assert!((out[g] - ss.sqrt()).abs() < 1e-12);
        }
    }
}
