//! Scalar BLAS-1 style kernels used throughout the solvers.
//!
//! These are written to auto-vectorize: fixed-width unrolled accumulators,
//! no bounds checks in the hot loops (slices pre-split into chunks).
//!
//! `dot` / `axpy` additionally dispatch to the explicit SIMD kernels in
//! [`super::simd`] when the `HSSR_SIMD` knob enables them; every SIMD
//! variant is bit-identical to the scalar reference here (same per-lane
//! operations, same reduction order, same sequential tail), so callers
//! never observe the knob numerically. The `*_scalar` functions are the
//! fixed references the conformance suite compares against.

use super::simd;

/// Dot product, dispatched: scalar reference by default, SIMD kernel when
/// `HSSR_SIMD` enables one (bit-identical either way).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    if simd::active() {
        return simd::dot(a, b);
    }
    dot_scalar(a, b)
}

/// `y += alpha * x`, dispatched like [`dot`].
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    if simd::active() {
        return simd::axpy(alpha, x, y);
    }
    axpy_scalar(alpha, x, y)
}

/// Fused `y += alpha·x; dot(w, y)` in a single traversal of `y` — the
/// fused-CD-epoch kernel. Bit-identical to `axpy(alpha, x, y)` followed
/// by `dot(w, y)` at every dispatch level (see [`super::simd::axpy_dot`]).
#[inline]
pub fn axpy_dot(alpha: f64, x: &[f64], w: &[f64], y: &mut [f64]) -> f64 {
    simd::axpy_dot(alpha, x, w, y)
}

/// Scalar reference dot product with 8-way unrolled accumulators
/// (auto-vectorizes to SSE2 on the x86-64 baseline).
#[inline]
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let (a8, atail) = a.split_at(chunks * 8);
    let (b8, btail) = b.split_at(chunks * 8);
    let mut acc = [0.0f64; 8];
    for (ca, cb) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for k in 0..8 {
            acc[k] += ca[k] * cb[k];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for (x, y) in atail.iter().zip(btail) {
        s += x * y;
    }
    s
}

/// Scalar reference `y += alpha * x`.
#[inline]
pub fn axpy_scalar(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    let (x8, xtail) = x.split_at(chunks * 8);
    let (y8, ytail) = y.split_at_mut(chunks * 8);
    for (cx, cy) in x8.chunks_exact(8).zip(y8.chunks_exact_mut(8)) {
        for k in 0..8 {
            cy[k] += alpha * cx[k];
        }
    }
    for (x, y) in xtail.iter().zip(ytail) {
        *y += alpha * x;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Sum of entries.
#[inline]
pub fn sum(x: &[f64]) -> f64 {
    x.iter().sum()
}

/// Arithmetic mean.
#[inline]
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        0.0
    } else {
        sum(x) / x.len() as f64
    }
}

/// Infinity norm (max |x_i|), returning 0 for empty input.
#[inline]
pub fn inf_norm(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, v| m.max(v.abs()))
}

/// Soft-threshold operator `S(z, t) = sign(z)·(|z| − t)₊` — the proximal map
/// of the ℓ1 penalty and the core of the coordinate-descent update.
#[inline(always)]
pub fn soft_threshold(z: f64, t: f64) -> f64 {
    if z > t {
        z - t
    } else if z < -t {
        z + t
    } else {
        0.0
    }
}

/// Argmax of |x_j|, with the max value. Returns `(0, 0.0)` for empty input.
pub fn abs_argmax(x: &[f64]) -> (usize, f64) {
    let mut best = (0usize, 0.0f64);
    for (j, &v) in x.iter().enumerate() {
        if v.abs() > best.1 {
            best = (j, v.abs());
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-10);
    }

    #[test]
    fn axpy_matches_naive() {
        let x: Vec<f64> = (0..29).map(|i| i as f64).collect();
        let mut y = vec![1.0; 29];
        axpy(0.5, &x, &mut y);
        for i in 0..29 {
            assert_eq!(y[i], 1.0 + 0.5 * i as f64);
        }
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn norms_and_means() {
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(inf_norm(&[-7.0, 2.0]), 7.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn abs_argmax_finds_peak() {
        let (j, v) = abs_argmax(&[1.0, -9.0, 3.0]);
        assert_eq!(j, 1);
        assert_eq!(v, 9.0);
    }
}
