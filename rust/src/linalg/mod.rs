//! Dense linear-algebra substrate.
//!
//! The lasso solvers work column-wise (coordinate descent touches one
//! feature column at a time; the screening scan is a column-parallel
//! reduction), so the canonical layout is **column-major**: column `j` of a
//! [`DenseMatrix`] is the contiguous slice `data[j*n .. (j+1)*n]`.

pub mod blocked;
pub mod ops;
pub mod pool;
pub mod simd;

use crate::error::{HssrError, Result};

/// A dense, column-major `n × p` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    n: usize,
    p: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Allocate an `n × p` matrix of zeros.
    pub fn zeros(n: usize, p: usize) -> Self {
        DenseMatrix { n, p, data: vec![0.0; n * p] }
    }

    /// Build from column-major data (length must be `n*p`).
    pub fn from_col_major(n: usize, p: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != n * p {
            return Err(HssrError::Dimension(format!(
                "from_col_major: data len {} != n*p = {}",
                data.len(),
                n * p
            )));
        }
        Ok(DenseMatrix { n, p, data })
    }

    /// Build by evaluating `f(i, j)` at every entry.
    pub fn from_fn(n: usize, p: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = DenseMatrix::zeros(n, p);
        for j in 0..p {
            let col = m.col_mut(j);
            for (i, v) in col.iter_mut().enumerate() {
                *v = f(i, j);
            }
        }
        m
    }

    /// Identity-scaled matrix is not needed; this builds a matrix whose
    /// columns are the given vectors.
    pub fn from_columns(cols: &[Vec<f64>]) -> Result<Self> {
        let p = cols.len();
        if p == 0 {
            return Err(HssrError::Dimension("from_columns: empty".into()));
        }
        let n = cols[0].len();
        let mut data = Vec::with_capacity(n * p);
        for c in cols {
            if c.len() != n {
                return Err(HssrError::Dimension("from_columns: ragged columns".into()));
            }
            data.extend_from_slice(c);
        }
        Ok(DenseMatrix { n, p, data })
    }

    /// Number of rows (observations).
    #[inline]
    pub fn nrows(&self) -> usize {
        self.n
    }

    /// Number of columns (features).
    #[inline]
    pub fn ncols(&self) -> usize {
        self.p
    }

    /// Immutable view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.p);
        &self.data[j * self.n..(j + 1) * self.n]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.p);
        &mut self.data[j * self.n..(j + 1) * self.n]
    }

    /// Entry accessor (row `i`, column `j`).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.n + i]
    }

    /// Entry setter.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.n + i] = v;
    }

    /// The backing column-major slice.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Consume into the backing column-major vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// A contiguous block of `w` columns starting at `j0`, as a slice.
    #[inline]
    pub fn col_block(&self, j0: usize, w: usize) -> &[f64] {
        debug_assert!(j0 + w <= self.p);
        &self.data[j0 * self.n..(j0 + w) * self.n]
    }

    /// Copy the submatrix of the given columns (used for group sub-blocks
    /// and for restricting the design to a screened feature set).
    pub fn select_columns(&self, idx: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.n, idx.len());
        for (k, &j) in idx.iter().enumerate() {
            out.col_mut(k).copy_from_slice(self.col(j));
        }
        out
    }

    /// `X · v` (length-`p` input, length-`n` output).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.p, "matvec: len(v) != p");
        let mut out = vec![0.0; self.n];
        for j in 0..self.p {
            let vj = v[j];
            if vj != 0.0 {
                ops::axpy(vj, self.col(j), &mut out);
            }
        }
        out
    }

    /// `Xᵀ · v` (length-`n` input, length-`p` output). The screening scan.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n, "matvec_t: len(v) != n");
        (0..self.p).map(|j| ops::dot(self.col(j), v)).collect()
    }

    /// Frobenius-squared norm.
    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        // [[1, 4], [2, 5], [3, 6]]  (3×2)
        DenseMatrix::from_col_major(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap()
    }

    #[test]
    fn layout_and_accessors() {
        let m = small();
        assert_eq!(m.nrows(), 3);
        assert_eq!(m.ncols(), 2);
        assert_eq!(m.col(0), &[1., 2., 3.]);
        assert_eq!(m.col(1), &[4., 5., 6.]);
        assert_eq!(m.get(1, 1), 5.0);
    }

    #[test]
    fn from_fn_matches_manual() {
        let m = DenseMatrix::from_fn(3, 2, |i, j| (i + 10 * j) as f64);
        assert_eq!(m.col(1), &[10., 11., 12.]);
    }

    #[test]
    fn matvec_and_transpose() {
        let m = small();
        assert_eq!(m.matvec(&[1.0, 2.0]), vec![9., 12., 15.]);
        assert_eq!(m.matvec_t(&[1.0, 1.0, 1.0]), vec![6., 15.]);
    }

    #[test]
    fn select_columns_copies() {
        let m = small();
        let s = m.select_columns(&[1]);
        assert_eq!(s.ncols(), 1);
        assert_eq!(s.col(0), &[4., 5., 6.]);
    }

    #[test]
    fn bad_dims_rejected() {
        assert!(DenseMatrix::from_col_major(2, 2, vec![0.0; 3]).is_err());
    }

    #[test]
    fn from_columns_roundtrip() {
        let m = DenseMatrix::from_columns(&[vec![1., 2.], vec![3., 4.]]).unwrap();
        assert_eq!(m.get(0, 1), 3.0);
        assert!(DenseMatrix::from_columns(&[vec![1.], vec![1., 2.]]).is_err());
    }
}
