//! Explicit SIMD micro-kernels behind the `HSSR_SIMD` runtime knob.
//!
//! The scalar kernels in [`super::ops`] are 8-way unrolled so the
//! compiler *may* vectorize them, but a portable build (`x86-64` baseline)
//! only gets SSE2. This module provides the hardware-shaped versions:
//!
//! * **f64**: portable 8-lane dot / axpy micro-kernels plus AVX2
//!   intrinsic versions, all **bit-identical** to [`super::ops::dot`] /
//!   [`super::ops::axpy`] — the scalar kernel's eight independent
//!   accumulators map exactly onto two 4-lane vector registers, its
//!   reduction `(a0+a4)+(a1+a5)+(a2+a6)+(a3+a7)` is exactly the lane-wise
//!   vector add `p = lo + hi` followed by the left-to-right scalar sum
//!   `((p0+p1)+p2)+p3`, and the tail is handled sequentially by the same
//!   code. No FMA is ever used (Rust never contracts float ops, and these
//!   kernels only emit mul/add), so every product and sum rounds exactly
//!   like the scalar reference.
//! * **f32**: a sequential scalar reference plus portable 16-lane and AVX2
//!   (2×8-lane) dot kernels for the mixed-precision screening scan. f32
//!   results are *not* bit-identical across kernels (the accumulation
//!   trees differ); they are covered by the proven error bound
//!   [`f32_scan_error_bound`], which holds for **any** summation order.
//!
//! Dispatch is process-global and read from `HSSR_SIMD` once, with a test
//! override ([`force`] / [`reset`]) so benches and the conformance suite
//! can A/B both paths in one process:
//!
//! * `HSSR_SIMD` unset or `0` — scalar kernels (the default; opt-in knob);
//! * `HSSR_SIMD=1` — autodetect: AVX2 intrinsics when the CPU supports
//!   them, otherwise the portable lane kernels;
//! * `HSSR_SIMD=portable` — force the portable lane kernels (no
//!   intrinsics, any architecture).
//!
//! The hot callers ([`super::ops::dot`], [`super::ops::axpy`], and through
//! them every blocked/fused kernel, the CD inner loop, and the store
//! scans) consult [`level`] per call — one relaxed atomic load, noise
//! against the O(n) kernel work — so the knob applies everywhere without
//! threading a config handle through the pool workers.

use std::sync::atomic::{AtomicU8, Ordering};

/// Dispatch level for the micro-kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Scalar reference kernels ([`super::ops`]).
    Scalar,
    /// Portable fixed-lane-array kernels (no intrinsics).
    Portable,
    /// AVX2 intrinsic kernels (x86-64 with runtime-detected support).
    Avx2,
}

impl Level {
    /// Display label for reports and benches.
    pub fn label(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Portable => "portable",
            Level::Avx2 => "avx2",
        }
    }
}

// 0 = uninitialized, 1 = scalar, 2 = portable, 3 = avx2.
static STATE: AtomicU8 = AtomicU8::new(0);

fn detect_auto() -> u8 {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return 3;
        }
    }
    2
}

fn init_from_env() -> u8 {
    let code = match std::env::var("HSSR_SIMD").as_deref() {
        Ok("1") | Ok("on") | Ok("true") | Ok("auto") | Ok("avx2") => detect_auto(),
        Ok("portable") | Ok("lanes") => 2,
        _ => 1,
    };
    STATE.store(code, Ordering::Relaxed);
    code
}

/// The active dispatch level (lazily initialized from `HSSR_SIMD`).
#[inline]
pub fn level() -> Level {
    let mut code = STATE.load(Ordering::Relaxed);
    if code == 0 {
        code = init_from_env();
    }
    match code {
        3 => Level::Avx2,
        2 => Level::Portable,
        _ => Level::Scalar,
    }
}

/// Whether a non-scalar kernel is active.
#[inline]
pub fn active() -> bool {
    level() != Level::Scalar
}

/// Test/bench override: force SIMD on (autodetected level) or off,
/// ignoring `HSSR_SIMD`. Process-global — callers that toggle it around a
/// measurement should restore with [`reset`] or a saved [`force`] state.
pub fn force(enabled: bool) {
    STATE.store(if enabled { detect_auto() } else { 1 }, Ordering::Relaxed);
}

/// Drop any [`force`] override and re-read `HSSR_SIMD`.
pub fn reset() {
    STATE.store(0, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// f64 kernels — every variant bit-identical to the ops.rs scalar reference.
// ---------------------------------------------------------------------------

/// Portable 8-lane dot: the scalar reference's accumulator array written
/// as an explicit lane kernel (same lane ops, same reduction order, same
/// sequential tail ⇒ bit-identical to [`super::ops::dot`]).
pub fn dot_lanes(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let (a8, atail) = a.split_at(chunks * 8);
    let (b8, btail) = b.split_at(chunks * 8);
    let mut acc = [0.0f64; 8];
    for (ca, cb) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for k in 0..8 {
            acc[k] += ca[k] * cb[k];
        }
    }
    let p = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
    let mut s = ((p[0] + p[1]) + p[2]) + p[3];
    for (x, y) in atail.iter().zip(btail) {
        s += x * y;
    }
    s
}

/// Portable 8-lane axpy (`y += alpha·x`); element-wise, so trivially
/// bit-identical to [`super::ops::axpy`].
pub fn axpy_lanes(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    let (x8, xtail) = x.split_at(chunks * 8);
    let (y8, ytail) = y.split_at_mut(chunks * 8);
    for (cx, cy) in x8.chunks_exact(8).zip(y8.chunks_exact_mut(8)) {
        for k in 0..8 {
            cy[k] += alpha * cx[k];
        }
    }
    for (x, y) in xtail.iter().zip(ytail) {
        *y += alpha * x;
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 intrinsic kernels. Safety: every function is
    //! `#[target_feature(enable = "avx2")]` and only called after runtime
    //! detection; loads/stores are unaligned-safe (`loadu`/`storeu`) and
    //! stay within the slices' bounds. Only mul/add are emitted — never
    //! FMA — so rounding matches the scalar reference operation for
    //! operation.

    #[allow(clippy::missing_safety_doc)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        use core::arch::x86_64::*;
        let chunks = a.len() / 8;
        let (a8, atail) = a.split_at(chunks * 8);
        let (b8, btail) = b.split_at(chunks * 8);
        let ap = a8.as_ptr();
        let bp = b8.as_ptr();
        // Two 4-lane accumulators = the scalar kernel's acc[0..4]/acc[4..8].
        let mut lo = _mm256_setzero_pd();
        let mut hi = _mm256_setzero_pd();
        for i in 0..chunks {
            let off = i * 8;
            let a0 = _mm256_loadu_pd(ap.add(off));
            let b0 = _mm256_loadu_pd(bp.add(off));
            let a1 = _mm256_loadu_pd(ap.add(off + 4));
            let b1 = _mm256_loadu_pd(bp.add(off + 4));
            lo = _mm256_add_pd(lo, _mm256_mul_pd(a0, b0));
            hi = _mm256_add_pd(hi, _mm256_mul_pd(a1, b1));
        }
        // p[k] = acc[k] + acc[k+4], then the scalar reduction order
        // ((p0+p1)+p2)+p3 — exactly ops::dot's
        // (a0+a4)+(a1+a5)+(a2+a6)+(a3+a7).
        let p = _mm256_add_pd(lo, hi);
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), p);
        let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
        for (x, y) in atail.iter().zip(btail) {
            s += x * y;
        }
        s
    }

    #[allow(clippy::missing_safety_doc)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        use core::arch::x86_64::*;
        let chunks = x.len() / 4;
        let (x4, xtail) = x.split_at(chunks * 4);
        let (y4, ytail) = y.split_at_mut(chunks * 4);
        let va = _mm256_set1_pd(alpha);
        let xp = x4.as_ptr();
        let yp = y4.as_mut_ptr();
        for i in 0..chunks {
            let off = i * 4;
            let vx = _mm256_loadu_pd(xp.add(off));
            let vy = _mm256_loadu_pd(yp.add(off));
            _mm256_storeu_pd(yp.add(off), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
        }
        for (x, y) in xtail.iter().zip(ytail) {
            *y += alpha * x;
        }
    }

    #[allow(clippy::missing_safety_doc)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_dot(alpha: f64, x: &[f64], w: &[f64], y: &mut [f64]) -> f64 {
        use core::arch::x86_64::*;
        let chunks = x.len() / 8;
        let (x8, xtail) = x.split_at(chunks * 8);
        let (w8, wtail) = w.split_at(chunks * 8);
        let (y8, ytail) = y.split_at_mut(chunks * 8);
        let va = _mm256_set1_pd(alpha);
        let xp = x8.as_ptr();
        let wp = w8.as_ptr();
        let yp = y8.as_mut_ptr();
        let mut lo = _mm256_setzero_pd();
        let mut hi = _mm256_setzero_pd();
        for i in 0..chunks {
            let off = i * 8;
            let y0 = _mm256_add_pd(
                _mm256_loadu_pd(yp.add(off)),
                _mm256_mul_pd(va, _mm256_loadu_pd(xp.add(off))),
            );
            let y1 = _mm256_add_pd(
                _mm256_loadu_pd(yp.add(off + 4)),
                _mm256_mul_pd(va, _mm256_loadu_pd(xp.add(off + 4))),
            );
            _mm256_storeu_pd(yp.add(off), y0);
            _mm256_storeu_pd(yp.add(off + 4), y1);
            lo = _mm256_add_pd(lo, _mm256_mul_pd(_mm256_loadu_pd(wp.add(off)), y0));
            hi = _mm256_add_pd(hi, _mm256_mul_pd(_mm256_loadu_pd(wp.add(off + 4)), y1));
        }
        let p = _mm256_add_pd(lo, hi);
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), p);
        let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
        for ((x, w), y) in xtail.iter().zip(wtail).zip(ytail) {
            *y += alpha * x;
            s += w * *y;
        }
        s
    }

    #[allow(clippy::missing_safety_doc)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
        use core::arch::x86_64::*;
        let chunks = a.len() / 16;
        let (a16, atail) = a.split_at(chunks * 16);
        let (b16, btail) = b.split_at(chunks * 16);
        let ap = a16.as_ptr();
        let bp = b16.as_ptr();
        let mut lo = _mm256_setzero_ps();
        let mut hi = _mm256_setzero_ps();
        for i in 0..chunks {
            let off = i * 16;
            lo = _mm256_add_ps(
                lo,
                _mm256_mul_ps(_mm256_loadu_ps(ap.add(off)), _mm256_loadu_ps(bp.add(off))),
            );
            hi = _mm256_add_ps(
                hi,
                _mm256_mul_ps(
                    _mm256_loadu_ps(ap.add(off + 8)),
                    _mm256_loadu_ps(bp.add(off + 8)),
                ),
            );
        }
        // Same reduction tree as the portable 16-lane kernel: p[k] =
        // acc[k] + acc[k+8], then a left-to-right scalar sum.
        let p = _mm256_add_ps(lo, hi);
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), p);
        let mut s = lanes[0];
        for &l in &lanes[1..] {
            s += l;
        }
        for (x, y) in atail.iter().zip(btail) {
            s += x * y;
        }
        s
    }
}

/// Dispatched dot product — bit-identical to [`super::ops::dot`] at every
/// level (see module docs for the lane ↔ accumulator mapping).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only set after runtime detection.
        Level::Avx2 => unsafe { avx2::dot(a, b) },
        Level::Portable => dot_lanes(a, b),
        _ => dot_lanes(a, b),
    }
}

/// Dispatched `y += alpha·x` — bit-identical at every level.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only set after runtime detection.
        Level::Avx2 => unsafe { avx2::axpy(alpha, x, y) },
        _ => axpy_lanes(alpha, x, y),
    }
}

/// Fused `y += alpha·x; dot(w, y)` in one traversal, for the fused CD
/// epoch: the deferred residual update of the previous coordinate and the
/// correlation of the next one share a single pass over `y`.
///
/// Bit-identical to `axpy(alpha, x, y)` followed by `dot(w, y)`: each
/// `y[i]` is updated exactly once before the dot term reads it, the update
/// is the same mul/add, and the dot accumulates in [`super::ops::dot`]'s
/// lane/reduction order.
pub fn axpy_dot(alpha: f64, x: &[f64], w: &[f64], y: &mut [f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(w.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if level() == Level::Avx2 {
        // SAFETY: Level::Avx2 is only set after runtime detection.
        return unsafe { avx2::axpy_dot(alpha, x, w, y) };
    }
    let chunks = x.len() / 8;
    let (x8, xtail) = x.split_at(chunks * 8);
    let (w8, wtail) = w.split_at(chunks * 8);
    let (y8, ytail) = y.split_at_mut(chunks * 8);
    let mut acc = [0.0f64; 8];
    for ((cx, cw), cy) in
        x8.chunks_exact(8).zip(w8.chunks_exact(8)).zip(y8.chunks_exact_mut(8))
    {
        for k in 0..8 {
            cy[k] += alpha * cx[k];
            acc[k] += cw[k] * cy[k];
        }
    }
    let p = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6], acc[3] + acc[7]];
    let mut s = ((p[0] + p[1]) + p[2]) + p[3];
    for ((x, w), y) in xtail.iter().zip(wtail).zip(ytail) {
        *y += alpha * x;
        s += w * *y;
    }
    s
}

// ---------------------------------------------------------------------------
// f32 kernels — the mixed-precision screening scan.
// ---------------------------------------------------------------------------

/// Sequential scalar f32 dot — the conformance reference for the f32
/// kernels.
pub fn dot_f32_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// Portable 16-lane f32 dot (two 8-lane accumulator blocks, sequential
/// tail). Not bit-identical to the sequential reference — covered by
/// [`f32_scan_error_bound`], which holds for any accumulation order.
pub fn dot_f32_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 16;
    let (a16, atail) = a.split_at(chunks * 16);
    let (b16, btail) = b.split_at(chunks * 16);
    let mut acc = [0.0f32; 16];
    for (ca, cb) in a16.chunks_exact(16).zip(b16.chunks_exact(16)) {
        for k in 0..16 {
            acc[k] += ca[k] * cb[k];
        }
    }
    let mut p = [0.0f32; 8];
    for k in 0..8 {
        p[k] = acc[k] + acc[k + 8];
    }
    let mut s = p[0];
    for &l in &p[1..] {
        s += l;
    }
    for (x, y) in atail.iter().zip(btail) {
        s += x * y;
    }
    s
}

/// Dispatched f32 dot: scalar reference when SIMD is off, lane/AVX2
/// kernel when on.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Level::Avx2 is only set after runtime detection.
        Level::Avx2 => unsafe { avx2::dot_f32(a, b) },
        Level::Portable => dot_f32_lanes(a, b),
        _ => dot_f32_scalar(a, b),
    }
}

/// Worst-case absolute error of the f32 screening scan entry
/// `z32_j = fl32(x32_jᵀ r32)/n` against the exact f64 `z_j = x_jᵀ r / n`,
/// for a standardized column (`‖x_j‖₂ = √n`) and residual 2-norm
/// `r_norm`:
///
/// ```text
/// |z32_j − z_j| ≤ (n + 4)·ε32·r_norm/√n + n·η32
/// ```
///
/// where `ε32 = 2⁻²³` (`f32::EPSILON`) and `η32` is the smallest normal
/// f32. Derivation: casting the inputs costs a relative `u = ε32/2` each;
/// an n-term f32 summation in **any** order carries the standard
/// `γ_n = nu/(1−nu)` factor; Cauchy–Schwarz bounds the accumulated
/// magnitude by `‖x_j‖·‖r‖ = √n·r_norm`. `(n+4)·ε32 ≈ 2·(n+2)·u` leaves a
/// ×2 margin over the proven `γ_{n+2}` factor, and the `n·η32` term
/// absorbs the absolute rounding of any subnormal intermediates.
pub fn f32_scan_error_bound(n: usize, r_norm: f64) -> f64 {
    let nf = n as f64;
    (nf + 4.0) * (f32::EPSILON as f64) * r_norm / nf.sqrt() + nf * (f32::MIN_POSITIVE as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::rng::Pcg64;

    fn vecs(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        (rng.normal_vec(n), rng.normal_vec(n))
    }

    #[test]
    fn lanes_dot_bit_identical_to_scalar() {
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 64, 100, 1031] {
            let (a, b) = vecs(n, 7 + n as u64);
            assert_eq!(dot_lanes(&a, &b).to_bits(), ops::dot(&a, &b).to_bits(), "n={n}");
        }
    }

    #[test]
    fn lanes_axpy_bit_identical_to_scalar() {
        for n in [0usize, 3, 8, 21, 130] {
            let (x, y0) = vecs(n, 31 + n as u64);
            let mut y1 = y0.clone();
            let mut y2 = y0.clone();
            ops::axpy(0.37, &x, &mut y1);
            axpy_lanes(0.37, &x, &mut y2);
            assert_eq!(y1, y2, "n={n}");
        }
    }

    #[test]
    fn axpy_dot_equals_axpy_then_dot() {
        for n in [0usize, 1, 8, 13, 64, 257] {
            let mut rng = Pcg64::new(91 + n as u64);
            let x = rng.normal_vec(n);
            let w = rng.normal_vec(n);
            let y0 = rng.normal_vec(n);
            let mut y1 = y0.clone();
            let mut y2 = y0.clone();
            ops::axpy(-0.61, &x, &mut y1);
            let want = ops::dot(&w, &y1);
            let got = axpy_dot(-0.61, &x, &w, &mut y2);
            assert_eq!(y1, y2, "residual drift at n={n}");
            assert_eq!(got.to_bits(), want.to_bits(), "dot drift at n={n}");
        }
    }

    #[test]
    fn forced_simd_dot_stays_bit_identical() {
        let before = level();
        for n in [5usize, 8, 64, 129, 1000] {
            let (a, b) = vecs(n, 400 + n as u64);
            force(false);
            let off = dot(&a, &b);
            force(true);
            let on = dot(&a, &b);
            assert_eq!(on.to_bits(), off.to_bits(), "n={n}, level={:?}", level());
        }
        force(before != Level::Scalar);
        reset();
    }

    #[test]
    fn f32_kernels_within_error_bound() {
        for n in [16usize, 33, 200, 1024] {
            let mut rng = Pcg64::new(17 + n as u64);
            // Standardized-like column: unit-variance entries.
            let a: Vec<f64> = rng.normal_vec(n);
            let r: Vec<f64> = rng.normal_vec(n);
            let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let r32: Vec<f32> = r.iter().map(|&v| v as f32).collect();
            let exact = ops::dot(&a, &r) / n as f64;
            let norm_a = ops::nrm2(&a);
            // Rescale the bound for a column of norm ‖a‖ instead of √n.
            let bound = f32_scan_error_bound(n, ops::nrm2(&r)) * norm_a / (n as f64).sqrt();
            for got in [
                dot_f32_scalar(&a32, &r32) as f64 / n as f64,
                dot_f32_lanes(&a32, &r32) as f64 / n as f64,
            ] {
                assert!(
                    (got - exact).abs() <= bound,
                    "n={n}: |{got} - {exact}| > {bound}"
                );
            }
        }
    }
}
