//! Integrity and serialization primitives for the fault-tolerance layer:
//! a dependency-free CRC32 (IEEE 802.3, the zlib polynomial) plus small
//! little-endian byte-buffer codecs.
//!
//! Consumers: the v2 column-store format ([`crate::data::store::format`])
//! checksums every chunk and the tail section; the path driver's
//! crash-resume checkpoints ([`crate::solver::driver`]) serialize warm-start
//! state through [`ByteWriter`]/[`ByteReader`] and seal the file with a
//! trailing CRC. Both sides must agree bit-for-bit, which is why the
//! implementation lives in one place.

use crate::error::{HssrError, Result};

/// The CRC32 lookup table (reflected polynomial 0xEDB88320), built once.
fn crc_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// Streaming CRC32 state: feed bytes with [`Crc32::update`], read the
/// digest with [`Crc32::finish`].
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

impl Crc32 {
    /// Fresh state (all-ones preset, per the IEEE definition).
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Absorb a byte slice.
    pub fn update(&mut self, bytes: &[u8]) {
        let table = crc_table();
        let mut c = self.state;
        for &b in bytes {
            c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// The final (bit-inverted) digest.
    pub fn finish(&self) -> u32 {
        !self.state
    }
}

/// One-shot CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// Little-endian append-only byte buffer for checkpoint serialization.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Fresh empty buffer.
    pub fn new() -> Self {
        ByteWriter::default()
    }

    /// Append a single byte.
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u32` (LE).
    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64` (LE).
    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64` (LE bit pattern — exact, no formatting round-trip).
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append raw bytes.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Append a length-prefixed f64 slice.
    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_u64(v.len() as u64);
        for &x in v {
            self.put_f64(x);
        }
    }

    /// Append a length-prefixed bool slice (one byte each).
    pub fn put_bools(&mut self, v: &[bool]) {
        self.put_u64(v.len() as u64);
        for &b in v {
            self.put_u8(b as u8);
        }
    }

    /// Append a length-prefixed nested byte blob.
    pub fn put_blob(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.put_bytes(v);
    }

    /// Consume into the underlying byte vector.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

/// Cursor over a little-endian byte buffer; every read is bounds-checked
/// and surfaces a typed [`HssrError::Corrupt`] on underrun (a truncated or
/// garbled checkpoint must never panic).
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Wrap a byte slice.
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(e) => {
                let s = &self.buf[self.pos..e];
                self.pos = e;
                Ok(s)
            }
            None => Err(HssrError::Corrupt(format!(
                "serialized blob truncated: need {n} bytes at offset {} of {}",
                self.pos,
                self.buf.len()
            ))),
        }
    }

    /// Read one byte.
    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u32` (LE).
    pub fn get_u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(b))
    }

    /// Read a `u64` (LE).
    pub fn get_u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(b))
    }

    /// Read an `f64` (LE bit pattern).
    pub fn get_f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8)?);
        Ok(f64::from_le_bytes(b))
    }

    /// Read `n` raw bytes.
    pub fn get_bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Read a length-prefixed f64 slice (length sanity-capped against the
    /// remaining buffer so a corrupt prefix cannot trigger a huge alloc).
    pub fn get_f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.get_u64()? as usize;
        if n > self.remaining() / 8 {
            return Err(HssrError::Corrupt(format!(
                "serialized f64 slice claims {n} items but only {} bytes remain",
                self.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_f64()?);
        }
        Ok(out)
    }

    /// Read a length-prefixed bool slice.
    pub fn get_bools(&mut self) -> Result<Vec<bool>> {
        let n = self.get_u64()? as usize;
        if n > self.remaining() {
            return Err(HssrError::Corrupt(format!(
                "serialized bool slice claims {n} items but only {} bytes remain",
                self.remaining()
            )));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.get_u8()? != 0);
        }
        Ok(out)
    }

    /// Read a length-prefixed nested byte blob.
    pub fn get_blob(&mut self) -> Result<&'a [u8]> {
        let n = self.get_u64()? as usize;
        if n > self.remaining() {
            return Err(HssrError::Corrupt(format!(
                "serialized blob claims {n} bytes but only {} remain",
                self.remaining()
            )));
        }
        self.take(n)
    }

    /// Bytes left unread.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Known-answer vectors for IEEE CRC32 (the "check" value of the
    /// catalogue entry, plus edge cases).
    #[test]
    fn crc32_known_answers() {
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    /// Streaming in arbitrary split points matches the one-shot digest.
    #[test]
    fn crc32_streaming_matches_one_shot() {
        let data: Vec<u8> = (0u32..1000).map(|i| (i * 7 + 3) as u8).collect();
        let want = crc32(&data);
        for split in [0, 1, 13, 500, 999, 1000] {
            let mut c = Crc32::new();
            c.update(&data[..split]);
            c.update(&data[split..]);
            assert_eq!(c.finish(), want, "split at {split}");
        }
    }

    #[test]
    fn byte_roundtrip_exact() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 1);
        w.put_f64(-0.0);
        w.put_f64(f64::NAN);
        w.put_f64s(&[1.5, -2.25, 1e300]);
        w.put_bools(&[true, false, true]);
        w.put_blob(b"nested");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.get_f64().unwrap().is_nan());
        assert_eq!(r.get_f64s().unwrap(), vec![1.5, -2.25, 1e300]);
        assert_eq!(r.get_bools().unwrap(), vec![true, false, true]);
        assert_eq!(r.get_blob().unwrap(), b"nested");
        assert_eq!(r.remaining(), 0);
    }

    /// Underruns and absurd length prefixes surface as typed `Corrupt`
    /// errors, never panics or giant allocations.
    #[test]
    fn truncation_is_typed_not_panicking() {
        let mut w = ByteWriter::new();
        w.put_u64(5);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..4]);
        assert!(matches!(r.get_u64(), Err(crate::error::HssrError::Corrupt(_))));
        // A length prefix far beyond the buffer is rejected up front.
        let mut w = ByteWriter::new();
        w.put_u64(u64::MAX / 2);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(matches!(r.get_f64s(), Err(crate::error::HssrError::Corrupt(_))));
        let mut r = ByteReader::new(&bytes);
        assert!(matches!(r.get_blob(), Err(crate::error::HssrError::Corrupt(_))));
    }
}
