//! Coordinate-descent inner loop for the lasso and elastic net.
//!
//! With standardized columns (`x_jᵀx_j/n = 1`) the update is closed form:
//!
//! ```text
//! z_j    = x_jᵀr/n + β_j
//! β_j⁺   = S(z_j, αλ) / (1 + (1−α)λ)          (lasso: α = 1)
//! r     −= (β_j⁺ − β_j)·x_j
//! ```
//!
//! The residual is maintained exactly, so `x_jᵀr/n` quantities seen by the
//! screening rules and the KKT checker always refer to the current iterate.
//!
//! The loop body is generic over [`ColAccess`]
//! ([`cd_cycle_on`]/[`cd_solve_on`]): the same updates run on the
//! resident design or, for `--engine ooc`, on a pinned store cursor —
//! bit-identical either way, since a spilled store serves the exact
//! standardized bytes. The historical dense entry points
//! ([`cd_cycle`]/[`cd_solve`]) are thin infallible wrappers.

use crate::error::{HssrError, Result};
use crate::linalg::{ops, DenseMatrix};
use crate::solver::columns::{ColAccess, DenseCols};
use crate::solver::Penalty;

/// Statistics from one inner-solver invocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct CdStats {
    /// Full cycles over the active list.
    pub cycles: usize,
    /// Individual coordinate updates (= cycles × |active| here).
    pub coord_updates: u64,
}

/// One full coordinate cycle over `active`, served by any column source
/// (`active` must be ascending, which every caller's working set is — a
/// pinned store cursor then swaps each chunk at most once per cycle).
/// Returns the largest |Δβ_j|; `Err` only from a store-backed source.
///
/// When the source serves column pairs ([`ColAccess::col_pair`], i.e. the
/// resident design), the residual update of each accepted coordinate is
/// *deferred* and folded into the next coordinate's correlation pass via
/// [`ops::axpy_dot`] — one traversal of `r` per update instead of two.
/// The fusion is bit-identical to the sequential axpy-then-dot (each
/// residual entry is updated once before the dot term reads it, in the
/// scalar kernel's exact lane and reduction order), so both code paths
/// produce the same iterates.
pub fn cd_cycle_on<C: ColAccess>(
    cols: &mut C,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
) -> Result<f64> {
    let n_inv = 1.0 / cols.nrows() as f64;
    let alpha = penalty.alpha();
    let thresh = alpha * lam;
    let denom = 1.0 + penalty.l2_weight() * lam;
    let mut max_delta = 0.0f64;
    if cols.fused_pairs() {
        // Deferred residual update of the previous accepted coordinate.
        let mut pending: Option<(usize, f64)> = None;
        for &j in active {
            let z = match pending.take() {
                Some((i, delta)) => match cols.col_pair(i, j)? {
                    Some((prev, col)) => {
                        ops::axpy_dot(-delta, prev, col, r) * n_inv + beta[j]
                    }
                    // Defensive: a source that advertised pairs but
                    // declined this one — flush, then scan sequentially.
                    None => {
                        ops::axpy(-delta, cols.col(i)?, r);
                        ops::dot(cols.col(j)?, r) * n_inv + beta[j]
                    }
                },
                None => ops::dot(cols.col(j)?, r) * n_inv + beta[j],
            };
            let b_new = ops::soft_threshold(z, thresh) / denom;
            let delta = b_new - beta[j];
            if delta != 0.0 {
                beta[j] = b_new;
                max_delta = max_delta.max(delta.abs());
                pending = Some((j, delta));
            }
        }
        if let Some((i, delta)) = pending {
            ops::axpy(-delta, cols.col(i)?, r);
        }
        return Ok(max_delta);
    }
    for &j in active {
        let col = cols.col(j)?;
        let z = ops::dot(col, r) * n_inv + beta[j];
        let b_new = ops::soft_threshold(z, thresh) / denom;
        let delta = b_new - beta[j];
        if delta != 0.0 {
            ops::axpy(-delta, col, r);
            beta[j] = b_new;
            max_delta = max_delta.max(delta.abs());
        }
    }
    Ok(max_delta)
}

/// One full coordinate cycle over `active` on the resident design.
/// Returns the largest |Δβ_j|.
pub fn cd_cycle(
    x: &DenseMatrix,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
) -> f64 {
    // The dense source never errs.
    cd_cycle_on(&mut DenseCols::new(x), penalty, lam, active, beta, r)
        .unwrap_or(f64::NAN)
}

/// Iterate [`cd_cycle_on`] until the largest coefficient change falls
/// below `tol` (or error after `max_iter` cycles).
#[allow(clippy::too_many_arguments)]
pub fn cd_solve_on<C: ColAccess>(
    cols: &mut C,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
    tol: f64,
    max_iter: usize,
    lambda_index: usize,
) -> Result<CdStats> {
    let mut stats = CdStats::default();
    if active.is_empty() {
        return Ok(stats);
    }
    let mut last_delta = f64::INFINITY;
    for _ in 0..max_iter {
        last_delta = cd_cycle_on(cols, penalty, lam, active, beta, r)?;
        stats.cycles += 1;
        stats.coord_updates += active.len() as u64;
        if !last_delta.is_finite() {
            // Divergence guardrail: a NaN/Inf update would otherwise
            // poison β and the residual for every later λ — surface it as
            // a typed, degradable error instead.
            return Err(HssrError::NonFinite {
                lambda_index,
                context: "coordinate-descent update delta".into(),
            });
        }
        if last_delta < tol {
            // NaN correlations soft-threshold to 0, so a poisoned iterate
            // can look "converged" — verify the residual before trusting
            // the solution.
            if r.iter().any(|v| !v.is_finite()) {
                return Err(HssrError::NonFinite {
                    lambda_index,
                    context: "coordinate-descent residual".into(),
                });
            }
            return Ok(stats);
        }
    }
    Err(HssrError::NoConvergence { lambda_index, max_iter, last_delta })
}

/// [`cd_solve_on`] over the resident design — the historical entry point.
#[allow(clippy::too_many_arguments)]
pub fn cd_solve(
    x: &DenseMatrix,
    penalty: Penalty,
    lam: f64,
    active: &[usize],
    beta: &mut [f64],
    r: &mut [f64],
    tol: f64,
    max_iter: usize,
    lambda_index: usize,
) -> Result<CdStats> {
    cd_solve_on(
        &mut DenseCols::new(x),
        penalty,
        lam,
        active,
        beta,
        r,
        tol,
        max_iter,
        lambda_index,
    )
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::linalg::blocked;

    /// On an orthonormal design (XᵀX/n = I), the lasso solution is the
    /// soft-thresholded OLS: β_j = S(x_jᵀy/n, λ). CD must find it in one
    /// pass (to numerical tolerance).
    #[test]
    fn orthonormal_design_closed_form() {
        // Build an exactly orthonormal design via group orthonormalization.
        let ds = DataSpec::synthetic(50, 8, 3).generate(1);
        let og = crate::data::standardize::orthonormalize_groups(&ds.x, &[0], &[8]);
        let x = og.x;
        let y = ds.y.clone();
        let lam = 0.3;
        let active: Vec<usize> = (0..8).collect();
        let mut beta = vec![0.0; 8];
        let mut r = y.clone();
        cd_solve(&x, Penalty::Lasso, lam, &active, &mut beta, &mut r, 1e-12, 100, 0).unwrap();
        let z = blocked::scan_all_vec(&x, &y);
        for j in 0..8 {
            let expect = ops::soft_threshold(z[j], lam);
            assert!((beta[j] - expect).abs() < 1e-9, "β[{j}]={} want {expect}", beta[j]);
        }
    }

    /// KKT conditions hold at the CD solution on a correlated design.
    #[test]
    fn kkt_satisfied_at_solution() {
        let ds = DataSpec::gene_like(60, 30).generate(2);
        let lam = {
            let z = blocked::scan_all_vec(&ds.x, &ds.y);
            0.5 * ops::inf_norm(&z)
        };
        let active: Vec<usize> = (0..30).collect();
        let mut beta = vec![0.0; 30];
        let mut r = ds.y.clone();
        cd_solve(&ds.x, Penalty::Lasso, lam, &active, &mut beta, &mut r, 1e-10, 10_000, 0)
            .unwrap();
        let z = blocked::scan_all_vec(&ds.x, &r);
        for j in 0..30 {
            if beta[j] != 0.0 {
                assert!(
                    (z[j] - lam * beta[j].signum()).abs() < 1e-6,
                    "active KKT at {j}: z={}, λ·sign={}",
                    z[j],
                    lam * beta[j].signum()
                );
            } else {
                assert!(z[j].abs() <= lam + 1e-6, "inactive KKT at {j}: |z|={}", z[j].abs());
            }
        }
    }

    /// Elastic-net KKT: for active j, x_jᵀr/n = αλ·sign(β_j) + (1−α)λ·β_j.
    #[test]
    fn enet_kkt_satisfied() {
        let ds = DataSpec::synthetic(60, 25, 5).generate(3);
        let pen = Penalty::ElasticNet { alpha: 0.6 };
        let z0 = blocked::scan_all_vec(&ds.x, &ds.y);
        let lam = 0.4 * ops::inf_norm(&z0) / 0.6;
        let active: Vec<usize> = (0..25).collect();
        let mut beta = vec![0.0; 25];
        let mut r = ds.y.clone();
        cd_solve(&ds.x, pen, lam, &active, &mut beta, &mut r, 1e-10, 10_000, 0).unwrap();
        let z = blocked::scan_all_vec(&ds.x, &r);
        for j in 0..25 {
            if beta[j] != 0.0 {
                let want = 0.6 * lam * beta[j].signum() + 0.4 * lam * beta[j];
                assert!((z[j] - want).abs() < 1e-6, "enet KKT at {j}");
            } else {
                assert!(z[j].abs() <= 0.6 * lam + 1e-6);
            }
        }
    }

    #[test]
    fn residual_maintained_exactly() {
        let ds = DataSpec::synthetic(40, 15, 4).generate(4);
        let active: Vec<usize> = (0..15).collect();
        let mut beta = vec![0.0; 15];
        let mut r = ds.y.clone();
        cd_solve(&ds.x, Penalty::Lasso, 0.1, &active, &mut beta, &mut r, 1e-9, 10_000, 0)
            .unwrap();
        let fit = ds.x.matvec(&beta);
        for i in 0..40 {
            assert!((r[i] - (ds.y[i] - fit[i])).abs() < 1e-8);
        }
    }

    #[test]
    fn nonconvergence_is_reported() {
        let ds = DataSpec::synthetic(30, 10, 3).generate(5);
        let active: Vec<usize> = (0..10).collect();
        let mut beta = vec![0.0; 10];
        let mut r = ds.y.clone();
        let err = cd_solve(&ds.x, Penalty::Lasso, 1e-4, &active, &mut beta, &mut r, 0.0, 3, 7)
            .unwrap_err();
        match err {
            HssrError::NoConvergence { lambda_index, max_iter, .. } => {
                assert_eq!(lambda_index, 7);
                assert_eq!(max_iter, 3);
            }
            other => panic!("wrong error {other}"),
        }
    }

    /// A poisoned (NaN) residual must surface as a typed `NonFinite` error
    /// — NaN correlations soft-threshold to 0, so without the guard the
    /// solve would falsely report convergence with garbage state.
    #[test]
    fn divergence_is_typed_nonfinite() {
        let ds = DataSpec::synthetic(20, 5, 2).generate(8);
        let active: Vec<usize> = (0..5).collect();
        let mut beta = vec![0.0; 5];
        let mut r = ds.y.clone();
        r[3] = f64::NAN;
        let err = cd_solve(&ds.x, Penalty::Lasso, 1e-3, &active, &mut beta, &mut r, 1e-9, 50, 4)
            .unwrap_err();
        match err {
            HssrError::NonFinite { lambda_index, .. } => assert_eq!(lambda_index, 4),
            other => panic!("wrong error {other}"),
        }
    }

    #[test]
    fn empty_active_set_is_noop() {
        let ds = DataSpec::synthetic(20, 5, 2).generate(6);
        let mut beta = vec![0.0; 5];
        let mut r = ds.y.clone();
        let st =
            cd_solve(&ds.x, Penalty::Lasso, 0.5, &[], &mut beta, &mut r, 1e-9, 10, 0).unwrap();
        assert_eq!(st.cycles, 0);
        assert_eq!(r, ds.y);
    }
}
