//! Post-convergence KKT checking — the step that makes strong-rule
//! screening exact (paper §2.1 and Algorithm 1 line 15).
//!
//! After the inner solver converges over the strong set `H`, every feature
//! in `S \ H` must be verified against the stationarity conditions (4)
//! (lasso), their elastic-net analogue, or (21) (group lasso). Violators
//! are added to `H` and the problem is re-solved.

use crate::solver::Penalty;

/// Relative slack applied to the KKT threshold to absorb the inner solver's
/// convergence tolerance (biglasso behaves identically).
pub const KKT_SLACK: f64 = 1e-7;

/// Scalar KKT test for an *inactive* feature: violation iff
/// `|z_j| > αλ(1 + slack)` where `z_j = x_jᵀr/n`.
#[inline]
pub fn violates(penalty: Penalty, lam: f64, z_j: f64) -> bool {
    z_j.abs() > penalty.alpha() * lam * (1.0 + KKT_SLACK)
}

/// Collect violating feature indices among `checked` (parallel slices of
/// indices and their freshly computed `z` values).
pub fn violations(penalty: Penalty, lam: f64, checked: &[usize], z: &[f64]) -> Vec<usize> {
    debug_assert_eq!(checked.len(), z.len());
    checked
        .iter()
        .zip(z)
        .filter(|&(_, &zj)| violates(penalty, lam, zj))
        .map(|(&j, _)| j)
        .collect()
}

/// Group KKT test for an inactive group: violation iff
/// `‖X_gᵀr/n‖ > αλ√W_g(1 + slack)` — the α scaling is the group
/// elastic-net analogue of rule (21) (α = 1 for the group lasso).
#[inline]
pub fn group_violates(penalty: Penalty, lam: f64, w_g: usize, znorm_g: f64) -> bool {
    znorm_g > penalty.alpha() * lam * (w_g as f64).sqrt() * (1.0 + KKT_SLACK)
}

/// Collect violating group indices.
pub fn group_violations(
    penalty: Penalty,
    lam: f64,
    checked: &[usize],
    znorm: &[f64],
    sizes: &[usize],
) -> Vec<usize> {
    debug_assert_eq!(checked.len(), znorm.len());
    checked
        .iter()
        .zip(znorm)
        .filter(|&(&g, &zn)| group_violates(penalty, lam, sizes[g], zn))
        .map(|(&g, _)| g)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_violation_boundary() {
        assert!(!violates(Penalty::Lasso, 0.5, 0.5));
        assert!(violates(Penalty::Lasso, 0.5, 0.5001));
        assert!(violates(Penalty::Lasso, 0.5, -0.6));
        // elastic net scales threshold by α
        let en = Penalty::ElasticNet { alpha: 0.5 };
        assert!(violates(en, 0.5, 0.3));
        assert!(!violates(en, 0.5, 0.2));
    }

    #[test]
    fn violation_collection() {
        let checked = vec![3usize, 9, 12];
        let z = vec![0.1, 0.9, -0.8];
        let v = violations(Penalty::Lasso, 0.5, &checked, &z);
        assert_eq!(v, vec![9, 12]);
    }

    #[test]
    fn group_violation_scaling() {
        // W=4 → threshold 2λ
        assert!(!group_violates(Penalty::Lasso, 0.3, 4, 0.6));
        assert!(group_violates(Penalty::Lasso, 0.3, 4, 0.61));
        let v = group_violations(Penalty::Lasso, 0.3, &[0, 1], &[0.61, 0.1], &[4, 4]);
        assert_eq!(v, vec![0]);
    }

    #[test]
    fn group_violation_enet_scales_by_alpha() {
        // W=4, α=0.5 → threshold λ instead of 2λ
        let en = Penalty::ElasticNet { alpha: 0.5 };
        assert!(group_violates(en, 0.3, 4, 0.31));
        assert!(!group_violates(en, 0.3, 4, 0.29));
        let v = group_violations(en, 0.3, &[0, 1], &[0.31, 0.29], &[4, 4]);
        assert_eq!(v, vec![0]);
    }
}
