//! Duality-gap certification for lasso solutions.
//!
//! The dual of problem (1) (paper eq. (6)–(7)) is
//!
//! ```text
//! max_θ  ‖y‖²/(2n) − nλ²/2 · ‖θ − y/(nλ)‖²   s.t. |x_jᵀθ| ≤ 1 ∀j.
//! ```
//!
//! Given any primal iterate `β` with residual `r = y − Xβ`, the scaled
//! residual `θ = r/(nλ) / max(1, ‖Xᵀr‖∞/(nλ))` is dual-feasible, so
//! `gap(β) = P(β) − D(θ) ≥ 0` with equality iff `β` is optimal. The gap is
//! the rigorous optimality certificate behind every safe rule (it bounds
//! `‖θ̂ − θ‖`), and a useful end-user diagnostic for convergence
//! tolerances.

use crate::linalg::{blocked, ops, DenseMatrix};

/// Primal objective, dual objective, and gap at a primal point.
#[derive(Clone, Copy, Debug)]
pub struct GapReport {
    /// Primal objective `‖r‖²/2n + λα‖β‖₁ + λ(1−α)/2‖β‖²`.
    pub primal: f64,
    /// Dual objective at the scaled-residual feasible point.
    pub dual: f64,
    /// `primal − dual ≥ 0` (up to float noise).
    pub gap: f64,
    /// The feasibility scaling applied (1 when `r/(nλ)` already feasible).
    pub scaling: f64,
}

/// Compute the duality gap of `(β, r)` at `lam` for the **lasso**
/// (`Penalty::Lasso`; the elastic net has an analogous augmented-design gap
/// obtained by calling this with the augmented problem).
pub fn lasso_gap(
    x: &DenseMatrix,
    y: &[f64],
    beta: &[f64],
    r: &[f64],
    lam: f64,
) -> GapReport {
    let n = x.nrows() as f64;
    let z = blocked::scan_all_vec(x, r); // Xᵀr/n
    let infeas = ops::inf_norm(&z) / lam;
    let scaling = infeas.max(1.0);
    // θ = r/(nλ·scaling);  D(θ) = ‖y‖²/2n − nλ²/2·‖θ − y/(nλ)‖²
    let mut dist_sq = 0.0;
    for i in 0..y.len() {
        let theta = r[i] / (n * lam * scaling);
        let d = theta - y[i] / (n * lam);
        dist_sq += d * d;
    }
    let dual = ops::nrm2_sq(y) / (2.0 * n) - n * lam * lam / 2.0 * dist_sq;
    let primal = ops::nrm2_sq(r) / (2.0 * n)
        + lam * beta.iter().map(|b| b.abs()).sum::<f64>();
    GapReport { primal, dual, gap: primal - dual, scaling }
}

/// Convenience: gap at a fitted path point.
pub fn gap_at(
    x: &DenseMatrix,
    y: &[f64],
    fit: &crate::solver::path::PathFit,
    k: usize,
) -> GapReport {
    let beta = fit.beta_dense(k);
    let xb = x.matvec(&beta);
    let r: Vec<f64> = y.iter().zip(&xb).map(|(yi, f)| yi - f).collect();
    lasso_gap(x, y, &beta, &r, fit.lambdas[k])
}

/// A β is `eps`-certified if its gap is below `eps · max(1, |primal|)`.
pub fn certified(report: &GapReport, eps: f64) -> bool {
    report.gap <= eps * report.primal.abs().max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::screening::RuleKind;
    use crate::solver::path::{fit_lasso_path, PathConfig};

    #[test]
    fn gap_small_at_solutions_along_path() {
        let ds = DataSpec::gene_like(80, 150).generate(1);
        let fit = fit_lasso_path(
            &ds,
            &PathConfig {
                rule: RuleKind::SsrBedpp,
                n_lambda: 20,
                tol: 1e-10,
                ..PathConfig::default()
            },
        )
        .unwrap();
        for k in 0..fit.lambdas.len() {
            let rep = gap_at(&ds.x, &ds.y, &fit, k);
            assert!(rep.gap >= -1e-9, "negative gap at λ#{k}: {}", rep.gap);
            assert!(certified(&rep, 1e-6), "λ#{k}: gap {} primal {}", rep.gap, rep.primal);
        }
    }

    #[test]
    fn gap_positive_for_suboptimal_point() {
        let ds = DataSpec::synthetic(60, 40, 4).generate(2);
        let lam = 0.3;
        let beta = vec![0.0; 40]; // β = 0 is not optimal at small λ
        let r = ds.y.clone();
        let rep = lasso_gap(&ds.x, &ds.y, &beta, &r, lam);
        // unless λ ≥ λmax, zero is suboptimal → positive gap
        assert!(rep.gap > 1e-4, "gap {}", rep.gap);
        assert!(rep.scaling > 1.0);
    }

    #[test]
    fn weak_duality_holds_everywhere() {
        use crate::prop::{check, PropConfig};
        check(PropConfig { cases: 16, seed: 9 }, |rng, _| {
            let ds = DataSpec::synthetic(40, 30, 3).generate(rng.next_u64());
            // arbitrary (not optimal) primal point
            let mut beta = vec![0.0; 30];
            for _ in 0..5 {
                beta[rng.below(30) as usize] = rng.normal() * 0.2;
            }
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let lam = 0.05 + rng.uniform() * 0.5;
            let rep = lasso_gap(&ds.x, &ds.y, &beta, &r, lam);
            if rep.gap < -1e-9 {
                return Err(format!("weak duality violated: gap = {}", rep.gap));
            }
            Ok(())
        });
    }
}
