//! Duality-gap certification for lasso-type solutions — and the
//! **dual-ball construction** behind the dynamic gap-safe screening rules.
//!
//! The dual of problem (1) (paper eq. (6)–(7)) is
//!
//! ```text
//! max_θ  ‖y‖²/(2n) − nλ²/2 · ‖θ − y/(nλ)‖²   s.t. |x_jᵀθ| ≤ 1 ∀j.
//! ```
//!
//! Given any primal iterate `β` with residual `r = y − Xβ`, the scaled
//! residual `θ = r/(nλ) / max(1, ‖Xᵀr‖∞/(nλ))` is dual-feasible, so
//! `gap(β) = P(β) − D(θ) ≥ 0` with equality iff `β` is optimal. The gap is
//! the rigorous optimality certificate behind every safe rule (it bounds
//! `‖θ̂ − θ‖`), and a useful end-user diagnostic for convergence
//! tolerances.
//!
//! ## Gap-safe dual balls
//!
//! Because the dual objective is strongly concave, any feasible `θ` and its
//! gap certify a **ball** containing the dual optimum:
//! `‖θ̂ − θ‖² ≤ 2·gap/μ`, where `μ` is the dual's concavity modulus
//! (Fercoq, Gramfort & Salmon 2015; Ndiaye et al. 2017). A unit `u` whose
//! constraint `‖X̃_uᵀθ‖ ≤ w_u` holds strictly over the whole ball is
//! certifiably inactive at the optimum — the screening test of
//! [`crate::screening::gapsafe`]. [`quadratic_ball`] builds the ball for
//! the quadratic-loss families (lasso / elastic net, columns and groups,
//! via the augmented design `X̃ = [X; √(n(1−α)λ)·I]`), [`logistic_ball`]
//! for the ℓ1/elastic-net logistic family (binary-entropy conjugate, with
//! the intercept's `1ᵀθ = 0` dual constraint handled by centering).

use crate::linalg::{blocked, ops, DenseMatrix};
use crate::solver::Penalty;

/// Primal objective, dual objective, and gap at a primal point.
#[derive(Clone, Copy, Debug)]
pub struct GapReport {
    /// Primal objective `‖r‖²/2n + λα‖β‖₁ + λ(1−α)/2‖β‖²`.
    pub primal: f64,
    /// Dual objective at the scaled-residual feasible point.
    pub dual: f64,
    /// `primal − dual ≥ 0` (up to float noise).
    pub gap: f64,
    /// The feasibility scaling applied (1 when `r/(nλ)` already feasible).
    pub scaling: f64,
}

/// Compute the duality gap of `(β, r)` at `lam` for the **lasso**
/// (`Penalty::Lasso`; the elastic net has an analogous augmented-design gap
/// obtained by calling this with the augmented problem).
pub fn lasso_gap(
    x: &DenseMatrix,
    y: &[f64],
    beta: &[f64],
    r: &[f64],
    lam: f64,
) -> GapReport {
    let n = x.nrows() as f64;
    let z = blocked::scan_all_vec(x, r); // Xᵀr/n
    let infeas = ops::inf_norm(&z) / lam;
    let scaling = infeas.max(1.0);
    // θ = r/(nλ·scaling);  D(θ) = ‖y‖²/2n − nλ²/2·‖θ − y/(nλ)‖²
    let mut dist_sq = 0.0;
    for i in 0..y.len() {
        let theta = r[i] / (n * lam * scaling);
        let d = theta - y[i] / (n * lam);
        dist_sq += d * d;
    }
    let dual = ops::nrm2_sq(y) / (2.0 * n) - n * lam * lam / 2.0 * dist_sq;
    let primal = ops::nrm2_sq(r) / (2.0 * n)
        + lam * beta.iter().map(|b| b.abs()).sum::<f64>();
    GapReport { primal, dual, gap: primal - dual, scaling }
}

/// Convenience: gap at a fitted path point.
pub fn gap_at(
    x: &DenseMatrix,
    y: &[f64],
    fit: &crate::solver::path::PathFit,
    k: usize,
) -> GapReport {
    let beta = fit.beta_dense(k);
    let xb = x.matvec(&beta);
    let r: Vec<f64> = y.iter().zip(&xb).map(|(yi, f)| yi - f).collect();
    lasso_gap(x, y, &beta, &r, fit.lambdas[k])
}

/// A β is `eps`-certified if its gap is below `eps · max(1, |primal|)`.
pub fn certified(report: &GapReport, eps: f64) -> bool {
    report.gap <= eps * report.primal.abs().max(1.0)
}

// ---------------------------------------------------------------------------
// Gap-safe dual balls (Fercoq, Gramfort & Salmon 2015; Ndiaye et al. 2017)
// ---------------------------------------------------------------------------

/// A dual-feasible point together with the certified ball that must contain
/// the dual optimum `θ̂(λ)` — the machinery behind the dynamic gap-safe
/// rules in [`crate::screening::gapsafe`].
///
/// Everything is expressed in the paper's scaling, where the dual
/// constraint of screening unit `u` reads `‖X̃_uᵀθ‖ ≤ w_u` on the
/// augmented design (`w_u = 1` for columns, `√W_g` for groups). The
/// screening test induced by the ball is then *unit-free*:
///
/// ```text
/// discard u  ⇔  ‖z̃_u‖ / scaling + rho < αλ·w_u,
/// z̃_u = X_uᵀr/n − (1−α)λ·β_u.
/// ```
#[derive(Clone, Copy, Debug)]
pub struct DualBall {
    /// Primal objective at the reference point `β`.
    pub primal: f64,
    /// Dual objective at the scaled feasible point `θ`.
    pub dual: f64,
    /// `max(primal − dual, 0)` — the certified optimality gap.
    pub gap: f64,
    /// The feasibility scaling `s ≥ 1` applied to the raw dual candidate.
    pub scaling: f64,
    /// `√(2·aug·γ·(gap + slack))` — the ball term of the screening test
    /// above, with `aug = 1 + (1−α)λ` (the augmented-column norm factor),
    /// `γ` the loss smoothness (1 for the quadratic loss, 1/4 for the pure
    /// ℓ1 logistic loss), and the tiny [`GAP_SLACK`] guard.
    pub rho: f64,
}

/// Relative slack folded into [`DualBall::rho`]: the gap is a difference of
/// two `O(‖y‖²/n)` quantities, so at a machine-precision-converged iterate
/// the subtraction can round to (or below) zero while the true gap is
/// positive — a zero radius could then discard an *active* boundary
/// feature. Padding the gap by `GAP_SLACK·(1 + |primal|)` keeps the radius
/// a guaranteed over-estimate at a completely negligible power cost
/// (`rho ≳ 1e-6`-sized floor on `O(1)` problems).
pub const GAP_SLACK: f64 = 1e-12;

/// Build the gap-safe [`DualBall`] for the **quadratic-loss** families
/// (lasso / elastic net, columns and groups) at `lam`, from an arbitrary
/// primal point.
///
/// * `y`, `r` — response and the point's residual `r = y − Xβ`;
/// * `beta_sq` — `‖β‖²`; `pen_l1` — the ℓ1-type penalty value (`‖β‖₁` for
///   columns, `Σ_g √W_g·‖β_g‖` for groups);
/// * `feas_inf` — `max_u ‖z̃_u‖ / w_u`, the dual infeasibility sup over
///   all screening units (`z̃_u` as in [`DualBall`]).
///
/// The dual candidate is the scaled augmented residual `θ = r̃/(nαλ·s)`
/// with `s = max(1, feas_inf/(αλ))`; the dual is `n(αλ)²`-strongly
/// concave, which folds into [`DualBall::rho`].
pub fn quadratic_ball(
    y: &[f64],
    r: &[f64],
    beta_sq: f64,
    pen_l1: f64,
    feas_inf: f64,
    lam: f64,
    penalty: Penalty,
) -> DualBall {
    let n = y.len() as f64;
    let lam_a = penalty.alpha() * lam;
    let ridge = penalty.l2_weight() * lam;
    let aug = 1.0 + ridge;
    let s = (feas_inf / lam_a).max(1.0);
    // D(θ) = (1/n)·Σᵢ(yᵢrᵢ/s − rᵢ²/(2s²)) − (1−α)λ‖β‖²/(2s²): the loss
    // rows' conjugates plus the elastic-net pseudo-rows' (0 at α = 1).
    let mut cross = 0.0;
    for (yi, ri) in y.iter().zip(r) {
        let (yi, ri) = (*yi, *ri);
        cross += yi * ri / s - ri * ri / (2.0 * s * s);
    }
    let dual = cross / n - ridge * beta_sq / (2.0 * s * s);
    let primal = ops::nrm2_sq(r) / (2.0 * n) + lam_a * pen_l1 + 0.5 * ridge * beta_sq;
    let gap = (primal - dual).max(0.0);
    let padded = gap + GAP_SLACK * (1.0 + primal.abs());
    DualBall { primal, dual, gap, scaling: s, rho: (2.0 * aug * padded).sqrt() }
}

/// `v·ln v` with the `0·ln 0 = 0` convention (guards boundary roundoff).
#[inline]
fn xlogx(v: f64) -> f64 {
    if v <= 0.0 {
        0.0
    } else {
        v * v.ln()
    }
}

/// Build the gap-safe [`DualBall`] for the ℓ1 / elastic-net **logistic**
/// family at `lam` from an arbitrary primal point, or `None` when no valid
/// dual point can be formed from it.
///
/// * `y` — 0/1 labels; `resid` — the score residual `y − p̂` at the point
///   (columns of the design must be centered, as standardization (2)
///   guarantees);
/// * the remaining parameters are as in [`quadratic_ball`].
///
/// The unpenalized intercept adds the dual constraint `1ᵀθ = 0`, so the
/// candidate is built from the *centered* residual `c = resid − mean`.
/// The logistic conjugate is the binary entropy, finite only for
/// `yᵢ − cᵢ/s ∈ [0, 1]`; when centering pushes a coordinate outside that
/// domain (a near-perfectly-fit sample while the intercept score is not
/// yet zero) no scaling can repair the sign, so the ball degenerates —
/// `None`, never an unsafe bound.
pub fn logistic_ball(
    y: &[f64],
    resid: &[f64],
    beta_sq: f64,
    pen_l1: f64,
    feas_inf: f64,
    lam: f64,
    penalty: Penalty,
) -> Option<DualBall> {
    let n = y.len() as f64;
    let lam_a = penalty.alpha() * lam;
    let ridge = penalty.l2_weight() * lam;
    let aug = 1.0 + ridge;
    let rbar = ops::mean(resid);
    let mut c_max = 0.0f64;
    for (yi, ri) in y.iter().zip(resid) {
        let c = *ri - rbar;
        if (*yi == 1.0 && c < 0.0) || (*yi == 0.0 && c > 0.0) {
            return None;
        }
        c_max = c_max.max(c.abs());
    }
    // s also covers the entropy domain width (|cᵢ|/s ≤ 1 coordinate-wise).
    let s = (feas_inf / lam_a).max(1.0).max(c_max);
    // Primal loss: cross-entropy, −ln(1 − |residᵢ|) per sample in both
    // label branches. An exactly-saturated sample gives +∞ → rho = ∞ → no
    // discards: the safe degenerate behavior.
    let mut loss = 0.0;
    for ri in resid {
        loss -= (-ri.abs()).ln_1p();
    }
    let primal = loss / n + lam_a * pen_l1 + 0.5 * ridge * beta_sq;
    // Dual: −(1/n)·Σᵢ[q·ln q + (1−q)·ln(1−q)] at q = yᵢ − cᵢ/s, minus the
    // elastic-net pseudo-rows' quadratic conjugates.
    let mut ent = 0.0;
    for (yi, ri) in y.iter().zip(resid) {
        let q = *yi - (*ri - rbar) / s;
        ent += xlogx(q) + xlogx(1.0 - q);
    }
    let dual = -ent / n - ridge * beta_sq / (2.0 * s * s);
    // Pure logistic rows are 1/4-smooth (σ′ ≤ 1/4) so the dual modulus
    // gains a factor 4; quadratic enet pseudo-rows cap γ back at 1.
    let gamma = if ridge == 0.0 { 0.25 } else { 1.0 };
    let gap = (primal - dual).max(0.0);
    let padded = gap + GAP_SLACK * (1.0 + primal.abs());
    Some(DualBall { primal, dual, gap, scaling: s, rho: (2.0 * aug * gamma * padded).sqrt() })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::data::DataSpec;
    use crate::screening::RuleKind;
    use crate::solver::path::{fit_lasso_path, PathConfig};

    #[test]
    fn gap_small_at_solutions_along_path() {
        let ds = DataSpec::gene_like(80, 150).generate(1);
        let fit = fit_lasso_path(
            &ds,
            &PathConfig {
                rule: RuleKind::SsrBedpp,
                n_lambda: 20,
                tol: 1e-10,
                ..PathConfig::default()
            },
        )
        .unwrap();
        for k in 0..fit.lambdas.len() {
            let rep = gap_at(&ds.x, &ds.y, &fit, k);
            assert!(rep.gap >= -1e-9, "negative gap at λ#{k}: {}", rep.gap);
            assert!(certified(&rep, 1e-6), "λ#{k}: gap {} primal {}", rep.gap, rep.primal);
        }
    }

    #[test]
    fn gap_positive_for_suboptimal_point() {
        let ds = DataSpec::synthetic(60, 40, 4).generate(2);
        let lam = 0.3;
        let beta = vec![0.0; 40]; // β = 0 is not optimal at small λ
        let r = ds.y.clone();
        let rep = lasso_gap(&ds.x, &ds.y, &beta, &r, lam);
        // unless λ ≥ λmax, zero is suboptimal → positive gap
        assert!(rep.gap > 1e-4, "gap {}", rep.gap);
        assert!(rep.scaling > 1.0);
    }

    /// For the lasso at an arbitrary point, [`quadratic_ball`] must agree
    /// with [`lasso_gap`] exactly (same dual point, same gap, same scaling).
    #[test]
    fn quadratic_ball_matches_lasso_gap() {
        let ds = DataSpec::synthetic(50, 30, 4).generate(11);
        let mut beta = vec![0.0; 30];
        beta[2] = 0.4;
        beta[9] = -0.15;
        let xb = ds.x.matvec(&beta);
        let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
        let lam = 0.25;
        let rep = lasso_gap(&ds.x, &ds.y, &beta, &r, lam);
        let z = blocked::scan_all_vec(&ds.x, &r);
        let feas = ops::inf_norm(&z);
        let l1: f64 = beta.iter().map(|b| b.abs()).sum();
        let sq: f64 = beta.iter().map(|b| b * b).sum();
        let ball = quadratic_ball(&ds.y, &r, sq, l1, feas, lam, Penalty::Lasso);
        assert!((ball.primal - rep.primal).abs() < 1e-12);
        assert!((ball.dual - rep.dual).abs() < 1e-10);
        assert!((ball.scaling - rep.scaling).abs() < 1e-12);
        assert!((ball.rho - (2.0 * rep.gap.max(0.0)).sqrt()).abs() < 1e-10);
    }

    /// Weak duality for the elastic-net ball at random suboptimal points:
    /// the (unclamped) primal−dual difference is never negative.
    #[test]
    fn enet_ball_weak_duality() {
        use crate::prop::{check, PropConfig};
        check(PropConfig { cases: 12, seed: 17 }, |rng, _| {
            let ds = DataSpec::synthetic(40, 25, 3).generate(rng.next_u64());
            let alpha = 0.4 + 0.5 * rng.uniform();
            let pen = Penalty::ElasticNet { alpha };
            let mut beta = vec![0.0; 25];
            for _ in 0..4 {
                beta[rng.below(25) as usize] = rng.normal() * 0.3;
            }
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let lam = 0.05 + rng.uniform() * 0.4;
            let ridge = pen.l2_weight() * lam;
            let z = blocked::scan_all_vec(&ds.x, &r);
            let feas = (0..25).fold(0.0f64, |m, j| m.max((z[j] - ridge * beta[j]).abs()));
            let l1: f64 = beta.iter().map(|b| b.abs()).sum();
            let sq: f64 = beta.iter().map(|b| b * b).sum();
            let ball = quadratic_ball(&ds.y, &r, sq, l1, feas, lam, pen);
            if ball.primal - ball.dual < -1e-9 {
                return Err(format!("enet weak duality violated: {}", ball.primal - ball.dual));
            }
            Ok(())
        });
    }

    /// The logistic ball at the null model (β = 0, b = logit(ȳ), λ = λmax)
    /// has an exactly zero gap, and weak duality holds at perturbed points.
    #[test]
    fn logistic_ball_null_model_and_weak_duality() {
        use crate::solver::logistic::synthetic_logistic;
        let (x, y, _) = synthetic_logistic(80, 20, 3, 5);
        let ybar = ops::mean(&y);
        let resid: Vec<f64> = y.iter().map(|yi| yi - ybar).collect();
        let z = blocked::scan_all_vec(&x, &resid);
        let lam_max = ops::inf_norm(&z);
        let ball =
            logistic_ball(&y, &resid, 0.0, 0.0, lam_max, lam_max, Penalty::Lasso).unwrap();
        assert!(ball.gap.abs() < 1e-10, "null-model gap {}", ball.gap);
        assert!((ball.scaling - 1.0).abs() < 1e-12);
        // Perturbed (suboptimal) dual points still satisfy weak duality.
        for frac in [0.9, 0.6, 0.3] {
            let lam = frac * lam_max;
            let b = logistic_ball(&y, &resid, 0.0, 0.0, lam_max, lam, Penalty::Lasso)
                .expect("null residual is always domain-feasible");
            assert!(b.primal - b.dual > -1e-10, "λ={frac}·λmax: {}", b.primal - b.dual);
            assert!(b.rho >= 0.0);
        }
    }

    #[test]
    fn weak_duality_holds_everywhere() {
        use crate::prop::{check, PropConfig};
        check(PropConfig { cases: 16, seed: 9 }, |rng, _| {
            let ds = DataSpec::synthetic(40, 30, 3).generate(rng.next_u64());
            // arbitrary (not optimal) primal point
            let mut beta = vec![0.0; 30];
            for _ in 0..5 {
                beta[rng.below(30) as usize] = rng.normal() * 0.2;
            }
            let xb = ds.x.matvec(&beta);
            let r: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, f)| y - f).collect();
            let lam = 0.05 + rng.uniform() * 0.5;
            let rep = lasso_gap(&ds.x, &ds.y, &beta, &r, lam);
            if rep.gap < -1e-9 {
                return Err(format!("weak duality violated: gap = {}", rep.gap));
            }
            Ok(())
        });
    }
}
